"""Pluggable scoring functions — the paper's ``experimental_score``.

MIREX's whole point is that a *new retrieval approach is a new scoring
function*, not a change to index machinery. The contract here is the TPU
adaptation of that idea: a scorer is a **blocked** function

    score_block(query_block, doc_block) -> scores [n_q, n_d]

so that new approaches stay ~20 lines while the scan engine and kernels keep
the MXU busy. Two families:

* ``lexical`` — raw-token scan, exactly the paper's setting. Documents are
  padded token-id arrays; term frequencies are recomputed on the fly from the
  raw text every scan (no index!), which is the "radical new approaches can use
  anything in the document" property the paper argues for.
* ``dense``   — learned-representation scan (two-tower recsys, neural IR); the
  block score is a plain matmul and the hot path of the Pallas kernel.

The default lexical scorer is the paper's own: Hiemstra's query-likelihood
language model with a document-length prior, eq. of [Hiemstra 2001]:

    score(q, d) = log |d| + sum_{t in q} log(1 + lam * tf(t,d) * |C|
                                                / ((1-lam) * cf(t) * |d|))
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

PAD_TOKEN = -1


class CollectionStats(NamedTuple):
    """Corpus-wide statistics (output of the stats MapReduce job)."""

    cf: jax.Array  # [vocab] collection term frequency
    df: jax.Array  # [vocab] document frequency
    total_terms: jax.Array  # scalar: |C|
    n_docs: jax.Array  # scalar
    avg_doc_len: jax.Array  # scalar


def term_frequencies(q_tokens: jax.Array, d_tokens: jax.Array) -> jax.Array:
    """tf[t, q, d] of each query term in each doc, from raw token ids.

    ``q_tokens [n_q, L_q]``, ``d_tokens [n_d, L_d]`` (PAD_TOKEN-padded) ->
    ``tf [n_q, L_q, n_d]`` float32. This *is* the sequential scan: no posting
    list, just an equality reduction over the raw document text.
    """
    # [n_q, L_q, n_d, L_d] equality, reduced over L_d.
    eq = q_tokens[:, :, None, None] == d_tokens[None, None, :, :]
    valid_d = (d_tokens != PAD_TOKEN)[None, None, :, :]
    return jnp.sum(eq & valid_d, axis=-1).astype(jnp.float32)


def hiemstra_lm(
    q_tokens: jax.Array,
    d_tokens: jax.Array,
    d_len: jax.Array,
    stats: CollectionStats,
    *,
    lam: float = 0.15,
    length_prior: bool = True,
    tf: jax.Array | None = None,
) -> jax.Array:
    """The paper's scorer: query-likelihood LM with length prior.

    ``tf`` lets a multi-scorer scan share one :func:`term_frequencies`
    reduction per corpus chunk across a whole grid of variants.
    """
    if tf is None:
        tf = term_frequencies(q_tokens, d_tokens)  # [n_q, L_q, n_d]
    cf = jnp.asarray(stats.cf)[jnp.clip(q_tokens, 0, None)].astype(jnp.float32)  # [n_q, L_q]
    q_valid = (q_tokens != PAD_TOKEN) & (cf > 0)
    safe_cf = jnp.where(cf > 0, cf, 1.0)
    d_len_f = jnp.maximum(d_len.astype(jnp.float32), 1.0)  # [n_d]
    odds = (
        lam
        * tf
        * jnp.asarray(stats.total_terms).astype(jnp.float32)
        / ((1.0 - lam) * safe_cf[:, :, None] * d_len_f[None, None, :])
    )
    per_term = jnp.log1p(odds) * q_valid[:, :, None]
    score = jnp.sum(per_term, axis=1)  # [n_q, n_d]
    if length_prior:
        score = score + jnp.log(d_len_f)[None, :]
    # padded corpus rows (len 0) must never enter the top-k
    return jnp.where((d_len > 0)[None, :], score, -jnp.inf)


def bm25(
    q_tokens: jax.Array,
    d_tokens: jax.Array,
    d_len: jax.Array,
    stats: CollectionStats,
    *,
    k1: float = 1.2,
    b: float = 0.75,
    tf: jax.Array | None = None,
) -> jax.Array:
    """Okapi BM25 over the raw-token scan (a "new approach" in 5 lines)."""
    if tf is None:
        tf = term_frequencies(q_tokens, d_tokens)
    df = jnp.asarray(stats.df)[jnp.clip(q_tokens, 0, None)].astype(jnp.float32)
    n = jnp.asarray(stats.n_docs).astype(jnp.float32)
    idf = jnp.log1p((n - df + 0.5) / (df + 0.5))
    q_valid = (q_tokens != PAD_TOKEN) & (df > 0)
    norm = k1 * (1.0 - b + b * d_len.astype(jnp.float32) / stats.avg_doc_len)
    per_term = idf[:, :, None] * tf * (k1 + 1.0) / (tf + norm[None, None, :])
    score = jnp.sum(per_term * q_valid[:, :, None], axis=1)
    return jnp.where((d_len > 0)[None, :], score, -jnp.inf)


def tfidf(
    q_tokens: jax.Array,
    d_tokens: jax.Array,
    d_len: jax.Array,
    stats: CollectionStats,
    *,
    tf: jax.Array | None = None,
) -> jax.Array:
    """Plain ltc-style tf-idf, length-normalized."""
    if tf is None:
        tf = term_frequencies(q_tokens, d_tokens)
    df = jnp.asarray(stats.df)[jnp.clip(q_tokens, 0, None)].astype(jnp.float32)
    n = jnp.asarray(stats.n_docs).astype(jnp.float32)
    idf = jnp.log((n + 1.0) / (df + 1.0))
    q_valid = (q_tokens != PAD_TOKEN) & (df > 0)
    w = jnp.log1p(tf) * idf[:, :, None] * q_valid[:, :, None]
    score = jnp.sum(w, axis=1) / jnp.sqrt(jnp.maximum(d_len.astype(jnp.float32), 1.0))[None, :]
    return jnp.where((d_len > 0)[None, :], score, -jnp.inf)


def dense_dot(q_vecs: jax.Array, d_vecs: jax.Array) -> jax.Array:
    """Dense inner-product block score — the MXU/Pallas hot path."""
    return jax.lax.dot_general(
        q_vecs,
        d_vecs,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def dense_cosine(q_vecs: jax.Array, d_vecs: jax.Array, eps: float = 1e-6) -> jax.Array:
    qn = q_vecs / (jnp.linalg.norm(q_vecs, axis=-1, keepdims=True) + eps)
    dn = d_vecs / (jnp.linalg.norm(d_vecs, axis=-1, keepdims=True) + eps)
    return dense_dot(qn, dn)


@dataclasses.dataclass(frozen=True)
class Scorer:
    """A retrieval approach = kind + block function (+ params).

    ``params`` records keyword overrides bound onto ``fn`` (a grid point in
    an experiment); ``base`` names the unparameterized scorer it came from.
    """

    name: str
    kind: str  # "lexical" | "dense"
    fn: Callable
    base: str | None = None
    params: tuple[tuple[str, object], ...] = ()

    def score_block(
        self,
        queries,
        doc_block,
        stats: CollectionStats | None = None,
        *,
        tf: jax.Array | None = None,
    ):
        if self.kind == "lexical":
            d_tokens, d_len = doc_block
            if tf is not None:
                return self.fn(queries, d_tokens, d_len, stats, tf=tf)
            return self.fn(queries, d_tokens, d_len, stats)
        return self.fn(queries, doc_block)


SCORERS: dict[str, Scorer] = {
    "ql_lm": Scorer("ql_lm", "lexical", hiemstra_lm),
    "bm25": Scorer("bm25", "lexical", bm25),
    "tfidf": Scorer("tfidf", "lexical", tfidf),
    "dense_dot": Scorer("dense_dot", "dense", dense_dot),
    "dense_cosine": Scorer("dense_cosine", "dense", dense_cosine),
}


def get_scorer(name: str) -> Scorer:
    try:
        return SCORERS[name]
    except KeyError:
        raise KeyError(f"unknown scorer {name!r}; available: {sorted(SCORERS)}") from None


def make_variant(base: str, name: str | None = None, **params) -> Scorer:
    """A grid point: ``base`` scorer with keyword parameters bound.

    ``make_variant("bm25", k1=0.9, b=0.4)`` is a *new retrieval approach* in
    the paper's sense — same block contract, new model — which is what lets
    one corpus pass score a whole parameter grid (`scan.search_local_multi`).
    """
    b = get_scorer(base)
    fn = functools.partial(b.fn, **params) if params else b.fn
    if name is None:
        name = base if not params else (
            base + "(" + ",".join(f"{k}={v}" for k, v in sorted(params.items())) + ")"
        )
    return Scorer(name, b.kind, fn, base=base, params=tuple(sorted(params.items())))
