"""Pluggable scoring functions — the paper's ``experimental_score``.

MIREX's whole point is that a *new retrieval approach is a new scoring
function*, not a change to index machinery. The contract here is the TPU
adaptation of that idea: a scorer is a **blocked** function

    score_block(query_block, doc_block) -> scores [n_q, n_d]

so that new approaches stay ~20 lines while the scan engine and kernels keep
the MXU busy. Two families:

* ``lexical`` — raw-token scan, exactly the paper's setting. Documents are
  padded token-id arrays; term frequencies are recomputed on the fly from the
  raw text every scan (no index!), which is the "radical new approaches can use
  anything in the document" property the paper argues for. Every lexical
  scorer further decomposes into the shared tf reduction plus a declarative
  **epilogue** (`EpilogueMode` + `LexicalEpilogue`, applied by
  `apply_epilogue`) — the contract the fused Pallas lexical-scan kernel
  consumes, and what lets one kernel pass score a whole model grid.
* ``dense``   — learned-representation scan (two-tower recsys, neural IR); the
  block score is a plain matmul and the hot path of the Pallas kernel.

The default lexical scorer is the paper's own: Hiemstra's query-likelihood
language model with a document-length prior, eq. of [Hiemstra 2001]:

    score(q, d) = log |d| + sum_{t in q} log(1 + lam * tf(t,d) * |C|
                                                / ((1-lam) * cf(t) * |d|))
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

PAD_TOKEN = -1


class CollectionStats(NamedTuple):
    """Corpus-wide statistics (output of the stats MapReduce job)."""

    cf: jax.Array  # [vocab] collection term frequency
    df: jax.Array  # [vocab] document frequency
    total_terms: jax.Array  # scalar: |C|
    n_docs: jax.Array  # scalar
    avg_doc_len: jax.Array  # scalar


def term_frequencies(
    q_tokens: jax.Array, d_tokens: jax.Array, *, tile_d: int = 16
) -> jax.Array:
    """tf[t, q, d] of each query term in each doc, from raw token ids.

    ``q_tokens [n_q, L_q]``, ``d_tokens [n_d, L_d]`` (PAD_TOKEN-padded) ->
    ``tf [n_q, L_q, n_d]`` float32. This *is* the sequential scan: no posting
    list, just an equality reduction over the raw document text.

    The reduction over ``L_d`` is tiled (``tile_d`` positions per step), so
    the live intermediate is ``[n_q, L_q, n_d, tile_d]`` — the full rank-4
    ``[n_q, L_q, n_d, L_d]`` cross-product is never materialized and the
    scan stays memory-bounded (~10x over the dense form on the CPU host;
    see benchmarks/lexical_scan.py). Query pads are remapped to a sentinel
    that matches nothing, which subsumes the doc-side validity mask: real
    tokens are >= 0, so they never equal PAD_TOKEN either.
    """
    n_d, L_d = d_tokens.shape
    q_safe = jnp.where(q_tokens == PAD_TOKEN, jnp.int32(PAD_TOKEN - 1), q_tokens)
    pad = (-L_d) % tile_d
    if pad:
        d_tokens = jnp.pad(d_tokens, ((0, 0), (0, pad)), constant_values=PAD_TOKEN)
    tiles = d_tokens.reshape(n_d, -1, tile_d).transpose(1, 0, 2)  # [n_tiles, n_d, tile_d]

    def fold(acc, tile):
        eq = q_safe[:, :, None, None] == tile[None, None, :, :]
        return acc + jnp.sum(eq, axis=-1, dtype=jnp.int32), None

    acc0 = jnp.zeros((*q_tokens.shape, n_d), jnp.int32)
    tf, _ = jax.lax.scan(fold, acc0, tiles)
    return tf.astype(jnp.float32)


def term_frequencies_dense(q_tokens: jax.Array, d_tokens: jax.Array) -> jax.Array:
    """Seed rank-4 form of :func:`term_frequencies`, kept as the parity
    oracle and the benchmark baseline — materializes the full
    ``[n_q, L_q, n_d, L_d]`` equality cross-product."""
    eq = q_tokens[:, :, None, None] == d_tokens[None, None, :, :]
    valid_d = (d_tokens != PAD_TOKEN)[None, None, :, :]
    return jnp.sum(eq & valid_d, axis=-1).astype(jnp.float32)


# --------------------------------------------------------------- epilogues
#
# Every lexical scorer decomposes into the *shared* term-frequency reduction
# (the dominant chunk cost) plus a cheap per-term **epilogue**: a declarative
# spec small enough to evaluate on the VPU inside the fused Pallas kernel
# (`repro.kernels.lexical_scan`) and on the pure-JAX fallback path with the
# *same code* (`apply_epilogue`), which is what makes kernel-vs-host parity
# bitwise for the scores. The static half (`EpilogueMode`) selects the
# per-term transform and the doc-length treatment; the traced half
# (`LexicalEpilogue`) is a per-term weight table plus two doc-length
# normalization scalars.


@dataclasses.dataclass(frozen=True)
class EpilogueMode:
    """Static (hashable) half of a lexical scorer's epilogue spec.

    ``mode`` picks the per-term transform of ``(weights w, tf, doc len)``:

    * ``"ql"``    — ``log1p(w * tf / |d|)``  (Hiemstra's log-odds)
    * ``"bm25"``  — ``w * tf / (tf + alpha + beta * |d|)``  (BM25 saturation)
    * ``"tfidf"`` — ``w * log1p(tf)``

    ``length_prior`` adds ``log |d|`` (QL LM document prior);
    ``length_norm="rsqrt"`` divides the summed score by ``sqrt(|d|)``.
    """

    mode: str  # "ql" | "bm25" | "tfidf"
    length_prior: bool = False
    length_norm: str = "none"  # "none" | "rsqrt"


class LexicalEpilogue(NamedTuple):
    """Traced half of the epilogue spec (per model in a grid).

    ``weights [n_q, L_q]`` fold the collection statistics and the query
    validity mask into one per-term table (zero for PAD / zero-frequency
    terms, so masked terms contribute exactly 0); ``alpha``/``beta`` are the
    BM25 doc-length normalization ``tf + alpha + beta*|d|`` (zero scalars
    for the other modes).
    """

    weights: jax.Array  # [n_q, L_q] float32
    alpha: jax.Array  # scalar float32
    beta: jax.Array  # scalar float32


def apply_epilogue(
    mode: EpilogueMode, ep: LexicalEpilogue, tf: jax.Array, d_len: jax.Array
) -> jax.Array:
    """Score a block from its term frequencies: ``[n_q, L_q, n_d] -> [n_q, n_d]``.

    Shared verbatim by the Pallas kernel epilogue and the pure-JAX fold, so
    the two paths agree bitwise given the same ``tf``. VPU-only ops: no
    gathers, no matmuls — the collection statistics were already folded into
    ``ep.weights`` when the epilogue was built.
    """
    d_len_f = jnp.maximum(d_len.astype(jnp.float32), 1.0)  # [n_d]
    w = ep.weights[:, :, None]  # [n_q, L_q, 1]
    if mode.mode == "ql":
        per_term = jnp.log1p(w * tf / d_len_f[None, None, :])
    elif mode.mode == "bm25":
        norm = ep.alpha + ep.beta * d_len.astype(jnp.float32)
        per_term = w * tf / (tf + norm[None, None, :])
    elif mode.mode == "tfidf":
        per_term = w * jnp.log1p(tf)
    else:
        raise ValueError(f"unknown epilogue mode {mode.mode!r}")
    score = jnp.sum(per_term, axis=1)  # [n_q, n_d]
    if mode.length_prior:
        score = score + jnp.log(d_len_f)[None, :]
    if mode.length_norm == "rsqrt":
        score = score / jnp.sqrt(d_len_f)[None, :]
    # padded corpus rows (len 0) must never enter the top-k
    return jnp.where((d_len > 0)[None, :], score, -jnp.inf)


def ql_lm_epilogue(
    q_tokens: jax.Array,
    stats: CollectionStats,
    *,
    lam: float = 0.15,
    length_prior: bool = True,
) -> tuple[EpilogueMode, LexicalEpilogue]:
    """Hiemstra QL LM: ``w = lam * |C| / ((1-lam) * cf)`` per valid term."""
    cf = jnp.asarray(stats.cf)[jnp.clip(q_tokens, 0, None)].astype(jnp.float32)
    q_valid = (q_tokens != PAD_TOKEN) & (cf > 0)
    safe_cf = jnp.where(cf > 0, cf, 1.0)
    total = jnp.asarray(stats.total_terms).astype(jnp.float32)
    w = jnp.where(q_valid, lam * total / ((1.0 - lam) * safe_cf), 0.0)
    zero = jnp.float32(0.0)
    return EpilogueMode("ql", length_prior=length_prior), LexicalEpilogue(w, zero, zero)


def bm25_epilogue(
    q_tokens: jax.Array,
    stats: CollectionStats,
    *,
    k1: float = 1.2,
    b: float = 0.75,
) -> tuple[EpilogueMode, LexicalEpilogue]:
    """Okapi BM25: ``w = idf * (k1+1)``, saturation ``tf + k1(1-b) + (k1 b/avgdl)|d|``."""
    df = jnp.asarray(stats.df)[jnp.clip(q_tokens, 0, None)].astype(jnp.float32)
    n = jnp.asarray(stats.n_docs).astype(jnp.float32)
    idf = jnp.log1p((n - df + 0.5) / (df + 0.5))
    q_valid = (q_tokens != PAD_TOKEN) & (df > 0)
    w = jnp.where(q_valid, idf * (k1 + 1.0), 0.0)
    avgdl = jnp.asarray(stats.avg_doc_len).astype(jnp.float32)
    return EpilogueMode("bm25"), LexicalEpilogue(
        w, jnp.float32(k1 * (1.0 - b)), jnp.float32(k1 * b) / avgdl
    )


def tfidf_epilogue(
    q_tokens: jax.Array, stats: CollectionStats
) -> tuple[EpilogueMode, LexicalEpilogue]:
    """ltc tf-idf: ``w = idf``, score scaled by ``1/sqrt(|d|)``."""
    df = jnp.asarray(stats.df)[jnp.clip(q_tokens, 0, None)].astype(jnp.float32)
    n = jnp.asarray(stats.n_docs).astype(jnp.float32)
    idf = jnp.log((n + 1.0) / (df + 1.0))
    q_valid = (q_tokens != PAD_TOKEN) & (df > 0)
    w = jnp.where(q_valid, idf, 0.0)
    zero = jnp.float32(0.0)
    return EpilogueMode("tfidf", length_norm="rsqrt"), LexicalEpilogue(w, zero, zero)


def hiemstra_lm(
    q_tokens: jax.Array,
    d_tokens: jax.Array,
    d_len: jax.Array,
    stats: CollectionStats,
    *,
    lam: float = 0.15,
    length_prior: bool = True,
    tf: jax.Array | None = None,
) -> jax.Array:
    """The paper's scorer: query-likelihood LM with length prior.

    ``tf`` lets a multi-scorer scan share one :func:`term_frequencies`
    reduction per corpus chunk across a whole grid of variants.
    """
    if tf is None:
        tf = term_frequencies(q_tokens, d_tokens)  # [n_q, L_q, n_d]
    mode, ep = ql_lm_epilogue(q_tokens, stats, lam=lam, length_prior=length_prior)
    return apply_epilogue(mode, ep, tf, d_len)


def bm25(
    q_tokens: jax.Array,
    d_tokens: jax.Array,
    d_len: jax.Array,
    stats: CollectionStats,
    *,
    k1: float = 1.2,
    b: float = 0.75,
    tf: jax.Array | None = None,
) -> jax.Array:
    """Okapi BM25 over the raw-token scan (a "new approach" in 5 lines)."""
    if tf is None:
        tf = term_frequencies(q_tokens, d_tokens)
    mode, ep = bm25_epilogue(q_tokens, stats, k1=k1, b=b)
    return apply_epilogue(mode, ep, tf, d_len)


def tfidf(
    q_tokens: jax.Array,
    d_tokens: jax.Array,
    d_len: jax.Array,
    stats: CollectionStats,
    *,
    tf: jax.Array | None = None,
) -> jax.Array:
    """Plain ltc-style tf-idf, length-normalized."""
    if tf is None:
        tf = term_frequencies(q_tokens, d_tokens)
    mode, ep = tfidf_epilogue(q_tokens, stats)
    return apply_epilogue(mode, ep, tf, d_len)


def dense_dot(q_vecs: jax.Array, d_vecs: jax.Array) -> jax.Array:
    """Dense inner-product block score — the MXU/Pallas hot path."""
    return jax.lax.dot_general(
        q_vecs,
        d_vecs,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def dense_cosine(q_vecs: jax.Array, d_vecs: jax.Array, eps: float = 1e-6) -> jax.Array:
    qn = q_vecs / (jnp.linalg.norm(q_vecs, axis=-1, keepdims=True) + eps)
    dn = d_vecs / (jnp.linalg.norm(d_vecs, axis=-1, keepdims=True) + eps)
    return dense_dot(qn, dn)


@dataclasses.dataclass(frozen=True)
class Scorer:
    """A retrieval approach = kind + block function (+ params).

    ``params`` records keyword overrides bound onto ``fn`` (a grid point in
    an experiment); ``base`` names the unparameterized scorer it came from.
    ``epilogue`` is the lexical decomposition contract
    ``(q_tokens, stats) -> (EpilogueMode, LexicalEpilogue)`` — the scorer
    restated as shared-tf + declarative epilogue, which is what the fused
    Pallas lexical kernel consumes (None for dense scorers).
    """

    name: str
    kind: str  # "lexical" | "dense"
    fn: Callable
    base: str | None = None
    params: tuple[tuple[str, object], ...] = ()
    epilogue: Callable | None = None

    def score_block(
        self,
        queries,
        doc_block,
        stats: CollectionStats | None = None,
        *,
        tf: jax.Array | None = None,
    ):
        if self.kind == "lexical":
            d_tokens, d_len = doc_block
            if tf is not None:
                return self.fn(queries, d_tokens, d_len, stats, tf=tf)
            return self.fn(queries, d_tokens, d_len, stats)
        return self.fn(queries, doc_block)


SCORERS: dict[str, Scorer] = {
    "ql_lm": Scorer("ql_lm", "lexical", hiemstra_lm, epilogue=ql_lm_epilogue),
    "bm25": Scorer("bm25", "lexical", bm25, epilogue=bm25_epilogue),
    "tfidf": Scorer("tfidf", "lexical", tfidf, epilogue=tfidf_epilogue),
    "dense_dot": Scorer("dense_dot", "dense", dense_dot),
    "dense_cosine": Scorer("dense_cosine", "dense", dense_cosine),
}


def get_scorer(name: str) -> Scorer:
    try:
        return SCORERS[name]
    except KeyError:
        raise KeyError(f"unknown scorer {name!r}; available: {sorted(SCORERS)}") from None


def make_variant(base: str, name: str | None = None, **params) -> Scorer:
    """A grid point: ``base`` scorer with keyword parameters bound.

    ``make_variant("bm25", k1=0.9, b=0.4)`` is a *new retrieval approach* in
    the paper's sense — same block contract, new model — which is what lets
    one corpus pass score a whole parameter grid (`scan.search_local_multi`).
    """
    b = get_scorer(base)
    fn = functools.partial(b.fn, **params) if params else b.fn
    ep = b.epilogue
    if ep is not None and params:
        ep = functools.partial(ep, **params)  # fn and epilogue share param names
    if name is None:
        name = base if not params else (
            base + "(" + ",".join(f"{k}={v}" for k, v in sorted(params.items())) + ")"
        )
    return Scorer(
        name, b.kind, fn, base=base, params=tuple(sorted(params.items())), epilogue=ep
    )


def lexical_epilogues(
    scorers: tuple[Scorer, ...] | list[Scorer],
    q_tokens: jax.Array,
    stats: CollectionStats,
) -> tuple[tuple[EpilogueMode, ...], jax.Array, jax.Array]:
    """Assemble a grid's epilogue specs for the fused lexical kernel.

    Returns ``(modes, weights [n_models, n_q, L_q], ab [n_models, 2])`` —
    the static mode tuple is hashable (a jit static arg), the weight tables
    and (alpha, beta) scalars ride along as traced arrays.
    """
    modes, weights, ab = [], [], []
    for s in scorers:
        if s.kind != "lexical" or s.epilogue is None:
            raise ValueError(f"scorer {s.name!r} has no lexical epilogue")
        mode, ep = s.epilogue(q_tokens, stats)
        modes.append(mode)
        weights.append(ep.weights)
        ab.append(jnp.stack([ep.alpha, ep.beta]))
    return tuple(modes), jnp.stack(weights), jnp.stack(ab)
