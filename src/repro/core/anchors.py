"""Corpus-preparation MapReduce jobs: collection statistics + anchor text.

The paper runs two jobs before searching: (1) collection statistics that feed
the LM scorer (term/document frequencies), and (2) anchor-text extraction,
which groups the link anchor strings pointing *at* each page into that page's
searchable representation (§3.2: 11 h on 15 machines; the representation the
TREC runs searched). Both are pure map+combine jobs with additive combiner
states, so they ride :func:`repro.core.pipeline.fold_chunks` /
``merge_across(psum)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pipeline
from repro.core.scoring import PAD_TOKEN, CollectionStats


def _chunk_stats(chunk_tokens: jax.Array, vocab: int):
    """Per-chunk (cf, df, total, n_docs) from raw padded token rows."""
    valid = chunk_tokens != PAD_TOKEN
    safe = jnp.where(valid, chunk_tokens, 0)
    cf = jnp.zeros((vocab,), jnp.int32).at[safe].add(valid.astype(jnp.int32))
    # df: count each term at most once per document via sort + first-occurrence.
    sorted_toks = jnp.sort(safe * valid + (1 - valid) * (vocab + 1), axis=-1)
    first = jnp.concatenate(
        [
            jnp.ones_like(sorted_toks[:, :1], bool),
            sorted_toks[:, 1:] != sorted_toks[:, :-1],
        ],
        axis=-1,
    ) & (sorted_toks <= vocab)
    df = (
        jnp.zeros((vocab + 2,), jnp.int32)
        .at[jnp.where(first, sorted_toks, vocab + 1)]
        .add(first.astype(jnp.int32))[:vocab]
    )
    # int32 accumulator: fine below 2^31 terms; real deployments enable x64.
    total = valid.sum().astype(jnp.int32)
    return cf, df, total


def collection_stats(
    d_tokens: jax.Array,
    d_len: jax.Array,
    vocab: int,
    *,
    chunk_size: int = 256,
    axis_name=None,
) -> CollectionStats:
    """The statistics job. Additive combiner -> psum merge across shards."""
    n = d_tokens.shape[0]
    state0 = (
        jnp.zeros((vocab,), jnp.int32),
        jnp.zeros((vocab,), jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.int32),
    )

    def fold(state, chunk, start):
        del start
        tokens, lens = chunk
        cf, df, total = _chunk_stats(tokens, vocab)
        n_docs = (lens > 0).sum().astype(jnp.int32)
        return (state[0] + cf, state[1] + df, state[2] + total, state[3] + n_docs)

    cf, df, total, n_docs = pipeline.fold_chunks((d_tokens, d_len), chunk_size, fold, state0)
    if axis_name is not None:
        cf, df, total, n_docs = pipeline.merge_across((cf, df, total, n_docs), axis_name)
    avg = total.astype(jnp.float32) / jnp.maximum(n_docs.astype(jnp.float32), 1.0)
    return CollectionStats(
        cf=cf, df=df, total_terms=total, n_docs=n_docs, avg_doc_len=avg
    )


def extract_anchors(
    link_dst: jax.Array,
    link_tokens: jax.Array,
    *,
    n_docs: int,
    max_anchor_len: int,
) -> tuple[jax.Array, jax.Array]:
    """Anchor-text extraction: group anchor strings by destination page.

    ``link_dst [E]`` destination doc ids, ``link_tokens [E, L_a]`` anchor
    token ids (PAD_TOKEN-padded). Returns the anchor-text document
    representation ``(tokens [n_docs, max_anchor_len], lens [n_docs])``: for
    each page, the concatenation of anchors pointing at it, truncated. This is
    the map (emit (dst, anchor)) + shuffle (group by dst) + reduce (concat) of
    the paper's first job, realized as sort + rank-within-group + scatter.
    """
    e, l_a = link_tokens.shape
    order = jnp.argsort(link_dst, stable=True)
    dst_sorted = link_dst[order]
    toks_sorted = link_tokens[order]
    # rank of each link within its destination group
    group_start = jnp.searchsorted(dst_sorted, dst_sorted, side="left")
    rank = jnp.arange(e, dtype=jnp.int32) - group_start.astype(jnp.int32)
    # each anchor token's target column in the output row
    n_valid = (toks_sorted != PAD_TOKEN).sum(-1)
    col_base = rank * l_a  # dense packing assumes fixed anchor stride
    cols = col_base[:, None] + jnp.arange(l_a, dtype=jnp.int32)[None, :]
    keep = (toks_sorted != PAD_TOKEN) & (cols < max_anchor_len)
    safe_cols = jnp.where(keep, cols, max_anchor_len)  # spill row for overflow
    out = jnp.full((n_docs, max_anchor_len + 1), PAD_TOKEN, link_tokens.dtype)
    out = out.at[dst_sorted[:, None], safe_cols].set(
        jnp.where(keep, toks_sorted, PAD_TOKEN), mode="drop"
    )
    out = out[:, :max_anchor_len]
    lens = (out != PAD_TOKEN).sum(-1)
    del n_valid
    return out, lens
