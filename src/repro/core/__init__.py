"""MIREX core: sequential-scan retrieval as a MapReduce-shaped JAX dataflow."""

from repro.core import anchors, invindex, pipeline, scan, scoring, topk
from repro.core.scoring import CollectionStats, Scorer, get_scorer
from repro.core.topk import TopKState

__all__ = [
    "anchors",
    "invindex",
    "pipeline",
    "scan",
    "scoring",
    "topk",
    "CollectionStats",
    "Scorer",
    "get_scorer",
    "TopKState",
]
