"""Inverted-index baseline — the paper's comparison system (Lemur stand-in).

MIREX §3.2 compares the sequential scan against Lemur running query-at-a-time
retrieval over an inverted index. To reproduce claim C2 (the per-query gap
closes as query sets grow) we need the baseline too, so here it is: a CSR
postings index (term -> [(doc, tf)]) built once, plus query-at-a-time scoring
that evaluates *exactly* the same Hiemstra LM / BM25 formulas as the scan
path. Identical math means `index_search(...) == sequential_scan(...)` is a
correctness oracle for the whole engine, not just a wall-clock baseline.

The index build is a host (numpy) job — deliberately: this is the 2010-style
system whose *construction cost* is what MIREX avoids; the experiment measures
its query path. Scoring is numpy query-at-a-time with accumulators (the
classic TAAT strategy Lemur uses for these models).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.scoring import PAD_TOKEN, CollectionStats


@dataclasses.dataclass
class InvertedIndex:
    offsets: np.ndarray  # [vocab+1] CSR offsets into postings
    doc_ids: np.ndarray  # [nnz]
    tfs: np.ndarray  # [nnz]
    doc_len: np.ndarray  # [n_docs]
    n_docs: int
    vocab: int

    @property
    def nnz(self) -> int:
        return int(self.doc_ids.shape[0])


def build_index(d_tokens: np.ndarray, d_len: np.ndarray, vocab: int) -> InvertedIndex:
    """One pass over the corpus -> CSR postings sorted by (term, doc)."""
    d_tokens = np.asarray(d_tokens)
    d_len = np.asarray(d_len)
    n_docs, _ = d_tokens.shape
    rows, cols = np.nonzero(d_tokens != PAD_TOKEN)
    terms = d_tokens[rows, cols]
    # unique (term, doc) pairs with counts = tf
    keys = terms.astype(np.int64) * n_docs + rows
    uniq, tf = np.unique(keys, return_counts=True)
    u_terms = (uniq // n_docs).astype(np.int32)
    u_docs = (uniq % n_docs).astype(np.int32)
    offsets = np.zeros(vocab + 1, np.int64)
    np.add.at(offsets[1:], u_terms, 1)
    offsets = np.cumsum(offsets)
    return InvertedIndex(
        offsets=offsets,
        doc_ids=u_docs,
        tfs=tf.astype(np.int32),
        doc_len=np.maximum(d_len.astype(np.int32), 1),
        n_docs=n_docs,
        vocab=vocab,
    )


def stats_from_index(index: InvertedIndex) -> CollectionStats:
    """The index already holds the collection statistics; export them."""
    cf = np.zeros(index.vocab, np.int32)
    df = np.zeros(index.vocab, np.int32)
    term_of = np.searchsorted(index.offsets, np.arange(index.nnz), side="right") - 1
    np.add.at(cf, term_of, index.tfs)
    np.add.at(df, term_of, 1)
    total = int(index.tfs.sum())
    return CollectionStats(
        cf=cf,
        df=df,
        total_terms=np.int64(total),
        n_docs=np.int32(index.n_docs),
        avg_doc_len=np.float32(total / max(index.n_docs, 1)),
    )


def search(
    index: InvertedIndex,
    q_tokens: np.ndarray,
    stats: CollectionStats,
    *,
    k: int,
    scorer: str = "ql_lm",
    lam: float = 0.15,
    k1: float = 1.2,
    b: float = 0.75,
) -> tuple[np.ndarray, np.ndarray]:
    """Query-at-a-time TAAT retrieval. Returns (scores [n_q,k], ids [n_q,k])."""
    q_tokens = np.asarray(q_tokens)
    cf = np.asarray(stats.cf).astype(np.float64)
    df = np.asarray(stats.df).astype(np.float64)
    total = float(stats.total_terms)
    n = float(stats.n_docs)
    avgdl = float(stats.avg_doc_len)
    dlen = index.doc_len.astype(np.float64)

    n_q = q_tokens.shape[0]
    out_scores = np.full((n_q, k), -np.inf, np.float32)
    out_ids = np.full((n_q, k), -1, np.int32)
    for qi in range(n_q):
        terms = q_tokens[qi]
        terms = terms[terms != PAD_TOKEN]
        if scorer == "ql_lm":
            acc = np.log(dlen).copy()  # length prior
        else:
            acc = np.zeros(index.n_docs, np.float64)
        for t in terms:
            t = int(t)
            lo, hi = index.offsets[t], index.offsets[t + 1]
            if hi == lo or cf[t] == 0:
                continue
            docs = index.doc_ids[lo:hi]
            tf = index.tfs[lo:hi].astype(np.float64)
            if scorer == "ql_lm":
                odds = lam * tf * total / ((1.0 - lam) * cf[t] * dlen[docs])
                acc[docs] += np.log1p(odds)
            elif scorer == "bm25":
                idf = np.log1p((n - df[t] + 0.5) / (df[t] + 0.5))
                norm = k1 * (1.0 - b + b * dlen[docs] / avgdl)
                acc[docs] += idf * tf * (k1 + 1.0) / (tf + norm)
            else:
                raise ValueError(f"indexed baseline does not implement {scorer!r}")
        top = np.argpartition(-acc, min(k, index.n_docs - 1))[:k]
        top = top[np.argsort(-acc[top], kind="stable")]
        out_scores[qi, : top.size] = acc[top]
        out_ids[qi, : top.size] = top
    return out_scores, out_ids
