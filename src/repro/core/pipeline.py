"""Generic MapReduce-shaped dataflow on JAX.

The three-stage shape of the paper's Figure 1 — map over an input split, fold
into an associative *combiner* state, merge states across machines — shows up
all over this framework (document scan, collection statistics, anchor
extraction, edge-sharded GNN aggregation, split-KV decode). This module is the
shared skeleton:

    state = fold_chunks(local_shard, chunk, fold_fn, init)   # map + combine
    state = merge_across(state, axis_name, merge_fn)          # shuffle + reduce

``fold_chunks`` is a ``lax.scan`` so the compiled HLO is one chunk's program
regardless of corpus size; ``merge_across`` is a single collective whose
payload is the (small, mergeable) combiner state — the paper's communication
bound, enforced by construction. Chunk folds are *idempotent re-reduces*: the
combiner state is associative/commutative, so a re-executed chunk (Hadoop-style
failure re-execution, straggler work stealing) merges to the same result.
"""

from __future__ import annotations

import queue as queue_mod
import threading
from typing import Any, Callable, Iterator, Sequence, TypeVar

import jax
import jax.numpy as jnp

from repro import obs
from repro.tune import config as tune_config

S = TypeVar("S")

Pytree = Any


def num_chunks(n: int, chunk_size: int) -> int:
    return -(-n // chunk_size)


def segments(n: int, chunk_size: int, chunks_per_segment: int) -> list[tuple[int, int]]:
    """Chunk-aligned ``[start, stop)`` row ranges for checkpointed folds.

    A resumable scan job folds one segment at a time and checkpoints the
    combiner state between segments; because every boundary is a chunk
    boundary, the segmented fold replays the exact per-chunk ``fold_fn``
    sequence of the unsegmented one (bit-identical resume, test-enforced).
    """
    if n % chunk_size:
        raise ValueError(f"leading dim {n} not divisible by chunk_size {chunk_size}")
    if chunks_per_segment < 1:
        raise ValueError(f"chunks_per_segment must be >= 1, got {chunks_per_segment}")
    step = chunk_size * chunks_per_segment
    return [(a, min(a + step, n)) for a in range(0, n, step)]


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1). Shared by the kernel combiner's
    bitonic padding and the serve layer's batch buckets."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def pad_leading(tree: Pytree, n_target: int, pad_values: Pytree | None = None) -> Pytree:
    """Pad every leaf's leading dim to ``n_target`` (with leaf-specific fill)."""

    def _pad(x, fill):
        pad = n_target - x.shape[0]
        if pad == 0:
            return x
        widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=fill)

    if pad_values is None:
        return jax.tree.map(lambda x: _pad(x, 0), tree)
    return jax.tree.map(_pad, tree, pad_values)


def _tree_nbytes(tree: Pytree) -> int:
    """Array bytes across a pytree's leaves (the staged-traffic counter's
    unit — packed segments stage fewer bytes for the same rows)."""
    return sum(leaf.nbytes for leaf in jax.tree.leaves(tree) if hasattr(leaf, "nbytes"))


def prefetch_segments(
    data: Pytree,
    segments: Sequence[tuple[int, int]],
    *,
    device=None,
    depth: int | None = None,
    cancel: threading.Event | None = None,
) -> Iterator[Pytree]:
    """Double-buffered host→device segment streaming for pipelined folds.

    Yields ``data[a:b]`` for each ``(a, b)`` in ``segments``, slicing and
    ``device_put``-ing on a background thread so that while segment *s*
    folds on the device, segment *s+1*'s transfer is already in flight —
    transfer hides under compute instead of serializing with it. ``depth``
    bounds the number of staged segments (2 = classic double buffering;
    ``None`` = the active :class:`repro.tune.TuningConfig`'s
    ``prefetch_depth``), so device memory holds at most ``depth`` segments
    of corpus at a time instead of a shard's whole slice.

    ``device=None`` skips the placement (slices stay wherever ``data``
    lives) but keeps the background slicing overlap. The iterator may be
    abandoned early (e.g. a failure-injection kill): closing it stops the
    worker thread and drops staged segments. ``cancel`` is an external stop
    signal — when the scheduler reassigns a shard (speculative rival won,
    worker retired), setting the event makes the producer stop staging
    further segments and the iterator end early instead of filling device
    memory with transfers nobody will fold.
    """
    if depth is None:
        depth = tune_config.resolve(None).prefetch_depth
    if depth < 1:
        raise ValueError(f"prefetch depth must be >= 1, got {depth}")
    segments = list(segments)
    staged_bytes = obs.metrics().counter("pipeline.staged_bytes")
    if len(segments) <= 1:
        # nothing to overlap with — skip the worker thread (a fully-resumed
        # job streams zero segments; a one-segment shard streams inline)
        for a, b in segments:
            if cancel is not None and cancel.is_set():
                return
            seg = jax.tree.map(lambda x: x[a:b], data)
            staged_bytes.inc(_tree_nbytes(seg))
            yield seg if device is None else jax.device_put(seg, device)
        return
    q: queue_mod.Queue = queue_mod.Queue(maxsize=depth)
    stop = threading.Event()
    _DONE = object()

    def _put(item) -> bool:
        while not stop.is_set():
            try:
                q.put(item, timeout=0.05)
                return True
            except queue_mod.Full:
                continue
        return False

    def _worker():
        tr = obs.tracer()
        occupancy = obs.metrics().gauge("pipeline.prefetch_occupancy")
        try:
            for i, (a, b) in enumerate(segments):
                if stop.is_set():
                    return
                if cancel is not None and cancel.is_set():
                    _put(_DONE)  # end the stream early, don't strand the consumer
                    return
                # the producer half of the pipeline: slice + transfer for
                # segment i while the consumer folds segment i-1
                with tr.span("prefetch.stage", "pipeline", segment_pos=i, rows=b - a):
                    seg = jax.tree.map(lambda x: x[a:b], data)
                    staged_bytes.inc(_tree_nbytes(seg))
                    if device is not None:
                        seg = jax.device_put(seg, device)
                if not _put(seg):
                    return
                occupancy.set(q.qsize())
            _put(_DONE)
        except BaseException as e:  # noqa: BLE001 — surfaced to the consumer
            _put(e)

    worker = threading.Thread(target=_worker, name="segment-prefetch", daemon=True)
    worker.start()
    try:
        while True:
            item = q.get()
            if item is _DONE:
                return
            if isinstance(item, BaseException):
                raise item
            yield item
    finally:
        stop.set()
        while not q.empty():  # unblock a producer stuck on a full queue
            try:
                q.get_nowait()
            except queue_mod.Empty:
                break
        worker.join(timeout=5.0)


def fold_chunks(
    data: Pytree,
    chunk_size: int,
    fold_fn: Callable[[S, Pytree, jax.Array], S],
    init_state: S,
) -> S:
    """Map+combine over a local shard, ``chunk_size`` rows at a time.

    ``fold_fn(state, chunk, chunk_start) -> state``. The leading dim of every
    leaf in ``data`` must be divisible by ``chunk_size`` (use
    :func:`pad_leading`). ``chunk_start`` is the global row offset of the
    chunk within the *local* shard, for id bookkeeping.
    """
    n = jax.tree.leaves(data)[0].shape[0]
    if n % chunk_size:
        raise ValueError(f"leading dim {n} not divisible by chunk_size {chunk_size}")
    n_chunk = n // chunk_size
    chunked = jax.tree.map(lambda x: x.reshape(n_chunk, chunk_size, *x.shape[1:]), data)
    starts = jnp.arange(n_chunk, dtype=jnp.int32) * chunk_size

    def body(state, xs):
        chunk, start = xs
        return fold_fn(state, chunk, start), None

    state, _ = jax.lax.scan(body, init_state, (chunked, starts))
    return state


def merge_across(
    state: S,
    axis_name: str | tuple[str, ...],
    merge_fn: Callable[[S, S], S] | None = None,
) -> S:
    """Reduce combiner states across a mesh axis (inside ``shard_map``).

    With ``merge_fn=None`` the state is assumed additive and reduced with
    ``psum`` (collection statistics, GNN partial aggregates). Otherwise each
    shard's state is all-gathered and folded left with ``merge_fn`` (top-k
    lists and other non-additive monoids).
    """
    if merge_fn is None:
        return jax.lax.psum(state, axis_name)
    n = jax.lax.psum(1, axis_name)
    gathered = jax.tree.map(
        lambda x: jax.lax.all_gather(x, axis_name, axis=0, tiled=False), state
    )
    out = jax.tree.map(lambda x: x[0], gathered)
    for i in range(1, n):
        out = merge_fn(out, jax.tree.map(lambda x, i=i: x[i], gathered))
    return out
