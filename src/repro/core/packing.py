"""Packed corpus segments — width-aware token storage, exact by construction.

The corpus everywhere else in this repro is a PAD-padded ``int32`` token
matrix: 4 bytes per position for vocabularies that fit in 8–21 bits. Every
hop that moves tokens — checkpoint I/O, host→device staging in
`pipeline.prefetch_segments`, HBM→VMEM tiles in the lexical-scan kernel —
pays those 4 bytes, and `BENCH_sharded.json` shows the scan is bandwidth
bound. This module shrinks bytes *moved* without touching bytes *written*:

    **pack on the producer, decode on the consumer, exact round-trip.**

Pack widths (chosen from the vocab size, ``mode="auto"``):

    ========  ======================  ==========================  =========
    mode      representable           storage                     bytes/tok
    ========  ======================  ==========================  =========
    ``u8``    vocab <= 255            ``uint8  [n, L]``           1
    ``u16``   vocab <= 65535          ``uint16 [n, L]``           2
    bitpack   bits(vocab) <= 31       ``int32  [n, G * bits]``    bits / 8
    ========  ======================  ==========================  =========

where ``bits = (vocab).bit_length()`` (the sentinel below must fit too) and
``G = ceil(L / 32)``. Bitpack is *bit-plane* layout: positions are grouped
32 at a time along ``L``; group ``g`` stores ``bits`` int32 words, and bit
``t`` of word ``p`` is bit ``p`` of the token at position ``32 g + t``.
Decode is ``token = sum_p ((word_p >> t) & 1) << p`` — an unrolled loop of
``bits`` shift/mask/add VPU ops per 32 positions, exact in integer
arithmetic, identical under numpy, XLA and Pallas (arithmetic right shift
plus ``& 1`` reads the correct bit even from a negative int32 word).

PAD handling: real tokens are ``0 .. vocab-1`` and `scoring.PAD_TOKEN` is
``-1``, which no unsigned width can hold — so pack maps PAD to the sentinel
value ``vocab`` (always representable by construction: widths are chosen
for ``vocab``, not ``vocab - 1``) and unpack maps it back. The round-trip
``unpack(pack(x)) == x`` is exact for every width, so scores downstream are
byte-identical to the unpacked path *by construction* — packing changes
bytes moved, never bytes written.

:class:`PackedCorpus` is a registered pytree (leaves: packed tokens and
lengths; the :class:`PackSpec` rides in the static treedef), so all
leading-dim plumbing — shard ``take``, segment slicing, ``fold_chunks``
reshape, ``NamedSharding`` placement, jit caching — works unchanged, and
two different pack specs can never alias one trace.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scoring import PAD_TOKEN

# knob values accepted by resolve_mode / TuningConfig.token_pack
PACK_MODES = ("none", "auto", "8", "16", "bitpack")
# storage layouts a PackSpec can carry ("none" never reaches a PackSpec)
_RESOLVED = ("u8", "u16", "bitpack")

_GROUP = 32  # positions per bit-plane group (one int32 word per plane)


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static description of one packed token matrix.

    Frozen and hashable so it can live in jit static arguments and pytree
    treedefs. ``length`` is the *unpacked* L (the packed trailing dim is
    derived from it); ``bits`` is only meaningful for ``mode="bitpack"``.
    """

    mode: str  # u8 | u16 | bitpack
    vocab: int  # tokens are 0..vocab-1; `vocab` itself is the PAD sentinel
    length: int  # unpacked trailing dim L
    bits: int = 0  # bit-plane count (bitpack only)

    def __post_init__(self):
        if self.mode not in _RESOLVED:
            raise ValueError(f"unknown pack mode {self.mode!r}; expected {_RESOLVED}")
        if self.vocab < 1:
            raise ValueError(f"vocab must be >= 1, got {self.vocab}")
        if self.length < 0:
            raise ValueError(f"length must be >= 0, got {self.length}")
        if self.mode == "u8" and self.vocab > 0xFF:
            raise ValueError(f"u8 cannot hold sentinel {self.vocab}")
        if self.mode == "u16" and self.vocab > 0xFFFF:
            raise ValueError(f"u16 cannot hold sentinel {self.vocab}")
        if self.mode == "bitpack":
            need = int(self.vocab).bit_length()
            if not 1 <= need <= 31:
                raise ValueError(f"bitpack needs 1..31 bits, vocab {self.vocab}")
            if self.bits != need:
                raise ValueError(f"bits {self.bits} != bit_length(vocab) {need}")

    @property
    def packed_width(self) -> int:
        """Trailing dim of the packed matrix."""
        if self.mode == "bitpack":
            return -(-self.length // _GROUP) * self.bits
        return self.length

    def packed_dtype(self) -> np.dtype:
        return np.dtype(
            {"u8": np.uint8, "u16": np.uint16, "bitpack": np.int32}[self.mode]
        )

    def nbytes(self, n_docs: int) -> int:
        """Token bytes for ``n_docs`` packed rows (lengths excluded)."""
        return n_docs * self.packed_width * self.packed_dtype().itemsize

    def describe(self) -> dict:
        return dataclasses.asdict(self)


def resolve_mode(vocab: int, mode: str) -> str:
    """Map a ``token_pack`` knob value to a storage layout for ``vocab``.

    ``"auto"`` picks the narrowest width that holds the sentinel ``vocab``:
    ``u8``, then ``u16``, then ``bitpack`` (bitpack only engages above 16
    bits — below that the cast decode of a native width is cheaper), then
    ``"none"`` for >=32-bit vocabs. A *forced* width the vocab cannot fit
    degrades to the auto choice rather than failing — the tuning contract:
    knobs degrade, never fail.
    """
    if mode not in PACK_MODES:
        raise ValueError(f"unknown token_pack {mode!r}; expected one of {PACK_MODES}")
    if mode == "none":
        return "none"
    bits = int(vocab).bit_length()
    if mode == "8" and vocab <= 0xFF:
        return "u8"
    if mode == "16" and vocab <= 0xFFFF:
        return "u16"
    if mode == "bitpack" and bits <= 31:
        return "bitpack"
    # auto, or a forced width that can't represent the sentinel
    if vocab <= 0xFF:
        return "u8"
    if vocab <= 0xFFFF:
        return "u16"
    if bits <= 31:
        return "bitpack"
    return "none"


def make_spec(vocab: int, length: int, mode: str) -> PackSpec | None:
    """Resolve ``mode`` for ``vocab`` into a spec; ``None`` means unpacked."""
    resolved = resolve_mode(vocab, mode)
    if resolved == "none":
        return None
    bits = int(vocab).bit_length() if resolved == "bitpack" else 0
    return PackSpec(mode=resolved, vocab=int(vocab), length=int(length), bits=bits)


def pack_tokens(tokens: Any, spec: PackSpec) -> np.ndarray:
    """Pack a PAD-padded int32 token matrix ``[n, L]`` under ``spec``.

    Host-side (numpy) — packing happens on the producer, before staging.
    Validates the token range: values outside ``{PAD_TOKEN} | [0, vocab)``
    cannot round-trip and raise instead of corrupting silently.
    """
    t = np.asarray(tokens)
    if t.ndim != 2 or t.shape[1] != spec.length:
        raise ValueError(f"tokens shape {t.shape} != [n, {spec.length}]")
    t = t.astype(np.int64, copy=False)
    bad = (t != PAD_TOKEN) & ((t < 0) | (t >= spec.vocab))
    if bad.any():
        raise ValueError(
            f"tokens outside [0, {spec.vocab}) ∪ {{PAD_TOKEN}} cannot be packed"
        )
    mapped = np.where(t == PAD_TOKEN, spec.vocab, t).astype(np.uint32)
    if spec.mode == "u8":
        return mapped.astype(np.uint8)
    if spec.mode == "u16":
        return mapped.astype(np.uint16)
    n, l = mapped.shape
    groups = -(-l // _GROUP)
    padded = np.zeros((n, groups * _GROUP), np.uint32)
    padded[:, :l] = mapped
    padded = padded.reshape(n, groups, _GROUP)
    # bit-plane transpose: word p of group g collects bit p of 32 tokens
    words = np.zeros((n, groups, spec.bits), np.uint32)
    shifts = np.arange(_GROUP, dtype=np.uint32)
    for p in range(spec.bits):
        plane = (padded >> np.uint32(p)) & np.uint32(1)  # [n, g, 32]
        words[:, :, p] = np.bitwise_or.reduce(plane << shifts, axis=-1)
    return words.reshape(n, groups * spec.bits).view(np.int32)


def unpack_tokens(packed: Any, spec: PackSpec, *, pad_to: int | None = None):
    """Decode packed tokens back to PAD-padded int32 ``[n, pad_to or L]``.

    Pure ``jnp`` and traceable — this is the mirrored decode that runs on
    the consumer: inside the Pallas kernel tile (right before the tf
    sub-tile loop) and in the host fold. ``pad_to`` > L appends PAD_TOKEN
    columns (the kernel's ``tile_d`` alignment). Exact: ``unpack_tokens(
    pack_tokens(x, spec), spec) == x`` bit-for-bit.
    """
    l = spec.length
    if pad_to is None:
        pad_to = l
    if pad_to < l:
        raise ValueError(f"pad_to {pad_to} < unpacked length {l}")
    if spec.mode in ("u8", "u16"):
        vals = packed.astype(jnp.int32)
    else:
        n = packed.shape[0]
        groups = -(-l // _GROUP) if l else 0
        words = packed.reshape(n, groups, spec.bits)
        # token t of group g: sum_p ((word[g, p] >> t) & 1) << p — arithmetic
        # shift + mask reads bit t exactly even from negative int32 words
        shifts = jnp.arange(_GROUP, dtype=jnp.int32)  # [32]
        vals = jnp.zeros((n, groups, _GROUP), jnp.int32)
        for p in range(spec.bits):  # static unroll: bits is spec metadata
            plane = (words[:, :, p : p + 1] >> shifts[None, None, :]) & 1
            vals = vals + (plane << p)
        vals = vals.reshape(n, groups * _GROUP)[:, :l]
    toks = jnp.where(vals == spec.vocab, jnp.int32(PAD_TOKEN), vals)
    if pad_to > l:
        toks = jnp.pad(toks, ((0, 0), (0, pad_to - l)), constant_values=PAD_TOKEN)
    return toks


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedCorpus:
    """A packed token matrix + doc lengths + the spec that decodes it.

    Drop-in replacement for the ``(tokens, lengths)`` corpus tuple on the
    lexical scan paths: a pytree whose leaves share the corpus leading dim
    (shard ``take``, segment slicing, ``fold_chunks``, sharding specs all
    work unchanged) and whose treedef carries the hashable spec (jit and
    the fold caches key on it for free).
    """

    tokens: Any  # packed [n, W], dtype per spec
    lengths: Any  # [n] int32
    spec: PackSpec

    def tree_flatten(self):
        return (self.tokens, self.lengths), self.spec

    @classmethod
    def tree_unflatten(cls, spec, leaves):
        return cls(leaves[0], leaves[1], spec)

    @property
    def n_docs(self) -> int:
        return self.tokens.shape[0]

    def unpack(self, *, pad_to: int | None = None):
        """Back to the plain ``(tokens, lengths)`` representation."""
        return unpack_tokens(self.tokens, self.spec, pad_to=pad_to), self.lengths


def pack_corpus(tokens: Any, lengths: Any, *, vocab: int, mode: str = "auto"):
    """Pack a corpus under a ``token_pack`` knob value.

    Returns a :class:`PackedCorpus`, or the plain ``(tokens, lengths)``
    tuple when the resolved mode is ``"none"`` (so callers can pass the
    result straight to the scan either way).
    """
    t = np.asarray(tokens)
    spec = make_spec(vocab, t.shape[1] if t.ndim == 2 else 0, mode)
    if spec is None:
        return tokens, lengths
    return PackedCorpus(pack_tokens(t, spec), np.asarray(lengths, np.int32), spec)


def tree_nbytes(tree: Any) -> int:
    """Total array bytes across a pytree's leaves (obs byte counters)."""
    return sum(
        leaf.nbytes for leaf in jax.tree.leaves(tree) if hasattr(leaf, "nbytes")
    )
