"""The sequential-scan search engine — MIREX's map phase, blocked for the MXU.

One pass over the (sharded) corpus scores *every* query against *every*
document and maintains a running top-k per query. Per-query cost amortizes
with query-set size (paper claim C1) because the corpus stream through
HBM/VMEM is paid once for the whole query block.

Layering:
  * :func:`search_local`  — fold over one device's corpus shard (pure JAX).
  * :func:`search_sharded` — shard_map over the mesh: local search + the
    combiner-bounded top-k merge (`topk.merge_across`).
  * dense-path hot loop optionally dispatches to the Pallas fused
    score+top-k kernel (`repro.kernels.ops.score_topk`).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import pipeline, topk
from repro.core.scoring import CollectionStats, Scorer


def search_local(
    queries: Any,
    docs: Any,
    scorer: Scorer,
    *,
    k: int,
    chunk_size: int,
    stats: CollectionStats | None = None,
    doc_id_offset: jax.Array | int = 0,
    use_kernel: bool = False,
) -> topk.TopKState:
    """Scan a local corpus shard; return top-k (global doc ids) per query.

    ``docs`` is ``(tokens [n, L], lens [n])`` for lexical scorers or a vector
    matrix ``[n, dim]`` for dense scorers. ``n`` must be a multiple of
    ``chunk_size``. ``doc_id_offset`` maps local row -> global doc id.
    """
    if scorer.kind == "dense" and use_kernel:
        from repro.kernels import ops  # local import: kernels are optional

        n_q = queries.shape[0]
        scores, ids = ops.score_topk(queries, docs, k=k, block_d=chunk_size)
        return topk.TopKState(scores=scores, ids=ids + jnp.int32(doc_id_offset))

    n_q = jax.tree.leaves(queries)[0].shape[0]
    state0 = topk.init(k, (n_q,))
    offset = jnp.asarray(doc_id_offset, jnp.int32)

    def fold(state, chunk, start):
        scores = scorer.score_block(queries, chunk, stats)  # [n_q, chunk_size]
        ids = offset + start + jnp.arange(scores.shape[-1], dtype=jnp.int32)
        return topk.update(state, scores, jnp.broadcast_to(ids, scores.shape))

    return pipeline.fold_chunks(docs, chunk_size, fold, state0)


def search_sharded(
    mesh: Mesh,
    axis_names: tuple[str, ...],
    queries: Any,
    docs: Any,
    scorer: Scorer,
    *,
    k: int,
    chunk_size: int,
    stats: CollectionStats | None = None,
    use_kernel: bool = False,
    tree_merge: bool = False,
):
    """Full MIREX job on a mesh: corpus sharded over ``axis_names``, queries
    replicated, per-shard scan, then the k-bounded distributed merge.

    Returns a jitted callable ``(queries, docs[, stats]) -> TopKState`` with
    global doc ids, replicated on every device.
    """
    doc_spec = P(axis_names)  # shard leading (document) dim
    docs_specs = jax.tree.map(lambda _: doc_spec, docs)
    q_specs = jax.tree.map(lambda _: P(), queries)
    stats_specs = None if stats is None else jax.tree.map(lambda _: P(), stats)

    n_shards = 1
    for a in axis_names:
        n_shards *= mesh.shape[a]
    n_docs_total = jax.tree.leaves(docs)[0].shape[0]
    if n_docs_total % n_shards:
        raise ValueError(f"{n_docs_total} docs not divisible by {n_shards} shards")
    per_shard = n_docs_total // n_shards

    def local_job(queries, docs, stats):
        # global shard index = flattened index over the sharding axes
        idx = 0
        for a in axis_names:
            idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
        state = search_local(
            queries,
            docs,
            scorer,
            k=k,
            chunk_size=chunk_size,
            stats=stats,
            doc_id_offset=idx * per_shard,
            use_kernel=use_kernel,
        )
        if tree_merge and len(axis_names) == 1:
            return topk.merge_across_tree(state, axis_names[0])
        return topk.merge_across(state, axis_names)

    sharded = shard_map(
        local_job,
        mesh=mesh,
        in_specs=(q_specs, docs_specs, stats_specs),
        out_specs=topk.TopKState(P(), P()),
        check_rep=False,
    )
    return jax.jit(functools.partial(sharded))


def search_dense_host(q_vecs, d_vecs, k: int):
    """Unblocked oracle (materializes the full score matrix) for tests."""
    scores = q_vecs.astype(jnp.float32) @ d_vecs.astype(jnp.float32).T
    return topk.topk_dense(scores, k)
