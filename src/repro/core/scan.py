"""The sequential-scan search engine — MIREX's map phase, blocked for the MXU.

One pass over the (sharded) corpus scores *every* query against *every*
document and maintains a running top-k per query. Per-query cost amortizes
with query-set size (paper claim C1) because the corpus stream through
HBM/VMEM is paid once for the whole query block.

Layering:
  * :func:`search_local`  — fold over one device's corpus shard (pure JAX).
  * :func:`search_local_multi` — same single pass, but folding a *stack* of
    scorer variants (a model grid) into per-model top-k states: the corpus
    chunk streams through HBM once for the whole grid, and for lexical
    grids the term-frequency reduction is computed once per chunk and
    shared (the experiment-side amortization mirroring claim C1).
  * mesh execution lives one layer up in `repro.cluster` (shard plans,
    shard_map scans, sharded jobs); :func:`search_sharded` remains as a
    deprecated alias for `repro.cluster.search_mesh`.
  * dense-path hot loop optionally dispatches to the Pallas fused
    score+top-k kernel (`repro.kernels.ops.score_topk`).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import packing, pipeline, scoring, topk
from repro.core.scoring import CollectionStats, Scorer
from repro.tune import config as tune_config
from repro.tune.config import TuningConfig


def _check_chunking(docs: Any, chunk_size: int) -> None:
    """Refuse corpus shards the chunked fold / kernel grid cannot cover."""
    n = jax.tree.leaves(docs)[0].shape[0]
    if n % chunk_size:
        raise ValueError(
            f"corpus has {n} rows, not a multiple of chunk_size {chunk_size}; "
            "pad the shard first (pipeline.pad_leading with PAD_TOKEN rows)"
        )


def _offset_ids(ids: jax.Array, doc_id_offset) -> jax.Array:
    """Local row -> global doc id, preserving the -1 empty-slot sentinel."""
    return jnp.where(ids >= 0, ids + jnp.int32(doc_id_offset), ids)


def search_local(
    queries: Any,
    docs: Any,
    scorer: Scorer,
    *,
    k: int,
    chunk_size: int,
    stats: CollectionStats | None = None,
    doc_id_offset: jax.Array | int = 0,
    use_kernel: bool = False,
    tuning: TuningConfig | None = None,
) -> topk.TopKState:
    """Scan a local corpus shard; return top-k (global doc ids) per query.

    ``docs`` is ``(tokens [n, L], lens [n])`` for lexical scorers or a vector
    matrix ``[n, dim]`` for dense scorers. ``n`` must be a multiple of
    ``chunk_size``. ``doc_id_offset`` maps local row -> global doc id.

    ``use_kernel`` dispatches to the fused Pallas path for *both* kinds:
    the dense score+top-k kernel, or the lexical scan kernel (shared
    on-chip tf + scorer epilogue + resident top-k). ``tuning`` (explicit or
    the process-active config) picks the kernel block geometry — block size
    only regroups the combiner fold, so results stay byte-identical.
    """
    _check_chunking(docs, chunk_size)
    if use_kernel:
        cfg = tune_config.resolve(tuning)
        if scorer.kind == "lexical":
            state = search_local_multi(
                queries, docs, (scorer,), k=k, chunk_size=chunk_size, stats=stats,
                doc_id_offset=doc_id_offset, use_kernel=True, tuning=cfg,
            )
            return topk.TopKState(scores=state.scores[0], ids=state.ids[0])
        from repro.kernels import ops  # local import: kernels are optional

        n_rows = jax.tree.leaves(docs)[0].shape[0]
        scores, ids = ops.score_topk(
            queries, docs, k=k, block_d=cfg.dense_block(chunk_size, n_rows)
        )
        return topk.TopKState(scores=scores, ids=_offset_ids(ids, doc_id_offset))

    n_q = jax.tree.leaves(queries)[0].shape[0]
    state0 = topk.init(k, (n_q,))
    offset = jnp.asarray(doc_id_offset, jnp.int32)
    # hoisted out of the scan body: one id vector per fold, not one per chunk
    chunk_ids = jnp.arange(chunk_size, dtype=jnp.int32)

    def fold(state, chunk, start):
        if isinstance(chunk, packing.PackedCorpus):
            chunk = chunk.unpack()  # mirrored decode: host parity with kernel
        scores = scorer.score_block(queries, chunk, stats)  # [n_q, chunk_size]
        ids = offset + start + chunk_ids
        return topk.update(state, scores, jnp.broadcast_to(ids, scores.shape))

    return pipeline.fold_chunks(docs, chunk_size, fold, state0)


def search_local_multi(
    queries: Any,
    docs: Any,
    scorers: tuple[Scorer, ...] | list[Scorer],
    *,
    k: int,
    chunk_size: int,
    stats: CollectionStats | None = None,
    doc_id_offset: jax.Array | int = 0,
    init_state: topk.TopKState | None = None,
    use_kernel: bool = False,
    tuning: TuningConfig | None = None,
) -> topk.TopKState:
    """Scan a corpus shard once, scoring a whole *grid* of models.

    Returns a stacked :class:`topk.TopKState` with shapes
    ``scores [n_models, n_q, k]`` / ``ids [n_models, n_q, k]`` — row ``m``
    is bit-identical to ``search_local(..., scorer=scorers[m], ...)`` (the
    per-row combiner fold is the same ``top_k`` over the same candidates).

    All scorers must share a ``kind`` (they consume the same corpus
    representation). For lexical grids the per-chunk
    :func:`scoring.term_frequencies` reduction — the dominant cost of a
    raw-token chunk — is computed once and shared by every variant.

    ``init_state`` resumes the fold from a previously checkpointed state
    (the scan-job runner in `repro.experiments.job`); associativity of the
    combiner makes the segmented fold equal to the unsegmented one.

    ``use_kernel`` runs a lexical grid through the fused Pallas kernel: the
    whole grid scans in **one kernel pass** — the tf reduction is shared in
    VMEM and each model's epilogue + top-k fold stays resident on-chip.
    """
    scorers = tuple(scorers)
    if not scorers:
        raise ValueError("need at least one scorer")
    kinds = {s.kind for s in scorers}
    if len(kinds) != 1:
        raise ValueError(f"multi-scorer scan needs a single kind, got {sorted(kinds)}")
    kind = kinds.pop()
    _check_chunking(docs, chunk_size)

    n_q = jax.tree.leaves(queries)[0].shape[0]
    state0 = init_state if init_state is not None else topk.init(k, (len(scorers), n_q))
    if state0.scores.shape[:-1] != (len(scorers), n_q):
        raise ValueError(
            f"init_state batch shape {state0.scores.shape[:-1]} != ({len(scorers)}, {n_q})"
        )
    if state0.k != k:
        # the fold truncates every block to state.k, so a mismatched init_state
        # would silently override the requested depth
        raise ValueError(f"init_state has k={state0.k}, requested k={k}")

    if use_kernel:
        if kind != "lexical":
            raise ValueError("use_kernel multi-scan supports lexical grids only")
        from repro.kernels import ops  # local import: kernels are optional

        cfg = tune_config.resolve(tuning)
        if isinstance(docs, packing.PackedCorpus):
            d_tokens, d_len, pack_spec = docs.tokens, docs.lengths, docs.spec
        else:
            (d_tokens, d_len), pack_spec = docs, None
        modes, weights, ab = scoring.lexical_epilogues(scorers, queries, stats)
        scores, ids = ops.lexical_scan_topk(
            queries, weights, ab, d_tokens, d_len, modes=modes, k=k,
            block_d=cfg.lex_block(chunk_size, d_tokens.shape[0]),
            tile_d=cfg.lex_tile_d, pack_spec=pack_spec,
        )
        state = topk.TopKState(scores=scores, ids=_offset_ids(ids, doc_id_offset))
        if init_state is not None:
            # resume: fold this pass's k-bounded result into the prior state
            # (associativity again — same candidates, same tie-break)
            state = topk.merge(init_state, state)
        return state

    offset = jnp.asarray(doc_id_offset, jnp.int32)
    # hoisted out of the scan body: one id vector per fold, not one per chunk
    chunk_ids = jnp.arange(chunk_size, dtype=jnp.int32)

    def fold(state, chunk, start):
        tf = None
        if kind == "lexical":
            if isinstance(chunk, packing.PackedCorpus):
                chunk = chunk.unpack()  # mirrored decode: parity with kernel
            d_tokens, _ = chunk
            tf = scoring.term_frequencies(queries, d_tokens)  # shared by the grid
        scores = jnp.stack(
            [s.score_block(queries, chunk, stats, tf=tf) for s in scorers]
        )  # [n_models, n_q, chunk_size]
        ids = offset + start + chunk_ids
        return topk.update(state, scores, jnp.broadcast_to(ids, scores.shape))

    return pipeline.fold_chunks(docs, chunk_size, fold, state0)


def search_sharded(
    mesh: Mesh,
    axis_names: tuple[str, ...],
    queries: Any,
    docs: Any,
    scorer: Scorer,
    *,
    k: int,
    chunk_size: int,
    stats: CollectionStats | None = None,
    use_kernel: bool = False,
    tree_merge: bool = False,
):
    """Deprecated alias for :func:`repro.cluster.search_mesh`.

    The mesh scan moved into the unified map/reduce layer (`repro.cluster`),
    which fixes this wrapper's dropped capabilities — ``use_kernel`` is now
    honored and whole model grids scan in one pass — and reduces through the
    same lexicographic merge as sharded jobs and serve sessions. This shim
    keeps the old single-scorer return shape (``[n_q, k]``) by squeezing the
    grid axis; ``tree_merge`` is ignored (the hierarchical lexicographic
    reduce bounds the gather buffer at ``axis_size·k`` already).
    """
    import warnings

    warnings.warn(
        "scan.search_sharded is deprecated; use repro.cluster.search_mesh "
        "(multi-model, kernel-dispatched, shared merge contract)",
        DeprecationWarning,
        stacklevel=2,
    )
    del tree_merge
    from repro import cluster  # local import: scan is cluster's lower layer

    fn = cluster.search_mesh(
        mesh, queries, docs, scorer,
        k=k, chunk_size=chunk_size, stats=stats,
        axis_names=axis_names, use_kernel=use_kernel,
    )

    @functools.wraps(fn)
    def squeezed(queries, docs, stats=None):
        state = fn(queries, docs, stats)
        return topk.TopKState(scores=state.scores[0], ids=state.ids[0])

    return squeezed


def search_dense_host(q_vecs, d_vecs, k: int):
    """Unblocked oracle (materializes the full score matrix) for tests."""
    scores = q_vecs.astype(jnp.float32) @ d_vecs.astype(jnp.float32).T
    return topk.topk_dense(scores, k)
