"""Mergeable top-k state — the MIREX *combiner*.

The paper's reducer/combiner keeps a ranked list of at most ``k`` (doc, score)
pairs per query; because the state is associative+commutative to merge, it can
be maintained per machine (combiner), per chunk (streaming scan), or per mesh
shard, and merged cheaply. At most ``k`` entries per query ever cross the
network — the paper's central communication bound — which here becomes "at
most ``k`` entries per query enter the all-gather".
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import compat

NEG_INF = float("-inf")


class TopKState(NamedTuple):
    """Running top-k of (score, id) pairs, sorted descending by score.

    Shapes: ``scores [..., k]`` float, ``ids [..., k]`` int32. Empty slots have
    score ``-inf`` and id ``-1``.
    """

    scores: jax.Array
    ids: jax.Array

    @property
    def k(self) -> int:
        return self.scores.shape[-1]


def init(k: int, batch_shape: tuple = (), dtype=jnp.float32) -> TopKState:
    """Fresh state with no entries."""
    return TopKState(
        scores=jnp.full((*batch_shape, k), NEG_INF, dtype=dtype),
        ids=jnp.full((*batch_shape, k), -1, dtype=jnp.int32),
    )


def init_host(k: int, batch_shape: tuple = ()) -> TopKState:
    """:func:`init` as host (numpy) arrays — same sentinel contract, zero
    device dispatches. Concurrent shard executors build their fresh states
    with this and ship them in one batched ``device_put``, instead of
    serializing eager ``full`` ops through the dispatch path."""
    import numpy as np

    return TopKState(
        scores=np.full((*batch_shape, k), NEG_INF, np.float32),
        ids=np.full((*batch_shape, k), -1, np.int32),
    )


def valid_mask(state: TopKState) -> jax.Array:
    """Boolean mask of occupied slots (corpus smaller than k leaves empties).

    Empty slots carry ``(-inf, -1)`` sentinels; run-file writers and eval
    must drop them rather than rank a nonexistent document.
    """
    return (state.ids >= 0) & (state.scores > NEG_INF)


def update(state: TopKState, cand_scores: jax.Array, cand_ids: jax.Array) -> TopKState:
    """Fold a block of candidates into the state (the combiner step).

    ``cand_scores [..., m]``, ``cand_ids [..., m]``. Cost is one
    ``top_k(k+m → k)`` — independent of how many candidates were seen before.
    """
    all_scores = jnp.concatenate([state.scores, cand_scores.astype(state.scores.dtype)], axis=-1)
    all_ids = jnp.concatenate([state.ids, cand_ids.astype(jnp.int32)], axis=-1)
    top_scores, pos = jax.lax.top_k(all_scores, state.k)
    top_ids = jnp.take_along_axis(all_ids, pos, axis=-1)
    return TopKState(scores=top_scores, ids=top_ids)


def merge(a: TopKState, b: TopKState) -> TopKState:
    """Associative merge of two states (reduce step)."""
    return update(a, b.scores, b.ids)


def merge_lex(a: TopKState, b: TopKState) -> TopKState:
    """k-bounded **lexicographic** merge — the cluster reduce contract.

    Both inputs must be sorted by (score desc, id asc), which every fold in
    this framework produces (``lax.top_k``'s positional tie-break over a
    monotone-id candidate stream *is* that order; the Pallas combiner sorts
    by it explicitly). The merge is one O(k log k) bitonic merge network
    (`kernels.score_topk.bitonic_merge_desc`), so its output is a pure
    function of the two value sets — no positional tie-break, no dependence
    on merge order or shard count. That value-determinism is what makes
    cross-shard rankings id-exact (and score-byte-exact) against a
    single-host oracle scan, which `repro.cluster` turns into the
    shard-count-invariance guarantee for merged TREC run files.

    Inputs are right-padded to a power-of-two width with ``(-inf, -1)``
    empty slots; a fold-produced state never holds a real-id entry at
    ``-inf`` (sentinels win that tie in both the host fold and the kernel
    combiner), so the padding preserves (score desc, id asc) sortedness.
    """
    # local import: core stays importable when the Pallas toolchain is absent
    from repro.kernels.score_topk import _pad_desc, bitonic_merge_desc

    if a.scores.shape != b.scores.shape:
        raise ValueError(f"merge_lex shape mismatch: {a.scores.shape} != {b.scores.shape}")
    k = a.k
    width = 1 if k <= 1 else 1 << (k - 1).bit_length()  # next pow2
    a_s, a_i = _pad_desc(a.scores, a.ids, width)
    b_s, b_i = _pad_desc(b.scores, b.ids, width)
    s, i = bitonic_merge_desc(a_s, a_i, b_s, b_i)
    return TopKState(scores=s[..., :k], ids=i[..., :k])


def reduce_lex(states) -> TopKState:
    """Fold any number of per-shard states through :func:`merge_lex`.

    Associative + value-deterministic, so grouping and shard order are free
    to vary (host loop, mesh all-gather, tree) without changing a bit of the
    result.
    """
    states = list(states)
    if not states:
        raise ValueError("reduce_lex needs at least one state")
    out = states[0]
    for s in states[1:]:
        out = merge_lex(out, s)
    return out


def merge_across_lex(state: TopKState, axis_name: str | tuple[str, ...]) -> TopKState:
    """Global lexicographic reduce across mesh axes (inside ``shard_map``).

    Same hierarchical staging as :func:`merge_across` (one stage per axis,
    re-reducing to k between stages, bounding the gather buffer at
    ``axis_size·k``), but folding with :func:`merge_lex` so the mesh reduce
    and the host-loop reduce (`repro.cluster`) share one merge contract.
    """
    if isinstance(axis_name, (tuple, list)):
        for a in axis_name:
            state = merge_across_lex(state, a)
        return state
    gathered = TopKState(
        scores=jax.lax.all_gather(state.scores, axis_name, axis=0, tiled=False),
        ids=jax.lax.all_gather(state.ids, axis_name, axis=0, tiled=False),
    )
    n = gathered.scores.shape[0]
    return reduce_lex(
        TopKState(scores=gathered.scores[i], ids=gathered.ids[i]) for i in range(n)
    )


def merge_across(
    state: TopKState, axis_name: str | tuple[str, ...], *, method: str = "staged"
) -> TopKState:
    """Global reduce: merge per-shard states across mesh axes.

    Implements the paper's shuffle with its communication bound intact: each
    shard contributes exactly ``k`` entries per query. Inside ``shard_map``.

    Beyond-paper scaling fix: the paper's single-stage merge (every machine's
    k to one reducer) works at 15 machines but at 512 shards the gather
    buffer is ``n_shards·k`` per query (21 GiB for scan_5kq on the 2-pod
    mesh). A tuple of axes is therefore merged **hierarchically** — one
    stage per mesh axis, re-reducing to k between stages — bounding the peak
    buffer at ``max(axis_size)·k`` per query. Associativity of the combiner
    (test_topk) is exactly what makes the staging legal.
    """
    if isinstance(axis_name, (tuple, list)):
        for a in axis_name:
            state = merge_across(state, a, method=method)
        return state
    if method == "tree":
        return merge_across_tree(state, axis_name)
    gathered_scores = jax.lax.all_gather(state.scores, axis_name, axis=-2, tiled=False)
    gathered_ids = jax.lax.all_gather(state.ids, axis_name, axis=-2, tiled=False)
    # [..., n_shards, k] -> [..., n_shards*k]
    flat_scores = gathered_scores.reshape(*gathered_scores.shape[:-2], -1)
    flat_ids = gathered_ids.reshape(*gathered_ids.shape[:-2], -1)
    top_scores, pos = jax.lax.top_k(flat_scores, state.k)
    top_ids = jnp.take_along_axis(flat_ids, pos, axis=-1)
    return TopKState(scores=top_scores, ids=top_ids)


def merge_across_tree(state: TopKState, axis_name: str) -> TopKState:
    """Log-depth tree merge via ``collective_permute`` (recursive halving).

    Communication-optimal alternative to :func:`merge_across` when ``k`` is
    large: each round exchanges ``k`` entries and immediately re-reduces to
    ``k``, so peak per-link traffic is ``k`` instead of ``n_shards * k``.
    Requires the axis size to be a power of two. All shards end with the
    global state (butterfly/all-reduce pattern).
    """
    n = compat.axis_size(axis_name)
    if n & (n - 1):
        raise ValueError(f"tree merge requires power-of-two axis size, got {n}")
    idx = jax.lax.axis_index(axis_name)
    step = 1
    while step < n:
        partner = idx ^ step
        perm = [(i, i ^ step) for i in range(n)]
        other = TopKState(
            scores=jax.lax.ppermute(state.scores, axis_name, perm),
            ids=jax.lax.ppermute(state.ids, axis_name, perm),
        )
        del partner
        state = merge(state, other)
        step <<= 1
    return state


@functools.partial(jax.jit, static_argnames=("k",))
def topk_dense(scores: jax.Array, k: int) -> TopKState:
    """One-shot top-k over a dense score row (utility for baselines/tests)."""
    top_scores, ids = jax.lax.top_k(scores, k)
    return TopKState(scores=top_scores, ids=ids.astype(jnp.int32))
