"""Architecture registry: ``get_config``, ``shapes_for``, ``input_specs``.

One module per assigned architecture (exact public configs, sources in each
file) plus the paper's own system (``mirex``). ``input_specs`` returns
weak-type-correct ShapeDtypeStruct stand-ins for every model input of a
(arch × shape) cell — shardable, no allocation — the dry-run currency.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import shapes as _shapes
from repro.configs.base import GNNConfig, MirexConfig, RecsysConfig, ShapeSpec, TransformerConfig
from repro.configs.archs import (
    dbrx_132b,
    dcn_v2,
    fm,
    gemma2_27b,
    gemma2_2b,
    h2o_danube_1_8b,
    mind,
    mirex,
    pna,
    qwen3_moe_30b_a3b,
    sasrec,
)

_MODULES = {
    "dbrx-132b": dbrx_132b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "h2o-danube-1.8b": h2o_danube_1_8b,
    "gemma2-27b": gemma2_27b,
    "gemma2-2b": gemma2_2b,
    "pna": pna,
    "dcn-v2": dcn_v2,
    "fm": fm,
    "mind": mind,
    "sasrec": sasrec,
    "mirex": mirex,
}

ARCH_IDS = tuple(_MODULES)
ASSIGNED_ARCHS = tuple(a for a in ARCH_IDS if a != "mirex")


def get_config(arch: str):
    try:
        return _MODULES[arch].config()
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}") from None


def family(arch: str) -> str:
    cfg = get_config(arch)
    if isinstance(cfg, TransformerConfig):
        return "lm"
    if isinstance(cfg, GNNConfig):
        return "gnn"
    if isinstance(cfg, RecsysConfig):
        return "recsys"
    return "mirex"


def shapes_for(arch: str) -> dict[str, ShapeSpec]:
    return {
        "lm": _shapes.LM_SHAPES,
        "gnn": _shapes.GNN_SHAPES,
        "recsys": _shapes.RECSYS_SHAPES,
        "mirex": _shapes.MIREX_SHAPES,
    }[family(arch)]


def all_cells(include_mirex: bool = False):
    """Every assigned (arch, shape) pair — 40 cells (+ mirex's own)."""
    archs = ARCH_IDS if include_mirex else ASSIGNED_ARCHS
    return [(a, s) for a in archs for s in shapes_for(a)]


def reduced_config(arch: str):
    """Tiny same-family config for CPU smoke tests: same *structure*
    (MoE-ness, window pattern, softcaps, interaction type), reduced dims."""
    import dataclasses

    cfg = get_config(arch)
    if isinstance(cfg, TransformerConfig):
        return dataclasses.replace(
            cfg,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
            head_dim=16 if cfg.head_dim is not None else None,
            d_ff=128,
            vocab=512,
            n_experts=4 if cfg.is_moe else 0,
            top_k=2 if cfg.is_moe else 0,
            sliding_window=8 if cfg.sliding_window is not None else None,
            dtype="float32",
            remat_chunk=1,
            grad_accum=1,
            opt_dtype="float32",
            q_block=16,
        )
    if isinstance(cfg, GNNConfig):
        return dataclasses.replace(cfg, n_layers=2, d_hidden=16)
    if isinstance(cfg, RecsysConfig):
        return dataclasses.replace(
            cfg,
            embed_dim=8,
            vocab_per_field=64,
            n_items=128,
            mlp_dims=(32, 16) if cfg.mlp_dims else (),
            seq_len=12 if cfg.seq_len else 0,
        )
    return dataclasses.replace(cfg, vocab=512, k=16, chunk_size=64, max_doc_len=32, dense_dim=32)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for the *batch* inputs of one cell.

    Params / optimizer / KV-cache stand-ins come from the model modules
    (param_shapes / cache_shapes); this covers what the data pipeline feeds.
    """
    cfg = get_config(arch)
    spec = shapes_for(arch)[shape_name]
    d = spec.dims
    kind = spec.kind
    if kind == "train":
        b, s = d["global_batch"], d["seq_len"]
        return {"tokens": _sds((b, s), "int32"), "labels": _sds((b, s), "int32")}
    if kind == "prefill":
        return {"tokens": _sds((d["global_batch"], d["seq_len"]), "int32")}
    if kind == "decode":
        return {"tokens": _sds((d["global_batch"],), "int32"), "t": _sds((), "int32")}
    if kind == "full_graph":
        e = d.get("n_edges_padded", d["n_edges"])
        return {
            "x": _sds((d["n_nodes"], d["d_feat"]), "float32"),
            "src": _sds((e,), "int32"),
            "dst": _sds((e,), "int32"),
            "labels": _sds((d["n_nodes"],), "int32"),
        }
    if kind == "minibatch":
        b = d["batch_nodes"]
        k1, k2 = d["fanout"]
        f = d["d_feat"]
        return {
            "seed_x": _sds((b, f), "float32"),
            "hop1_x": _sds((b, k1, f), "float32"),
            "hop2_x": _sds((b, k1, k2, f), "float32"),
            "labels": _sds((b,), "int32"),
        }
    if kind == "batched_graphs":
        b, n, e, f = d["batch"], d["n_nodes"], d["n_edges"], d["d_feat"]
        return {
            "x": _sds((b, n, f), "float32"),
            "src": _sds((b, e), "int32"),
            "dst": _sds((b, e), "int32"),
            "labels": _sds((b,), "int32"),
        }
    if kind in ("rec_train", "rec_serve"):
        b = d["batch"]
        if cfg.variant in ("fm", "dcn-v2"):
            out = {"sparse_ids": _sds((b, cfg.n_sparse), "int32")}
            if cfg.n_dense:
                out["dense"] = _sds((b, cfg.n_dense), "float32")
            if kind == "rec_train":
                out["labels"] = _sds((b,), "float32")
            return out
        out = {"history": _sds((b, max(cfg.seq_len, 50)), "int32")}
        if kind == "rec_train":
            out["target"] = _sds((b, max(cfg.seq_len, 50)), "int32")
        return out
    if kind == "retrieval":
        n = d["n_candidates"]
        if cfg.variant in ("fm", "dcn-v2"):
            out = {"sparse_ids": _sds((1, cfg.n_sparse), "int32")}
            if cfg.n_dense:
                out["dense"] = _sds((1, cfg.n_dense), "float32")
        else:
            out = {"history": _sds((1, max(cfg.seq_len, 50)), "int32")}
        out["cand_ids"] = _sds((n,), "int32")
        return out
    if kind == "scan":
        return {
            "q_tokens": _sds((d["n_queries"], cfg.max_q_len), "int32"),
            "d_tokens": _sds((d["n_docs"], d["doc_len"]), "int32"),
            "d_len": _sds((d["n_docs"],), "int32"),
        }
    if kind == "dense_scan":
        return {
            "q_vecs": _sds((d["n_queries"], d["dim"]), "float32"),
            "d_vecs": _sds((d["n_docs"], d["dim"]), "float32"),
        }
    raise ValueError(f"unknown cell kind {kind}")
