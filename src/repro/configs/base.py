"""Config dataclasses + shape specs for every assigned architecture family."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    """Decoder-only LM (dense or MoE)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # None -> d_model // n_heads
    # MoE (n_experts == 0 -> dense FFN)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # attention flavour
    sliding_window: int | None = None  # SWA width (local layers)
    local_global_alternating: bool = False  # gemma2: even layers local
    attn_softcap: float | None = None
    final_softcap: float | None = None
    rope_theta: float = 10_000.0
    activation: str = "silu"  # swiglu | geglu via "gelu"
    rms_one_plus: bool = False  # gemma-style (1 + w) RMSNorm scale
    dtype: str = "bfloat16"
    remat: bool = True
    remat_chunk: int = 1  # >1: two-level checkpointing, layers per chunk
    remat_policy: str = "nothing"  # nothing | dots (save matmul outputs)
    grad_accum: int = 1  # microbatches per step (grad accumulation)
    opt_dtype: str = "float32"  # Adam moment dtype (bf16 at extreme scale)
    q_block: int = 512  # chunked-attention query block

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        ffn = 3 * d * f * max(self.n_experts, 1)
        router = d * self.n_experts
        per_layer = attn + ffn + router + 2 * d
        return v * d * 2 + self.n_layers * per_layer + d

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        ffn = 3 * d * f * self.top_k
        per_layer = attn + ffn + d * self.n_experts + 2 * d
        return v * d * 2 + self.n_layers * per_layer + d


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    """PNA-style message-passing network."""

    name: str
    n_layers: int
    d_hidden: int
    aggregators: tuple[str, ...] = ("mean", "max", "min", "std")
    scalers: tuple[str, ...] = ("identity", "amplification", "attenuation")
    n_classes: int = 16
    delta: float = 1.0  # mean log-degree normalizer (dataset constant)
    dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    """Sparse-embedding + feature-interaction ranking/retrieval model."""

    name: str
    variant: str  # dcn-v2 | fm | mind | sasrec
    embed_dim: int
    n_dense: int = 0
    n_sparse: int = 0
    vocab_per_field: int = 1_000_000
    # dcn-v2
    n_cross_layers: int = 0
    mlp_dims: tuple[int, ...] = ()
    # mind
    n_interests: int = 0
    capsule_iters: int = 0
    # sasrec
    n_blocks: int = 0
    n_heads: int = 0
    seq_len: int = 0
    n_items: int = 3_000_000
    dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MirexConfig:
    """The paper's own system: scan + top-k over a (sharded) corpus."""

    name: str = "mirex"
    scorer: str = "ql_lm"
    k: int = 1000
    chunk_size: int = 1024
    vocab: int = 65_536
    max_doc_len: int = 128
    max_q_len: int = 8
    dense_dim: int = 256  # dense-representation scan path


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (architecture × input-shape) cell."""

    name: str
    kind: str  # train | prefill | decode | full_graph | minibatch | batched_graphs | rec_train | rec_serve | retrieval | scan
    dims: dict

    def __str__(self) -> str:
        return f"{self.name}({self.kind}:{self.dims})"
