"""Shape registries: every assigned (architecture × input-shape) cell.

All global batch/edge/candidate counts divide both production meshes
(256 and 512 ways) — where a public number doesn't (cora's 10 556 edges,
the 10⁶ candidates), the generator pads to the next divisible size and the
pad rows are masked out (out-of-range segment ids / -inf scores), noted here.
"""

from __future__ import annotations

from repro.configs.base import ShapeSpec


def _round_to(x: int, m: int) -> int:
    return -(-x // m) * m


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    "decode_32k": ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    "long_500k": ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm",
        "full_graph",
        {
            "n_nodes": _round_to(2708, 512),  # cora, padded 2708 -> 3072
            "n_edges": 10556,
            # dst-bucketed 1D partition: uniform per-shard slabs with a 4×
            # skew allowance (cora is tiny and very skewed)
            "n_edges_padded": _round_to(4 * 10556, 4096),
            "d_feat": 1433,
            "n_classes": 7,
        },
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg",
        "minibatch",
        {
            "n_nodes": 232_965,  # reddit
            "n_edges": 114_615_892,
            "batch_nodes": 1024,
            "fanout": (15, 10),
            "d_feat": 602,
            "n_classes": 41,
        },
    ),
    "ogb_products": ShapeSpec(
        "ogb_products",
        "full_graph",
        {
            "n_nodes": _round_to(2_449_029, 512),  # padded -> 2 449 408
            "n_edges": 61_859_140,
            # 1.3× skew allowance for the dst-bucketed partition
            "n_edges_padded": _round_to(int(1.3 * 61_859_140), 4096),
            "d_feat": 100,
            "n_classes": 47,
        },
    ),
    "molecule": ShapeSpec(
        "molecule",
        "batched_graphs",
        {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 28, "n_classes": 2},
    ),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "rec_train", {"batch": 65_536}),
    "serve_p99": ShapeSpec("serve_p99", "rec_serve", {"batch": 512}),
    "serve_bulk": ShapeSpec("serve_bulk", "rec_serve", {"batch": 262_144}),
    "retrieval_cand": ShapeSpec(
        "retrieval_cand",
        "retrieval",
        # 10^6 candidates padded to 2^20 (divides 256 and 512)
        {"batch": 1, "n_candidates": 1_048_576},
    ),
}

MIREX_SHAPES = {
    "scan_50q": ShapeSpec(
        "scan_50q", "scan", {"n_docs": 1_048_576, "n_queries": 64, "doc_len": 128}
    ),
    "scan_5kq": ShapeSpec(
        "scan_5kq", "scan", {"n_docs": 1_048_576, "n_queries": 5120, "doc_len": 128}
    ),
    "dense_scan": ShapeSpec(
        "dense_scan", "dense_scan", {"n_docs": 16_777_216, "n_queries": 4096, "dim": 256}
    ),
}
