"""fm [recsys] — factorization machine, pairwise ⟨vᵢ,vⱼ⟩xᵢxⱼ via the O(nk)
sum-square trick. [ICDM'10 (Rendle); paper]"""

from repro.configs.base import RecsysConfig


def config() -> RecsysConfig:
    return RecsysConfig(
        name="fm",
        variant="fm",
        n_sparse=39,
        embed_dim=10,
        vocab_per_field=1_000_000,
    )
