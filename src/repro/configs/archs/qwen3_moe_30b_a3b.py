"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, fine-grained experts.
[hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.configs.base import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-moe-30b-a3b",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_ff=768,
        vocab=151936,
        n_experts=128,
        top_k=8,
        activation="silu",
        rope_theta=1_000_000.0,
    )
