"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]"""

from repro.configs.base import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="h2o-danube-1.8b",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab=32000,
        sliding_window=4096,  # mistral-style SWA on every layer
        activation="silu",
        rope_theta=10_000.0,
    )
