"""dbrx-132b [moe] — 16 experts top-4, fine-grained.
[hf:databricks/dbrx-base; unverified]"""

from repro.configs.base import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="dbrx-132b",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab=100352,
        n_experts=16,
        top_k=4,
        activation="silu",
        rope_theta=500_000.0,
        remat_chunk=5,  # two-level checkpointing: 8 chunks × 5 layers
        grad_accum=8,  # 8 microbatches: peak activations ÷8 at 132B scale
        # f32 Adam moments for 132B params on 256×16GB chips cannot fit
        # (8 B/param = 4.1 GiB/chip after full sharding); bf16 moments are
        # the standard trade at this chip count.
        opt_dtype="bfloat16",
    )
