from repro.configs.archs import (
    dbrx_132b,
    dcn_v2,
    fm,
    gemma2_27b,
    gemma2_2b,
    h2o_danube_1_8b,
    mind,
    mirex,
    pna,
    qwen3_moe_30b_a3b,
    sasrec,
)

__all__ = [
    "dbrx_132b",
    "dcn_v2",
    "fm",
    "gemma2_27b",
    "gemma2_2b",
    "h2o_danube_1_8b",
    "mind",
    "mirex",
    "pna",
    "qwen3_moe_30b_a3b",
    "sasrec",
]
