"""gemma2-27b [dense] — local+global alternating attention, logit softcaps,
gemma (1+w) RMSNorm, GeGLU. [arXiv:2408.00118; hf]"""

from repro.configs.base import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-27b",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        d_ff=36864,
        vocab=256000,
        head_dim=128,
        sliding_window=4096,
        local_global_alternating=True,
        attn_softcap=50.0,
        final_softcap=30.0,
        activation="gelu",
        rms_one_plus=True,
        rope_theta=10_000.0,
        remat_chunk=2,  # 23 chunks × 2 layers: carry stack ÷2, keeps local/global pairing
        grad_accum=8,  # per-microbatch activations ÷8 (27B dense, d_ff 36k)
    )
