"""gemma2-2b [dense] — local+global alternating, logit softcaps; 8 heads (so
attention TP falls back to dp-only on a 16-way model axis — see DESIGN §5).
[arXiv:2408.00118; hf]"""

from repro.configs.base import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-2b",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        d_ff=9216,
        vocab=256000,
        head_dim=256,
        sliding_window=4096,
        local_global_alternating=True,
        attn_softcap=50.0,
        final_softcap=30.0,
        activation="gelu",
        rms_one_plus=True,
        rope_theta=10_000.0,
    )
