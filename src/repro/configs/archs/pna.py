"""pna [gnn] — 4 aggregators (mean/max/min/std) × scalers (id/amp/atten).
[arXiv:2004.05718; paper]"""

from repro.configs.base import GNNConfig


def config() -> GNNConfig:
    return GNNConfig(
        name="pna",
        n_layers=4,
        d_hidden=75,
        aggregators=("mean", "max", "min", "std"),
        scalers=("identity", "amplification", "attenuation"),
        delta=2.5,  # E[log(deg+1)] over the training graphs (dataset constant)
    )
