"""mirex — the paper's own system: sequential-scan search over a sharded
corpus with the k-bounded combiner merge. [Hiemstra & Hauff, TR-CTIT-10-15]"""

from repro.configs.base import MirexConfig


def config() -> MirexConfig:
    return MirexConfig(
        name="mirex",
        scorer="ql_lm",
        k=1000,
        chunk_size=16384,  # §Perf: 3.7× lower HBM term vs 1024
        vocab=65_536,
        max_doc_len=128,
        max_q_len=8,
        dense_dim=256,
    )
