"""sasrec [recsys] — causal self-attention over item history.
[arXiv:1808.09781; paper]"""

from repro.configs.base import RecsysConfig


def config() -> RecsysConfig:
    return RecsysConfig(
        name="sasrec",
        variant="sasrec",
        embed_dim=50,
        n_blocks=2,
        n_heads=1,
        seq_len=50,
        n_items=3_000_000,
    )
