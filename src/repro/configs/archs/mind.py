"""mind [recsys] — multi-interest capsule routing (B2I dynamic routing).
[arXiv:1904.08030; unverified]"""

from repro.configs.base import RecsysConfig


def config() -> RecsysConfig:
    return RecsysConfig(
        name="mind",
        variant="mind",
        embed_dim=64,
        n_interests=4,
        capsule_iters=3,
        seq_len=50,
        n_items=3_000_000,
    )
