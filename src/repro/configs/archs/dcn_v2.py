"""dcn-v2 [recsys] — 13 dense + 26 sparse fields (criteo layout), 3 full-rank
cross layers, stacked MLP 1024-1024-512. [arXiv:2008.13535; paper]"""

from repro.configs.base import RecsysConfig


def config() -> RecsysConfig:
    return RecsysConfig(
        name="dcn-v2",
        variant="dcn-v2",
        n_dense=13,
        n_sparse=26,
        embed_dim=16,
        n_cross_layers=3,
        mlp_dims=(1024, 1024, 512),
        vocab_per_field=1_000_000,
    )
