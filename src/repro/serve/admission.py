"""Per-session admission control and backpressure: decide *at enqueue time*.

A production retrieval service cannot admit unboundedly: past the knee of
the open-loop load curve, every admitted request makes every other request
later, and the queue — not the scan — becomes the latency. This module is
the gate in front of the microbatcher:

* a **bounded admission queue** — when the pending depth reaches
  ``queue_limit``, new arrivals are rejected (shed) or asked to retry
  (block), instead of growing an unbounded backlog;
* **per-tenant token buckets** — each (tenant, lane) pair can carry a
  sustained-rate + burst budget, so one tenant cannot starve the rest;
* **two-tier QoS lanes** — ``interactive`` and ``batch``. The batch lane
  *yields under pressure*: it is admitted only below a fractional
  watermark of the queue limit, and not at all while the adaptive policy
  reports the latency SLO at risk (`set_pressure`). Interactive traffic
  keeps the full queue.

Decisions are **typed results**, not exceptions: :class:`Admitted` /
:class:`Shed` / :class:`Blocked`. The service layer counts every decision
in the obs metrics registry (``serve.admitted`` / ``serve.shed`` +
per-reason and per-lane counters) and traces sheds, so load-shedding is
auditable, never silent.

The contract mirrors the rest of the serving layer: admission changes
*which* requests run and *when* — never the bytes of any request that
completes.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

INTERACTIVE = "interactive"
BATCH = "batch"
LANES = (INTERACTIVE, BATCH)

# shed reasons (the typed-result / counter vocabulary)
QUEUE_FULL = "queue_full"
RATE_LIMITED = "rate_limited"
BATCH_YIELD = "batch_yield"


@dataclasses.dataclass(frozen=True)
class Admitted:
    """The request entered the microbatch queue; ``rid`` is live."""

    rid: int
    lane: str = INTERACTIVE
    tenant: str = "default"

    @property
    def admitted(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class Shed:
    """The request was rejected at enqueue time and will never run."""

    reason: str  # queue_full | rate_limited | batch_yield
    lane: str = INTERACTIVE
    tenant: str = "default"

    @property
    def admitted(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class Blocked:
    """Backpressure: not admitted now, retry later (``retry_at`` is a
    clock hint when one exists — token refill time — else None, meaning
    'after the next dispatch drains the queue')."""

    reason: str
    lane: str = INTERACTIVE
    tenant: str = "default"
    retry_at: float | None = None

    @property
    def admitted(self) -> bool:
        return False


class TokenBucket:
    """The classic leaky budget: ``rate`` tokens/s refill up to ``burst``.

    Time is injected per call (same discipline as the microbatcher), so
    bucket behavior is deterministic under test and under the virtual
    clock of the open-loop load generator.
    """

    __slots__ = ("rate", "burst", "_tokens", "_last")

    def __init__(self, rate: float, burst: float):
        if rate <= 0 or burst <= 0:
            raise ValueError(f"rate and burst must be positive: {rate}, {burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._last: float | None = None

    def _refill(self, now: float) -> None:
        if self._last is not None and now > self._last:
            self._tokens = min(self.burst, self._tokens + (now - self._last) * self.rate)
        if self._last is None or now > self._last:
            self._last = now

    def peek(self, now: float) -> float:
        self._refill(now)
        return self._tokens

    def take(self, now: float) -> bool:
        self._refill(now)
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True
        return False

    def next_token_at(self, now: float) -> float:
        """Earliest time a full token will be available (a Blocked hint)."""
        self._refill(now)
        if self._tokens >= 1.0:
            return now
        return now + (1.0 - self._tokens) / self.rate


class AdmissionController:
    """The shed-or-block gate in front of a service's microbatchers.

    ``queue_limit`` bounds the *pending* request count the controller will
    admit into (the service passes the live depth per decision — the
    controller holds no queue of its own). ``batch_watermark`` is the
    fraction of ``queue_limit`` above which the batch lane yields;
    ``on_full`` picks the decision type for a full queue (``"shed"`` drops
    with a typed result, ``"block"`` asks the caller to retry).

    Rates are optional: a (tenant, lane) with no bucket is uncapped.
    ``set_rate`` installs one; ``"*"`` as tenant installs a per-lane
    default applied to tenants without their own bucket (each such tenant
    still gets its *own* bucket instance at the default rate — a shared
    default must not make tenants share a budget).
    """

    def __init__(
        self,
        *,
        queue_limit: int = 256,
        batch_watermark: float = 0.5,
        on_full: str = "shed",
    ):
        if queue_limit < 1:
            raise ValueError("queue_limit must be >= 1")
        if not 0.0 <= batch_watermark <= 1.0:
            raise ValueError(f"batch_watermark must be in [0,1]: {batch_watermark}")
        if on_full not in ("shed", "block"):
            raise ValueError(f"on_full must be 'shed' or 'block': {on_full!r}")
        self.queue_limit = queue_limit
        self.batch_watermark = batch_watermark
        self.on_full = on_full
        self._buckets: dict[tuple[str, str], TokenBucket] = {}
        self._default_rates: dict[str, tuple[float, float]] = {}  # lane -> (rate, burst)
        self._pressure = False

    # -- configuration ------------------------------------------------------

    def set_rate(self, tenant: str, lane: str, rate: float, burst: float) -> None:
        """Install a token bucket for (tenant, lane); tenant ``"*"`` sets
        the per-lane default for tenants without an explicit bucket."""
        if lane not in LANES:
            raise ValueError(f"unknown lane {lane!r}; lanes: {LANES}")
        if tenant == "*":
            self._default_rates[lane] = (rate, burst)
        else:
            self._buckets[(tenant, lane)] = TokenBucket(rate, burst)

    def set_pressure(self, pressure: bool) -> None:
        """The adaptive policy's backpressure signal: while True, the batch
        lane yields entirely (interactive keeps the queue)."""
        self._pressure = bool(pressure)

    @property
    def pressure(self) -> bool:
        return self._pressure

    def _bucket(self, tenant: str, lane: str) -> TokenBucket | None:
        b = self._buckets.get((tenant, lane))
        if b is None and lane in self._default_rates:
            rate, burst = self._default_rates[lane]
            b = self._buckets[(tenant, lane)] = TokenBucket(rate, burst)
        return b

    # -- the decision -------------------------------------------------------

    def admit(
        self, *, tenant: str, lane: str, now: float, queue_depth: int
    ) -> Shed | Blocked | None:
        """One enqueue-time decision. Returns ``None`` to admit, else the
        typed rejection. Decision order: rate limit (cheapest to recover
        from — the bucket refills), then queue bound, then QoS yield."""
        if lane not in LANES:
            raise ValueError(f"unknown lane {lane!r}; lanes: {LANES}")
        bucket = self._bucket(tenant, lane)
        if bucket is not None and not bucket.take(now):
            if self.on_full == "block":
                return Blocked(
                    RATE_LIMITED, lane, tenant, retry_at=bucket.next_token_at(now)
                )
            return Shed(RATE_LIMITED, lane, tenant)
        if queue_depth >= self.queue_limit:
            if self.on_full == "block":
                return Blocked(QUEUE_FULL, lane, tenant)
            return Shed(QUEUE_FULL, lane, tenant)
        if lane == BATCH and (
            self._pressure or queue_depth >= self.batch_watermark * self.queue_limit
        ):
            # batch yields: under pressure or above its watermark the lane
            # gives its queue headroom to interactive traffic
            if self.on_full == "block":
                return Blocked(BATCH_YIELD, lane, tenant)
            return Shed(BATCH_YIELD, lane, tenant)
        return None

    def describe(self) -> dict:
        return {
            "queue_limit": self.queue_limit,
            "batch_watermark": self.batch_watermark,
            "on_full": self.on_full,
            "pressure": self._pressure,
            "buckets": sorted(f"{t}/{l}" for (t, l) in self._buckets),
        }
