"""Serve-mode benchmarking: batch-size vs latency/throughput (claim C1).

``sweep_batch_sizes`` replays the same session at several microbatch sizes
and records one curve point per size — per-query latency should *fall* as
the block grows, because the corpus stream through the scan is paid once
per block. ``write_bench_json`` persists the curve (BENCH_serve.json) so
successive PRs can diff serving regressions.
"""

from __future__ import annotations

import json
from typing import Callable, Sequence

import numpy as np

from repro import obs
from repro.serve.service import RetrievalService
from repro.tune import config as tune_config


def sweep_batch_sizes(
    session,
    make_queries: Callable[[int, int], np.ndarray],
    batch_sizes: Sequence[int],
    *,
    repeats: int = 3,
    warmup: int = 1,
    max_delay: float = 60.0,
) -> dict:
    """Measure one full-block dispatch per batch size; median of repeats.

    ``make_queries(n, seed)`` supplies the query rows. The session's corpus
    stays resident across the whole sweep — only the service/batcher wrapper
    is rebuilt per size, so this measures the steady-state serving path.
    """
    curve = []
    for bs in batch_sizes:
        service = RetrievalService(
            {session.kind: session}, max_batch=bs, max_delay=max_delay
        )
        latencies = []
        for rep in range(warmup + repeats):
            queries = make_queries(bs, rep)
            n_seen = len(service.metrics)
            for row in queries:
                service.submit(row, session.kind)
            results = service.poll()
            assert len(results) == bs, (len(results), bs)
            # a wave larger than the bucket-ladder cap dispatches as
            # several blocks — the wave's latency is their sum
            wave = service.metrics[n_seen:]
            if rep >= warmup:
                latencies.append(sum(r.latency_s for r in wave))
        lat = float(np.median(latencies))
        pt = {
            "batch": bs,
            "n_padded": sum(r.n_padded for r in wave),
            "n_blocks": len(wave),
            "latency_ms": lat * 1e3,
            "us_per_query": lat / bs * 1e6,
            "qps": bs / lat,
        }
        # per-point C1 view: batching must never make a query *more* expensive
        pt["amortization_x"] = curve[0]["us_per_query"] / pt["us_per_query"] if curve else 1.0
        curve.append(pt)
    payload = {
        "benchmark": "serve_latency",
        "kind": session.kind,
        "scorer": session.scorer.name,
        "n_docs": session.n_docs,
        "k": session.k,
        "chunk_size": session.chunk_size,
        "batch_sizes": list(batch_sizes),
        "curve": curve,
    }
    if len(curve) >= 2:
        payload["amortization_x"] = curve[0]["us_per_query"] / curve[-1]["us_per_query"]
    return payload


def write_bench_json(payload: dict, path: str = "BENCH_serve.json") -> str:
    """Persist a benchmark payload, stamped with where it was measured.

    Every ``BENCH_*.json`` carries a ``provenance`` block (host, backend,
    jax version, device count) so perf numbers recorded on different
    machines or backends are comparable — or visibly not. The active
    TuningConfig's hash/source is stamped alongside for the same reason.
    """
    payload = dict(payload)
    payload.setdefault("provenance", obs.provenance())
    payload.setdefault("tuning", tune_config.provenance())
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path
