"""SLO-driven adaptive microbatch control: the serving-layer closed loop.

The microbatch triggers are a latency/amortization tradeoff: a bigger
block is cheaper per query (claim C1) but waits longer to fill; a longer
deadline raises occupancy but pays queue wait. The static knobs picked
offline are only right for one load level — this module re-picks them
*online*, from the live request-latency distribution the service already
records into its injected :class:`~repro.obs.metrics.Metrics` registry.

The control loop, once per ``interval_s`` (driven from ``service.poll``):

1. **read** — recent p99 of admission→reply request latency from the
   *windowed* histogram (``serve.recent.request_s``; a ring of fixed-time
   sub-windows, so stale samples age out — the policy reacts to the last
   ``window_s`` seconds, not the run's lifetime);
2. **decide** — compare against the SLO with a hysteresis band: above
   ``slo · (1+band)`` tighten, below ``slo · (1-band)`` relax, inside the
   band do nothing (the band is what keeps a marginal load level from
   flapping the knobs);
3. **act** — one bounded step on the effective triggers, written through
   the batchers' :meth:`~repro.serve.microbatch.Microbatcher.retune`
   (TuningConfig-shaped knobs: ``serve_max_batch`` halves/doubles within
   its bounds, ``serve_max_delay_s`` moves geometrically within its
   bounds), and the backpressure signal (p99 above SLO) forwarded to the
   admission controller so the batch QoS lane yields.

Oscillation control is structural, not tuned: the hysteresis band, the
bounded per-tick step, and a **cooldown** after every direction flip — a
reversal attempted within ``cooldown_intervals`` ticks of the previous
flip is *damped* (counted, not applied). An applied flip inside the
cooldown would be a bug in this guard; it is counted separately as
``serve.policy.oscillation_violations`` and CI asserts that counter stays
zero under sustained load.

Every decision is auditable: an instant event ``serve.policy`` (observed
p99, SLO, direction, the knob values written) lands in the Chrome trace,
and gauges/counters mirror the current knobs and adjustment counts.

Contract, same as obs and tune: **the policy changes speed and admission,
never bytes** — any request that completes returns results byte-identical
to the static-config oracle (grouping only decides when a scan runs and
which queries share it).
"""

from __future__ import annotations

from typing import Callable, Iterable

from repro import obs
from repro.obs.metrics import Histogram, Metrics
from repro.serve.admission import AdmissionController
from repro.serve.microbatch import Microbatcher

# decision labels (trace vocabulary)
TIGHTEN = "tighten"
RELAX = "relax"
HOLD = "hold"
DAMPED = "damped"
AT_BOUND = "at_bound"


class AdaptiveBatchPolicy:
    """Closed-loop controller over a service's microbatch triggers.

    Construct with the latency SLO and (optionally) explicit knob bounds,
    hand it to :class:`~repro.serve.service.RetrievalService`; the service
    binds it to its batchers, admission controller, and windowed request
    histogram, then drives :meth:`tick` from every ``poll``.

    ``batch_bounds`` / ``delay_bounds`` default at bind time from the
    batcher's own knobs: batch may shrink to ``min_bucket`` and grow to
    the bucket-ladder cap (``max_bucket`` — growing past it would only
    split again), delay may shrink to 0.1 ms and grow to
    ``max(initial delay, slo/4)``.
    """

    def __init__(
        self,
        *,
        slo_p99_s: float,
        interval_s: float = 0.25,
        band: float = 0.2,
        cooldown_intervals: int = 2,
        min_samples: int = 16,
        window_s: float | None = None,
        batch_bounds: tuple[int, int] | None = None,
        delay_bounds: tuple[float, float] | None = None,
    ):
        if slo_p99_s <= 0:
            raise ValueError("slo_p99_s must be positive")
        if interval_s <= 0:
            raise ValueError("interval_s must be positive")
        if not 0.0 < band < 1.0:
            raise ValueError(f"band must be in (0,1): {band}")
        if cooldown_intervals < 1:
            raise ValueError("cooldown_intervals must be >= 1")
        self.slo_p99_s = slo_p99_s
        self.interval_s = interval_s
        self.band = band
        self.cooldown_s = cooldown_intervals * interval_s
        self.min_samples = min_samples
        # the recency horizon of the histogram the policy reads: long
        # enough to hold a few intervals of samples, short enough to
        # forget the previous load level quickly
        self.window_s = window_s if window_s is not None else max(8 * interval_s, 2.0)
        self._batch_bounds = batch_bounds
        self._delay_bounds = delay_bounds

        # bound at bind()
        self._batchers: tuple[Microbatcher, ...] = ()
        self._admission: AdmissionController | None = None
        self._hist: Histogram | None = None
        self._met: Callable[[], Metrics] | None = None

        # controller state
        self._eff_batch: int | None = None
        self._eff_delay: float | None = None
        self._last_tick: float | None = None
        self._last_direction = 0
        self._last_flip_t: float | None = None
        self.adjustments = 0
        self.damped = 0
        self.flips = 0
        self.oscillation_violations = 0

    # -- wiring -------------------------------------------------------------

    def bind(
        self,
        *,
        batchers: Iterable[Microbatcher],
        request_hist: Histogram,
        metrics: Callable[[], Metrics],
        admission: AdmissionController | None = None,
    ) -> None:
        """Attach the policy to one service's moving parts (the service
        calls this once, at construction)."""
        self._batchers = tuple(batchers)
        if not self._batchers:
            raise ValueError("policy needs at least one batcher")
        self._hist = request_hist
        self._met = metrics
        self._admission = admission
        b = self._batchers[0]
        self._eff_batch = min(
            b.max_batch, b.max_bucket if b.max_bucket is not None else b.max_batch
        )
        self._eff_delay = b.max_delay
        if self._batch_bounds is None:
            hi = b.max_bucket if b.max_bucket is not None else max(b.max_batch, 1)
            self._batch_bounds = (b.min_bucket, max(hi, self._eff_batch))
        if self._delay_bounds is None:
            self._delay_bounds = (
                1e-4,
                max(b.max_delay, self.slo_p99_s / 4.0),
            )

    @property
    def effective(self) -> dict:
        """The knobs the policy currently holds (TuningConfig-shaped)."""
        return {
            "serve_max_batch": self._eff_batch,
            "serve_max_delay_s": self._eff_delay,
        }

    # -- the loop -----------------------------------------------------------

    def tick(self, now: float) -> str | None:
        """One control-loop step; returns the decision label or None when
        the tick was skipped (inside the interval, or too few samples)."""
        if self._hist is None:
            raise RuntimeError("policy not bound to a service")
        if self._last_tick is not None and now - self._last_tick < self.interval_s:
            return None
        self._last_tick = now

        n = self._hist.count
        if n < self.min_samples:
            return None
        p99 = self._hist.quantile(0.99)

        # backpressure first: the batch lane yields the moment the SLO is
        # at risk, independent of whether a knob step fires this tick
        if self._admission is not None:
            self._admission.set_pressure(p99 > self.slo_p99_s)

        hi = self.slo_p99_s * (1.0 + self.band)
        lo = self.slo_p99_s * (1.0 - self.band)
        direction = -1 if p99 > hi else (1 if p99 < lo else 0)
        if direction == 0:
            self._trace(now, p99, HOLD)
            return HOLD

        if self._last_direction != 0 and direction != self._last_direction:
            if self._last_flip_t is not None and now - self._last_flip_t < self.cooldown_s:
                # a reversal this soon after the last one is the oscillation
                # signature: damp it (hold the knobs, count the attempt)
                self.damped += 1
                self._counter("serve.policy.damped").inc()
                self._trace(now, p99, DAMPED, direction=direction)
                return DAMPED
            # applied flip: record it, and self-check the guard — a flip
            # landing inside the cooldown would mean the damper is broken
            if self._last_flip_t is not None and now - self._last_flip_t < self.cooldown_s:
                self.oscillation_violations += 1  # pragma: no cover — guard bug
                self._counter("serve.policy.oscillation_violations").inc()
            self.flips += 1
            self._counter("serve.policy.flips").inc()
            self._last_flip_t = now

        b_lo, b_hi = self._batch_bounds
        d_lo, d_hi = self._delay_bounds
        if direction < 0:
            new_batch = max(self._eff_batch // 2, b_lo)
            new_delay = max(self._eff_delay * 0.5, d_lo)
            label = TIGHTEN
        else:
            new_batch = min(self._eff_batch * 2, b_hi)
            new_delay = min(self._eff_delay * 1.5, d_hi)
            label = RELAX
        if new_batch == self._eff_batch and new_delay == self._eff_delay:
            # already pinned at the bound in this direction
            self._last_direction = direction
            self._trace(now, p99, AT_BOUND, direction=direction)
            return AT_BOUND

        self._eff_batch, self._eff_delay = new_batch, new_delay
        self._last_direction = direction
        for batcher in self._batchers:
            batcher.retune(max_batch=new_batch, max_delay=new_delay)
        self.adjustments += 1
        met = self._met()
        met.counter("serve.policy.adjustments").inc()
        met.gauge("serve.policy.max_batch").set(new_batch)
        met.gauge("serve.policy.max_delay_s").set(new_delay)
        self._trace(now, p99, label, direction=direction)
        return label

    # -- plumbing -----------------------------------------------------------

    def _counter(self, name: str):
        return self._met().counter(name)

    def _trace(self, now: float, p99: float, decision: str, *, direction: int = 0):
        obs.tracer().instant(
            "serve.policy",
            "serve",
            decision=decision,
            direction=direction,
            p99_ms=round(p99 * 1e3, 3),
            slo_ms=round(self.slo_p99_s * 1e3, 3),
            serve_max_batch=self._eff_batch,
            serve_max_delay_s=self._eff_delay,
            pressure=self._admission.pressure if self._admission is not None else False,
        )

    def describe(self) -> dict:
        """Policy provenance for reports / BENCH payloads."""
        return {
            "slo_p99_ms": self.slo_p99_s * 1e3,
            "interval_s": self.interval_s,
            "band": self.band,
            "window_s": self.window_s,
            "batch_bounds": list(self._batch_bounds) if self._batch_bounds else None,
            "delay_bounds": list(self._delay_bounds) if self._delay_bounds else None,
            "effective": self.effective,
            "adjustments": self.adjustments,
            "flips": self.flips,
            "damped": self.damped,
            "oscillation_violations": self.oscillation_violations,
        }
