"""Streaming retrieval service: admission, microbatching, resident sessions.

Public surface:

* :class:`~repro.serve.service.RetrievalService` — submit/poll/drain facade.
* :class:`~repro.serve.session.LexicalSession` /
  :class:`~repro.serve.session.DenseSession` — resident-corpus scan state.
* :class:`~repro.serve.session.ShardedLexicalSession` — the same session
  surface with the corpus resident *sharded* across a JAX mesh, reducing
  through the `repro.cluster` merge contract.
* :class:`~repro.serve.microbatch.Microbatcher` — deadline/size triggers +
  MXU-bucket padding (importable standalone for tests).
* :mod:`repro.serve.bench` — the C1 batch-size/latency sweep.
"""

from repro.serve.microbatch import Microbatcher, QueryBlock, SearchRequest
from repro.serve.service import BatchRecord, RetrievalService, SearchResult
from repro.serve.session import DenseSession, LexicalSession, ShardedLexicalSession

__all__ = [
    "BatchRecord",
    "DenseSession",
    "LexicalSession",
    "Microbatcher",
    "QueryBlock",
    "RetrievalService",
    "SearchRequest",
    "SearchResult",
    "ShardedLexicalSession",
]
