"""Streaming retrieval service: admission, microbatching, resident sessions.

Public surface:

* :class:`~repro.serve.service.RetrievalService` — submit/poll/drain facade
  (``try_submit`` for typed admission outcomes).
* :class:`~repro.serve.session.LexicalSession` /
  :class:`~repro.serve.session.DenseSession` — resident-corpus scan state.
* :class:`~repro.serve.session.ShardedLexicalSession` — the same session
  surface with the corpus resident *sharded* across a JAX mesh, reducing
  through the `repro.cluster` merge contract.
* :class:`~repro.serve.microbatch.Microbatcher` — deadline/size triggers +
  MXU-bucket padding, capped ladder (importable standalone for tests).
* :class:`~repro.serve.admission.AdmissionController` — bounded queue,
  per-tenant token buckets, QoS lanes; typed Admitted/Shed/Blocked.
* :class:`~repro.serve.policy.AdaptiveBatchPolicy` — the SLO closed loop
  over the microbatch triggers.
* :mod:`repro.serve.loadgen` — open-loop sustained-load generation on a
  virtual clock (Poisson/burst schedules, metered sessions).
* :mod:`repro.serve.bench` — the C1 batch-size/latency sweep.
"""

from repro.serve.admission import (
    Admitted,
    AdmissionController,
    Blocked,
    Shed,
    TokenBucket,
)
from repro.serve.loadgen import (
    MeteredSession,
    OpenLoopResult,
    VirtualClock,
    burst_schedule,
    poisson_schedule,
    run_open_loop,
)
from repro.serve.microbatch import Microbatcher, QueryBlock, SearchRequest
from repro.serve.policy import AdaptiveBatchPolicy
from repro.serve.service import (
    BatchRecord,
    RejectedError,
    RetrievalService,
    SearchResult,
)
from repro.serve.session import DenseSession, LexicalSession, ShardedLexicalSession

__all__ = [
    "AdaptiveBatchPolicy",
    "Admitted",
    "AdmissionController",
    "BatchRecord",
    "Blocked",
    "DenseSession",
    "LexicalSession",
    "MeteredSession",
    "Microbatcher",
    "OpenLoopResult",
    "QueryBlock",
    "RejectedError",
    "RetrievalService",
    "SearchRequest",
    "SearchResult",
    "ShardedLexicalSession",
    "Shed",
    "TokenBucket",
    "VirtualClock",
    "burst_schedule",
    "poisson_schedule",
    "run_open_loop",
]
