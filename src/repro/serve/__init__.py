"""Streaming retrieval service: admission, microbatching, resident sessions.

Public surface:

* :class:`~repro.serve.service.RetrievalService` — submit/poll/drain facade.
* :class:`~repro.serve.session.LexicalSession` /
  :class:`~repro.serve.session.DenseSession` — resident-corpus scan state.
* :class:`~repro.serve.microbatch.Microbatcher` — deadline/size triggers +
  MXU-bucket padding (importable standalone for tests).
* :mod:`repro.serve.bench` — the C1 batch-size/latency sweep.
"""

from repro.serve.microbatch import Microbatcher, QueryBlock, SearchRequest
from repro.serve.service import BatchRecord, RetrievalService, SearchResult
from repro.serve.session import DenseSession, LexicalSession

__all__ = [
    "BatchRecord",
    "DenseSession",
    "LexicalSession",
    "Microbatcher",
    "QueryBlock",
    "RetrievalService",
    "SearchRequest",
    "SearchResult",
]
