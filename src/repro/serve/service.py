"""The retrieval service: admission → microbatch → dispatch → unpad.

Request lifecycle (see docs/ARCHITECTURE.md §Serve):

1. ``try_submit(query, kind, tenant=, lane=)`` consults the admission
   controller (bounded queue, per-tenant token buckets, QoS lanes) and
   either admits the query into the kind's microbatcher — returning a
   typed :class:`~repro.serve.admission.Admitted` with the request id —
   or rejects it with a typed ``Shed``/``Blocked`` (counted in obs,
   traced, never silent). ``submit`` is the legacy/raw surface: it
   bypasses a missing controller entirely and raises on rejection.
2. ``poll()`` first runs the adaptive policy tick (if one is installed:
   the closed loop that retunes the microbatch triggers against the
   latency SLO), then closes every block whose size/deadline trigger has
   fired and dispatches it: lexical blocks to the raw-token chunked scan
   (``scan.search_local`` fold), dense blocks to the Pallas fused
   score+top-k kernel — one resident-corpus session per kind.
3. Padding rows are stripped and per-request ``SearchResult``s are returned
   keyed by request id; a ``BatchRecord`` per block (real/padded size,
   queue wait, device latency, trigger) lands in ``service.metrics``.

``drain()`` force-flushes at shutdown. The wall clock is injectable so the
deadline trigger is testable; production callers use the monotonic clock.
The open-loop load generator (`repro.serve.loadgen`) drives the same
surface under a virtual clock.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping

import numpy as np

from repro import obs
from repro.obs.metrics import Metrics
from repro.serve.admission import Admitted, AdmissionController, Blocked, Shed
from repro.serve.microbatch import Microbatcher, QueryBlock, unpad_results
from repro.serve.policy import AdaptiveBatchPolicy
from repro.serve.session import DenseSession, LexicalSession, ShardedLexicalSession


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Per-request top-k, already on host with padding stripped."""

    rid: int
    scores: np.ndarray  # [k] float32, descending
    ids: np.ndarray  # [k] int32 global doc ids


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """Telemetry for one dispatched block (one point on the C1 curve)."""

    kind: str
    n_real: int
    n_padded: int
    trigger: str
    queue_wait_s: float  # oldest request's admission -> block close
    latency_s: float  # dispatch -> results on host

    @property
    def us_per_query(self) -> float:
        return self.latency_s / max(self.n_real, 1) * 1e6

    @property
    def occupancy(self) -> float:
        return self.n_real / max(self.n_padded, 1)


class RejectedError(RuntimeError):
    """``submit`` (the raw, exception-style surface) hit admission control;
    the typed outcome rides along for callers that want the details."""

    def __init__(self, outcome: Shed | Blocked):
        super().__init__(f"request rejected: {outcome}")
        self.outcome = outcome


# batch sizes are small integers bucketed like the padder buckets them:
# powers of two (latency buckets would waste resolution below 1.0)
_BATCH_BOUNDS = tuple(float(1 << i) for i in range(11))  # 1 .. 1024
# occupancy is a fraction: linear buckets resolve the whole [0, 1] range
_OCCUPANCY_BOUNDS = tuple(i / 10 for i in range(1, 11))


class RetrievalService:
    """Dispatcher over resident-corpus sessions, one microbatcher per kind.

    ``admission`` installs enqueue-time load shedding / backpressure and
    QoS lanes (:class:`~repro.serve.admission.AdmissionController`);
    ``policy`` installs the SLO closed loop
    (:class:`~repro.serve.policy.AdaptiveBatchPolicy`) — the service binds
    it to its batchers, the admission controller, and a *windowed* request
    latency histogram (``serve.recent.request_s``) created against the
    service clock, then ticks it from every ``poll``. Neither changes any
    completed request's bytes: admission decides *whether* a query runs,
    the policy decides *when* and *with whom* — results are byte-identical
    to the static-config service for every request that completes.
    """

    def __init__(
        self,
        sessions: Mapping[str, LexicalSession | DenseSession | ShardedLexicalSession],
        *,
        max_batch: int | None = None,
        max_delay: float | None = None,
        min_bucket: int | None = None,
        max_bucket: int | None = None,
        clock: Callable[[], float] = time.monotonic,
        registry: Metrics | None = None,
        tuning=None,
        admission: AdmissionController | None = None,
        policy: AdaptiveBatchPolicy | None = None,
    ):
        if not sessions:
            raise ValueError("need at least one session")
        self.sessions = dict(sessions)
        self._clock = clock
        # ``registry`` pins the service's histograms/counters to one owned
        # Metrics (the launcher's shutdown summary); default is the process
        # registry, resolved per dispatch so obs.session() swaps apply
        self._registry = registry
        # trigger knobs default (None) from `tuning` / the active TuningConfig
        self._batchers = {
            kind: Microbatcher(
                max_batch=max_batch,
                max_delay=max_delay,
                min_bucket=min_bucket,
                max_bucket=max_bucket,
                pad_value=sess.pad_value,
                tuning=tuning,
            )
            for kind, sess in self.sessions.items()
        }
        self._next_rid = 0
        self.metrics: list[BatchRecord] = []
        self.admission = admission
        self.policy = policy
        if policy is not None:
            # the windowed (recent-quantile) histogram the policy reads is
            # created here, against the service clock, so get-or-create
            # races can never hand the policy a cumulative instrument
            hist = self._met().histogram(
                "serve.recent.request_s",
                window_s=policy.window_s,
                clock=self._clock,
            )
            policy.bind(
                batchers=self._batchers.values(),
                request_hist=hist,
                metrics=self._met,
                admission=admission,
            )

    def _met(self) -> Metrics:
        return self._registry if self._registry is not None else obs.metrics()

    @property
    def kinds(self) -> tuple[str, ...]:
        return tuple(self.sessions)

    def _resolve_kind(self, kind: str | None) -> str:
        if kind is None:
            if len(self.sessions) != 1:
                raise ValueError(f"ambiguous kind; service has {self.kinds}")
            return next(iter(self.sessions))
        if kind not in self._batchers:
            raise KeyError(f"no session {kind!r}; available: {self.kinds}")
        return kind

    def try_submit(
        self,
        query: np.ndarray,
        kind: str | None = None,
        *,
        tenant: str = "default",
        lane: str = "interactive",
    ) -> Admitted | Shed | Blocked:
        """Admission-checked submit: returns a typed outcome, never raises
        on rejection. Without an admission controller every request admits."""
        kind = self._resolve_kind(kind)
        now = self._clock()
        met = self._met()
        if self.admission is not None:
            rejection = self.admission.admit(
                tenant=tenant, lane=lane, now=now, queue_depth=self.pending(kind)
            )
            if rejection is not None:
                met.counter("serve.shed").inc()
                met.counter(f"serve.shed.{rejection.reason}").inc()
                met.counter(f"serve.lane.{lane}.shed").inc()
                obs.tracer().instant(
                    "serve.shed",
                    "serve",
                    reason=rejection.reason,
                    lane=lane,
                    tenant=tenant,
                    kind=kind,
                    blocked=isinstance(rejection, Blocked),
                )
                return rejection
        rid = self._next_rid
        self._next_rid += 1
        self._batchers[kind].submit(rid, query, now)
        met.counter("serve.admitted").inc()
        met.counter(f"serve.lane.{lane}.admitted").inc()
        return Admitted(rid=rid, lane=lane, tenant=tenant)

    def submit(self, query: np.ndarray, kind: str | None = None) -> int:
        """Admit one query; returns its request id without blocking.

        The raw surface: with no admission controller installed this is
        unconditional (the historical behavior); with one, a rejection
        raises :class:`RejectedError` — callers that want shed/blocked as
        data use :meth:`try_submit`.
        """
        outcome = self.try_submit(query, kind)
        if not outcome.admitted:
            raise RejectedError(outcome)
        return outcome.rid

    def pending(self, kind: str | None = None) -> int:
        if kind is not None:
            return len(self._batchers[kind])
        return sum(len(b) for b in self._batchers.values())

    def _dispatch(self, kind: str, block: QueryBlock) -> dict[int, SearchResult]:
        session = self.sessions[kind]
        tr = obs.tracer()
        t0 = self._clock()
        with tr.span(
            "serve.dispatch", "serve",
            kind=kind, n_real=block.n_real, n_padded=block.n_padded,
            trigger=block.trigger,
        ):
            state = session.search(block.queries)
        latency = self._clock() - t0
        self.metrics.append(
            BatchRecord(
                kind=kind,
                n_real=block.n_real,
                n_padded=block.n_padded,
                trigger=block.trigger,
                queue_wait_s=block.closed_at - block.oldest_arrival,
                latency_s=latency,
            )
        )
        met = self._met()
        met.counter("serve.requests").inc(block.n_real)
        met.counter("serve.batches").inc()
        met.histogram("serve.batch_size", bounds=_BATCH_BOUNDS).observe(block.n_real)
        met.histogram(
            "serve.batch_occupancy", bounds=_OCCUPANCY_BOUNDS
        ).observe(block.n_real / block.n_padded)
        met.histogram("serve.queue_wait_s").observe(
            block.closed_at - block.oldest_arrival
        )
        met.histogram("serve.latency_s").observe(latency)
        # per-request lifecycle spans (enqueue → reply), recorded at reply
        # time on the service clock (== the tracer clock in production)
        done = self._clock()
        request_hist = met.histogram("serve.request_s")
        recent = (
            met.histogram("serve.recent.request_s") if self.policy is not None else None
        )
        for rid, arrival in zip(block.rids, block.arrivals):
            tr.record("serve.request", arrival, done, "serve", rid=rid, kind=kind)
            request_hist.observe(done - arrival)
            if recent is not None:
                recent.observe(done - arrival)
        scores = unpad_results(np.asarray(state.scores), block.n_real)
        ids = unpad_results(np.asarray(state.ids), block.n_real)
        return {
            rid: SearchResult(rid=rid, scores=scores[row], ids=ids[row])
            for row, rid in enumerate(block.rids)
        }

    def poll(self, limit: int | None = None) -> dict[int, SearchResult]:
        """Dispatch every block whose size/deadline trigger has fired
        (at most ``limit`` blocks when given — the load generator uses
        ``limit=1`` to timestamp completions per block). Runs the adaptive
        policy tick first, so trigger changes apply to the blocks this
        poll closes."""
        if self.policy is not None:
            self.policy.tick(self._clock())
        out: dict[int, SearchResult] = {}
        dispatched = 0
        for kind, batcher in self._batchers.items():
            while (block := batcher.pop_block(self._clock())) is not None:
                out.update(self._dispatch(kind, block))
                dispatched += 1
                if limit is not None and dispatched >= limit:
                    return out
        return out

    def drain(self) -> dict[int, SearchResult]:
        """Force-flush all pending queries (shutdown / end of stream)."""
        out: dict[int, SearchResult] = {}
        for kind, batcher in self._batchers.items():
            for block in batcher.drain(self._clock()):
                out.update(self._dispatch(kind, block))
        return out

    def next_deadline(self) -> float | None:
        """Earliest pending deadline across kinds (event-loop sleep hint)."""
        deadlines = [
            d for b in self._batchers.values() if (d := b.next_deadline()) is not None
        ]
        return min(deadlines) if deadlines else None

    def ready_at(self, now: float) -> float | None:
        """Earliest time ``>= now`` at which some batcher's trigger will
        have fired: ``now`` itself if a block is already ready (size
        trigger, or an expired deadline), else the earliest pending
        deadline; None when nothing is queued. The load generator's event
        source for 'when could the server next start a dispatch'."""
        best: float | None = None
        for b in self._batchers.values():
            if b.ready(now):
                return now
            d = b.next_deadline()
            if d is not None and (best is None or d < best):
                best = d
        return best
