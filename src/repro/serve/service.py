"""The retrieval service: admission → microbatch → dispatch → unpad.

Request lifecycle (see docs/ARCHITECTURE.md §Serve):

1. ``submit(query, kind)`` admits a query into the kind's microbatcher and
   returns a request id immediately (no device work on the submit path).
2. ``poll()`` closes every block whose size/deadline trigger has fired and
   dispatches it: lexical blocks to the raw-token chunked scan
   (``scan.search_local`` fold), dense blocks to the Pallas fused
   score+top-k kernel — one resident-corpus session per kind.
3. Padding rows are stripped and per-request ``SearchResult``s are returned
   keyed by request id; a ``BatchRecord`` per block (real/padded size,
   queue wait, device latency, trigger) lands in ``service.metrics``.

``drain()`` force-flushes at shutdown. The wall clock is injectable so the
deadline trigger is testable; production callers use the monotonic clock.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping

import numpy as np

from repro import obs
from repro.obs.metrics import Metrics
from repro.serve.microbatch import Microbatcher, QueryBlock, unpad_results
from repro.serve.session import DenseSession, LexicalSession, ShardedLexicalSession


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Per-request top-k, already on host with padding stripped."""

    rid: int
    scores: np.ndarray  # [k] float32, descending
    ids: np.ndarray  # [k] int32 global doc ids


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """Telemetry for one dispatched block (one point on the C1 curve)."""

    kind: str
    n_real: int
    n_padded: int
    trigger: str
    queue_wait_s: float  # oldest request's admission -> block close
    latency_s: float  # dispatch -> results on host

    @property
    def us_per_query(self) -> float:
        return self.latency_s / max(self.n_real, 1) * 1e6


# batch sizes are small integers bucketed like the padder buckets them:
# powers of two (latency buckets would waste resolution below 1.0)
_BATCH_BOUNDS = tuple(float(1 << i) for i in range(11))  # 1 .. 1024


class RetrievalService:
    """Dispatcher over resident-corpus sessions, one microbatcher per kind."""

    def __init__(
        self,
        sessions: Mapping[str, LexicalSession | DenseSession | ShardedLexicalSession],
        *,
        max_batch: int | None = None,
        max_delay: float | None = None,
        min_bucket: int | None = None,
        clock: Callable[[], float] = time.monotonic,
        registry: Metrics | None = None,
        tuning=None,
    ):
        if not sessions:
            raise ValueError("need at least one session")
        self.sessions = dict(sessions)
        self._clock = clock
        # ``registry`` pins the service's histograms/counters to one owned
        # Metrics (the launcher's shutdown summary); default is the process
        # registry, resolved per dispatch so obs.session() swaps apply
        self._registry = registry
        # trigger knobs default (None) from `tuning` / the active TuningConfig
        self._batchers = {
            kind: Microbatcher(
                max_batch=max_batch,
                max_delay=max_delay,
                min_bucket=min_bucket,
                pad_value=sess.pad_value,
                tuning=tuning,
            )
            for kind, sess in self.sessions.items()
        }
        self._next_rid = 0
        self.metrics: list[BatchRecord] = []

    @property
    def kinds(self) -> tuple[str, ...]:
        return tuple(self.sessions)

    def submit(self, query: np.ndarray, kind: str | None = None) -> int:
        """Admit one query; returns its request id without blocking."""
        if kind is None:
            if len(self.sessions) != 1:
                raise ValueError(f"ambiguous kind; service has {self.kinds}")
            kind = next(iter(self.sessions))
        if kind not in self._batchers:
            raise KeyError(f"no session {kind!r}; available: {self.kinds}")
        rid = self._next_rid
        self._next_rid += 1
        self._batchers[kind].submit(rid, query, self._clock())
        return rid

    def pending(self, kind: str | None = None) -> int:
        if kind is not None:
            return len(self._batchers[kind])
        return sum(len(b) for b in self._batchers.values())

    def _dispatch(self, kind: str, block: QueryBlock) -> dict[int, SearchResult]:
        session = self.sessions[kind]
        tr = obs.tracer()
        t0 = self._clock()
        with tr.span(
            "serve.dispatch", "serve",
            kind=kind, n_real=block.n_real, n_padded=block.n_padded,
            trigger=block.trigger,
        ):
            state = session.search(block.queries)
        latency = self._clock() - t0
        self.metrics.append(
            BatchRecord(
                kind=kind,
                n_real=block.n_real,
                n_padded=block.n_padded,
                trigger=block.trigger,
                queue_wait_s=block.closed_at - block.oldest_arrival,
                latency_s=latency,
            )
        )
        met = self._registry if self._registry is not None else obs.metrics()
        met.counter("serve.requests").inc(block.n_real)
        met.counter("serve.batches").inc()
        met.histogram("serve.batch_size", bounds=_BATCH_BOUNDS).observe(block.n_real)
        met.histogram("serve.queue_wait_s").observe(
            block.closed_at - block.oldest_arrival
        )
        met.histogram("serve.latency_s").observe(latency)
        # per-request lifecycle spans (enqueue → reply), recorded at reply
        # time on the service clock (== the tracer clock in production)
        done = self._clock()
        for rid, arrival in zip(block.rids, block.arrivals):
            tr.record("serve.request", arrival, done, "serve", rid=rid, kind=kind)
        scores = unpad_results(np.asarray(state.scores), block.n_real)
        ids = unpad_results(np.asarray(state.ids), block.n_real)
        return {
            rid: SearchResult(rid=rid, scores=scores[row], ids=ids[row])
            for row, rid in enumerate(block.rids)
        }

    def poll(self) -> dict[int, SearchResult]:
        """Dispatch every block whose size/deadline trigger has fired."""
        out: dict[int, SearchResult] = {}
        for kind, batcher in self._batchers.items():
            while (block := batcher.pop_block(self._clock())) is not None:
                out.update(self._dispatch(kind, block))
        return out

    def drain(self) -> dict[int, SearchResult]:
        """Force-flush all pending queries (shutdown / end of stream)."""
        out: dict[int, SearchResult] = {}
        for kind, batcher in self._batchers.items():
            for block in batcher.drain(self._clock()):
                out.update(self._dispatch(kind, block))
        return out

    def next_deadline(self) -> float | None:
        """Earliest pending deadline across kinds (event-loop sleep hint)."""
        deadlines = [
            d for b in self._batchers.values() if (d := b.next_deadline()) is not None
        ]
        return min(deadlines) if deadlines else None
