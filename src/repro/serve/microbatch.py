"""Admission queue + microbatcher: turn a request stream into query blocks.

Claim C1 makes MIREX a natural *service*: per-query scan cost falls as the
query block grows, so the serving layer's job is to hold arriving queries
just long enough to form a big block, then scan once for all of them. Two
triggers close a block:

* **size** — the queue reached the effective block size (``max_batch``
  capped by the bucket ladder, see below); fire immediately, waiting
  longer buys nothing.
* **deadline** — the *oldest* queued request has waited ``max_delay``
  seconds; fire with whatever is queued (tail-latency bound).

Blocks are padded up to MXU-friendly bucket sizes (powers of two, at least
``min_bucket``) so the jitted scan handlers retrace once per bucket instead
of once per distinct batch size. Padding rows use a sentinel query (PAD
tokens / zero vectors) whose results are dropped by :func:`unpad_results`.

The bucket ladder is **capped** at ``max_bucket`` (the measured per-query
sweet spot — past it per-query scan cost *rises* again, the @256
amortization cliff), and a backlog larger than the cap is split into
several <= cap blocks instead of padding up a rare giant bucket: the
ladder stays finite (bounded retraces) and every dispatch stays at or
below the sweet spot. Splitting only regroups dispatches — per-request
results are byte-identical whatever the grouping.

Time is injected (every mutating call takes ``now``) so trigger logic is
deterministic under test; the service layer supplies a real clock.

The trigger knobs resolve from the active :class:`repro.tune.TuningConfig`
exactly once, at construction — never on the per-request enqueue or
per-block close paths — and again only on an explicit :meth:`retune`
(the adaptive policy's write surface).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.pipeline import next_pow2
from repro.tune import config as tune_config


def bucket_size(
    n: int, *, min_bucket: int | None = None, max_bucket: int | None = None
) -> int:
    """Padded batch size for ``n`` queries: next power of two, floored at
    ``min_bucket`` and capped at ``max_bucket`` (the ladder cap; a block
    *larger* than the cap — which the batcher never produces — pads to its
    own power of two so padding can never truncate real rows).

    ``None`` knobs resolve from the active tuning config — hot paths
    (the batcher) pass both explicitly, so this per-call resolution only
    happens on direct standalone calls.
    """
    if n < 1:
        raise ValueError("empty batch has no bucket")
    if min_bucket is None or max_bucket is None:
        cfg = tune_config.resolve(None)
        if min_bucket is None:
            min_bucket = cfg.serve_min_bucket
        if max_bucket is None:
            max_bucket = cfg.serve_max_bucket
    size = max(min_bucket, next_pow2(n))
    if max_bucket is not None and n <= max_bucket:
        size = min(size, max_bucket)
    return size


def pad_rows(queries: np.ndarray, n_target: int, pad_value) -> np.ndarray:
    """Pad the leading (batch) dim with sentinel rows up to ``n_target``."""
    n = queries.shape[0]
    if n > n_target:
        raise ValueError(f"batch {n} exceeds target {n_target}")
    if n == n_target:
        return queries
    pad = np.full((n_target - n, *queries.shape[1:]), pad_value, queries.dtype)
    return np.concatenate([queries, pad], axis=0)


def unpad_results(arr, n_real: int):
    """Drop the rows that belong to padding queries."""
    return arr[:n_real]


@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """One admitted query: tokens ``[L]`` (lexical) or a vector ``[dim]``."""

    rid: int
    query: np.ndarray
    arrival: float


@dataclasses.dataclass(frozen=True)
class QueryBlock:
    """A closed microbatch, padded and ready to scan."""

    queries: np.ndarray  # [n_padded, ...] — rows past n_real are sentinels
    rids: tuple[int, ...]
    n_real: int
    trigger: str  # "size" | "deadline" | "flush"
    closed_at: float
    oldest_arrival: float
    arrivals: tuple[float, ...] = ()  # per-request admission times, rid-aligned

    @property
    def n_padded(self) -> int:
        return self.queries.shape[0]


class Microbatcher:
    """Deadline/size-triggered admission queue for one query family.

    ``pad_value`` fills both the sentinel rows of a short batch and must be
    inert under the scorer (PAD_TOKEN for lexical queries, 0.0 for dense
    vectors — both score every document identically, and their rows are
    discarded before results leave the service).

    The trigger knobs default (``None``) from the active
    :class:`repro.tune.TuningConfig` — ``serve_max_batch`` /
    ``serve_max_delay_s`` / ``serve_min_bucket`` / ``serve_max_bucket`` —
    resolved **once here** (and re-resolved only by :meth:`retune`), never
    per enqueue. The effective per-block size is
    ``min(max_batch, max_bucket)``: asking for a bigger block than the
    bucket-ladder cap would only pad past the sweet spot.
    """

    def __init__(
        self,
        *,
        max_batch: int | None = None,
        max_delay: float | None = None,
        min_bucket: int | None = None,
        max_bucket: int | None = None,
        pad_value=0,
        tuning=None,
    ):
        cfg = tune_config.resolve(tuning)
        self.pad_value = pad_value
        self._pending: list[SearchRequest] = []
        self._apply_knobs(
            max_batch=cfg.serve_max_batch if max_batch is None else max_batch,
            max_delay=cfg.serve_max_delay_s if max_delay is None else max_delay,
            min_bucket=cfg.serve_min_bucket if min_bucket is None else min_bucket,
            max_bucket=cfg.serve_max_bucket if max_bucket is None else max_bucket,
        )

    def _apply_knobs(
        self,
        *,
        max_batch: int,
        max_delay: float,
        min_bucket: int,
        max_bucket: int | None,
    ) -> None:
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_delay < 0:
            raise ValueError("max_delay must be >= 0")
        if min_bucket < 1:
            raise ValueError("min_bucket must be >= 1")
        if max_bucket is not None and max_bucket < min_bucket:
            raise ValueError(
                f"max_bucket {max_bucket} below min_bucket {min_bucket}"
            )
        self.max_batch = max_batch
        self.max_delay = max_delay
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        # one block never exceeds the ladder cap: oversize backlogs split
        self._block_cap = (
            max_batch if max_bucket is None else min(max_batch, max_bucket)
        )

    def retune(
        self,
        *,
        max_batch: int | None = None,
        max_delay: float | None = None,
        min_bucket: int | None = None,
        max_bucket: int | object = "keep",
        tuning=None,
    ) -> dict:
        """Rewrite the trigger knobs in place (the adaptive policy's write
        surface; also the only other point where the tuning config is
        consulted). ``None`` keeps the current value except for
        ``max_bucket``, where ``None`` means *uncap* (pass nothing to keep).
        With ``tuning=`` given, unspecified knobs re-resolve from that
        config instead. Returns the effective knob table."""
        if tuning is not None:
            cfg = tune_config.resolve(tuning)
            base = {
                "max_batch": cfg.serve_max_batch,
                "max_delay": cfg.serve_max_delay_s,
                "min_bucket": cfg.serve_min_bucket,
                "max_bucket": cfg.serve_max_bucket,
            }
        else:
            base = {
                "max_batch": self.max_batch,
                "max_delay": self.max_delay,
                "min_bucket": self.min_bucket,
                "max_bucket": self.max_bucket,
            }
        self._apply_knobs(
            max_batch=base["max_batch"] if max_batch is None else max_batch,
            max_delay=base["max_delay"] if max_delay is None else max_delay,
            min_bucket=base["min_bucket"] if min_bucket is None else min_bucket,
            max_bucket=base["max_bucket"] if max_bucket == "keep" else max_bucket,
        )
        return {
            "serve_max_batch": self.max_batch,
            "serve_max_delay_s": self.max_delay,
            "serve_min_bucket": self.min_bucket,
            "serve_max_bucket": self.max_bucket,
        }

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, rid: int, query: np.ndarray, now: float) -> None:
        self._pending.append(SearchRequest(rid=rid, query=np.asarray(query), arrival=now))

    def _trigger(self, now: float) -> str | None:
        if not self._pending:
            return None
        if len(self._pending) >= self._block_cap:
            return "size"
        # same expression as next_deadline(): an event loop that sleeps to
        # exactly the returned deadline must observe the trigger as fired
        # (now - arrival >= max_delay differs from this in float rounding)
        if now >= self._pending[0].arrival + self.max_delay:
            return "deadline"
        return None

    def ready(self, now: float) -> bool:
        return self._trigger(now) is not None

    def next_deadline(self) -> float | None:
        """Absolute time at which the oldest request forces a flush."""
        if not self._pending:
            return None
        return self._pending[0].arrival + self.max_delay

    def pop_block(self, now: float, *, force: bool = False) -> QueryBlock | None:
        """Close and return the next block, or None if no trigger fired.

        A backlog larger than the block cap yields a <= cap block and
        leaves the remainder queued — the remainder's oldest arrival keeps
        its (already expired) deadline, so the next ``pop_block`` fires
        again immediately: oversize backlogs drain as several sweet-spot
        blocks within one poll loop.
        """
        trigger = "flush" if (force and self._pending) else self._trigger(now)
        if trigger is None:
            return None
        take, self._pending = (
            self._pending[: self._block_cap],
            self._pending[self._block_cap :],
        )
        stacked = np.stack([r.query for r in take], axis=0)
        padded = pad_rows(
            stacked,
            bucket_size(
                len(take), min_bucket=self.min_bucket, max_bucket=self.max_bucket
            ),
            self.pad_value,
        )
        return QueryBlock(
            queries=padded,
            rids=tuple(r.rid for r in take),
            n_real=len(take),
            trigger=trigger,
            closed_at=now,
            oldest_arrival=take[0].arrival,
            arrivals=tuple(r.arrival for r in take),
        )

    def drain(self, now: float) -> list[QueryBlock]:
        """Flush everything pending into (possibly several) blocks."""
        blocks = []
        while self._pending:
            blocks.append(self.pop_block(now, force=True))
        return blocks
