"""Admission queue + microbatcher: turn a request stream into query blocks.

Claim C1 makes MIREX a natural *service*: per-query scan cost falls as the
query block grows, so the serving layer's job is to hold arriving queries
just long enough to form a big block, then scan once for all of them. Two
triggers close a block:

* **size** — the queue reached ``max_batch`` queries (the amortization
  target); fire immediately, waiting longer buys nothing.
* **deadline** — the *oldest* queued request has waited ``max_delay``
  seconds; fire with whatever is queued (tail-latency bound).

Blocks are padded up to MXU-friendly bucket sizes (powers of two, at least
``min_bucket``) so the jitted scan handlers retrace once per bucket instead
of once per distinct batch size. Padding rows use a sentinel query (PAD
tokens / zero vectors) whose results are dropped by :func:`unpad_results`.

Time is injected (every mutating call takes ``now``) so trigger logic is
deterministic under test; the service layer supplies a real clock.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.pipeline import next_pow2
from repro.tune import config as tune_config


def bucket_size(n: int, *, min_bucket: int | None = None) -> int:
    """Padded batch size for ``n`` queries: next power of two, floored
    (``min_bucket=None`` = the active tuning's ``serve_min_bucket``)."""
    if n < 1:
        raise ValueError("empty batch has no bucket")
    if min_bucket is None:
        min_bucket = tune_config.resolve(None).serve_min_bucket
    return max(min_bucket, next_pow2(n))


def pad_rows(queries: np.ndarray, n_target: int, pad_value) -> np.ndarray:
    """Pad the leading (batch) dim with sentinel rows up to ``n_target``."""
    n = queries.shape[0]
    if n > n_target:
        raise ValueError(f"batch {n} exceeds target {n_target}")
    if n == n_target:
        return queries
    pad = np.full((n_target - n, *queries.shape[1:]), pad_value, queries.dtype)
    return np.concatenate([queries, pad], axis=0)


def unpad_results(arr, n_real: int):
    """Drop the rows that belong to padding queries."""
    return arr[:n_real]


@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """One admitted query: tokens ``[L]`` (lexical) or a vector ``[dim]``."""

    rid: int
    query: np.ndarray
    arrival: float


@dataclasses.dataclass(frozen=True)
class QueryBlock:
    """A closed microbatch, padded and ready to scan."""

    queries: np.ndarray  # [n_padded, ...] — rows past n_real are sentinels
    rids: tuple[int, ...]
    n_real: int
    trigger: str  # "size" | "deadline" | "flush"
    closed_at: float
    oldest_arrival: float
    arrivals: tuple[float, ...] = ()  # per-request admission times, rid-aligned

    @property
    def n_padded(self) -> int:
        return self.queries.shape[0]


class Microbatcher:
    """Deadline/size-triggered admission queue for one query family.

    ``pad_value`` fills both the sentinel rows of a short batch and must be
    inert under the scorer (PAD_TOKEN for lexical queries, 0.0 for dense
    vectors — both score every document identically, and their rows are
    discarded before results leave the service).

    The three trigger knobs default (``None``) from the active
    :class:`repro.tune.TuningConfig` — ``serve_max_batch`` /
    ``serve_max_delay_s`` / ``serve_min_bucket``, whose defaults are the
    historical 64 / 5 ms / 8.
    """

    def __init__(
        self,
        *,
        max_batch: int | None = None,
        max_delay: float | None = None,
        min_bucket: int | None = None,
        pad_value=0,
        tuning=None,
    ):
        cfg = tune_config.resolve(tuning)
        max_batch = cfg.serve_max_batch if max_batch is None else max_batch
        max_delay = cfg.serve_max_delay_s if max_delay is None else max_delay
        min_bucket = cfg.serve_min_bucket if min_bucket is None else min_bucket
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_delay < 0:
            raise ValueError("max_delay must be >= 0")
        self.max_batch = max_batch
        self.max_delay = max_delay
        self.min_bucket = min_bucket
        self.pad_value = pad_value
        self._pending: list[SearchRequest] = []

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, rid: int, query: np.ndarray, now: float) -> None:
        self._pending.append(SearchRequest(rid=rid, query=np.asarray(query), arrival=now))

    def _trigger(self, now: float) -> str | None:
        if not self._pending:
            return None
        if len(self._pending) >= self.max_batch:
            return "size"
        if now - self._pending[0].arrival >= self.max_delay:
            return "deadline"
        return None

    def ready(self, now: float) -> bool:
        return self._trigger(now) is not None

    def next_deadline(self) -> float | None:
        """Absolute time at which the oldest request forces a flush."""
        if not self._pending:
            return None
        return self._pending[0].arrival + self.max_delay

    def pop_block(self, now: float, *, force: bool = False) -> QueryBlock | None:
        """Close and return the next block, or None if no trigger fired."""
        trigger = "flush" if (force and self._pending) else self._trigger(now)
        if trigger is None:
            return None
        take, self._pending = (
            self._pending[: self.max_batch],
            self._pending[self.max_batch :],
        )
        stacked = np.stack([r.query for r in take], axis=0)
        padded = pad_rows(
            stacked, bucket_size(len(take), min_bucket=self.min_bucket), self.pad_value
        )
        return QueryBlock(
            queries=padded,
            rids=tuple(r.rid for r in take),
            n_real=len(take),
            trigger=trigger,
            closed_at=now,
            oldest_arrival=take[0].arrival,
            arrivals=tuple(r.arrival for r in take),
        )

    def drain(self, now: float) -> list[QueryBlock]:
        """Flush everything pending into (possibly several) blocks."""
        blocks = []
        while self._pending:
            blocks.append(self.pop_block(now, force=True))
        return blocks
