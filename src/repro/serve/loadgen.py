"""Open-loop sustained-load generation for the retrieval service.

Closed-loop benchmarks (issue a batch, wait, issue the next — the C1
sweep) can never see the serving knee: the client self-throttles, so the
queue never grows. An **open-loop** generator issues requests at their
scheduled arrival times *regardless of completions* — past the capacity
knee the backlog grows without bound and tail latency explodes, which is
exactly the regime the admission controller and the adaptive policy
exist for.

The generator is a **discrete-event simulation on a virtual clock**, not
a wall-clock threadpool: arrivals are stamped at their nominal schedule
times, and the server's clock advances by the *real, measured* scan time
of every dispatched block (:class:`MeteredSession` wraps the real session
and meters each ``search`` with ``perf_counter``). Real kernel latencies,
deterministic interleaving — the same seed replays the same run, and the
latency of every request is exact (arrival stamp → metered completion),
not quantized by poll-loop sleeps.

Mechanically the loop interleaves two event sources in time order:

* the **arrival schedule** (:func:`poisson_schedule` /
  :func:`burst_schedule`, seeded) — the clock is rewound to the nominal
  arrival time to stamp the submit (the windowed obs histograms tolerate
  rewinds by design), then restored to server time;
* the service's **next deadline** — a block whose deadline expires while
  the server is busy dispatches as soon as the server frees up, exactly
  like a real single-threaded event loop.

Dispatches go through ``service.poll(limit=1)`` so every block's
completion time is read off the virtual clock individually.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Sequence

import numpy as np

from repro.serve.admission import Admitted, Blocked, Shed
from repro.serve.service import RetrievalService, SearchResult


def poisson_schedule(
    qps: float, n: int, *, seed: int = 0, start: float = 0.0
) -> np.ndarray:
    """``n`` arrival times of a Poisson process at ``qps`` (seeded)."""
    if qps <= 0:
        raise ValueError("qps must be positive")
    rng = np.random.default_rng(seed)
    return start + np.cumsum(rng.exponential(1.0 / qps, size=n))


def burst_schedule(
    qps: float,
    n: int,
    *,
    seed: int = 0,
    start: float = 0.0,
    burst_factor: float = 4.0,
    duty: float = 0.25,
    period_s: float = 1.0,
) -> np.ndarray:
    """Bursty arrivals: a Poisson process whose rate alternates each
    ``period_s`` between ``qps * burst_factor`` (for the ``duty`` fraction
    of the period) and a floor rate — same seed, same schedule. The *mean*
    rate is approximately ``qps`` when ``burst_factor * duty <= 1``."""
    if qps <= 0:
        raise ValueError("qps must be positive")
    if not 0.0 < duty < 1.0:
        raise ValueError(f"duty must be in (0,1): {duty}")
    if burst_factor < 1.0:
        raise ValueError(f"burst_factor must be >= 1: {burst_factor}")
    rng = np.random.default_rng(seed)
    high = qps * burst_factor
    # the off-phase rate that keeps the long-run mean at qps, floored so
    # the process never stalls entirely
    low = max(qps * (1.0 - burst_factor * duty) / (1.0 - duty), qps * 0.05)
    out = np.empty(n)
    t = start
    for i in range(n):
        rate = high if (t % period_s) < duty * period_s else low
        t += rng.exponential(1.0 / rate)
        out[i] = t
    return out


class VirtualClock:
    """The injectable clock of a simulated serving run. ``advance`` moves
    forward (metered scan time); ``rewind`` is permitted only for stamping
    an arrival that nominally happened while the server was busy."""

    __slots__ = ("t",)

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"cannot advance by {dt}")
        self.t += dt

    def set(self, t: float) -> None:
        self.t = float(t)


class MeteredSession:
    """Wrap a real session so every ``search`` advances the virtual clock
    by its real, host-synchronized wall time. Everything else (pad_value,
    kind, k, n_docs, ...) delegates to the wrapped session."""

    def __init__(self, session, clock: VirtualClock, *, scale: float = 1.0):
        if scale <= 0:
            raise ValueError("scale must be positive")
        self._session = session
        self._clock = clock
        self._scale = scale

    def __getattr__(self, name):
        return getattr(self._session, name)

    def search(self, queries):
        t0 = time.perf_counter()
        state = self._session.search(queries)
        # force the device work to completion so the metered time is the
        # real scan latency, not the async dispatch cost
        np.asarray(state.scores)
        self._clock.advance((time.perf_counter() - t0) * self._scale)
        return state


@dataclasses.dataclass
class OpenLoopResult:
    """One sustained-load run: exact per-request outcomes on the virtual
    timeline. ``rid_of[i]`` maps offered-request index → rid (admitted
    requests only); sheds carry the typed admission outcome."""

    arrivals: np.ndarray  # [n_offered] nominal arrival times
    rid_of: dict[int, int]
    results: dict[int, SearchResult]
    completions: dict[int, float]  # rid -> virtual completion time
    shed: list[tuple[int, Shed | Blocked]]
    duration_s: float

    @property
    def n_offered(self) -> int:
        return len(self.arrivals)

    @property
    def n_completed(self) -> int:
        return len(self.completions)

    @property
    def shed_rate(self) -> float:
        return len(self.shed) / max(self.n_offered, 1)

    @property
    def offered_qps(self) -> float:
        span = self.arrivals[-1] - self.arrivals[0] if len(self.arrivals) > 1 else 0.0
        return (self.n_offered - 1) / span if span > 0 else float("inf")

    def latencies(self) -> np.ndarray:
        """Completed requests' admission→reply latency, seconds, exact."""
        arrival_of_rid = {
            rid: self.arrivals[i] for i, rid in self.rid_of.items()
        }
        return np.array(
            [t - arrival_of_rid[rid] for rid, t in sorted(self.completions.items())]
        )

    def latency_quantiles(self) -> dict[str, float]:
        lat = self.latencies()
        if lat.size == 0:
            return {"p50_ms": float("nan"), "p95_ms": float("nan"), "p99_ms": float("nan")}
        return {
            "p50_ms": float(np.quantile(lat, 0.50) * 1e3),
            "p95_ms": float(np.quantile(lat, 0.95) * 1e3),
            "p99_ms": float(np.quantile(lat, 0.99) * 1e3),
        }


def run_open_loop(
    service: RetrievalService,
    clock: VirtualClock,
    schedule: Sequence[float],
    queries: np.ndarray,
    *,
    kind: str | None = None,
    lane_of: Callable[[int], str] | None = None,
    tenant_of: Callable[[int], str] | None = None,
) -> OpenLoopResult:
    """Drive ``service`` (built on ``clock`` and metered sessions) through
    the arrival ``schedule``: request ``i`` submits ``queries[i]`` at
    ``schedule[i]``. Returns exact per-request outcomes.

    The event loop processes, in virtual-time order, whichever comes first
    of the next arrival and the server's next possible dispatch (a trigger
    that has fired, or the next microbatch deadline — either way no
    earlier than the time the server frees up). Arrivals that nominally
    land *during* a scan are enqueued before the next block closes, so
    queue depth at admission time is the real backlog — a trigger that
    expires while the server is busy fires the moment it frees up, and one
    block dispatches per event so every completion lands at its own
    metered clock reading.
    """
    schedule = np.asarray(schedule, dtype=float)
    n = len(schedule)
    if len(queries) < n:
        raise ValueError(f"{n} arrivals but only {len(queries)} queries")
    if n and np.any(np.diff(schedule) < 0):
        raise ValueError("schedule must be sorted")

    rid_of: dict[int, int] = {}
    results: dict[int, SearchResult] = {}
    completions: dict[int, float] = {}
    shed: list[tuple[int, Shed | Blocked]] = []

    start_t = clock.t
    server_t = clock.t
    i = 0
    while i < n or service.pending() > 0:
        next_arrival = schedule[i] if i < n else math.inf
        ra = service.ready_at(server_t)
        dispatch_at = math.inf if ra is None else max(server_t, ra)
        if math.isinf(next_arrival) and math.isinf(dispatch_at):
            # pending work but no trigger will ever fire (infinite
            # max_delay): force-flush at server time
            clock.set(server_t)
            for rid, res in service.drain().items():
                results[rid] = res
                completions[rid] = clock.t
            break
        if next_arrival <= dispatch_at:
            # stamp the submit at the *nominal* arrival time, even when the
            # server is currently busy past it (that is what open-loop
            # means); then restore server time
            clock.set(next_arrival)
            outcome = service.try_submit(
                queries[i],
                kind,
                tenant=tenant_of(i) if tenant_of is not None else "default",
                lane=lane_of(i) if lane_of is not None else "interactive",
            )
            if isinstance(outcome, Admitted):
                rid_of[i] = outcome.rid
            else:
                shed.append((i, outcome))
            i += 1
            clock.set(server_t)
            continue
        # dispatch exactly one block at the trigger time (or as soon as the
        # server is free); the metered scan advances the clock, and any
        # arrivals that nominally landed during it are enqueued (above,
        # with their true stamps) before the next block closes
        clock.set(dispatch_at)
        ready = service.poll(limit=1)
        done_t = clock.t
        for rid, res in ready.items():
            results[rid] = res
            completions[rid] = done_t
        server_t = clock.t

    return OpenLoopResult(
        arrivals=schedule,
        rid_of=rid_of,
        results=results,
        completions=completions,
        shed=shed,
        duration_s=clock.t - start_t,
    )
