"""Resident-corpus sessions: the corpus lives on device, queries stream by.

A session owns one scorer kind's device-resident state — token matrices +
collection statistics for lexical scans, the vector matrix for dense scans —
plus a jitted scan handler. The handler is traced once per padded batch
bucket (``jax.jit`` caches by shape; the microbatcher's power-of-two
buckets bound the number of traces), so steady-state serving never
recompiles. This is the paper's "keep the collection on the cluster,
ship only queries and top-k back" discipline, with HBM as the cluster —
and with a real mesh as the cluster for :class:`ShardedLexicalSession`,
which keeps the corpus resident *sharded* and reduces every microbatch
through the `repro.cluster` merge contract.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import cluster
from repro.core import anchors, packing, scan, topk
from repro.core.scoring import PAD_TOKEN, CollectionStats, Scorer, get_scorer
from repro.tune import config as tune_config


def _pack_resident(tokens, lengths, *, vocab: int | None, mode: str | None):
    """Resolve the resident corpus representation for a lexical session.

    ``mode=None`` follows the active tuning's ``token_pack`` knob. Returns
    the plain int32 ``(tokens, lengths)`` tuple, or a ``PackedCorpus``
    whose device arrays hold the narrow representation — resident HBM drops
    by the pack ratio, so bigger corpora fit resident, and the scan decodes
    per chunk/tile with bit-identical results. Packing needs the vocab
    (for the sentinel); without one we stay unpacked rather than fail.
    """
    if mode is None:
        mode = tune_config.active().config.token_pack
    t32 = jnp.asarray(tokens, jnp.int32)
    l32 = jnp.asarray(lengths, jnp.int32)
    if mode == "none" or vocab is None:
        return (t32, l32)
    packed = packing.pack_corpus(
        np.asarray(tokens, np.int32), np.asarray(lengths, np.int32),
        vocab=vocab, mode=mode,
    )
    if not isinstance(packed, packing.PackedCorpus):
        return (t32, l32)
    return jax.tree.map(jnp.asarray, packed)


class LexicalSession:
    """Raw-token scan service state for one lexical scorer (ql_lm/bm25/...).

    The fold path is :func:`repro.core.scan.search_local`'s chunked scan —
    term frequencies recomputed from raw text per block, no index. The tf
    reduction is tiled over document positions on every path, so per-chunk
    memory stays ``O(n_q·L_q·chunk)`` however large the batch grows (the
    serve-path amortization fix: the seed rank-4 form made big batches
    *slower*, inverting claim C1). ``use_kernel=None`` resolves from the
    Pallas backend — the fused lexical kernel where it compiles (TPU), the
    tiled pure-JAX fold elsewhere; pass True/False to force.
    """

    kind = "lexical"
    pad_value = PAD_TOKEN

    def __init__(
        self,
        tokens: np.ndarray,
        lengths: np.ndarray,
        scorer: Scorer | str,
        *,
        k: int,
        chunk_size: int,
        stats: CollectionStats | None = None,
        vocab: int | None = None,
        use_kernel: bool | None = None,
        token_pack: str | None = None,
    ):
        self.scorer = get_scorer(scorer) if isinstance(scorer, str) else scorer
        if self.scorer.kind != "lexical":
            raise ValueError(f"scorer {self.scorer.name!r} is not lexical")
        self.use_kernel = use_kernel  # None = auto-resolve at each (re)trace
        self.k = k
        self.chunk_size = chunk_size
        tokens32 = jnp.asarray(tokens, jnp.int32)
        self._lengths = jnp.asarray(lengths, jnp.int32)
        if tokens32.shape[0] % chunk_size:
            raise ValueError(
                f"{tokens32.shape[0]} docs not divisible by chunk {chunk_size}"
            )
        if stats is None:
            if vocab is None:
                raise ValueError("need stats or vocab to derive collection statistics")
            stats = anchors.collection_stats(
                tokens32, self._lengths, vocab=vocab, chunk_size=chunk_size
            )
        self._stats = jax.tree.map(jnp.asarray, stats)
        # the resident corpus: packed when the knob (argument or active
        # tuning) says so — the int32 matrix then never stays on device,
        # only the narrow representation does. Stats above were computed
        # from the raw tokens, pack-invariantly. The sentinel needs the
        # vocab; derive it from the stats' cf table when not passed.
        if vocab is None:
            vocab = int(self._stats.cf.shape[0])
        self._docs = _pack_resident(tokens, lengths, vocab=vocab, mode=token_pack)

        scorer_, k_, chunk_ = self.scorer, k, chunk_size
        docs, st = self._docs, self._stats

        @jax.jit
        def _handle(q):
            # resolved at trace time: set_kernel_backend clears jit caches,
            # so a backend flip re-resolves on the next call (ops.py contract)
            kern = use_kernel
            if kern is None:
                from repro.kernels import ops

                kern = ops.kernel_backend() == "compiled"
            return scan.search_local(
                q, docs, scorer_, k=k_, chunk_size=chunk_, stats=st, use_kernel=kern
            )

        self._handle = _handle

    @property
    def n_docs(self) -> int:
        return int(self._lengths.shape[0])

    @property
    def pack_mode(self) -> str:
        """Resolved resident storage: ``none`` or the PackSpec mode."""
        if isinstance(self._docs, packing.PackedCorpus):
            return self._docs.spec.mode
        return "none"

    @property
    def resident_corpus_bytes(self) -> int:
        """Device bytes held by the resident corpus (tokens + lengths)."""
        return packing.tree_nbytes(self._docs)

    def search(self, q_block: np.ndarray) -> topk.TopKState:
        """Scan one padded query block; blocks until results are on host."""
        return jax.block_until_ready(self._handle(jnp.asarray(q_block, jnp.int32)))


class ShardedLexicalSession:
    """Shard-resident lexical session: the corpus lives *sharded* on a mesh.

    The paper's cluster as a service: each device holds one contiguous
    corpus shard (placed once at construction via ``NamedSharding`` over the
    scan axes), microbatches of queries are replicated to every shard, each
    shard runs the same map fold as the single-host session
    (`cluster.map_shard`, kernel-dispatched), and shard results reduce
    through the cluster merge contract (`topk.merge_across_lex`) — so a
    sharded session's rankings are bit-identical to the resident single-host
    session's, whatever the mesh shape. Drop-in for ``LexicalSession`` under
    `repro.serve.service.RetrievalService` (same ``kind``/``pad_value``/
    ``search`` surface, same ``[n_q, k]`` result shape).

    The mesh program comes from the shared `cluster.search_mesh` cache
    (memoized on mesh/axes/grid config/corpus size), so a second session
    over the same resident corpus — or one rebuilt after a service restart —
    reuses the already-traced program instead of compiling its own, the same
    compile-once discipline the pipelined scan executor applies to shard
    folds (`cluster.segment_fold`).

    ``use_kernel=None`` resolves from the Pallas backend once, at
    construction (the mesh program is built here, not per call).
    """

    kind = "lexical"
    pad_value = PAD_TOKEN

    def __init__(
        self,
        mesh: Mesh,
        tokens: np.ndarray,
        lengths: np.ndarray,
        scorer: Scorer | str,
        *,
        k: int,
        chunk_size: int,
        stats: CollectionStats | None = None,
        vocab: int | None = None,
        use_kernel: bool | None = None,
        axis_names: tuple[str, ...] | None = None,
        token_pack: str | None = None,
    ):
        self.scorer = get_scorer(scorer) if isinstance(scorer, str) else scorer
        if self.scorer.kind != "lexical":
            raise ValueError(f"scorer {self.scorer.name!r} is not lexical")
        if use_kernel is None:
            from repro.kernels import ops

            use_kernel = ops.kernel_backend() == "compiled"
        self.use_kernel = use_kernel
        self.k = k
        self.chunk_size = chunk_size
        self.mesh = mesh
        if axis_names is None:
            axis_names = cluster.mesh_scan_axes(mesh)
        self.axis_names = axis_names
        # the plan validates the geometry (equal chunk-aligned shards over
        # the scan axes) even though placement is by NamedSharding here
        self.plan = cluster.plan_for_mesh(
            mesh, int(np.asarray(tokens).shape[0]), chunk_size=chunk_size,
            axis_names=axis_names,
        )
        doc_sharding = NamedSharding(mesh, P(axis_names))
        repl = NamedSharding(mesh, P())
        if stats is None:
            if vocab is None:
                raise ValueError("need stats or vocab to derive collection statistics")
            stats = anchors.collection_stats(
                jnp.asarray(tokens, jnp.int32), jnp.asarray(lengths, jnp.int32),
                vocab=vocab, chunk_size=chunk_size,
            )
        self._stats = jax.tree.map(lambda x: jax.device_put(jnp.asarray(x), repl), stats)
        if vocab is None:
            vocab = int(self._stats.cf.shape[0])
        # both corpus leaves (packed or not) share the doc leading dim, so
        # one PartitionSpec places either representation shard-resident
        self._docs = jax.tree.map(
            lambda x: jax.device_put(x, doc_sharding),
            _pack_resident(tokens, lengths, vocab=vocab, mode=token_pack),
        )
        self._lengths = (
            self._docs.lengths
            if isinstance(self._docs, packing.PackedCorpus)
            else self._docs[1]
        )

        self._fn = cluster.search_mesh(
            mesh,
            jnp.zeros((1, 1), jnp.int32),  # query prototype: specs need structure only
            self._docs,
            self.scorer,
            k=k,
            chunk_size=chunk_size,
            stats=self._stats,
            axis_names=axis_names,
            use_kernel=use_kernel,
        )

    @property
    def n_docs(self) -> int:
        return int(self._lengths.shape[0])

    @property
    def pack_mode(self) -> str:
        """Resolved resident storage: ``none`` or the PackSpec mode."""
        if isinstance(self._docs, packing.PackedCorpus):
            return self._docs.spec.mode
        return "none"

    @property
    def resident_corpus_bytes(self) -> int:
        """Device bytes held by the resident corpus (tokens + lengths)."""
        return packing.tree_nbytes(self._docs)

    def search(self, q_block: np.ndarray) -> topk.TopKState:
        """Scan one padded query block across all shards; blocks until the
        merged (replicated) top-k is on host."""
        state = self._fn(
            jnp.asarray(q_block, jnp.int32), self._docs, self._stats
        )
        # one scorer -> drop the grid axis: service rows are [n_q, k]
        return jax.block_until_ready(
            topk.TopKState(scores=state.scores[0], ids=state.ids[0])
        )


class DenseSession:
    """Vector-scan service state; the hot path is the Pallas score+top-k
    kernel (``use_kernel=True``), falling back to the pure-JAX chunked fold.
    """

    kind = "dense"
    pad_value = 0.0

    def __init__(
        self,
        vectors: np.ndarray,
        scorer: Scorer | str = "dense_dot",
        *,
        k: int,
        chunk_size: int,
        use_kernel: bool = True,
    ):
        self.scorer = get_scorer(scorer) if isinstance(scorer, str) else scorer
        if self.scorer.kind != "dense":
            raise ValueError(f"scorer {self.scorer.name!r} is not dense")
        self.k = k
        self.chunk_size = chunk_size
        self.use_kernel = use_kernel
        self._vectors = jnp.asarray(vectors, jnp.float32)
        if self._vectors.shape[0] % chunk_size:
            raise ValueError(
                f"{self._vectors.shape[0]} docs not divisible by chunk {chunk_size}"
            )

        scorer_, k_, chunk_, kern = self.scorer, k, chunk_size, use_kernel
        vecs = self._vectors

        @jax.jit
        def _handle(q):
            return scan.search_local(
                q, vecs, scorer_, k=k_, chunk_size=chunk_, use_kernel=kern
            )

        self._handle = _handle

    @property
    def n_docs(self) -> int:
        return int(self._vectors.shape[0])

    @property
    def dim(self) -> int:
        return int(self._vectors.shape[1])

    def search(self, q_block: np.ndarray) -> topk.TopKState:
        return jax.block_until_ready(self._handle(jnp.asarray(q_block, jnp.float32)))
