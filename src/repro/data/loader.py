"""Sharded, restart-safe host->device batch feed.

At production scale the input pipeline must (a) place each batch shard
directly on its devices (no host gather), (b) be *deterministic given the
step*, so a job restarted from a checkpoint at step N consumes exactly the
batches it would have seen — MIREX's re-execution-safe mapper inputs, but for
training. Batches are generated (or read) per-step from a pure
``make_batch(step) -> dict[str, np.ndarray]`` and laid out with
``jax.make_array_from_process_local_data`` under the batch sharding.
"""

from __future__ import annotations

from typing import Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ShardedBatchLoader:
    def __init__(
        self,
        mesh: Mesh,
        batch_axes: tuple[str, ...],
        make_batch: Callable[[int], dict[str, np.ndarray]],
        *,
        prefetch: int = 2,
    ):
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.make_batch = make_batch
        self.prefetch = prefetch

    def sharding_for(self, arr: np.ndarray) -> NamedSharding:
        spec = P(self.batch_axes, *([None] * (arr.ndim - 1)))
        return NamedSharding(self.mesh, spec)

    def get(self, step: int) -> dict[str, jax.Array]:
        host = self.make_batch(step)
        return {
            k: jax.make_array_from_process_local_data(self.sharding_for(v), v)
            for k, v in host.items()
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.get(step)
            step += 1
