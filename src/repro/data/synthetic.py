"""Deterministic synthetic data: corpora, queries, qrels, links, graphs, logs.

ClueWeb09 does not fit in this container, so every experiment runs on
statistically-shaped stand-ins: Zipf token corpora (web text is Zipfian, which
is what makes both posting lists and scan-time term matching realistic),
power-law link graphs for the anchor job, and the recsys/GNN generators the
assigned architectures need. Everything is keyed by an integer seed and a
chunk index so a restarted job regenerates byte-identical shards
(restart-safe data, see DESIGN §5).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.scoring import PAD_TOKEN


@dataclasses.dataclass(frozen=True)
class Corpus:
    tokens: np.ndarray  # [n_docs, max_len] int32, PAD_TOKEN-padded
    lengths: np.ndarray  # [n_docs] int32


def _zipf_tokens(rng: np.random.Generator, n: int, vocab: int, alpha: float) -> np.ndarray:
    """Zipf-ish token ids in [0, vocab) via inverse-CDF over rank weights."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks**-alpha
    probs /= probs.sum()
    return rng.choice(vocab, size=n, p=probs).astype(np.int32)


def make_corpus(
    *,
    n_docs: int,
    vocab: int,
    max_len: int = 64,
    min_len: int = 8,
    alpha: float = 1.1,
    seed: int = 0,
) -> Corpus:
    rng = np.random.default_rng(seed)
    lengths = rng.integers(min_len, max_len + 1, size=n_docs).astype(np.int32)
    tokens = np.full((n_docs, max_len), PAD_TOKEN, np.int32)
    flat = _zipf_tokens(rng, int(lengths.sum()), vocab, alpha)
    pos = 0
    for i, l in enumerate(lengths):
        tokens[i, :l] = flat[pos : pos + l]
        pos += l
    return Corpus(tokens=tokens, lengths=lengths)


def make_queries(
    corpus: Corpus,
    *,
    n_queries: int,
    max_q_len: int = 4,
    seed: int = 1,
) -> np.ndarray:
    """Queries sampled from corpus text (so they have matches), padded."""
    rng = np.random.default_rng(seed)
    n_docs = corpus.tokens.shape[0]
    q = np.full((n_queries, max_q_len), PAD_TOKEN, np.int32)
    for i in range(n_queries):
        qlen = int(rng.integers(1, max_q_len + 1))
        doc = int(rng.integers(0, n_docs))
        dlen = int(corpus.lengths[doc])
        picks = rng.integers(0, dlen, size=qlen)
        q[i, :qlen] = corpus.tokens[doc, picks]
    return q


def make_qrels(
    corpus: Corpus,
    queries: np.ndarray,
    *,
    per_query: int = 20,
    seed: int = 2,
) -> np.ndarray:
    """Synthetic relevance: for each query the docs with the highest raw
    query-term density are 'relevant' (a golden standard generated from the
    scoring-model family, per DESIGN C4 — sanity, not SOTA)."""
    rng = np.random.default_rng(seed)
    n_q = queries.shape[0]
    qrels = np.zeros((n_q, corpus.tokens.shape[0]), bool)
    lengths = np.maximum(corpus.lengths, 1)
    for qi in range(n_q):
        terms = queries[qi][queries[qi] != PAD_TOKEN]
        density = np.zeros(corpus.tokens.shape[0], np.float64)
        for t in terms:
            density += (corpus.tokens == t).sum(-1)
        density = density / lengths
        density += rng.normal(0, 1e-9, density.shape)  # tie-break
        top = np.argsort(-density)[:per_query]
        qrels[qi, top[density[top] > 0]] = True
    return qrels


def make_links(
    *,
    n_docs: int,
    n_links: int,
    vocab: int,
    max_anchor_len: int = 6,
    seed: int = 3,
) -> tuple[np.ndarray, np.ndarray]:
    """Power-law link graph + anchor token strings for the anchor job."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-ish dst distribution
    w = (np.arange(1, n_docs + 1, dtype=np.float64)) ** -0.9
    w /= w.sum()
    dst = rng.choice(n_docs, size=n_links, p=w).astype(np.int32)
    tokens = np.full((n_links, max_anchor_len), PAD_TOKEN, np.int32)
    lens = rng.integers(1, max_anchor_len + 1, size=n_links)
    flat = _zipf_tokens(rng, int(lens.sum()), vocab, 1.05)
    pos = 0
    for i, l in enumerate(lens):
        tokens[i, :l] = flat[pos : pos + l]
        pos += l
    return dst, tokens


def make_dense_corpus(*, n_docs: int, dim: int, seed: int = 4) -> np.ndarray:
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((n_docs, dim)).astype(np.float32)
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


def make_lm_batch(
    *, batch: int, seq_len: int, vocab: int, seed: int = 0, chunk: int = 0
) -> dict[str, np.ndarray]:
    """Deterministic LM training batch keyed by (seed, chunk) for restarts."""
    rng = np.random.default_rng((seed, chunk))
    tokens = rng.integers(0, vocab, size=(batch, seq_len + 1), dtype=np.int64)
    return {
        "tokens": tokens[:, :-1].astype(np.int32),
        "labels": tokens[:, 1:].astype(np.int32),
    }


def make_graph(
    *, n_nodes: int, n_edges: int, d_feat: int, n_classes: int = 16, seed: int = 5
) -> dict[str, np.ndarray]:
    """Random power-law graph (COO edge list, sorted by dst for segment ops)."""
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n_nodes + 1, dtype=np.float64)) ** -0.8
    w /= w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=w).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    order = np.argsort(dst, kind="stable")
    return {
        "src": src[order],
        "dst": dst[order],
        "x": rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
        "y": rng.integers(0, n_classes, size=n_nodes, dtype=np.int32),
    }


def make_recsys_batch(
    *,
    batch: int,
    n_dense: int,
    n_sparse: int,
    vocab_per_field: int,
    seed: int = 0,
    chunk: int = 0,
) -> dict[str, np.ndarray]:
    rng = np.random.default_rng((seed, chunk))
    return {
        "dense": rng.standard_normal((batch, n_dense)).astype(np.float32)
        if n_dense
        else np.zeros((batch, 0), np.float32),
        "sparse_ids": rng.integers(
            0, vocab_per_field, size=(batch, n_sparse), dtype=np.int32
        ),
        "labels": rng.integers(0, 2, size=(batch,)).astype(np.float32),
    }


def make_item_sequences(
    *, batch: int, seq_len: int, n_items: int, seed: int = 0, chunk: int = 0
) -> dict[str, np.ndarray]:
    rng = np.random.default_rng((seed, chunk))
    seq = rng.integers(1, n_items, size=(batch, seq_len + 1), dtype=np.int32)
    return {"history": seq[:, :-1], "target": seq[:, 1:]}
