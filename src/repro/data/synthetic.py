"""Deterministic synthetic data: corpora, queries, qrels, links, graphs, logs.

ClueWeb09 does not fit in this container, so every experiment runs on
statistically-shaped stand-ins: Zipf token corpora (web text is Zipfian, which
is what makes both posting lists and scan-time term matching realistic),
power-law link graphs for the anchor job, and the recsys/GNN generators the
assigned architectures need. Everything is keyed by an integer seed and a
chunk index so a restarted job regenerates byte-identical shards
(restart-safe data, see DESIGN §5).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.scoring import PAD_TOKEN


@dataclasses.dataclass(frozen=True)
class Corpus:
    tokens: np.ndarray  # [n_docs, max_len] int32, PAD_TOKEN-padded
    lengths: np.ndarray  # [n_docs] int32


def _zipf_tokens(rng: np.random.Generator, n: int, vocab: int, alpha: float) -> np.ndarray:
    """Zipf-ish token ids in [0, vocab) via inverse-CDF over rank weights."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks**-alpha
    probs /= probs.sum()
    return rng.choice(vocab, size=n, p=probs).astype(np.int32)


def make_corpus(
    *,
    n_docs: int,
    vocab: int,
    max_len: int = 64,
    min_len: int = 8,
    alpha: float = 1.1,
    seed: int = 0,
) -> Corpus:
    rng = np.random.default_rng(seed)
    lengths = rng.integers(min_len, max_len + 1, size=n_docs).astype(np.int32)
    tokens = np.full((n_docs, max_len), PAD_TOKEN, np.int32)
    flat = _zipf_tokens(rng, int(lengths.sum()), vocab, alpha)
    pos = 0
    for i, l in enumerate(lengths):
        tokens[i, :l] = flat[pos : pos + l]
        pos += l
    return Corpus(tokens=tokens, lengths=lengths)


def make_queries(
    corpus: Corpus,
    *,
    n_queries: int,
    max_q_len: int = 4,
    seed: int = 1,
) -> np.ndarray:
    """Queries sampled from corpus text (so they have matches), padded."""
    rng = np.random.default_rng(seed)
    n_docs = corpus.tokens.shape[0]
    q = np.full((n_queries, max_q_len), PAD_TOKEN, np.int32)
    for i in range(n_queries):
        qlen = int(rng.integers(1, max_q_len + 1))
        doc = int(rng.integers(0, n_docs))
        dlen = int(corpus.lengths[doc])
        picks = rng.integers(0, dlen, size=qlen)
        q[i, :qlen] = corpus.tokens[doc, picks]
    return q


def _density_ranked(
    corpus: Corpus, queries: np.ndarray, per_query: int, seed: int
) -> list[np.ndarray]:
    """Per query: the ``per_query`` densest matching docs, best first.

    The single source of the synthetic gold standard — binary and graded
    qrels both consume this ranking, which is what keeps
    ``make_graded_qrels(...) > 0 == make_qrels(...)`` true by construction."""
    rng = np.random.default_rng(seed)
    lengths = np.maximum(corpus.lengths, 1)
    ranked = []
    for qi in range(queries.shape[0]):
        terms = queries[qi][queries[qi] != PAD_TOKEN]
        density = np.zeros(corpus.tokens.shape[0], np.float64)
        for t in terms:
            density += (corpus.tokens == t).sum(-1)
        density = density / lengths
        density += rng.normal(0, 1e-9, density.shape)  # tie-break
        top = np.argsort(-density)[:per_query]
        ranked.append(top[density[top] > 0])
    return ranked


def make_qrels(
    corpus: Corpus,
    queries: np.ndarray,
    *,
    per_query: int = 20,
    seed: int = 2,
) -> np.ndarray:
    """Synthetic relevance: for each query the docs with the highest raw
    query-term density are 'relevant' (a golden standard generated from the
    scoring-model family, per DESIGN C4 — sanity, not SOTA)."""
    qrels = np.zeros((queries.shape[0], corpus.tokens.shape[0]), bool)
    for qi, top in enumerate(_density_ranked(corpus, queries, per_query, seed)):
        qrels[qi, top] = True
    return qrels


def make_graded_qrels(
    corpus: Corpus,
    queries: np.ndarray,
    *,
    per_query: int = 20,
    max_grade: int = 3,
    seed: int = 2,
) -> np.ndarray:
    """Graded relevance (0..max_grade) for NDCG: same density ranking as
    :func:`make_qrels`, with grades assigned by rank band (denser ⇒ higher)."""
    qrels = np.zeros((queries.shape[0], corpus.tokens.shape[0]), np.int8)
    for qi, top in enumerate(_density_ranked(corpus, queries, per_query, seed)):
        for rank, doc in enumerate(top):
            band = rank * max_grade // max(len(top), 1)  # 0 = densest band
            qrels[qi, doc] = max_grade - band
    return qrels


def make_links(
    *,
    n_docs: int,
    n_links: int,
    vocab: int,
    max_anchor_len: int = 6,
    seed: int = 3,
) -> tuple[np.ndarray, np.ndarray]:
    """Power-law link graph + anchor token strings for the anchor job."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-ish dst distribution
    w = (np.arange(1, n_docs + 1, dtype=np.float64)) ** -0.9
    w /= w.sum()
    dst = rng.choice(n_docs, size=n_links, p=w).astype(np.int32)
    tokens = np.full((n_links, max_anchor_len), PAD_TOKEN, np.int32)
    lens = rng.integers(1, max_anchor_len + 1, size=n_links)
    flat = _zipf_tokens(rng, int(lens.sum()), vocab, 1.05)
    pos = 0
    for i, l in enumerate(lens):
        tokens[i, :l] = flat[pos : pos + l]
        pos += l
    return dst, tokens


def make_dense_corpus(*, n_docs: int, dim: int, seed: int = 4) -> np.ndarray:
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((n_docs, dim)).astype(np.float32)
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


def make_lm_batch(
    *, batch: int, seq_len: int, vocab: int, seed: int = 0, chunk: int = 0
) -> dict[str, np.ndarray]:
    """Deterministic LM training batch keyed by (seed, chunk) for restarts.

    Tokens are Zipf-distributed (like the corpora above): uniform tokens have
    no learnable structure at all — loss starts at ln|V| and can only walk in
    place — whereas a skewed unigram distribution gives training runs real
    signal (the convergence tests in test_system assert on it)."""
    rng = np.random.default_rng((seed, chunk))
    tokens = _zipf_tokens(rng, batch * (seq_len + 1), vocab, 1.2).reshape(
        batch, seq_len + 1
    )
    return {
        "tokens": tokens[:, :-1].astype(np.int32),
        "labels": tokens[:, 1:].astype(np.int32),
    }


def make_graph(
    *, n_nodes: int, n_edges: int, d_feat: int, n_classes: int = 16, seed: int = 5
) -> dict[str, np.ndarray]:
    """Random power-law graph (COO edge list, sorted by dst for segment ops)."""
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n_nodes + 1, dtype=np.float64)) ** -0.8
    w /= w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=w).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    order = np.argsort(dst, kind="stable")
    return {
        "src": src[order],
        "dst": dst[order],
        "x": rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
        "y": rng.integers(0, n_classes, size=n_nodes, dtype=np.int32),
    }


def make_recsys_batch(
    *,
    batch: int,
    n_dense: int,
    n_sparse: int,
    vocab_per_field: int,
    seed: int = 0,
    chunk: int = 0,
) -> dict[str, np.ndarray]:
    rng = np.random.default_rng((seed, chunk))
    dense = (
        rng.standard_normal((batch, n_dense)).astype(np.float32)
        if n_dense
        else np.zeros((batch, 0), np.float32)
    )
    sparse_ids = rng.integers(0, vocab_per_field, size=(batch, n_sparse), dtype=np.int32)
    # learnable labels from a fixed linear teacher over the dense features
    # (plus a small per-field id-parity term): coin-flip labels would pin the
    # achievable loss at ln 2 and make convergence tests meaningless
    logit = dense @ np.linspace(-1.0, 1.0, n_dense) if n_dense else np.zeros(batch)
    if n_sparse:
        logit = logit + 0.5 * ((sparse_ids[:, 0] % 2) * 2 - 1)
    return {
        "dense": dense,
        "sparse_ids": sparse_ids,
        "labels": (logit > 0).astype(np.float32),
    }


def make_item_sequences(
    *, batch: int, seq_len: int, n_items: int, seed: int = 0, chunk: int = 0
) -> dict[str, np.ndarray]:
    rng = np.random.default_rng((seed, chunk))
    seq = rng.integers(1, n_items, size=(batch, seq_len + 1), dtype=np.int32)
    return {"history": seq[:, :-1], "target": seq[:, 1:]}
