"""Host-side 1D graph partitioning: bucket edges by destination shard.

The sharded full-graph forward (models/gnn.py, mode="bucketed") contracts
that mesh shard ``s`` receives exactly the edges whose destination node lies
in its contiguous node range, padded to a uniform bucket size with ghost
edges (``dst = n_nodes``, dropped by the out-of-range segment ids). This is
the standard vertex-partitioned (1D) layout; the partition is computed once
on hosts as part of data loading.
"""

from __future__ import annotations

import numpy as np


def bucket_edges(
    src: np.ndarray,
    dst: np.ndarray,
    *,
    n_nodes: int,
    n_shards: int,
    bucket_size: int | None = None,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Returns (src_bucketed, dst_bucketed, bucket_size): arrays of length
    ``n_shards * bucket_size`` where slab s holds edges with
    ``dst // (n_nodes/n_shards) == s`` (ghost-padded)."""
    assert n_nodes % n_shards == 0, (n_nodes, n_shards)
    n_loc = n_nodes // n_shards
    shard_of = dst // n_loc
    counts = np.bincount(shard_of, minlength=n_shards)
    if bucket_size is None:
        bucket_size = int(counts.max())
    if counts.max() > bucket_size:
        raise ValueError(
            f"bucket overflow: max shard load {counts.max()} > bucket {bucket_size}; "
            "increase the padded edge budget (skew beyond the 1.3× allowance)"
        )
    order = np.argsort(shard_of, kind="stable")
    src_s, dst_s = src[order], dst[order]
    out_src = np.zeros((n_shards, bucket_size), np.int32)
    out_dst = np.full((n_shards, bucket_size), n_nodes, np.int32)  # ghosts
    start = 0
    for s in range(n_shards):
        c = counts[s]
        out_src[s, :c] = src_s[start : start + c]
        out_dst[s, :c] = dst_s[start : start + c]
        start += c
    return out_src.reshape(-1), out_dst.reshape(-1), bucket_size
