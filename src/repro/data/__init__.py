from repro.data import synthetic
from repro.data.loader import ShardedBatchLoader

__all__ = ["synthetic", "ShardedBatchLoader"]
