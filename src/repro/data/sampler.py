"""Host-side fixed-fanout neighbor sampler (GraphSAGE-style) for minibatch_lg.

Builds a CSR adjacency once, then per step samples a fixed-fanout computation
tree for a seed batch: deterministic given (seed, step) — the restart-safe
contract shared with the rest of the data pipeline. Sampling is with
replacement (nodes with degree < fanout repeat neighbors; isolated nodes
self-loop), which keeps every tensor statically shaped for jit.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # [N+1]
    indices: np.ndarray  # [E]
    feats: np.ndarray  # [N, F]
    labels: np.ndarray  # [N]

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1


def build_csr(src: np.ndarray, dst: np.ndarray, feats: np.ndarray, labels: np.ndarray) -> CSRGraph:
    """CSR over *incoming* edges: neighbors(v) = sources of edges into v."""
    n = feats.shape[0]
    order = np.argsort(dst, kind="stable")
    indices = src[order]
    counts = np.bincount(dst, minlength=n)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=indices, feats=feats, labels=labels)


def sample_neighbors(g: CSRGraph, nodes: np.ndarray, fanout: int, rng: np.random.Generator) -> np.ndarray:
    """[len(nodes), fanout] sampled in-neighbors (self-loop when isolated)."""
    lo = g.indptr[nodes]
    hi = g.indptr[nodes + 1]
    deg = hi - lo
    pick = rng.integers(0, np.maximum(deg, 1)[:, None], size=(nodes.shape[0], fanout))
    neigh = g.indices[np.minimum(lo[:, None] + pick, len(g.indices) - 1 if len(g.indices) else 0)]
    return np.where(deg[:, None] > 0, neigh, nodes[:, None]).astype(np.int32)


def sample_batch(
    g: CSRGraph,
    *,
    batch_nodes: int,
    fanout: tuple[int, int],
    seed: int,
    step: int,
) -> dict[str, np.ndarray]:
    """One training batch: seeds + 2-hop computation-tree features."""
    rng = np.random.default_rng((seed, step))
    seeds = rng.integers(0, g.n_nodes, size=batch_nodes).astype(np.int32)
    k1, k2 = fanout
    hop1 = sample_neighbors(g, seeds, k1, rng)  # [B, K1]
    hop2 = sample_neighbors(g, hop1.reshape(-1), k2, rng).reshape(batch_nodes, k1, k2)
    return {
        "seed_x": g.feats[seeds],
        "hop1_x": g.feats[hop1],
        "hop2_x": g.feats[hop2],
        "labels": g.labels[seeds].astype(np.int32),
    }
