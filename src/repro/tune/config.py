"""The one frozen config every performance knob in this repro lives in.

Before this module, every knob was a hand-picked constant scattered across
layers: the lexical kernel's ``block_d``/``tile_d``, flash attention's
``block_q``/``block_k``, the decode kernel's ``block_s``, the fold's
``chunk_size``, the pipelined executor's prefetch ``depth`` and worker
count, the scheduler's retry backoff, the serve layer's microbatch
triggers. :class:`TuningConfig` centralizes them with **defaults that
reproduce today's hand-picked values bit-for-bit** — a default-constructed
config changes nothing, anywhere, which is the property the whole
autotuning contract rests on:

    **tuning changes speed, never bytes.**

Every knob here is execution geometry: block/tile sizes only regroup the
value-deterministic top-k merges, the tf reduction accumulates in int32,
prefetch/worker/writer knobs reorder work that commutes. Run files produced
under *any* legal ``TuningConfig`` are byte-identical to the default-config
oracle (property-tested in ``tests/test_tune.py``, CI-enforced on the
smoke grid).

Threading model: code paths accept an explicit ``tuning=`` argument and
fall back to the process-wide active config (:func:`active` /
:func:`set_active` / the :func:`use` context manager). The active config is
a module global, not thread-local, so worker threads of a sharded job see
the config their driver installed. Knobs that shape *compiled programs*
(the kernel block sizes) are part of the jit-cache keys in
`cluster.mapreduce` via :meth:`TuningConfig.fold_key` — two configs that
compile different programs can never alias one cache entry.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
from typing import Any, Iterator
import contextlib

# Bump when knobs are added/removed/re-meaning-ed: persisted winner-cache
# entries recorded under another version are stale and fall back to defaults.
SPACE_VERSION = 3  # v3: + token_pack (packed corpus segments, core.packing)

# legal token_pack values (mirrors packing.PACK_MODES; kept literal here so
# config stays importable without jax)
_TOKEN_PACK_MODES = ("none", "auto", "8", "16", "bitpack")


@dataclasses.dataclass(frozen=True)
class TuningConfig:
    """Every performance knob, one frozen record. Defaults == today's
    hand-picked values, so ``TuningConfig()`` is the identity config.

    ``None`` on the geometry knobs means "follow the caller": ``chunk_size``
    defers to the experiment/job's declared chunking, ``lex_block_d`` /
    ``dense_block_d`` follow ``chunk_size`` on the scan paths (today's
    behavior of passing ``block_d=chunk_size`` into the kernels) and the
    kernels' native defaults (512 / 1024) on direct calls, ``max_workers``
    defers to one-worker-per-device. ``serve_max_bucket=None`` means an
    uncapped bucket ladder (its default is a *cap*, 128 — the measured
    serve sweet spot; capping only regroups dispatches, so results stay
    byte-identical and the identity contract is on bytes, not grouping).
    """

    # -- scan fold / pipelined executor (cluster.job / core.pipeline) -------
    chunk_size: int | None = None  # rows per fold chunk; None = caller's
    prefetch_depth: int = 2  # staged segments ahead of the fold
    max_workers: int | None = None  # shard pool cap; None = per device
    cross_shard_prefetch: bool = True  # stage next shard's first segment
    writer_reuse: bool = False  # share the async ckpt writer per worker
    keep_checkpoints: int = 2  # committed segments kept on disk
    # -- scheduler retry pacing (cluster.scheduler) -------------------------
    backoff_base: float = 0.1
    backoff_cap: float = 5.0
    # -- fused lexical-scan kernel (kernels.lexical_scan) -------------------
    lex_block_d: int | None = None  # doc tile; None = chunk_size / 512
    lex_tile_d: int = 16  # L_d sub-tile of the tf reduction
    # -- dense score+top-k kernel (kernels.score_topk) ----------------------
    dense_block_d: int | None = None  # doc tile; None = chunk_size / 1024
    # -- flash kernels (kernels.flash_attn / flash_decode) ------------------
    flash_block_q: int = 128
    flash_block_k: int = 128
    decode_block_s: int = 512
    # -- serve microbatching (serve.microbatch / serve.service) -------------
    serve_max_batch: int = 64
    serve_max_delay_s: float = 5e-3
    serve_min_bucket: int = 8
    # bucket-ladder cap: blocks never pad past this, and oversize takes are
    # split into <= cap dispatches (the @256 amortization-cliff fix — past
    # the MXU/cache sweet spot per-query cost *rises*, so two sweet-spot
    # scans beat one giant one). None = uncapped (the pre-cap ladder).
    serve_max_bucket: int | None = 128
    # -- packed corpus segments (core.packing) ------------------------------
    # Token storage width for corpora the runner/serve layer prepares:
    # "none" keeps int32 (the identity default), "auto" picks the narrowest
    # width the vocab fits (u8/u16/bitpack), "8"/"16"/"bitpack" force one
    # (degrading to auto's choice if the vocab doesn't fit — knobs degrade,
    # never fail). Packed segments decode exactly on the consumer, so this
    # knob changes bytes moved, never bytes written. Not part of fold_key:
    # a packed corpus is a different pytree treedef, which jit and the
    # mesh/fold caches already key on.
    token_pack: str = "none"

    def __post_init__(self):
        for name in (
            "chunk_size", "lex_block_d", "dense_block_d", "max_workers",
            "serve_max_bucket",
        ):
            v = getattr(self, name)
            if v is not None and (not isinstance(v, int) or v < 1):
                raise ValueError(f"{name} must be a positive int or None, got {v!r}")
        for name in (
            "prefetch_depth", "keep_checkpoints", "lex_tile_d",
            "flash_block_q", "flash_block_k", "decode_block_s",
            "serve_max_batch", "serve_min_bucket",
        ):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{name} must be a positive int, got {v!r}")
        for name in ("backoff_base", "backoff_cap", "serve_max_delay_s"):
            v = getattr(self, name)
            if not isinstance(v, (int, float)) or v < 0:
                raise ValueError(f"{name} must be a non-negative number, got {v!r}")
        if self.token_pack not in _TOKEN_PACK_MODES:
            raise ValueError(
                f"token_pack must be one of {_TOKEN_PACK_MODES}, "
                f"got {self.token_pack!r}"
            )
        if (
            self.serve_max_bucket is not None
            and self.serve_max_bucket < self.serve_min_bucket
        ):
            raise ValueError(
                f"serve_max_bucket {self.serve_max_bucket} below "
                f"serve_min_bucket {self.serve_min_bucket}"
            )

    # -- derivation ---------------------------------------------------------

    def replace(self, **kw: Any) -> "TuningConfig":
        return dataclasses.replace(self, **kw)

    def describe(self) -> dict:
        """JSON-able full knob table (report / cache payloads)."""
        return dataclasses.asdict(self)

    def overrides(self) -> dict:
        """Only the knobs that differ from the defaults — the readable form
        for reports ('{}' literally means 'the hand-picked configuration')."""
        base = DEFAULT.describe()
        return {k: v for k, v in self.describe().items() if v != base[k]}

    @classmethod
    def from_dict(cls, d: dict, *, strict: bool = True) -> "TuningConfig":
        """Build from a (possibly partial) knob dict. ``strict`` rejects
        unknown knob names — the stale-cache guard: an entry recorded under
        a different knob space must not half-apply."""
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown and strict:
            raise ValueError(f"unknown tuning knobs {sorted(unknown)}")
        return cls(**{k: v for k, v in d.items() if k in fields})

    def config_hash(self) -> str:
        """Short content hash of (knob space version, full knob table) —
        stamped into report.json and BENCH provenance so perf numbers are
        attributable to the exact configuration that produced them."""
        payload = json.dumps(
            {"space_version": SPACE_VERSION, "config": self.describe()},
            sort_keys=True,
        )
        return hashlib.sha256(payload.encode()).hexdigest()[:12]

    # -- resolution helpers (the scan-path geometry rules) ------------------

    def resolve_chunk_size(self, declared: int) -> int:
        """Effective fold chunk size given the job's declared one."""
        return self.chunk_size if self.chunk_size is not None else declared

    def lex_block(self, chunk_size: int, n_rows: int | None = None) -> int:
        """Lexical-kernel doc tile for a scan over ``chunk_size`` chunks.

        ``None`` follows the chunk (today's behavior); an explicit block
        that doesn't divide the shard gracefully falls back to the chunk —
        the scan must never fail on a knob, only ignore it (byte-identical
        either way: block size only regroups the combiner fold).
        """
        block = self.lex_block_d if self.lex_block_d is not None else chunk_size
        if n_rows is not None and n_rows % block:
            block = chunk_size
        return block

    def dense_block(self, chunk_size: int, n_rows: int | None = None) -> int:
        """Dense-kernel doc tile; same rules as :meth:`lex_block`."""
        block = self.dense_block_d if self.dense_block_d is not None else chunk_size
        if n_rows is not None and n_rows % block:
            block = chunk_size
        return block

    def fold_key(self, use_kernel: bool) -> tuple:
        """The knobs that shape the *compiled* fold program — the tuning
        component of `cluster.segment_fold`'s (and `search_mesh`'s) cache
        key. Host folds are shaped by chunk_size alone (already in the key);
        kernel folds additionally bake the block/tile geometry into the
        traced Pallas program, so those knobs must key the cache or two
        configs would silently share one program."""
        if not use_kernel:
            return ()
        return (self.lex_block_d, self.lex_tile_d, self.dense_block_d)


DEFAULT = TuningConfig()


@dataclasses.dataclass(frozen=True)
class ActiveTuning:
    """The installed config plus where it came from — provenance for
    report.json / BENCH_*.json stamping."""

    config: TuningConfig = DEFAULT
    source: str = "default"  # default | explicit | file | cache | search
    cache_hit: bool = False

    def provenance(self) -> dict:
        return {
            "config_hash": self.config.config_hash(),
            "source": self.source,
            "cache_hit": self.cache_hit,
        }


_LOCK = threading.Lock()
_active = ActiveTuning()


def active() -> ActiveTuning:
    """The process-wide active tuning (never None; defaults when unset)."""
    return _active


def set_active(
    config: TuningConfig | None,
    *,
    source: str = "explicit",
    cache_hit: bool = False,
) -> ActiveTuning:
    """Install ``config`` as the process-wide active tuning; returns the
    *previous* record so callers can restore it. ``None`` restores defaults."""
    global _active
    with _LOCK:
        prev = _active
        if config is None:
            _active = ActiveTuning()
        else:
            _active = ActiveTuning(config=config, source=source, cache_hit=cache_hit)
        return prev


def _restore(record: ActiveTuning) -> None:
    global _active
    with _LOCK:
        _active = record


@contextlib.contextmanager
def use(
    config: TuningConfig | None,
    *,
    source: str = "explicit",
    cache_hit: bool = False,
) -> Iterator[ActiveTuning]:
    """Scoped :func:`set_active` — the autotune harness measures every
    candidate under ``with use(candidate): ...`` and leaks nothing."""
    prev = set_active(config, source=source, cache_hit=cache_hit)
    try:
        yield active()
    finally:
        _restore(prev)


def resolve(tuning: TuningConfig | None) -> TuningConfig:
    """Explicit argument wins; otherwise the active config. The standard
    first line of every ``tuning=``-threaded code path."""
    return tuning if tuning is not None else _active.config


def provenance() -> dict:
    """The active config's provenance block (benchmarks stamp this)."""
    return _active.provenance()


def save(config: TuningConfig, path: str) -> str:
    """Write a config as JSON (the ``--tuning-config`` file format: a flat
    knob dict; missing knobs mean 'default')."""
    with open(path, "w") as f:
        json.dump(config.describe(), f, indent=2)
        f.write("\n")
    return path


def load(path: str) -> TuningConfig:
    """Read a ``--tuning-config`` JSON file (flat knob dict, strict)."""
    with open(path) as f:
        return TuningConfig.from_dict(json.load(f), strict=True)
