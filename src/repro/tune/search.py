"""Async model-based knob search — AMBS over the legal TuningConfig space.

The shape of deephyper's asynchronous model-based search, sized for a knob
space of dozens of points rather than millions: a *candidate generator*
samples legal configs from a declared discrete space (shape/divisibility
constraints applied at generation time, so no measurement budget is ever
spent on a config the kernels would reject), a *cheap surrogate* fitted on
the trials so far ranks the unmeasured candidates, and an async evaluation
loop keeps ``workers`` measurements in flight, refitting and re-ranking
each time one lands — the budget flows toward the promising region of the
space instead of being spread uniformly.

The surrogate is a distance-weighted nearest-neighbor predictor over the
knobs' *value indices* (each knob's values are an ordered scale; normalized
index distance is a sane metric on block sizes and batch buckets alike).
That is deliberately the cheapest model that still ranks: with budgets of
8–64 trials a fitted GP/forest is noise, and the predictor must cost
microseconds because it reranks after every trial.

Determinism: the generator and the ranking tie-breaks are seeded, and with
``workers=1`` (the default — benchmark measurements contend for the same
hardware, so parallel trials pollute each other) the whole search is a
reproducible function of (space, seed, measured times).

Measurements come from the existing benchmark entry points — the search
never invents its own timing loop; see ``benchmarks/autotune.py`` for the
harness that binds spaces to `cluster.run_sharded_scan_job` / the serve
sweep and enforces the byte-identity contract on every trial.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import itertools
import random
from typing import Callable, Sequence

from repro.tune.config import DEFAULT, TuningConfig


@dataclasses.dataclass(frozen=True)
class Knob:
    """One tunable dimension: the TuningConfig field and its legal values,
    ordered (the surrogate's distance metric is index distance on this
    scale)."""

    name: str
    values: tuple

    def __post_init__(self):
        if not self.values:
            raise ValueError(f"knob {self.name!r} has no values")


@dataclasses.dataclass(frozen=True)
class KnobSpace:
    """A legal sub-space of TuningConfig for one workload kind.

    ``constraint`` rejects structurally-illegal combinations (a chunk that
    doesn't divide the shard, a block that doesn't divide the chunk) at
    candidate-generation time. ``base`` carries the knobs this space does
    *not* search (a serve space leaves the scan knobs at their defaults).
    """

    kind: str
    knobs: tuple[Knob, ...]
    constraint: Callable[[TuningConfig], bool] | None = None
    base: TuningConfig = DEFAULT

    def config(self, assignment: dict) -> TuningConfig:
        return self.base.replace(**assignment)

    def is_legal(self, cfg: TuningConfig) -> bool:
        return self.constraint is None or bool(self.constraint(cfg))

    def size(self) -> int:
        n = 1
        for k in self.knobs:
            n *= len(k.values)
        return n

    def candidates(self, *, max_candidates: int = 4096, seed: int = 0) -> list[TuningConfig]:
        """All legal configs (small spaces) or a seeded uniform sample
        (large ones), with the space's base — the default configuration —
        always candidate #0: the search can then never report a winner
        worse than the default, because the default is *in* the tournament.
        """
        rng = random.Random(seed)
        names = [k.name for k in self.knobs]
        out: list[TuningConfig] = []
        seen: set[tuple] = set()

        def admit(combo) -> None:
            cfg = self.config(dict(zip(names, combo)))
            key = tuple(sorted(cfg.describe().items(), key=lambda kv: kv[0]))
            if key in seen:
                return
            if not self.is_legal(cfg):
                return
            seen.add(key)
            out.append(cfg)

        base_combo = tuple(
            getattr(self.base, k.name) for k in self.knobs
        )
        admit(base_combo)  # the default-config oracle rides in every pool
        if self.size() <= max_candidates:
            for combo in itertools.product(*(k.values for k in self.knobs)):
                admit(combo)
        else:
            tries = 0
            while len(out) < max_candidates and tries < max_candidates * 20:
                admit(tuple(rng.choice(k.values) for k in self.knobs))
                tries += 1
        return out


@dataclasses.dataclass
class Trial:
    """One measured candidate. ``score`` is the figure of merit (higher is
    better — docs/s, qps); failed measurements keep the error and rank last."""

    config: TuningConfig
    score: float
    wall_s: float = 0.0
    error: str | None = None

    @property
    def ok(self) -> bool:
        return self.error is None


class Surrogate:
    """Distance-weighted k-NN score predictor on normalized knob indices.

    ``fit`` is O(trials); ``predict`` is O(trials · knobs). Unseen regions
    predict the observed mean, so exploration never starves: a candidate far
    from every measurement ranks around average, above the known-bad tail.
    """

    def __init__(self, space: KnobSpace, k: int = 3):
        self.space = space
        self.k = max(1, k)
        self._index = {
            knob.name: {v: i for i, v in enumerate(knob.values)}
            for knob in space.knobs
        }
        self._points: list[tuple[tuple[float, ...], float]] = []
        self._mean = 0.0

    def _encode(self, cfg: TuningConfig) -> tuple[float, ...]:
        coords = []
        for knob in self.space.knobs:
            idx = self._index[knob.name]
            v = getattr(cfg, knob.name)
            denom = max(1, len(knob.values) - 1)
            coords.append(idx.get(v, 0) / denom)
        return tuple(coords)

    def fit(self, trials: Sequence[Trial]) -> None:
        ok = [t for t in trials if t.ok]
        self._points = [(self._encode(t.config), t.score) for t in ok]
        self._mean = sum(s for _, s in self._points) / len(self._points) if ok else 0.0

    def predict(self, cfg: TuningConfig) -> float:
        if not self._points:
            return 0.0
        x = self._encode(cfg)
        dists = sorted(
            (sum(abs(a - b) for a, b in zip(x, p)), s) for p, s in self._points
        )[: self.k]
        num = den = 0.0
        for d, s in dists:
            w = 1.0 / (1e-6 + d)
            num += w * s
            den += w
        blend = num / den
        # shrink toward the mean with distance: far candidates are guesses
        nearest = dists[0][0]
        trust = 1.0 / (1.0 + nearest * len(self.space.knobs))
        return trust * blend + (1.0 - trust) * self._mean


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """The tournament outcome: best (incl. the default), every trial, and
    the default's own measurement for the default-vs-tuned curve."""

    space: KnobSpace
    best: Trial
    default: Trial
    trials: tuple[Trial, ...]

    @property
    def speedup_x(self) -> float:
        if not self.default.ok or self.default.score <= 0:
            return 1.0
        return self.best.score / self.default.score

    def describe(self) -> dict:
        return {
            "kind": self.space.kind,
            "n_trials": len(self.trials),
            "space_size": self.space.size(),
            "default": {
                "config_hash": self.default.config.config_hash(),
                "score": self.default.score,
            },
            "best": {
                "config_hash": self.best.config.config_hash(),
                "overrides": self.best.config.overrides(),
                "score": self.best.score,
            },
            "speedup_x": self.speedup_x,
            "trials": [
                {
                    "overrides": t.config.overrides(),
                    "score": t.score,
                    "wall_s": t.wall_s,
                    "error": t.error,
                }
                for t in self.trials
            ],
        }


def search(
    space: KnobSpace,
    measure: Callable[[TuningConfig], float],
    *,
    budget: int = 16,
    seed: int = 0,
    init_random: int = 3,
    workers: int = 1,
    log: Callable[[str], None] | None = None,
) -> SearchResult:
    """Run the AMBS loop: measure the default + ``init_random`` seeded
    picks, then keep ``workers`` measurements in flight, each next candidate
    being the surrogate's argmax over the unmeasured pool (refit on every
    completion). ``measure(config)`` returns the figure of merit (higher is
    better) and may raise — a failed trial scores ``-inf`` and teaches the
    surrogate to avoid its region.

    The default config is always trial #0, so ``result.best`` is ≥ the
    default *by construction within this measurement session* — autotuning
    can surface "nothing beats the defaults here" (speedup 1.0) but never a
    recorded regression.
    """
    if budget < 1:
        raise ValueError(f"budget must be >= 1, got {budget}")
    pool = space.candidates(seed=seed)
    rng = random.Random(seed + 1)
    surrogate = Surrogate(space)
    trials: list[Trial] = []
    measured: set[str] = set()

    def run_one(cfg: TuningConfig) -> Trial:
        import time

        t0 = time.perf_counter()
        try:
            score = float(measure(cfg))
        except Exception as e:  # noqa: BLE001 — an illegal-at-runtime config is data
            return Trial(
                config=cfg, score=float("-inf"),
                wall_s=time.perf_counter() - t0, error=f"{type(e).__name__}: {e}",
            )
        return Trial(config=cfg, score=score, wall_s=time.perf_counter() - t0)

    def next_candidate() -> TuningConfig | None:
        remaining = [c for c in pool if c.config_hash() not in measured]
        if not remaining:
            return None
        n_done = len([t for t in trials if t.ok])
        if len(measured) < 1 + init_random or n_done == 0:
            # bootstrap: the default first, then seeded exploration
            if pool[0].config_hash() not in measured:
                return pool[0]
            return rng.choice(remaining)
        surrogate.fit(trials)
        return max(remaining, key=lambda c: (surrogate.predict(c), c.config_hash()))

    budget = min(budget, len(pool))
    with concurrent.futures.ThreadPoolExecutor(max_workers=max(1, workers)) as ex:
        in_flight: dict = {}
        launched = 0
        while launched < budget and len(in_flight) < max(1, workers):
            cand = next_candidate()
            if cand is None:
                break
            measured.add(cand.config_hash())
            in_flight[ex.submit(run_one, cand)] = cand
            launched += 1
        while in_flight:
            done, _ = concurrent.futures.wait(
                in_flight, return_when=concurrent.futures.FIRST_COMPLETED
            )
            for fut in done:
                in_flight.pop(fut)
                trial = fut.result()
                trials.append(trial)
                if log is not None:
                    tag = f"{trial.score:.1f}" if trial.ok else trial.error
                    log(f"[tune:{space.kind}] {trial.config.overrides() or 'default'} -> {tag}")
                if launched < budget:
                    cand = next_candidate()
                    if cand is not None:
                        measured.add(cand.config_hash())
                        in_flight[ex.submit(run_one, cand)] = cand
                        launched += 1

    # trials land in completion order; the default is identified by content,
    # not position (async workers may finish out of launch order)
    default_hash = pool[0].config_hash()
    default_trial = next(
        t for t in trials if t.config.config_hash() == default_hash
    )
    ok = [t for t in trials if t.ok]
    if not ok:
        raise RuntimeError(
            f"every {space.kind} trial failed; first error: {trials[0].error}"
        )
    best = max(ok, key=lambda t: (t.score, t is default_trial))
    return SearchResult(
        space=space, best=best, default=default_trial, trials=tuple(trials)
    )
