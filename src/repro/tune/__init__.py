"""repro.tune — the autotuning subsystem.

Three pieces (see docs/ARCHITECTURE.md §Autotuning):

* :mod:`repro.tune.config` — the frozen :class:`TuningConfig` centralizing
  every performance knob (kernel block/tile sizes, chunk/prefetch/worker
  geometry, scheduler backoff, serve microbatch triggers), threaded through
  kernels/scan/pipeline/cluster/serve with defaults that reproduce the old
  hand-picked values bit-for-bit. **Tuning changes speed, never bytes.**
* :mod:`repro.tune.search` — the async model-based search (candidate
  generator over the legal knob space + cheap surrogate ranking + async
  measurement loop) that finds winners against the existing benchmarks.
* :mod:`repro.tune.cache` — the persistent winner cache, keyed
  kind × backend × shape-signature × knob-space version like the jit fold
  cache, with :func:`best_config` lookup and graceful default fallback.

The shape-signature helpers here are the *shared vocabulary* between the
recorder (``benchmarks/autotune.py``) and the readers (the experiment
runner's ``--tune``): both sides build the signature from the same fields,
so a recorded winner is found by construction, not by string luck.
"""

from repro.tune import cache, config, search  # noqa: F401
from repro.tune.cache import TuneCache, backend_sig, best_config  # noqa: F401
from repro.tune.config import (  # noqa: F401
    DEFAULT,
    SPACE_VERSION,
    ActiveTuning,
    TuningConfig,
    active,
    load,
    provenance,
    resolve,
    save,
    set_active,
    use,
)
from repro.tune.search import Knob, KnobSpace, SearchResult, Surrogate, Trial  # noqa: F401
from repro.tune.search import search as run_search  # noqa: F401


def scan_shape_sig(
    *,
    n_docs: int,
    n_queries: int,
    k: int,
    n_shards: int,
    n_models: int,
    max_doc_len: int,
) -> str:
    """Shape signature of a sharded scan job — what a scan-tuning winner is
    keyed on. Chunk size is deliberately *absent*: it is a knob, not a
    shape (the tuned chunk replaces the declared one)."""
    return (
        f"scan:d{n_docs}:q{n_queries}:L{max_doc_len}:k{k}"
        f":s{n_shards}:m{n_models}"
    )


def scan_shape_sig_for(spec) -> str:
    """The scan signature of an `repro.experiments.grid.ExperimentSpec` —
    the runner's ``--tune`` lookup and ``benchmarks/autotune.py``'s smoke
    target both call this, which is the agreement that makes the CI
    write→reload→hit round-trip structural."""
    return scan_shape_sig(
        n_docs=spec.n_docs,
        n_queries=spec.n_queries,
        k=spec.k,
        n_shards=spec.n_shards,
        n_models=len(spec.scorers()),
        max_doc_len=spec.max_doc_len,
    )


def serve_shape_sig(*, n_docs: int, k: int, chunk_size: int, kind: str) -> str:
    """Shape signature of a serve session (microbatch-trigger tuning)."""
    return f"serve:{kind}:d{n_docs}:k{k}:c{chunk_size}"
