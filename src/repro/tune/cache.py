"""Persistent autotune winner cache — keyed like the jit fold cache.

`cluster.segment_fold` memoizes compiled programs on (scorer grid, k,
chunk_size, kernel, tuning geometry); this cache memoizes *winning
TuningConfigs* the same way, one level up and across processes:

    key = kind × backend × shape-signature × knob-space version

* **kind** — what was measured ("scan_job", "serve", ...); a serve winner
  must never be handed to a scan job even if the shape strings collide.
* **backend** — ``jax.default_backend()`` plus the resolved kernel backend
  when the measurement ran through Pallas (`backend_sig`); a CPU-interpret
  winner says nothing about a TPU.
* **shape-signature** — the workload geometry (docs × queries × k ×
  shards × models), built by `repro.tune.scan_shape_sig` and friends so
  the recorder (benchmarks/autotune.py) and the reader (the experiment
  runner's ``--tune`` lookup) agree by construction.
* **knob-space version** — `config.SPACE_VERSION`; bumping it stales every
  recorded winner at once, because a knob that changed meaning would
  otherwise half-apply.

Lookups degrade, never fail: a miss, a stale version, a kind mismatch, an
unreadable file, or an entry whose knobs no longer parse all fall back to
the defaults with ``cache_hit=False`` — ``--tune`` on a cold cache is just
a slower spelling of the default run.
"""

from __future__ import annotations

import json
import os
from typing import Any

from repro.tune.config import SPACE_VERSION, DEFAULT, TuningConfig

DEFAULT_PATH = "results/tune_cache.json"


def cache_path(path: str | None = None) -> str:
    """Resolve the cache file: explicit arg > $REPRO_TUNE_CACHE > default."""
    return path or os.environ.get("REPRO_TUNE_CACHE") or DEFAULT_PATH


def backend_sig(*, use_kernel: bool = False) -> str:
    """The backend half of the key: XLA backend, plus the resolved Pallas
    mode when the measured path runs through the kernels (an interpret-mode
    winner and a compiled-mode winner are different experiments)."""
    import jax

    sig = jax.default_backend()
    if use_kernel:
        from repro.kernels import ops

        sig += "+" + ops.kernel_backend()
    return sig


def cache_key(kind: str, backend: str, shape: str, version: int = SPACE_VERSION) -> str:
    return f"{kind}|{backend}|{shape}|v{version}"


class TuneCache:
    """The on-disk winner table: one JSON file, atomic rewrite on put."""

    def __init__(self, path: str | None = None):
        self.path = cache_path(path)

    # -- I/O ------------------------------------------------------------------

    def _read(self) -> dict:
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            return {"entries": {}}
        if not isinstance(data, dict) or not isinstance(data.get("entries"), dict):
            return {"entries": {}}
        return data

    def _write(self, data: dict) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = os.path.join(d or ".", ".tmp-" + os.path.basename(self.path))
        with open(tmp, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)

    # -- API ------------------------------------------------------------------

    def put(
        self,
        *,
        kind: str,
        shape: str,
        config: TuningConfig,
        score: float,
        backend: str | None = None,
        meta: dict | None = None,
    ) -> str:
        """Record a winner; returns its key. ``score`` is the measured
        figure of merit (docs/s, qps — higher is better), kept so a later
        re-tune can tell whether it actually improved on the record."""
        backend = backend if backend is not None else backend_sig()
        key = cache_key(kind, backend, shape)
        data = self._read()
        data["entries"][key] = {
            "kind": kind,
            "backend": backend,
            "shape": shape,
            "space_version": SPACE_VERSION,
            "config": config.overrides(),  # defaults stay implicit
            "config_hash": config.config_hash(),
            "score": float(score),
            "meta": meta or {},
        }
        self._write(data)
        return key

    def get(
        self, *, kind: str, shape: str, backend: str | None = None
    ) -> tuple[TuningConfig, bool]:
        """(config, hit). Every failure mode — miss, stale knob-space
        version, recorded-kind mismatch, unparsable knobs — returns
        ``(DEFAULT, False)``; a hit returns the recorded winner."""
        backend = backend if backend is not None else backend_sig()
        entry = self._read()["entries"].get(cache_key(kind, backend, shape))
        if not isinstance(entry, dict):
            return DEFAULT, False
        if entry.get("space_version") != SPACE_VERSION:
            return DEFAULT, False  # stale: knobs may have changed meaning
        if entry.get("kind") != kind:
            return DEFAULT, False  # a corrupted/hand-edited entry
        try:
            cfg = TuningConfig.from_dict(entry.get("config") or {}, strict=True)
        except (TypeError, ValueError):
            return DEFAULT, False
        return cfg, True

    def entry(self, *, kind: str, shape: str, backend: str | None = None) -> Any:
        """The raw recorded entry (score, meta, hash) or None — for tests
        and the autotune report."""
        backend = backend if backend is not None else backend_sig()
        return self._read()["entries"].get(cache_key(kind, backend, shape))


def best_config(
    kind: str,
    *,
    shape: str,
    backend: str | None = None,
    path: str | None = None,
) -> tuple[TuningConfig, bool]:
    """The one-call lookup: ``repro.tune.best_config("scan_job",
    shape=sig)`` → (winner-or-default, cache_hit)."""
    return TuneCache(path).get(kind=kind, shape=shape, backend=backend)
