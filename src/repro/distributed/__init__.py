from repro.distributed.sharding import AxisRules, RULES_SINGLE_POD, RULES_MULTI_POD, logical_to_spec

__all__ = ["AxisRules", "RULES_SINGLE_POD", "RULES_MULTI_POD", "logical_to_spec"]
