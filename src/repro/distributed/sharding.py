"""Mesh-axis vocabulary and logical→physical sharding rules.

Every model in the framework is written against *logical* axes; the mapping to
physical mesh axes lives here, so the same model code runs on the single-pod
``("data","model")`` mesh and the multi-pod ``("pod","data","model")`` mesh
(and on a laptop with a 1-device mesh for smoke tests).

Logical axes:
  ``dp``     — batch / corpus-shard / edge-shard axis set (pod composes here)
  ``tp``     — tensor/expert-parallel axis ("model")
  ``scan``   — corpus & candidate scan axis set: all mesh axes flattened
               (a MIREX scan wants *every* chip to own documents)
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRules:
    dp: tuple[str, ...]
    tp: str

    @property
    def all_axes(self) -> tuple[str, ...]:
        return (*self.dp, self.tp)

    @property
    def scan_axes(self) -> tuple[str, ...]:
        """Physical axes behind the logical "scan" axis — every mesh axis.

        The corpus-scan vocabulary `repro.cluster` plans over
        (`cluster.plan_for_mesh`, `cluster.search_mesh`): a MIREX scan wants
        all chips owning documents, so "scan" flattens the whole mesh.
        Deduplicated: on a single-axis mesh the degenerate
        :func:`rules_for_mesh` fallback maps dp and tp to the *same* axis,
        and a repeated name would double-count shards (and build an invalid
        duplicate-axis PartitionSpec).
        """
        return tuple(dict.fromkeys(self.all_axes))

    def spec(self, *logical: str | None) -> P:
        """Build a PartitionSpec from logical axis names per dim."""
        return P(*[logical_to_spec(self, name) for name in logical])

    def shard(self, mesh: Mesh, *logical: str | None) -> NamedSharding:
        return NamedSharding(mesh, self.spec(*logical))


def logical_to_spec(rules: AxisRules, name: str | None):
    if name is None:
        return None
    if name == "dp":
        return rules.dp if len(rules.dp) > 1 else rules.dp[0]
    if name == "tp":
        return rules.tp
    if name == "scan":
        return rules.all_axes
    raise ValueError(f"unknown logical axis {name!r}")


RULES_SINGLE_POD = AxisRules(dp=("data",), tp="model")
RULES_MULTI_POD = AxisRules(dp=("pod", "data"), tp="model")


def rules_for_mesh(mesh: Mesh) -> AxisRules:
    names = mesh.axis_names
    if "pod" in names:
        return RULES_MULTI_POD
    if names == ("data", "model"):
        return RULES_SINGLE_POD
    # degenerate test meshes: first axis = dp, last = tp
    return AxisRules(dp=tuple(names[:-1]) or (names[0],), tp=names[-1])


def constrain(x, mesh: Mesh, rules: AxisRules, *logical: str | None):
    """with_sharding_constraint via logical names (no-op off-mesh)."""
    return jax.lax.with_sharding_constraint(x, rules.shard(mesh, *logical))
