"""Gradient compression for the DP all-reduce (error-feedback top-k / sign).

At 1000+ nodes the data-parallel gradient all-reduce is the cross-pod
bottleneck (DCN links are ~10× slower than ICI). Two standard compressors,
both with **error feedback** (the residual of what was not transmitted is
carried to the next step, which restores convergence [Karimireddy'19]):

* ``topk``  — keep the k largest-|g| entries per tensor; exchange (values,
  indices); this is — again — the MIREX combiner bound applied to gradients:
  each shard contributes k entries, merge is a sum-scatter.
* ``sign``  — 1 bit/coordinate + per-tensor scale (signSGD with majority vote).

Used by ``launch/train.py --grad-compress`` inside a shard_map DP ring;
the dry-run default keeps the exact all-reduce.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: dict  # same structure as grads


def ef_init(params) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def _topk_compress_leaf(g: jax.Array, frac: float):
    """Keep top-k |values|; return (values, flat indices, shape)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    del vals
    picked = flat[idx]
    return picked, idx


def _topk_decompress_leaf(vals, idx, shape):
    import math

    flat = jnp.zeros((math.prod(shape),), vals.dtype)
    return flat.at[idx].add(vals).reshape(shape)


def topk_allreduce(grads, ef: ErrorFeedbackState, axis_name, *, frac: float = 0.01):
    """Error-feedback top-k all-reduce over ``axis_name`` (inside shard_map).

    Each shard transmits only ``frac`` of the coordinates (values+indices via
    a dense scatter + psum — on TPU the scatter+psum lowers to one fused
    all-reduce of the sparse-restored tensor; the *information* exchanged is
    k entries per shard, and the error-feedback residual keeps the rest).
    """

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        vals, idx = _topk_compress_leaf(acc, frac)
        sparse = _topk_decompress_leaf(vals, idx, acc.shape)
        new_r = acc - sparse  # what we did not transmit
        reduced = jax.lax.pmean(sparse, axis_name)
        return reduced.astype(g.dtype), new_r

    out = jax.tree.map(one, grads, ef.residual)
    new_grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, ErrorFeedbackState(residual=new_res)


def sign_allreduce(grads, ef: ErrorFeedbackState, axis_name):
    """Error-feedback signSGD with per-tensor L1 scale (1 bit/coord)."""

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        scale = jnp.mean(jnp.abs(acc))
        q = jnp.sign(acc) * scale
        new_r = acc - q
        reduced = jax.lax.pmean(q, axis_name)
        return reduced.astype(g.dtype), new_r

    out = jax.tree.map(one, grads, ef.residual)
    new_grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, ErrorFeedbackState(residual=new_res)
