"""AdamW + schedule + clipping, with ZeRO-1-style state sharding.

Optimizer moments are fp32 regardless of parameter dtype. ``opt_state_specs``
derives the moment shardings from the parameter shardings and *additionally*
shards any dp-replicated moment over the dp axes on its first divisible dim
(ZeRO-1): at 512 chips the moments of a replicated 2.6 B-param model drop from
21 GB/chip to <100 MB/chip. XLA inserts the corresponding reduce-scatter /
all-gather around the update.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import AxisRules


class AdamWState(NamedTuple):
    step: jax.Array  # int32 scalar
    m: dict
    v: dict


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_state_shapes(param_shapes, moment_dtype=jnp.float32) -> AdamWState:
    md = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(moment_dtype))
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(md, param_shapes),
        v=jax.tree.map(md, param_shapes),
    )


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr_t * u).astype(p.dtype),
            m2.astype(m.dtype),
            v2.astype(v.dtype),
        )

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm


def _zero1_spec(spec: P, shape: tuple, rules: AxisRules, dp_size: int) -> P:
    """Extra-shard a moment over dp on the first divisible unsharded dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a:
                used.add(a)
    if any(a in used for a in rules.dp):
        return spec  # already dp-sharded somewhere
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % dp_size == 0 and dim > 0:
            entries[i] = rules.dp if len(rules.dp) > 1 else rules.dp[0]
            return P(*entries)
    return spec


def opt_state_specs(param_specs, param_shapes, rules: AxisRules, dp_size: int, *, zero1: bool = True):
    """Moment shardings = param shardings (+ ZeRO-1 dp sharding)."""
    if zero1:
        mspec = jax.tree.map(
            lambda sp, sh: _zero1_spec(sp, sh.shape, rules, dp_size),
            param_specs,
            param_shapes,
            is_leaf=lambda x: isinstance(x, P),
        )
    else:
        mspec = param_specs
    return AdamWState(step=P(), m=mspec, v=mspec)
