"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container): the kernel body
executes in Python on CPU for correctness; on a TPU backend the same call
compiles to Mosaic.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attn import flash_attention_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.score_topk import score_topk_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("k", "block_d"))
def score_topk(q, d, *, k: int, block_d: int = 1024):
    """Fused streaming score+top-k (MIREX map+combine). -> (scores, ids)."""
    return score_topk_pallas(q, d, k=k, block_d=block_d, interpret=_interpret_default())


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "cap", "block_q", "block_k")
)
def flash_attention(q, k, v, *, causal=True, window=None, cap=None,
                    block_q: int = 128, block_k: int = 128):
    """Blockwise attention (causal/window/softcap/GQA). q [B,S,H,hd]."""
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, cap=cap,
        block_q=block_q, block_k=block_k, interpret=_interpret_default(),
    )


@functools.partial(jax.jit, static_argnames=("window", "cap", "block_s"))
def flash_decode(q, k_cache, v_cache, t, *, window=None, cap=None, block_s: int = 512):
    """Split-KV single-token decode. q [B,H,hd], caches [B,S,KV,hd]."""
    return flash_decode_pallas(
        q, k_cache, v_cache, t, window=window, cap=cap,
        block_s=block_s, interpret=_interpret_default(),
    )
