"""Jitted public wrappers for the Pallas kernels.

Backend selection: by default kernels run ``interpret=True`` off-TPU (this
container) — the kernel body executes in Python on CPU for correctness —
and compile to Mosaic on a TPU backend. Override either way with the
``REPRO_KERNEL_BACKEND`` env var (``auto`` | ``interpret`` | ``compiled``)
or programmatically with :func:`set_kernel_backend`.

Block/tile geometry: every wrapper's block argument defaults to ``None`` =
"the active :class:`repro.tune.TuningConfig`'s value" — resolved *before*
the jit boundary, so the block size is an ordinary static argument of the
compiled program and two different tunings can never alias one trace. The
default config reproduces the historical hand-picked constants (q/k blocks
128, decode block 512, lexical block 512 / tile 16, dense block 1024)
bit-for-bit; block geometry only regroups value-deterministic merges, so
tuning it changes speed, never output bytes.
"""

from __future__ import annotations

import functools
import os

import jax

from repro.kernels.flash_attn import flash_attention_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.lexical_scan import lexical_scan_topk_pallas
from repro.kernels.score_topk import score_topk_pallas
from repro.tune import config as tune_config

_BACKENDS = ("auto", "interpret", "compiled")
_backend_override: str | None = None


def set_kernel_backend(mode: str | None) -> None:
    """Force the Pallas execution mode for all kernel wrappers.

    ``"interpret"`` runs kernel bodies in Python (portable, slow),
    ``"compiled"`` always lowers to the real backend (Mosaic on TPU),
    ``"auto"``/``None`` restores the default backend sniffing. Clears all
    jit caches (``jax.clear_caches``) so already-traced callers — including
    outer jitted closures like the serve sessions — retrace with the new
    mode on their next call.
    """
    global _backend_override
    if mode is not None and mode not in _BACKENDS:
        raise ValueError(f"unknown kernel backend {mode!r}; expected one of {_BACKENDS}")
    _backend_override = None if mode in (None, "auto") else mode
    jax.clear_caches()


def kernel_backend() -> str:
    """Resolved mode: explicit override > env var > backend sniffing."""
    mode = _backend_override or os.environ.get("REPRO_KERNEL_BACKEND", "auto")
    if mode not in _BACKENDS:
        raise ValueError(
            f"REPRO_KERNEL_BACKEND={mode!r} invalid; expected one of {_BACKENDS}"
        )
    if mode == "auto":
        return "compiled" if jax.default_backend() == "tpu" else "interpret"
    return mode


def _interpret_default() -> bool:
    return kernel_backend() == "interpret"


@functools.partial(jax.jit, static_argnames=("k", "block_d", "merge"))
def _score_topk_jit(q, d, *, k: int, block_d: int, merge: str):
    return score_topk_pallas(
        q, d, k=k, block_d=block_d, merge=merge, interpret=_interpret_default()
    )


def score_topk(q, d, *, k: int, block_d: int | None = None, merge: str = "bitonic"):
    """Fused streaming score+top-k (MIREX map+combine). -> (scores, ids).

    ``merge="bitonic"`` is the k-bounded combiner (O(k log k) per block);
    ``merge="concat"`` is the legacy full re-sort, kept for parity checks.
    ``block_d=None`` takes the active tuning's ``dense_block_d`` (1024 when
    untuned — the historical default).
    """
    if block_d is None:
        block_d = tune_config.active().config.dense_block_d or 1024
    return _score_topk_jit(q, d, k=k, block_d=block_d, merge=merge)


@functools.partial(
    jax.jit, static_argnames=("modes", "k", "block_d", "tile_d", "pack_spec")
)
def _lexical_scan_topk_jit(
    q_tokens, weights, ab, d_tokens, d_len, *, modes, k: int,
    block_d: int, tile_d: int, pack_spec,
):
    return lexical_scan_topk_pallas(
        q_tokens, weights, ab, d_tokens, d_len,
        modes=modes, k=k, block_d=block_d, tile_d=tile_d,
        interpret=_interpret_default(), pack_spec=pack_spec,
    )


def lexical_scan_topk(
    q_tokens, weights, ab, d_tokens, d_len, *, modes, k: int,
    block_d: int | None = None, tile_d: int | None = None, pack_spec=None,
):
    """Fused multi-model lexical scan (shared on-chip tf + per-model scorer
    epilogues + resident top-k). -> ``(scores, ids) [n_models, n_q, k]``.

    ``modes`` is the static tuple of `scoring.EpilogueMode`; build all three
    arguments from a scorer grid with `scoring.lexical_epilogues`.
    ``block_d``/``tile_d`` default to the active tuning's ``lex_block_d`` /
    ``lex_tile_d`` (512 / 16 when untuned). ``pack_spec`` (a frozen
    `packing.PackSpec`, static like the block geometry) marks ``d_tokens``
    as packed and turns on the in-VMEM tile decode — bit-identical results,
    fewer bytes streamed.
    """
    if block_d is None or tile_d is None:
        cfg = tune_config.active().config
        if block_d is None:
            block_d = cfg.lex_block_d or 512
        if tile_d is None:
            tile_d = cfg.lex_tile_d
    return _lexical_scan_topk_jit(
        q_tokens, weights, ab, d_tokens, d_len,
        modes=modes, k=k, block_d=block_d, tile_d=tile_d, pack_spec=pack_spec,
    )


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "cap", "block_q", "block_k")
)
def _flash_attention_jit(q, k, v, *, causal, window, cap, block_q, block_k):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, cap=cap,
        block_q=block_q, block_k=block_k, interpret=_interpret_default(),
    )


def flash_attention(q, k, v, *, causal=True, window=None, cap=None,
                    block_q: int | None = None, block_k: int | None = None):
    """Blockwise attention (causal/window/softcap/GQA). q [B,S,H,hd].

    ``block_q``/``block_k`` default to the active tuning's
    ``flash_block_q``/``flash_block_k`` (128/128 when untuned).
    """
    if block_q is None or block_k is None:
        cfg = tune_config.active().config
        block_q = cfg.flash_block_q if block_q is None else block_q
        block_k = cfg.flash_block_k if block_k is None else block_k
    return _flash_attention_jit(
        q, k, v, causal=causal, window=window, cap=cap,
        block_q=block_q, block_k=block_k,
    )


@functools.partial(jax.jit, static_argnames=("window", "cap", "block_s"))
def _flash_decode_jit(q, k_cache, v_cache, t, *, window, cap, block_s):
    return flash_decode_pallas(
        q, k_cache, v_cache, t, window=window, cap=cap,
        block_s=block_s, interpret=_interpret_default(),
    )


def flash_decode(
    q, k_cache, v_cache, t, *, window=None, cap=None, block_s: int | None = None
):
    """Split-KV single-token decode. q [B,H,hd], caches [B,S,KV,hd].

    ``block_s=None`` takes the active tuning's ``decode_block_s`` (512
    when untuned).
    """
    if block_s is None:
        block_s = tune_config.active().config.decode_block_s
    return _flash_decode_jit(
        q, k_cache, v_cache, t, window=window, cap=cap, block_s=block_s
    )
