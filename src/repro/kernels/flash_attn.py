"""Blockwise (flash) attention Pallas kernel: causal + sliding-window +
gemma2 logit softcap + native GQA via head-index mapping.

Grid ``(B, H, n_q_blocks, n_kv_blocks)`` — the kv dimension is innermost and
sequential, carrying the online-softmax state ``(m, l, acc)`` in VMEM
scratch. The kv BlockSpec maps query head ``h`` to its GQA group
``h * KV // H``, so grouped KV is read directly from the ``[B, S, KV, hd]``
layout with no expansion. Scores tile ``[block_q, block_k]`` lives only in
VMEM (this is the kernel the pure-JAX ``chunked_attention`` mirrors; the
model uses that HLO on the dry-run host and this kernel on real TPUs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, window, cap, block_q, block_k, n_kv_blocks,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]  # [block_q, hd]
    k = k_ref[0, :, 0, :]  # [block_k, hd]
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [block_q, block_k]
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    pos_q = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    pos_k = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        ok &= pos_k <= pos_q
    if window is not None:
        ok &= pos_q - pos_k < window
    s = jnp.where(ok, s, NEG)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, S, KV, hd]
    v: jax.Array,  # [B, S, KV, hd]
    *,
    causal: bool = True,
    window: int | None = None,
    cap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, s, h, hd = q.shape
    kv = k.shape[2]
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nqb, nkb = s // block_q, s // block_k
    kernel = functools.partial(
        _flash_kernel,
        scale=hd**-0.5,
        causal=causal,
        window=window,
        cap=cap,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=nkb,
    )
    grp = h // kv
    return pl.pallas_call(
        kernel,
        grid=(b, h, nqb, nkb),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda bb, hh, qi, ki: (bb, qi, hh, 0)),
            pl.BlockSpec(
                (1, block_k, 1, hd), lambda bb, hh, qi, ki: (bb, ki, hh // grp, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, hd), lambda bb, hh, qi, ki: (bb, ki, hh // grp, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, hd), lambda bb, hh, qi, ki: (bb, qi, hh, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, s, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
