"""Fused Pallas lexical-scan kernel — the paper's *actual* hot loop in VMEM.

MIREX's headline claim is that sequentially scanning raw documents is fast
enough for large-scale IR experiments; this kernel makes the raw-token scan
bandwidth-bound on the document stream, the way the paper argues it should
be. Each TPU grid step streams one ``[block_d, L_d]`` document-token tile
HBM→VMEM and:

1. **tf reduction on-chip** — query-term frequencies are accumulated by
   reducing over ``L_d`` in ``tile_d``-wide sub-tiles, so peak live memory
   is ``O(n_q · L_q · block_d · tile_d)`` and the rank-4
   ``[n_q, L_q, n_d, L_d]`` equality cross-product never exists anywhere.
2. **scorer epilogues on the VPU** — each model in the grid applies its
   declarative epilogue spec (`scoring.EpilogueMode` +
   weight table / normalization scalars) to the *shared* tf block via
   `scoring.apply_epilogue` — literally the same code the pure-JAX fallback
   runs, so kernel-vs-host score parity is bitwise given the same tf.
3. **resident top-k fold** — each model's block scores fold into a resident
   ``[n_models, n_q, k]`` state with the k-bounded bitonic combiner
   (`score_topk.bitonic_merge_desc`): the output refs double as the running
   state because the TPU grid executes sequentially (combiner semantics).

Because the tf reduction — the dominant cost of a raw-token chunk — is
computed once per tile and shared by every epilogue, a whole **model grid
scans in a single kernel pass**: PR 2's experiment-side amortization
(claim C1 on the model axis), moved from the XLA path into VMEM.

BlockSpecs: queries ``[n_q, L_q]``, weights ``[n_models, n_q, L_q]`` and
normalization scalars ``[n_models, 2]`` are resident across steps; doc
tokens ``[block_d, L_d]`` and lengths ``[1, block_d]`` are streamed;
outputs ``[n_models, n_q, k]`` are pinned to block (0, 0, 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import packing
from repro.core.pipeline import next_pow2
from repro.core.scoring import PAD_TOKEN, EpilogueMode, LexicalEpilogue
from repro.core.scoring import apply_epilogue
from repro.kernels.score_topk import _pad_desc, bitonic_merge_desc


def _block_term_frequencies(q_tok, d_tok, *, tile_d: int) -> jax.Array:
    """On-chip tf for one doc tile: ``[n_q, L_q], [block_d, L_d] -> [n_q, L_q, block_d]``.

    Reduces over ``L_d`` in ``tile_d`` sub-tiles with an int32 accumulator —
    identical accumulation order (and therefore identical integers) to the
    tiled host fallback in `scoring.term_frequencies`. ``L_d`` must be a
    multiple of ``tile_d`` (the wrapper pads with PAD_TOKEN); query pads are
    pre-remapped by the wrapper so no validity mask is needed here.
    """
    n_q, l_q = q_tok.shape
    block_d, l_d = d_tok.shape

    def fold(t, acc):
        sub = jax.lax.dynamic_slice_in_dim(d_tok, t * tile_d, tile_d, axis=1)
        eq = q_tok[:, :, None, None] == sub[None, None, :, :]
        return acc + jnp.sum(eq, axis=-1, dtype=jnp.int32)

    acc0 = jnp.zeros((n_q, l_q, block_d), jnp.int32)
    tf = jax.lax.fori_loop(0, l_d // tile_d, fold, acc0)
    return tf.astype(jnp.float32)


def _lexical_scan_kernel(
    q_ref,  # [n_q, L_q] int32 — resident (pads remapped to PAD_TOKEN - 1)
    w_ref,  # [n_models, n_q, L_q] f32 — resident weight tables
    ab_ref,  # [n_models, 2] f32 — resident (alpha, beta) per model
    d_ref,  # [block_d, L_d] int32 — or packed [block_d, W] when pack_spec
    dlen_ref,  # [1, block_d] int32 — this step's doc lengths
    out_s_ref,  # [n_models, n_q, k] f32 — resident top-k scores
    out_i_ref,  # [n_models, n_q, k] int32 — resident top-k ids
    *,
    modes: tuple[EpilogueMode, ...],
    block_d: int,
    k: int,
    tile_d: int,
    pack_spec: packing.PackSpec | None = None,
    l_dec: int = 0,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_s_ref[...] = jnp.full_like(out_s_ref, -jnp.inf)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    q = q_ref[...]
    d = d_ref[...]
    if pack_spec is not None:
        # decode the packed tile in VMEM right before the tf sub-tile loop:
        # the stream tile stays `pack_spec.packed_width` wide in HBM and the
        # int32 [block_d, L_d] view only ever exists on-chip. `l_dec` is the
        # tile_d-aligned unpacked width (same PAD_TOKEN fill as the unpacked
        # wrapper path), so the tf reduction below is identical either way.
        d = packing.unpack_tokens(d, pack_spec, pad_to=l_dec)
    dlen = dlen_ref[0, :]  # [block_d]
    tf = _block_term_frequencies(q, d, tile_d=tile_d)  # shared by the grid

    k_pad = next_pow2(k)
    cand_k = min(k, block_d)
    for m, mode in enumerate(modes):  # n_models is static: unrolled epilogues
        ep = LexicalEpilogue(w_ref[m], ab_ref[m, 0], ab_ref[m, 1])
        s = apply_epilogue(mode, ep, tf, dlen)  # [n_q, block_d], VPU only
        ids = step * block_d + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        cand_s, cand_pos = jax.lax.top_k(s, cand_k)  # sorted descending
        cand_i = jnp.take_along_axis(ids, cand_pos, axis=1)
        # zero-length rows score -inf; blank their ids so the merged state
        # carries the host fold's (-inf, -1) empty-slot sentinel, never a
        # padded corpus row
        cand_i = jnp.where(cand_s == -jnp.inf, -1, cand_i)
        cand_s, cand_i = _pad_desc(cand_s, cand_i, k_pad)
        state_s, state_i = _pad_desc(out_s_ref[m], out_i_ref[m], k_pad)
        top_s, top_i = bitonic_merge_desc(state_s, state_i, cand_s, cand_i)
        out_s_ref[m] = top_s[:, :k]
        out_i_ref[m] = top_i[:, :k]


def lexical_scan_topk_pallas(
    q_tokens: jax.Array,  # [n_q, L_q] int32, PAD_TOKEN-padded
    weights: jax.Array,  # [n_models, n_q, L_q] f32
    ab: jax.Array,  # [n_models, 2] f32
    d_tokens: jax.Array,  # [n_d, L_d] int32, PAD_TOKEN-padded — or packed [n_d, W]
    d_len: jax.Array,  # [n_d] int32
    *,
    modes: tuple[EpilogueMode, ...],
    k: int,
    block_d: int = 512,
    tile_d: int = 16,
    interpret: bool = True,
    pack_spec: packing.PackSpec | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused multi-model lexical scan -> ``(scores, ids) [n_models, n_q, k]``.

    Ids are block-local (0-based over ``n_d``); empty slots carry the
    ``(-inf, -1)`` sentinels of `topk.TopKState`.

    With ``pack_spec``, ``d_tokens`` is the packed matrix from
    `packing.pack_tokens` — the stream tile is ``pack_spec.packed_width``
    columns instead of ``L_d`` (1/4 to 1/2 the HBM traffic) and each tile is
    decoded in VMEM before the tf loop. The decode is exact, so results are
    bit-identical to the unpacked call.
    """
    n_q, l_q = q_tokens.shape
    n_d = d_tokens.shape[0]
    n_models = weights.shape[0]
    if len(modes) != n_models:
        raise ValueError(f"{len(modes)} modes for {n_models} weight tables")
    if n_d % block_d:
        raise ValueError(f"{n_d} docs not divisible by block_d {block_d}")
    # query pads -> a token that matches nothing (doc pads are PAD_TOKEN,
    # real tokens >= 0), replacing the doc-side validity mask
    q_safe = jnp.where(q_tokens == PAD_TOKEN, jnp.int32(PAD_TOKEN - 1), q_tokens)
    if pack_spec is not None:
        if d_tokens.shape[1] != pack_spec.packed_width:
            raise ValueError(
                f"packed width {d_tokens.shape[1]} != spec {pack_spec.packed_width}"
            )
        l_d = d_tokens.shape[1]  # streamed width: the packed one
        l_dec = pack_spec.length + (-pack_spec.length) % tile_d
    else:
        l_d = d_tokens.shape[1]
        l_dec = 0
        pad = (-l_d) % tile_d
        if pad:
            d_tokens = jnp.pad(
                d_tokens, ((0, 0), (0, pad)), constant_values=PAD_TOKEN
            )
            l_d += pad
    kernel = functools.partial(
        _lexical_scan_kernel, modes=modes, block_d=block_d, k=k, tile_d=tile_d,
        pack_spec=pack_spec, l_dec=l_dec,
    )
    return pl.pallas_call(
        kernel,
        grid=(n_d // block_d,),
        in_specs=[
            pl.BlockSpec((n_q, l_q), lambda i: (0, 0)),  # Q resident in VMEM
            pl.BlockSpec((n_models, n_q, l_q), lambda i: (0, 0, 0)),  # weights resident
            pl.BlockSpec((n_models, 2), lambda i: (0, 0)),  # norm scalars resident
            pl.BlockSpec((block_d, l_d), lambda i: (i, 0)),  # doc tokens streamed
            pl.BlockSpec((1, block_d), lambda i: (0, i)),  # doc lengths streamed
        ],
        out_specs=[
            pl.BlockSpec((n_models, n_q, k), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_models, n_q, k), lambda i: (0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_models, n_q, k), jnp.float32),
            jax.ShapeDtypeStruct((n_models, n_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(q_safe, weights, ab, d_tokens, d_len.reshape(1, n_d))
