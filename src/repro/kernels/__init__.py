"""Pallas TPU kernels (validated via interpret=True on the dry-run host):
score_topk (MIREX fused map+combine), flash_attn, flash_decode."""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
