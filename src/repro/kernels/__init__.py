"""Pallas TPU kernels (validated via interpret=True on the dry-run host):
score_topk (MIREX fused map+combine, dense), lexical_scan (fused raw-token
scan: on-chip tf + scorer epilogues + resident multi-model top-k),
flash_attn, flash_decode."""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
