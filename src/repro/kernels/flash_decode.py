"""Split-KV decode Pallas kernel — the MIREX combine step as attention.

One new token attends over a long KV cache: the cache is split into
sequence blocks (grid), each block produces the mergeable partial
``(m, l, acc)`` and the sequential grid folds it — the in-kernel analogue of
the cross-chip LSE merge in ``models/attention.py`` (which handles the
shard level; this kernel is the intra-chip split). GQA native: grid is
(B, KV) over batch and kv heads; the q block carries that head's group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _decode_kernel(
    t_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, window, cap, block_s, n_s_blocks,
):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    t = t_ref[0]
    q = q_ref[0, 0]  # [G, hd]
    k = k_ref[0, :, 0, :]  # [block_s, hd]
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [G, block_s]
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = pos <= t
    if window is not None:
        ok &= t - pos < window
    s = jnp.where(ok, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(si == n_s_blocks - 1)
    def _finish():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_decode_pallas(
    q: jax.Array,  # [B, H, hd] — one new token per sequence
    k_cache: jax.Array,  # [B, S, KV, hd]
    v_cache: jax.Array,  # [B, S, KV, hd]
    t: jax.Array,  # scalar int32 current position
    *,
    window: int | None = None,
    cap: float | None = None,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    assert s % block_s == 0, (s, block_s)
    nsb = s // block_s
    kernel = functools.partial(
        _decode_kernel,
        scale=hd**-0.5,
        window=window,
        cap=cap,
        block_s=block_s,
        n_s_blocks=nsb,
    )
    q4 = q.reshape(b, kv, g, hd)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nsb),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # t scalar
            pl.BlockSpec((1, 1, g, hd), lambda bb, hh, si: (bb, hh, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda bb, hh, si: (bb, si, hh, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda bb, hh, si: (bb, si, hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bb, hh, si: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(t, jnp.int32).reshape(1), q4, k_cache, v_cache)
    return out.reshape(b, h, hd)
