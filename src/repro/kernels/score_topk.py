"""Fused streaming score + top-k Pallas kernel — MIREX map+combine in VMEM.

The paper's hot loop scores every query against a stream of documents and
keeps a running top-k. On TPU that is: stream document blocks HBM→VMEM, hit
the MXU with a ``[n_q, dim] × [dim, block_d]`` tile, and fold the block's
scores into a resident ``[n_q, k]`` top-k state — the full ``[n_q, n_d]``
score matrix never exists, so HBM traffic is ``O(n_d · dim)`` instead of
``O(n_q · n_d)``. The TPU grid executes sequentially, which is exactly the
combiner semantics: the output refs double as the running state.

BlockSpecs: Q ``(n_q, dim)`` resident across steps; D ``(block_d, dim)``
streamed; outputs ``(n_q, k)`` pinned to block (0, 0). MXU alignment wants
``n_q % 8 == 0``, ``dim % 128 == 0``, ``block_d % 128 == 0``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_topk_kernel(q_ref, d_ref, out_s_ref, out_i_ref, *, block_d: int, k: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_s_ref[...] = jnp.full_like(out_s_ref, -jnp.inf)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    q = q_ref[...]  # [n_q, dim] — resident
    d = d_ref[...]  # [block_d, dim] — this step's stream block
    s = jax.lax.dot_general(
        q, d, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [n_q, block_d] on the MXU
    ids = step * block_d + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)

    # combiner fold: merge block candidates into the running state
    cat_s = jnp.concatenate([out_s_ref[...], s], axis=1)
    cat_i = jnp.concatenate([out_i_ref[...], ids], axis=1)
    top_s, pos = jax.lax.top_k(cat_s, k)
    out_s_ref[...] = top_s
    out_i_ref[...] = jnp.take_along_axis(cat_i, pos, axis=1)


def score_topk_pallas(
    q: jax.Array,  # [n_q, dim]
    d: jax.Array,  # [n_d, dim]
    *,
    k: int,
    block_d: int = 1024,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    n_q, dim = q.shape
    n_d, _ = d.shape
    assert n_d % block_d == 0, (n_d, block_d)
    kernel = functools.partial(_score_topk_kernel, block_d=block_d, k=k)
    return pl.pallas_call(
        kernel,
        grid=(n_d // block_d,),
        in_specs=[
            pl.BlockSpec((n_q, dim), lambda i: (0, 0)),  # Q resident in VMEM
            pl.BlockSpec((block_d, dim), lambda i: (i, 0)),  # D streamed
        ],
        out_specs=[
            pl.BlockSpec((n_q, k), lambda i: (0, 0)),
            pl.BlockSpec((n_q, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_q, k), jnp.float32),
            jax.ShapeDtypeStruct((n_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, d)
