"""Fused streaming score + top-k Pallas kernel — MIREX map+combine in VMEM.

The paper's hot loop scores every query against a stream of documents and
keeps a running top-k. On TPU that is: stream document blocks HBM→VMEM, hit
the MXU with a ``[n_q, dim] × [dim, block_d]`` tile, and fold the block's
scores into a resident ``[n_q, k]`` top-k state — the full ``[n_q, n_d]``
score matrix never exists, so HBM traffic is ``O(n_d · dim)`` instead of
``O(n_q · n_d)``. The TPU grid executes sequentially, which is exactly the
combiner semantics: the output refs double as the running state.

Combiner fold (``merge="bitonic"``, the default): the resident state is kept
sorted descending, so folding a block only needs the block's own top-k
(``lax.top_k`` over ``block_d``, sorted descending for free) merged against
the state. Two sorted-k lists concatenated head-to-tail form a bitonic
sequence, so a single O(k log k) bitonic *merge* network — ``log2(2k)``
compare-exchange stages, each a reshape + elementwise max/min on the VPU —
re-sorts them, instead of the legacy ``concatenate + top_k`` re-sort over
``k + block_d`` candidates (``merge="concat"``, kept for parity testing).

BlockSpecs: Q ``(n_q, dim)`` resident across steps; D ``(block_d, dim)``
streamed; outputs ``(n_q, k)`` pinned to block (0, 0). MXU alignment wants
``n_q % 8 == 0``, ``dim % 128 == 0``, ``block_d % 128 == 0``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pipeline import next_pow2


def bitonic_merge_desc(
    a_s: jax.Array, a_i: jax.Array, b_s: jax.Array, b_i: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Merge two ``[..., m]`` (score, id) lists sorted by (score desc, id
    asc); keep the top m under that same lexicographic order.

    ``a ++ reverse(b)`` is bitonic (descending then ascending), so one
    bitonic merge network — ``log2(2m) `` compare-exchange stages expressed
    as reshapes + ``where`` (VPU-friendly: no gathers) — yields the 2m
    values fully sorted; the first m are the merged top-m. ``m`` must be a
    power of two (pad with ``-inf``/``-1`` first).

    Ties break toward the **smaller id**. Scan candidates carry strictly
    increasing doc ids across stream blocks, so this is exactly
    ``lax.top_k``'s positional tie-break on the host fold
    (`topk.update`) — what keeps kernel and host rankings id-exact even on
    the equal scores lexical scoring mass-produces (e.g. every
    zero-match document under BM25).
    """
    m = a_s.shape[-1]
    assert m & (m - 1) == 0, f"bitonic merge needs power-of-two width, got {m}"
    lead = a_s.shape[:-1]
    s = jnp.concatenate([a_s, b_s[..., ::-1]], axis=-1)
    i = jnp.concatenate([a_i, b_i[..., ::-1]], axis=-1)
    length = 2 * m
    stride = m
    while stride >= 1:
        sr = s.reshape(*lead, length // (2 * stride), 2, stride)
        ir = i.reshape(*lead, length // (2 * stride), 2, stride)
        lo_s, hi_s = sr[..., 0, :], sr[..., 1, :]
        lo_i, hi_i = ir[..., 0, :], ir[..., 1, :]
        # descending by score, ascending by id on ties: max to lower position
        keep = (lo_s > hi_s) | ((lo_s == hi_s) & (lo_i <= hi_i))
        max_s = jnp.where(keep, lo_s, hi_s)
        min_s = jnp.where(keep, hi_s, lo_s)
        max_i = jnp.where(keep, lo_i, hi_i)
        min_i = jnp.where(keep, hi_i, lo_i)
        s = jnp.stack([max_s, min_s], axis=-2).reshape(*lead, length)
        i = jnp.stack([max_i, min_i], axis=-2).reshape(*lead, length)
        stride //= 2
    return s[..., :m], i[..., :m]


def _pad_desc(s: jax.Array, i: jax.Array, width: int) -> tuple[jax.Array, jax.Array]:
    """Right-pad descending-sorted lists with (-inf, -1) sentinels."""
    pad = width - s.shape[-1]
    if pad == 0:
        return s, i
    widths = [(0, 0)] * (s.ndim - 1) + [(0, pad)]
    return (
        jnp.pad(s, widths, constant_values=-jnp.inf),
        jnp.pad(i, widths, constant_values=-1),
    )


def _score_topk_kernel(
    q_ref, d_ref, out_s_ref, out_i_ref, *, block_d: int, k: int, merge: str
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_s_ref[...] = jnp.full_like(out_s_ref, -jnp.inf)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    q = q_ref[...]  # [n_q, dim] — resident
    d = d_ref[...]  # [block_d, dim] — this step's stream block
    s = jax.lax.dot_general(
        q, d, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [n_q, block_d] on the MXU
    ids = step * block_d + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)

    if merge == "concat":
        # legacy combiner: re-sort all k + block_d candidates every step
        cat_s = jnp.concatenate([out_s_ref[...], s], axis=1)
        cat_i = jnp.concatenate([out_i_ref[...], ids], axis=1)
        top_s, pos = jax.lax.top_k(cat_s, k)
        out_s_ref[...] = top_s
        out_i_ref[...] = jnp.take_along_axis(cat_i, pos, axis=1)
        return

    # k-bounded combiner: only the block's top-k ever meets the state
    k_pad = next_pow2(k)
    cand_k = min(k, block_d)
    cand_s, cand_pos = jax.lax.top_k(s, cand_k)  # sorted descending
    cand_i = jnp.take_along_axis(ids, cand_pos, axis=1)
    cand_s, cand_i = _pad_desc(cand_s, cand_i, k_pad)
    state_s, state_i = _pad_desc(out_s_ref[...], out_i_ref[...], k_pad)
    top_s, top_i = bitonic_merge_desc(state_s, state_i, cand_s, cand_i)
    out_s_ref[...] = top_s[:, :k]
    out_i_ref[...] = top_i[:, :k]


def score_topk_pallas(
    q: jax.Array,  # [n_q, dim]
    d: jax.Array,  # [n_d, dim]
    *,
    k: int,
    block_d: int = 1024,
    interpret: bool = True,
    merge: str = "bitonic",
) -> tuple[jax.Array, jax.Array]:
    if merge not in ("bitonic", "concat"):
        raise ValueError(f"unknown merge {merge!r}; expected 'bitonic' or 'concat'")
    n_q, dim = q.shape
    n_d, _ = d.shape
    assert n_d % block_d == 0, (n_d, block_d)
    kernel = functools.partial(_score_topk_kernel, block_d=block_d, k=k, merge=merge)
    return pl.pallas_call(
        kernel,
        grid=(n_d // block_d,),
        in_specs=[
            pl.BlockSpec((n_q, dim), lambda i: (0, 0)),  # Q resident in VMEM
            pl.BlockSpec((block_d, dim), lambda i: (i, 0)),  # D streamed
        ],
        out_specs=[
            pl.BlockSpec((n_q, k), lambda i: (0, 0)),
            pl.BlockSpec((n_q, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_q, k), jnp.float32),
            jax.ShapeDtypeStruct((n_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, d)
