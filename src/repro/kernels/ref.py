"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def score_topk_ref(q, d, *, k):
    """Materialize all scores; top-k per query."""
    s = (q.astype(jnp.float32) @ d.astype(jnp.float32).T)
    scores, ids = jax.lax.top_k(s, k)
    return scores, ids.astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal=True, window=None, cap=None):
    """Full-matrix softmax attention with GQA/window/softcap."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    k_exp = jnp.repeat(k, g, axis=2)
    v_exp = jnp.repeat(v, g, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k_exp, preferred_element_type=jnp.float32)
    sc = sc * (hd**-0.5)
    if cap is not None:
        sc = cap * jnp.tanh(sc / cap)
    pos = jnp.arange(s)
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= pos[None, :] <= pos[:, None]
    if window is not None:
        ok &= pos[:, None] - pos[None, :] < window
    sc = jnp.where(ok[None, None], sc, NEG)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v_exp)


def flash_decode_ref(q, k_cache, v_cache, t, *, window=None, cap=None):
    """One-token attention over a cache, positions <= t."""
    b, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    sc = jnp.einsum(
        "bkgd,bskd->bkgs", q.reshape(b, kv, g, hd), k_cache,
        preferred_element_type=jnp.float32,
    ) * (hd**-0.5)
    if cap is not None:
        sc = cap * jnp.tanh(sc / cap)
    pos = jnp.arange(k_cache.shape[1])
    ok = pos <= t
    if window is not None:
        ok &= t - pos < window
    sc = jnp.where(ok[None, None, None], sc, NEG)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, h, hd)
