"""Sharded, atomic, elastic checkpointing.

Fault-tolerance contract (DESIGN §5):
  * **atomic** — a checkpoint directory is written under ``.tmp-`` and
    renamed into place; a crash mid-write can never corrupt the latest good
    step (Hadoop's rename-commit, kept on purpose).
  * **sharded** — each leaf is saved as one ``.npy``; at multi-host scale
    each host would save only its addressable shards (the single-host
    container saves everything, same layout).
  * **elastic** — ``restore(..., shardings=)`` device_puts every leaf under
    the *current* mesh's NamedSharding, so a job restarted on a different
    topology (16×16 ↔ 2×16×16, or a degraded pod) resumes from the same
    bytes — elastic scaling without conversion jobs.

Leaf paths are flattened with ``jax.tree_util.keystr`` into a manifest, so
structure changes are detected instead of silently mis-zipped.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

# numpy can't serialize ml_dtypes (bfloat16 etc.) natively; store them as
# same-width unsigned ints and record the true dtype in the manifest.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves], treedef


def save(ckpt_dir: str, step: int, tree) -> str:
    """Write checkpoint for ``step``; returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp-step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    named, _ = _flatten(tree)
    manifest = []
    for i, (key, leaf) in enumerate(named):
        arr = np.asarray(jax.device_get(leaf))
        true_dtype = str(arr.dtype)
        if true_dtype in _VIEW_AS:
            arr = arr.view(_VIEW_AS[true_dtype])
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest.append({"key": key, "file": fname, "shape": list(arr.shape), "dtype": true_dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit
    return final


def all_steps(ckpt_dir: str) -> list[int]:
    """Committed checkpoint steps (ascending); uncommitted .tmp dirs excluded."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))
    )


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def prune(ckpt_dir: str, keep: int) -> list[int]:
    """Delete all but the newest ``keep`` checkpoints; returns removed steps.

    Bounds the disk footprint of segment-checkpointed scan jobs (one commit
    per corpus segment) without ever touching the newest good step.
    """
    if keep < 1:
        raise ValueError(f"keep must be >= 1, got {keep}")
    steps = all_steps(ckpt_dir)
    drop = steps[:-keep] if len(steps) > keep else []
    for s in drop:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
    return drop


def restore(ckpt_dir: str, step: int, tree_like, *, shardings=None):
    """Load ``step`` into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedShardings (the *current*
    mesh) — this is the elastic-rescale path.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    named, treedef = _flatten(tree_like)
    by_key = {m["key"]: m for m in manifest["leaves"]}
    if set(by_key) != {k for k, _ in named}:
        missing = {k for k, _ in named} ^ set(by_key)
        raise ValueError(f"checkpoint structure mismatch; differing keys: {sorted(missing)[:5]}")
    shard_named = None
    if shardings is not None:
        shard_named, _ = _flatten(shardings)
        shard_named = dict(shard_named)
    leaves = []
    for key, like in named:
        meta = by_key[key]
        arr = np.load(os.path.join(d, meta["file"]))
        if meta["dtype"] in _VIEW_AS:
            arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
        if shard_named is not None:
            leaves.append(jax.device_put(arr, shard_named[key]))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
