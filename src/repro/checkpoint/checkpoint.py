"""Sharded, atomic, elastic checkpointing.

Fault-tolerance contract (DESIGN §5):
  * **atomic** — a checkpoint directory is written under ``.tmp-`` and
    renamed into place; a crash mid-write can never corrupt the latest good
    step (Hadoop's rename-commit, kept on purpose).
  * **sharded** — each leaf is saved as one ``.npy``; at multi-host scale
    each host would save only its addressable shards (the single-host
    container saves everything, same layout).
  * **elastic** — ``restore(..., shardings=)`` device_puts every leaf under
    the *current* mesh's NamedSharding, so a job restarted on a different
    topology (16×16 ↔ 2×16×16, or a degraded pod) resumes from the same
    bytes — elastic scaling without conversion jobs.

Leaf paths are flattened with ``jax.tree_util.keystr`` into a manifest, so
structure changes are detected instead of silently mis-zipped.
"""

from __future__ import annotations

import json
import os
import queue as queue_mod
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro import obs

# numpy can't serialize ml_dtypes (bfloat16 etc.) natively; store them as
# same-width unsigned ints and record the true dtype in the manifest.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves], treedef


def save(ckpt_dir: str, step: int, tree, *, on_commit=None) -> str:
    """Write checkpoint for ``step``; returns the final directory.

    ``on_commit(step, tmp_dir)``, if given, runs after the full write but
    *before* the rename-commit — an error raised there aborts the commit and
    leaves only the ``.tmp-`` dir behind (exactly the disk state a real I/O
    failure at that instant would leave). This is the checkpoint-writer
    fault-injection point used by ``cluster.faults``; a later retry of the
    same step removes the stale tmp dir and commits cleanly.
    """
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp-step_{step:08d}")
    t_save = time.monotonic()
    with obs.tracer().span("ckpt.save", "ckpt", step=step):
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        named, _ = _flatten(tree)
        manifest = []
        written = 0
        for i, (key, leaf) in enumerate(named):
            arr = np.asarray(jax.device_get(leaf))
            true_dtype = str(arr.dtype)
            if true_dtype in _VIEW_AS:
                arr = arr.view(_VIEW_AS[true_dtype])
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            written += arr.nbytes
            manifest.append({"key": key, "file": fname, "shape": list(arr.shape), "dtype": true_dtype})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)
        if on_commit is not None:
            on_commit(step, tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        t_rename = time.monotonic()
        with obs.tracer().span("ckpt.rename", "ckpt", step=step):
            os.replace(tmp, final)  # atomic commit
        met = obs.metrics()
        met.histogram("ckpt.rename_s").observe(time.monotonic() - t_rename)
        met.histogram("ckpt.save_s").observe(time.monotonic() - t_save)
        # array payload only (manifest.json excluded): the packed-corpus
        # contract is "bytes moved, never bytes written" — state checkpoints
        # are pack-invariant, so this counter is how traces prove it
        met.counter("ckpt.written_bytes").inc(written)
    return final


def replace_dir(src: str, dst: str) -> None:
    """Promote checkpoint dir ``src`` over ``dst`` (speculative-win commit).

    Not a single atomic step when ``dst`` already exists (the rmtree+rename
    pair has a window with no ``dst``), but ``src`` holds a complete,
    committed lineage throughout — a crash in the window loses no data, and
    the scan-job resume path treats a missing shard dir as a fresh start.
    """
    if os.path.exists(dst):
        shutil.rmtree(dst)
    os.replace(src, dst)


def all_steps(ckpt_dir: str) -> list[int]:
    """Committed checkpoint steps (ascending); uncommitted .tmp dirs excluded."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))
    )


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def prune(ckpt_dir: str, keep: int) -> list[int]:
    """Delete all but the newest ``keep`` checkpoints; returns removed steps.

    Bounds the disk footprint of segment-checkpointed scan jobs (one commit
    per corpus segment) without ever touching the newest good step.
    """
    if keep < 1:
        raise ValueError(f"keep must be >= 1, got {keep}")
    steps = all_steps(ckpt_dir)
    drop = steps[:-keep] if len(steps) > keep else []
    for s in drop:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
    return drop


class AsyncCheckpointer:
    """Ordered background committer: checkpoint I/O off the critical path.

    A pipelined scan job hands each post-segment commit sequence —
    ``save(step)`` → progress manifest → ``prune`` — to one writer thread
    and keeps folding the next segment; the device arrays it enqueues are
    immutable, so the writer's later ``device_get`` reads exactly the
    committed value. The contract that makes this safe to swap for inline
    commits:

    * **same order** — tasks run strictly in submission order on a single
      thread, so the on-disk write sequence is identical to the synchronous
      path's; a hard kill at any instant leaves a disk state the
      synchronous path could also have left (atomicity of each ``save`` is
      unchanged — the rename-commit happens on the writer thread).
    * **fail-stop** — the first task error poisons the queue: later tasks
      are skipped (a progress manifest must never claim a commit whose
      ``save`` failed) and the error re-raises on the next
      :meth:`drain`/:meth:`submit`/:meth:`close`.
    * **drain barrier** — :meth:`drain` blocks until everything submitted
      so far is durably on disk; jobs drain before reporting a step done
      (e.g. ahead of an injected lost-ack kill) and before returning, so
      resume semantics match the synchronous path exactly.
    """

    def __init__(self):
        self._queue: queue_mod.Queue = queue_mod.Queue()
        self._error: BaseException | None = None
        self._thread = threading.Thread(
            target=self._run, name="ckpt-writer", daemon=True
        )
        self._closed = False
        self._thread.start()

    def _run(self):
        while True:
            item = self._queue.get()
            try:
                if item is None:
                    return
                if self._error is None:  # poison: skip everything after a failure
                    fn, args, kwargs = item
                    fn(*args, **kwargs)
            except BaseException as e:  # noqa: BLE001 — re-raised on drain
                self._error = e
            finally:
                self._queue.task_done()
                obs.metrics().gauge("ckpt.writer_queue_depth").set(
                    self._queue.qsize()
                )

    def _check(self):
        # the error stays set: a failed commit poisons the writer for good,
        # so no later task (e.g. a progress manifest claiming the failed
        # step) can ever run, even after the error has been reported once
        if self._error is not None:
            raise self._error

    def submit(self, fn, *args, **kwargs) -> None:
        """Enqueue ``fn(*args, **kwargs)`` after everything already queued."""
        if self._closed:
            raise RuntimeError("AsyncCheckpointer is closed")
        self._check()
        self._queue.put((fn, args, kwargs))
        obs.metrics().gauge("ckpt.writer_queue_depth").set(self._queue.qsize())

    def drain(self) -> None:
        """Block until all submitted work is on disk; re-raise writer errors."""
        self._queue.join()
        self._check()

    def _shutdown(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._queue.join()
        self._queue.put(None)
        self._thread.join()

    def close(self) -> None:
        """Drain, stop the writer thread, and re-raise any pending error."""
        was_closed = self._closed
        self._shutdown()
        if not was_closed:
            self._check()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        # don't mask an in-flight exception (e.g. an injected kill) with a
        # writer error; the writer error still surfaces for clean exits
        if exc_type is not None:
            self._shutdown()
            return False
        self.close()
        return False


def restore(ckpt_dir: str, step: int, tree_like, *, shardings=None):
    """Load ``step`` into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedShardings (the *current*
    mesh) — this is the elastic-rescale path.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    named, treedef = _flatten(tree_like)
    by_key = {m["key"]: m for m in manifest["leaves"]}
    if set(by_key) != {k for k, _ in named}:
        missing = {k for k, _ in named} ^ set(by_key)
        raise ValueError(f"checkpoint structure mismatch; differing keys: {sorted(missing)[:5]}")
    shard_named = None
    if shardings is not None:
        shard_named, _ = _flatten(shardings)
        shard_named = dict(shard_named)
    leaves = []
    for key, like in named:
        meta = by_key[key]
        arr = np.load(os.path.join(d, meta["file"]))
        if meta["dtype"] in _VIEW_AS:
            arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
        if shard_named is not None:
            leaves.append(jax.device_put(arr, shard_named[key]))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
