from repro.checkpoint.checkpoint import (
    AsyncCheckpointer,
    all_steps,
    latest_step,
    prune,
    restore,
    save,
)

__all__ = [
    "AsyncCheckpointer",
    "save",
    "restore",
    "latest_step",
    "all_steps",
    "prune",
]
