from repro.checkpoint.checkpoint import (
    AsyncCheckpointer,
    all_steps,
    latest_step,
    prune,
    replace_dir,
    restore,
    save,
)

__all__ = [
    "AsyncCheckpointer",
    "save",
    "replace_dir",
    "restore",
    "latest_step",
    "all_steps",
    "prune",
]
