"""TREC run-file and qrels I/O — the experiment subsystem's on-disk contract.

A *run* is the classic six-column format every TREC tool understands::

    <query_id> Q0 <doc_id> <rank> <score> <run_tag>

and qrels are the four-column judgment format::

    <query_id> 0 <doc_id> <grade>

Writers are deterministic byte-for-byte for identical inputs (scores are
formatted with ``%.17g``, which round-trips float64 exactly), which is what
lets the resumable scan job assert *bit-identical run files* after a
kill/resume — a stronger artifact-level guarantee than comparing in-memory
arrays. Ids are written as ``q<i>`` / ``d<j>`` and parsed back to ints.
"""

from __future__ import annotations

import os

import numpy as np


def _write_atomic(path: str, text: str) -> str:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return path


def write_run(
    path: str,
    ids: np.ndarray,
    scores: np.ndarray,
    *,
    run_tag: str,
    valid: np.ndarray | None = None,
) -> str:
    """Write ``ids/scores [n_q, k]`` (rank order) as a TREC run file.

    ``valid`` masks out empty combiner slots (``topk.valid_mask``); masked
    rows are simply omitted, as TREC permits ragged run depths per query.
    """
    ids = np.asarray(ids)
    scores = np.asarray(scores)
    lines = []
    for qi in range(ids.shape[0]):
        for rank in range(ids.shape[1]):
            if valid is not None and not valid[qi, rank]:
                continue
            lines.append(
                f"q{qi} Q0 d{int(ids[qi, rank])} {rank + 1} "
                f"{float(scores[qi, rank]):.17g} {run_tag}"
            )
    return _write_atomic(path, "\n".join(lines) + "\n")


def read_run(path: str, *, depth: int | None = None) -> tuple[np.ndarray, np.ndarray, str]:
    """Parse a run file back to ``(ids, scores, run_tag)`` dense arrays.

    Missing (omitted) ranks come back as ``(-1, -inf)`` — the same empty-slot
    sentinels as :class:`repro.core.topk.TopKState`, so a written+reread run
    evaluates identically to the in-memory state it came from.
    """
    rows: dict[int, list[tuple[int, int, float]]] = {}
    tag = ""
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            qid, _, did, rank, score, tag = line.split()
            rows.setdefault(int(qid[1:]), []).append(
                (int(rank), int(did[1:]), float(score))
            )
    if not rows:
        return np.zeros((0, 0), np.int32), np.zeros((0, 0), np.float64), tag
    n_q = max(rows) + 1
    if depth is None:
        depth = max(r for entries in rows.values() for r, _, _ in entries)
    ids = np.full((n_q, depth), -1, np.int32)
    scores = np.full((n_q, depth), -np.inf, np.float64)
    for qi, entries in rows.items():
        for rank, did, score in entries:
            ids[qi, rank - 1] = did
            scores[qi, rank - 1] = score
    return ids, scores, tag


def write_qrels(path: str, qrels: np.ndarray) -> str:
    """Write a grade matrix ``[n_q, n_docs]`` as four-column TREC qrels
    (only judged, i.e. grade > 0, pairs are emitted)."""
    qrels = np.asarray(qrels)
    lines = []
    for qi, doc in zip(*np.nonzero(qrels > 0)):
        lines.append(f"q{qi} 0 d{int(doc)} {int(qrels[qi, doc])}")
    return _write_atomic(path, "\n".join(lines) + "\n")


def read_qrels(path: str, *, n_queries: int | None = None, n_docs: int | None = None) -> np.ndarray:
    """Parse qrels back to a dense grade matrix."""
    triples = []
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            qid, _, did, grade = line.split()
            triples.append((int(qid[1:]), int(did[1:]), int(grade)))
    n_q = n_queries if n_queries is not None else max(q for q, _, _ in triples) + 1
    n_d = n_docs if n_docs is not None else max(d for _, d, _ in triples) + 1
    out = np.zeros((n_q, n_d), np.int8)
    for q, d, g in triples:
        out[q, d] = g
    return out
