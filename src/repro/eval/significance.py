"""Paired randomization (Fisher permutation) test between two runs.

The standard IR significance test (Smucker, Allan & Carterette, CIKM'07):
under H0 the two systems are exchangeable per query, so each query's pair of
metric values can be swapped freely. The test statistic is the mean per-query
difference; its null distribution is sampled by random sign flips of the
observed differences. Exact for small query sets, assumption-free (no
normality, unlike the t-test), and it consumes exactly the per-query vectors
`repro.eval.metrics.evaluate_run` already returns.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class SignificanceResult(NamedTuple):
    mean_a: float
    mean_b: float
    diff: float  # mean_a - mean_b
    p_value: float  # two-sided
    n_permutations: int

    def __str__(self) -> str:
        return (
            f"diff={self.diff:+.4f} (A={self.mean_a:.4f}, B={self.mean_b:.4f}), "
            f"p={self.p_value:.4f} [{self.n_permutations} permutations]"
        )


def paired_randomization_test(
    per_query_a: np.ndarray,
    per_query_b: np.ndarray,
    *,
    n_permutations: int = 10_000,
    seed: int = 0,
) -> SignificanceResult:
    """Two-sided paired randomization test on per-query metric vectors.

    The +1/(n+1) smoothing makes the Monte-Carlo p-value a valid test (the
    observed labeling is itself one permutation), so p is never exactly 0.
    """
    a = np.asarray(per_query_a, np.float64)
    b = np.asarray(per_query_b, np.float64)
    if a.shape != b.shape or a.ndim != 1:
        raise ValueError(f"need matching per-query vectors, got {a.shape} vs {b.shape}")
    if a.size == 0:
        raise ValueError("empty per-query vectors")
    d = a - b
    observed = d.mean()
    rng = np.random.default_rng(seed)
    signs = rng.choice((-1.0, 1.0), size=(n_permutations, d.size))
    null = (signs * d).mean(axis=1)
    p = (np.sum(np.abs(null) >= abs(observed)) + 1.0) / (n_permutations + 1.0)
    return SignificanceResult(
        mean_a=float(a.mean()),
        mean_b=float(b.mean()),
        diff=float(observed),
        p_value=float(p),
        n_permutations=n_permutations,
    )
