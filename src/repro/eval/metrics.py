"""TREC-style rank metrics over (run, qrels) pairs — host-side numpy.

Evaluation is deliberately *not* a JAX dataflow: runs are small (``n_q × k``
after the combiner bound) and TREC semantics are full of ragged, data-dependent
bookkeeping (per-query relevant counts, graded gains, rank cutoffs) that belong
on the host. Everything takes

    run_ids [n_q, depth] int   — ranked doc ids, best first; ``-1`` = empty slot
    qrels   [n_q, n_docs] int/bool — relevance grades (binary qrels are grade 1)

and returns **per-query** vectors; the scalar aggregate (MAP, MRR, mean P@k …)
is just ``.mean()``. Keeping per-query values first-class is what makes the
paired randomization significance test (`repro.eval.significance`) a one-liner
downstream instead of a re-evaluation.

Conventions follow trec_eval: AP divides by the number of relevant documents
(not the cutoff), queries with no relevant documents score 0 everywhere, and
NDCG uses exponential gains ``2^grade - 1`` with ``log2(rank+1)`` discounts.
"""

from __future__ import annotations

import numpy as np


def _grades_at_ranks(run_ids: np.ndarray, qrels: np.ndarray) -> np.ndarray:
    """Relevance grade of each ranked position, 0 for empty (-1) slots."""
    run_ids = np.asarray(run_ids)
    qrels = np.asarray(qrels)
    if run_ids.ndim != 2 or qrels.ndim != 2 or run_ids.shape[0] != qrels.shape[0]:
        raise ValueError(f"shape mismatch: run {run_ids.shape} vs qrels {qrels.shape}")
    safe = np.clip(run_ids, 0, qrels.shape[1] - 1)
    g = np.take_along_axis(qrels.astype(np.float64), safe, axis=1)
    return np.where(run_ids >= 0, g, 0.0)


def precision_at_k(run_ids: np.ndarray, qrels: np.ndarray, k: int) -> np.ndarray:
    """P@k per query (graded qrels are binarized as grade > 0)."""
    rel = _grades_at_ranks(run_ids[:, :k], qrels) > 0
    return rel.sum(axis=1) / float(k)


def recall_at_k(run_ids: np.ndarray, qrels: np.ndarray, k: int) -> np.ndarray:
    """Fraction of each query's relevant docs retrieved in the top k."""
    rel = _grades_at_ranks(run_ids[:, :k], qrels) > 0
    n_rel = (np.asarray(qrels) > 0).sum(axis=1)
    return np.where(n_rel > 0, rel.sum(axis=1) / np.maximum(n_rel, 1), 0.0)


def average_precision(run_ids: np.ndarray, qrels: np.ndarray) -> np.ndarray:
    """AP per query over the full run depth; MAP = ``average_precision().mean()``."""
    rel = _grades_at_ranks(run_ids, qrels) > 0
    ranks = np.arange(1, rel.shape[1] + 1, dtype=np.float64)
    prec_at_rank = np.cumsum(rel, axis=1) / ranks  # P@rank at every position
    n_rel = (np.asarray(qrels) > 0).sum(axis=1)
    ap_sum = (prec_at_rank * rel).sum(axis=1)
    return np.where(n_rel > 0, ap_sum / np.maximum(n_rel, 1), 0.0)


def reciprocal_rank(run_ids: np.ndarray, qrels: np.ndarray) -> np.ndarray:
    """1/rank of the first relevant doc per query (0 if none retrieved)."""
    rel = _grades_at_ranks(run_ids, qrels) > 0
    first = np.argmax(rel, axis=1)  # 0 when no hit — disambiguate via any()
    return np.where(rel.any(axis=1), 1.0 / (first + 1.0), 0.0)


def ndcg_at_k(run_ids: np.ndarray, qrels: np.ndarray, k: int) -> np.ndarray:
    """NDCG@k per query with exponential gains (graded or binary qrels).

    A run shallower than ``k`` simply contributes no gain at the missing
    ranks (ideal DCG still uses the full ``k``), matching trec_eval."""
    gains = 2.0 ** _grades_at_ranks(run_ids[:, :k], qrels) - 1.0
    discounts = 1.0 / np.log2(np.arange(2, k + 2, dtype=np.float64))
    dcg = (gains * discounts[: gains.shape[1]]).sum(axis=1)
    # ideal ranking: each query's grades sorted descending, truncated to k
    ideal = np.sort(np.asarray(qrels).astype(np.float64), axis=1)[:, ::-1][:, :k]
    idcg = ((2.0**ideal - 1.0) * discounts[: ideal.shape[1]]).sum(axis=1)
    return np.where(idcg > 0, dcg / np.maximum(idcg, 1e-12), 0.0)


PER_QUERY_METRICS = {
    "ap": average_precision,
    "rr": reciprocal_rank,
}
AT_K_METRICS = {
    "p": precision_at_k,
    "recall": recall_at_k,
    "ndcg": ndcg_at_k,
}


def evaluate_run(
    run_ids: np.ndarray,
    qrels: np.ndarray,
    *,
    ks: tuple[int, ...] = (5, 10, 20),
) -> dict:
    """The full report card for one run.

    Returns ``{"aggregate": {...}, "per_query": {...}}`` where aggregates are
    floats (``map``, ``mrr``, ``p@k`` / ``recall@k`` / ``ndcg@k`` per cutoff)
    and per-query vectors back the significance test.
    """
    depth = np.asarray(run_ids).shape[1]
    per_query: dict[str, np.ndarray] = {
        "ap": average_precision(run_ids, qrels),
        "rr": reciprocal_rank(run_ids, qrels),
    }
    for k in ks:
        if k > depth:
            raise ValueError(f"cutoff {k} exceeds run depth {depth}")
        for short, fn in AT_K_METRICS.items():
            per_query[f"{short}@{k}"] = fn(run_ids, qrels, k)
    aggregate = {
        "map" if name == "ap" else "mrr" if name == "rr" else name: float(v.mean())
        for name, v in per_query.items()
    }
    return {"aggregate": aggregate, "per_query": per_query}
