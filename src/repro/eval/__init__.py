"""TREC-style evaluation: rank metrics, run/qrels I/O, significance testing.

The measurement half of the batch experiment engine (`repro.experiments`):
scan jobs produce run files, this package turns (run, qrels) into MAP / P@k /
NDCG / MRR / recall report cards and paired-randomization p-values between
runs. Also the single source of truth for quality numbers elsewhere in the
repo (`benchmarks/quality_pk.py` asserts through these functions).
"""

from repro.eval import metrics, significance, trec
from repro.eval.metrics import (
    average_precision,
    evaluate_run,
    ndcg_at_k,
    precision_at_k,
    recall_at_k,
    reciprocal_rank,
)
from repro.eval.significance import SignificanceResult, paired_randomization_test
from repro.eval.trec import read_qrels, read_run, write_qrels, write_run

__all__ = [
    "metrics",
    "significance",
    "trec",
    "average_precision",
    "evaluate_run",
    "ndcg_at_k",
    "precision_at_k",
    "recall_at_k",
    "reciprocal_rank",
    "SignificanceResult",
    "paired_randomization_test",
    "read_qrels",
    "read_run",
    "write_qrels",
    "write_run",
]
