"""Mesh-sharded scan jobs — one map/reduce layer from kernel to runner.

Paper §2 as a subsystem: a **plan** partitions the corpus into chunk- and
segment-aligned shards (`cluster.plan`), a **map** runs the one shard fold
every substrate shares (`cluster.mapreduce.map_shard` — multi-model
single-pass, fused Pallas kernel under ``use_kernel``), and a **reduce**
merges per-shard top-k states through the k-bounded lexicographic bitonic
merge (`cluster.mapreduce.reduce_states`), whose value-determinism makes
merged rankings — and the TREC run files written from them — byte-identical
at every shard count. `cluster.job` adds the operational layer: per-shard
checkpoints, progress manifests, and independent kill/resume.

Scan, experiment jobs, and serve sessions all reduce through this one merge
contract, so future scaling work (multi-process meshes, real corpora) stays
local to this package.

`cluster.faults` + `cluster.scheduler` are the Hadoop-style reliability
layer the paper leans on: deterministic seeded fault injection (crashes,
writer errors, stragglers, dead workers) driving a work-stealing shard
scheduler with checkpoint-resumed retries and speculative re-execution —
under any injected schedule the merged result stays byte-identical to the
fault-free single-host oracle.
"""

from repro.cluster.faults import (
    FaultSchedule,
    FaultSpec,
    InjectedFault,
    InjectedWriterError,
    ShardCancelled,
    WorkerCrash,
    build_schedule,
    parse_fault,
)
from repro.cluster.scheduler import SchedulerStats, ShardScheduler
from repro.cluster.plan import (
    Shard,
    ShardPlan,
    mesh_scan_axes,
    plan_for_mesh,
    plan_shards,
)
from repro.cluster.mapreduce import (
    FOLD_TRACE_COUNTS,
    map_shard,
    reduce_states,
    scan_shards,
    search_mesh,
    segment_fold,
)
from repro.cluster.job import (
    ScanJobResult,
    ShardedScanResult,
    read_cluster_manifest,
    read_progress,
    run_scan_job,
    run_sharded_scan_job,
    shard_ckpt_dir,
    spec_ckpt_dir,
)

__all__ = [
    "FOLD_TRACE_COUNTS",
    "FaultSchedule",
    "FaultSpec",
    "InjectedFault",
    "InjectedWriterError",
    "SchedulerStats",
    "Shard",
    "ShardCancelled",
    "ShardPlan",
    "ShardScheduler",
    "ScanJobResult",
    "ShardedScanResult",
    "WorkerCrash",
    "build_schedule",
    "map_shard",
    "mesh_scan_axes",
    "parse_fault",
    "plan_for_mesh",
    "plan_shards",
    "read_cluster_manifest",
    "read_progress",
    "reduce_states",
    "run_scan_job",
    "run_sharded_scan_job",
    "scan_shards",
    "search_mesh",
    "segment_fold",
    "shard_ckpt_dir",
    "spec_ckpt_dir",
]
