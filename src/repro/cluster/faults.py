"""Deterministic fault injection for sharded scan jobs.

The paper's reliability story is Hadoop's: machines die, disks fail, and
some workers are just slow, yet the job finishes and the answer doesn't
change. To *test* that story we need faults that are injectable on demand,
deterministic under a seed, and visible to assertions — not a single
hard-coded ``fail_at_segment`` RuntimeError.

A :class:`FaultSpec` names one fault; a :class:`FaultSchedule` is a set of
specs that `cluster.job.run_scan_job` consults at each injection point of
the per-segment loop:

* **crash** — the worker process "dies" on a shard, either *before* the
  segment's checkpoint commits (work since the last commit is lost) or
  *after* it (the canonical lost-ack kill: the commit is durable but never
  acknowledged). Raises :class:`WorkerCrash`.
* **writer_error** — the checkpoint writer fails mid-commit (disk full,
  I/O error) via the :func:`repro.checkpoint.save` ``on_commit`` hook, so
  the atomic rename never happens and a ``.tmp`` dir is left behind —
  exactly the poisoned-dir state a real I/O fault leaves. Raises
  :class:`InjectedWriterError` (an ``OSError``).
* **straggler** — the shard still produces correct results, just slowly:
  a per-segment delay, the speculative-execution trigger.
* **dead_worker** — a *scheduler worker* (not a shard) stops picking up
  work, optionally after completing a few shards; the work queue must
  drain through the surviving workers (work stealing).

Faults match on ``(shard, segment, attempt)`` — ``attempts=(0,)`` (the
default for crashes and writer errors) makes a fault *transient*: it fires
on the first execution attempt and lets the retry succeed, which is how
real lost machines behave from the scheduler's point of view.
``attempts="all"`` makes it *permanent* (the retry-exhaustion path).
Matching is stateless, so the same schedule object drives a sequential
reference run and a concurrent scheduled run identically; every fault that
actually fires is recorded in :attr:`FaultSchedule.fired` for assertions.

:func:`FaultSchedule.random` derives a whole chaos schedule from one seed
(crash × phase × straggler × writer-error per shard), so a CI matrix is
``for seed in 0 1 2`` instead of a hand-enumerated fault zoo.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Iterable, Sequence

import numpy as np

from repro import obs

KINDS = ("crash", "writer_error", "straggler", "dead_worker")
PHASES = ("pre_commit", "post_commit")


class InjectedFault(RuntimeError):
    """Base of all injected failures (stragglers are delays, not errors)."""


class WorkerCrash(InjectedFault):
    """An injected worker death. Subclasses RuntimeError with the historic
    "injected failure" message so pre-FaultSpec tests and CI keep matching."""


class InjectedWriterError(OSError):
    """An injected checkpoint-writer I/O failure (poisons the async writer)."""


class ShardCancelled(Exception):
    """A shard attempt stopped because a rival copy committed first.

    Not a failure: the scheduler treats it as a clean discard (it never
    counts against ``max_retries`` and never surfaces to the caller).
    """


def _normalize_attempts(kind: str, attempts) -> tuple[int, ...] | None:
    """``None`` means "every attempt" (permanent); tuples are explicit."""
    if attempts == "auto":
        # crashes and writer errors default to transient (first attempt
        # only — the retry succeeds); stragglers and dead workers are
        # conditions, not events, so they default to permanent
        return (0,) if kind in ("crash", "writer_error") else None
    if attempts in ("all", None):
        return None
    if isinstance(attempts, int):
        return (attempts,)
    return tuple(int(a) for a in attempts)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injectable fault. ``shard=None`` / ``segment=None`` mean "any"."""

    kind: str
    shard: int | None = None
    segment: int | None = None
    phase: str = "post_commit"  # crash only: pre_commit | post_commit
    attempts: tuple[int, ...] | str | None = "auto"
    delay_s: float = 0.0  # straggler: sleep per matching segment
    worker: int | None = None  # dead_worker: which scheduler worker dies
    after_shards: int = 0  # dead_worker: die after completing this many

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")
        if self.phase not in PHASES:
            raise ValueError(f"unknown crash phase {self.phase!r}; one of {PHASES}")
        if self.kind in ("crash", "writer_error") and self.segment is None:
            raise ValueError(f"{self.kind} fault needs an explicit segment")
        if self.kind == "straggler" and self.delay_s < 0:
            raise ValueError(f"straggler delay must be >= 0, got {self.delay_s}")
        if self.kind == "dead_worker" and self.worker is None:
            raise ValueError("dead_worker fault needs an explicit worker")
        object.__setattr__(
            self, "attempts", _normalize_attempts(self.kind, self.attempts)
        )

    def matches(self, shard: int, segment: int, attempt: int) -> bool:
        return (
            (self.shard is None or self.shard == shard)
            and (self.segment is None or self.segment == segment)
            and (self.attempts is None or attempt in self.attempts)
        )

    def describe(self) -> dict:
        d = dataclasses.asdict(self)
        d["attempts"] = "all" if self.attempts is None else list(self.attempts)
        return d


def parse_fault(spec: str) -> FaultSpec:
    """Parse the CLI syntax ``kind:key=val,key=val`` into a :class:`FaultSpec`.

    Examples: ``crash:shard=1,segment=0,phase=pre_commit``,
    ``writer_error:shard=0,segment=1``, ``straggler:shard=2,delay=0.05``,
    ``dead_worker:worker=0``, ``crash:shard=3,segment=0,attempts=all``.
    """
    kind, _, params = spec.partition(":")
    if kind not in KINDS:
        raise ValueError(f"unknown fault kind {kind!r} in {spec!r}; one of {KINDS}")
    kwargs: dict = {}
    if params:
        for item in params.split(","):
            key, sep, val = item.partition("=")
            if not sep or not val:
                raise ValueError(f"malformed fault param {item!r} in {spec!r}")
            if key == "delay":
                key = "delay_s"
            if key in ("shard", "segment", "worker", "after_shards"):
                kwargs[key] = int(val)
            elif key == "delay_s":
                kwargs[key] = float(val)
            elif key == "attempts":
                kwargs[key] = "all" if val == "all" else tuple(
                    int(a) for a in val.split("|")
                )
            elif key == "phase":
                kwargs[key] = val
            else:
                raise ValueError(f"unknown fault param {key!r} in {spec!r}")
    return FaultSpec(kind=kind, **kwargs)


class FaultSchedule:
    """A set of :class:`FaultSpec`\\ s plus a thread-safe log of fired faults.

    Matching is stateless (pure function of ``(shard, segment, attempt)``),
    so one schedule drives any executor; the :attr:`fired` log records what
    actually happened, for test assertions and report counters.
    """

    def __init__(self, specs: Iterable[FaultSpec] = ()):
        self.specs: tuple[FaultSpec, ...] = tuple(specs)
        self._lock = threading.Lock()
        self._fired: list[dict] = []
        self._dead_recorded: set[int] = set()

    # -- construction --------------------------------------------------------

    def add(self, spec: FaultSpec) -> "FaultSchedule":
        """Append a spec in place (keeps the caller's ``fired`` log live)."""
        self.specs = self.specs + (spec,)
        return self

    @classmethod
    def from_legacy(cls, fail_at_segment: int, fail_at_shard: int) -> "FaultSchedule":
        """The deprecated ``fail_at_segment``/``fail_at_shard`` kwargs as a
        schedule: one transient post-commit crash on one shard — the only
        fault the pre-FaultSpec plumbing could express."""
        return cls(
            [
                FaultSpec(
                    kind="crash",
                    shard=fail_at_shard,
                    segment=fail_at_segment,
                    phase="post_commit",
                )
            ]
        )

    @classmethod
    def random(
        cls,
        seed: int,
        *,
        n_shards: int,
        n_segments: int,
        p_crash: float = 0.5,
        p_straggler: float = 0.5,
        p_writer_error: float = 0.25,
        max_delay_s: float = 0.02,
    ) -> "FaultSchedule":
        """A seeded chaos schedule: per shard, maybe a transient crash (random
        segment × random phase), maybe a writer error, maybe a straggler
        delay. Always contains at least one crash so every seed exercises the
        retry path. Deterministic: same seed → same schedule."""
        rng = np.random.default_rng(seed)
        specs: list[FaultSpec] = []
        for shard in range(n_shards):
            if rng.random() < p_crash:
                specs.append(
                    FaultSpec(
                        kind="crash",
                        shard=shard,
                        segment=int(rng.integers(n_segments)),
                        phase=PHASES[int(rng.integers(2))],
                    )
                )
            if rng.random() < p_writer_error:
                specs.append(
                    FaultSpec(
                        kind="writer_error",
                        shard=shard,
                        segment=int(rng.integers(n_segments)),
                    )
                )
            if rng.random() < p_straggler:
                specs.append(
                    FaultSpec(
                        kind="straggler",
                        shard=shard,
                        delay_s=float(rng.uniform(0.25, 1.0) * max_delay_s),
                    )
                )
        if not any(s.kind == "crash" for s in specs):
            specs.append(
                FaultSpec(
                    kind="crash",
                    shard=int(rng.integers(n_shards)),
                    segment=int(rng.integers(n_segments)),
                    phase=PHASES[int(rng.integers(2))],
                )
            )
        return cls(specs)

    # -- bookkeeping ---------------------------------------------------------

    def _record(self, spec: FaultSpec, **ctx) -> None:
        with self._lock:
            self._fired.append({"kind": spec.kind, **ctx})
        # every firing doubles as a trace marker on the injecting thread —
        # recording only; no control flow ever depends on the tracer
        obs.tracer().instant(f"fault.{spec.kind}", "fault", **ctx)

    @property
    def fired(self) -> list[dict]:
        """Snapshot of every fault that actually fired (thread-safe copy)."""
        with self._lock:
            return list(self._fired)

    def count_fired(self, kind: str) -> int:
        with self._lock:
            return sum(1 for e in self._fired if e["kind"] == kind)

    def describe(self) -> list[dict]:
        return [s.describe() for s in self.specs]

    # -- injection points (called from the per-segment loop) -----------------

    def maybe_delay(
        self, shard: int, segment: int, attempt: int, cancel=None
    ) -> float:
        """Apply every matching straggler delay; returns seconds slept.

        ``cancel`` (a ``threading.Event``) makes the sleep interruptible so
        a cancelled straggler stops promptly instead of finishing its nap.
        """
        total = 0.0
        for spec in self.specs:
            if spec.kind == "straggler" and spec.matches(shard, segment, attempt):
                total += spec.delay_s
        if total > 0.0:
            self._record(
                FaultSpec(kind="straggler", delay_s=total),
                shard=shard, segment=segment, attempt=attempt, delay_s=total,
            )
            if cancel is not None:
                cancel.wait(total)
            else:
                time.sleep(total)
        return total

    def crash_at(
        self, shard: int, segment: int, attempt: int, phase: str
    ) -> FaultSpec | None:
        """The matching crash spec for this ``phase``, recorded — or None."""
        for spec in self.specs:
            if (
                spec.kind == "crash"
                and spec.phase == phase
                and spec.matches(shard, segment, attempt)
            ):
                self._record(
                    spec, shard=shard, segment=segment, attempt=attempt, phase=phase
                )
                return spec
        return None

    def commit_hook(
        self, shard: int, segment: int, attempt: int
    ) -> Callable[[int, str], None] | None:
        """An ``on_commit`` hook for :func:`repro.checkpoint.save` that fails
        the commit *before* the atomic rename — or None when no writer-error
        spec matches. The raise happens on whichever thread runs the save
        (the async writer's, usually), poisoning it exactly like a real I/O
        error would."""
        for spec in self.specs:
            if spec.kind == "writer_error" and spec.matches(shard, segment, attempt):

                def fail_commit(step: int, tmp_dir: str, _spec=spec) -> None:
                    self._record(
                        _spec, shard=shard, segment=segment, attempt=attempt
                    )
                    raise InjectedWriterError(
                        f"injected checkpoint-writer error on shard {shard} "
                        f"segment {segment} (attempt {attempt})"
                    )

                return fail_commit
        return None

    def worker_dead(self, worker: int, shards_done: int) -> bool:
        """True when scheduler worker ``worker`` should stop taking work."""
        for spec in self.specs:
            if (
                spec.kind == "dead_worker"
                and spec.worker == worker
                and shards_done >= spec.after_shards
            ):
                with self._lock:
                    fresh = worker not in self._dead_recorded
                    if fresh:
                        self._dead_recorded.add(worker)
                        self._fired.append(
                            {"kind": "dead_worker", "worker": worker,
                             "after_shards": shards_done}
                        )
                if fresh:
                    obs.tracer().instant(
                        "fault.dead_worker", "fault",
                        worker=worker, after_shards=shards_done,
                    )
                return True
        return False


def build_schedule(specs: Sequence[str]) -> FaultSchedule:
    """Parse a list of CLI fault strings into one schedule."""
    return FaultSchedule([parse_fault(s) for s in specs])
