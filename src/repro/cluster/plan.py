"""Shard plans — the cluster's unit of work assignment.

MIREX's cluster hands each machine a contiguous slice of the collection and
lets it scan sequentially; everything else (fault tolerance, merging) follows
from how those slices are cut. A :class:`ShardPlan` is that cut made explicit:
chunk-aligned, contiguous, covering ``[0, n_docs)`` exactly once, with each
shard's global ``doc_id_offset`` equal to its start row so local top-k ids map
to global ids by one sentinel-preserving add.

Two invariants make downstream guarantees structural rather than accidental:

* **chunk alignment** — every shard boundary is a chunk boundary, so a
  shard's fold scores each chunk from exactly the rows the single-host fold
  would, and a chunk's scores are a pure function of its rows (the fold
  state only *selects*, never rewrites them) — score bytes match
  bit-for-bit whatever the shard count (test-enforced);
* **equal shards** — every shard folds identical array shapes, so all
  shards share one jit trace and the checkpoint/resume contract of the
  single-shard job applies to each verbatim.

Plans are built either by count (:func:`plan_shards`) or from a JAX mesh via
the logical-axis vocabulary (:func:`plan_for_mesh` +
`distributed.sharding.AxisRules`): the "scan" logical axis — every mesh axis
flattened — is the MIREX default, because a corpus scan wants *all* chips
owning documents.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import AxisRules, rules_for_mesh


@dataclasses.dataclass(frozen=True)
class Shard:
    """One contiguous corpus slice: global rows ``[start, stop)``."""

    index: int
    start: int
    stop: int

    @property
    def n_rows(self) -> int:
        return self.stop - self.start

    @property
    def doc_id_offset(self) -> int:
        """Local row -> global doc id offset (== start: slices are contiguous)."""
        return self.start

    def take(self, docs: Any) -> Any:
        """Slice this shard's rows out of a docs pytree."""
        return jax.tree.map(lambda x: x[self.start : self.stop], docs)


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """A full partition of the corpus into scan shards.

    ``axis_names`` records the mesh axes the plan was derived from (empty for
    host-loop plans); geometry, not placement — the same plan executes as a
    host loop, a round-robin multi-device loop, or a ``shard_map``.
    """

    n_docs: int
    chunk_size: int
    shards: tuple[Shard, ...]
    axis_names: tuple[str, ...] = ()

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def describe(self) -> dict:
        """JSON-able geometry for progress manifests / reports."""
        return {
            "n_docs": self.n_docs,
            "chunk_size": self.chunk_size,
            "n_shards": self.n_shards,
            "axis_names": list(self.axis_names),
            "shards": [[s.start, s.stop] for s in self.shards],
        }


def plan_shards(
    n_docs: int,
    *,
    n_shards: int,
    chunk_size: int,
    axis_names: Sequence[str] = (),
) -> ShardPlan:
    """Cut ``[0, n_docs)`` into ``n_shards`` equal chunk-aligned contiguous
    slices.

    Equal sizes are required (not just preferred): every shard then folds
    identical array shapes, which keeps jit traces shared across shards and
    makes the merged result bit-identical to the single-host scan on every
    backend. Pad the corpus first (``pipeline.pad_leading``) if it doesn't
    divide.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_docs % n_shards:
        raise ValueError(
            f"{n_docs} docs not divisible into {n_shards} equal shards; "
            "pad the corpus first (pipeline.pad_leading with PAD_TOKEN rows)"
        )
    per_shard = n_docs // n_shards
    if per_shard % chunk_size:
        raise ValueError(
            f"shard size {per_shard} not a multiple of chunk_size {chunk_size}"
        )
    shards = tuple(
        Shard(index=i, start=i * per_shard, stop=(i + 1) * per_shard)
        for i in range(n_shards)
    )
    return ShardPlan(
        n_docs=n_docs,
        chunk_size=chunk_size,
        shards=shards,
        axis_names=tuple(axis_names),
    )


def mesh_scan_axes(mesh: Mesh, rules: AxisRules | None = None) -> tuple[str, ...]:
    """The physical axes behind the logical "scan" axis: all of them."""
    rules = rules if rules is not None else rules_for_mesh(mesh)
    return rules.scan_axes


def plan_for_mesh(
    mesh: Mesh,
    n_docs: int,
    *,
    chunk_size: int,
    rules: AxisRules | None = None,
    axis_names: Sequence[str] | None = None,
) -> ShardPlan:
    """One shard per device along the scan axes of ``mesh``.

    ``axis_names=None`` shards over the logical "scan" axis (every mesh axis
    — the MIREX default); pass a subset to scan on a slice of the mesh, e.g.
    ``("data",)`` to keep "model" free for tensor parallelism.
    """
    if axis_names is None:
        axis_names = mesh_scan_axes(mesh, rules)
    n_shards = 1
    for a in axis_names:
        n_shards *= mesh.shape[a]
    return plan_shards(
        n_docs,
        n_shards=n_shards,
        chunk_size=chunk_size,
        axis_names=axis_names,
    )
