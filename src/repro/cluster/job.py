"""Checkpointed sharded scan jobs — MIREX's cluster, kill/resume per shard.

The Hadoop property the paper leans on (any split can be re-executed and
re-reduced without changing the answer) holds here at two nested levels:

* **within a shard** — the corpus folds one chunk-aligned *segment* at a
  time through a single jitted multi-scorer fold; after every segment the
  stacked ``TopKState`` commits via the atomic-rename checkpointer and a
  ``progress.json`` manifest is rewritten, so a killed shard restarts from
  its last committed segment and replays the exact per-chunk instruction
  stream of an uninterrupted run (bit-identical, test-enforced);
* **across shards** — each shard owns its own checkpoint directory and
  progress manifest, fails and resumes independently, and the final
  :func:`repro.cluster.mapreduce.reduce_states` merge is value-deterministic,
  so the merged state (and every TREC run file written from it) is
  byte-identical whatever subset of shards died, resumed, or ran on which
  device — and byte-identical to the one-shard job, which is literally this
  code with a trivial plan.

Failure injection goes through :mod:`repro.cluster.faults`: a seeded
``FaultSchedule`` can crash any shard at any segment (before or after the
checkpoint commit), fail the checkpoint writer mid-commit, slow shards down
(stragglers), and retire scheduler workers. The legacy
``fail_at_segment``/``fail_at_shard`` kwargs survive as thin deprecated
aliases for one transient post-commit crash — the canonical lost-ack kill
point, and the only fault the old plumbing could express.

**The reliability layer** (:mod:`repro.cluster.scheduler`) turns the
pipelined executor's static shard-per-worker assignment into a work queue:
idle workers steal queued shards, failed shards retry with capped
exponential backoff from their last committed segment checkpoint
(``max_retries``), and when the queue drains the slowest in-flight shard is
speculatively re-executed from its checkpoint (``speculative=True``),
first-committed-wins. None of it changes a byte of any artifact — every
attempt replays the same chunk-aligned fold, and the reduce stays
plan-ordered.

**The pipelined executor** (``pipeline=True``, the default) overlaps
everything the sequential path serializes, without changing a byte of any
artifact:

* one compiled fold — `cluster.mapreduce.segment_fold` is jit-cached per
  (grid, k, chunk, kernel) configuration, so all shards and segments of a
  job (and every later job with the same config) share one program instead
  of re-tracing per ``run_scan_job`` call;
* double-buffered segments — `pipeline.prefetch_segments` stages segment
  *s+1*'s host→device transfer while segment *s* folds, and stops eagerly
  staging a shard's whole doc slice on its device up front;
* async checkpoints — the ``save → progress → prune`` commit sequence runs
  on a `checkpoint.AsyncCheckpointer` writer thread in submission order,
  with a drain barrier before any reported kill/completion, so kill/resume
  disk states are exactly the synchronous path's;
* concurrent shards — ``run_sharded_scan_job`` runs shards on a
  device-aware thread pool (one worker per assigned device, round-robin
  placement preserved), then reduces through the same value-deterministic
  merge, so merged states stay byte-identical to the sequential executor
  and the single-host oracle.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
import warnings
from typing import Any, Sequence

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro import obs
from repro.core import pipeline, topk
from repro.core.scoring import CollectionStats, Scorer
from repro.tune import config as tune_config
from repro.tune.config import TuningConfig

from repro.cluster.faults import FaultSchedule, ShardCancelled, WorkerCrash
from repro.cluster.mapreduce import reduce_states, segment_fold
from repro.cluster.plan import ShardPlan, plan_shards
from repro.cluster.scheduler import SchedulerStats, ShardScheduler


@dataclasses.dataclass(frozen=True)
class ScanJobResult:
    state: topk.TopKState  # stacked [n_models, n_q, k]
    segments_run: int  # segments executed by *this* invocation
    segments_total: int
    resumed_from: int  # segment index the run started at (0 = fresh)


@dataclasses.dataclass(frozen=True)
class ShardedScanResult:
    """Merged result of a sharded job + each shard's own job result."""

    state: topk.TopKState  # merged [n_models, n_q, k]
    plan: ShardPlan
    shard_results: tuple[ScanJobResult, ...]
    scheduler: SchedulerStats | None = None  # retry/steal/speculation counters

    @property
    def segments_run(self) -> int:
        return sum(r.segments_run for r in self.shard_results)

    @property
    def segments_total(self) -> int:
        return sum(r.segments_total for r in self.shard_results)

    @property
    def resumed(self) -> bool:
        return any(r.resumed_from for r in self.shard_results)


def _job_fingerprint(
    queries, docs, scorers, k: int, chunk_size: int, segment_chunks: int,
    doc_id_offset: int, stats,
) -> str:
    """Cheap identity of (data, grid, chunking, segmentation) — guards resume.

    A checkpointed TopKState from a *different* job can have exactly the same
    array shapes (same model count / query count / k), so shape checks alone
    would silently resume the wrong experiment. Hash the configuration, the
    full query set (small) and a strided row sample of the corpus instead.
    ``segment_chunks`` matters because the checkpoint step counts *segments*:
    reinterpreting it under a different segmentation would skip or double-fold
    corpus rows without any shape mismatch. ``doc_id_offset`` makes every
    shard of a sharded job a *distinct* job, so shard checkpoints can never
    be cross-adopted (e.g. after re-planning the same dir at a different
    shard count).
    """
    h = hashlib.sha256()
    h.update(
        repr(
            (k, chunk_size, segment_chunks, doc_id_offset, [s.name for s in scorers])
        ).encode()
    )
    for leaf in jax.tree.leaves(queries):
        h.update(np.asarray(leaf).tobytes())
    for leaf in jax.tree.leaves(docs):
        h.update(repr(tuple(leaf.shape)).encode())
        stride = max(1, leaf.shape[0] // 64)
        h.update(np.asarray(leaf[::stride][:64]).tobytes())
    # stats shape the scores: resuming under different collection statistics
    # would merge incompatible partial scores without any shape mismatch
    if stats is not None:
        for leaf in jax.tree.leaves(stats):
            h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()[:16]


# distinguishes "stream ended early" (scheduler cancel closed the prefetch
# stream) from any real segment value when pulling with a default
_STREAM_ENDED = object()


def _chain_first(first, rest):
    """Prepend an already-staged segment to a prefetch stream, keeping the
    stream's close() semantics (the consumer's ``finally`` closes us, we
    close the underlying prefetch iterator and its worker thread)."""
    try:
        yield first
        yield from rest
    finally:
        rest.close()


def _write_json(path: str, payload: dict) -> None:
    tmp = os.path.join(os.path.dirname(path), ".tmp-" + os.path.basename(path))
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)


def _write_progress(ckpt_dir: str, payload: dict) -> None:
    _write_json(os.path.join(ckpt_dir, "progress.json"), payload)


def read_progress(ckpt_dir: str) -> dict | None:
    path = os.path.join(ckpt_dir, "progress.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run_scan_job(
    queries: Any,
    docs: Any,
    scorers: Sequence[Scorer],
    *,
    k: int,
    chunk_size: int,
    segment_chunks: int,
    stats: CollectionStats | None = None,
    ckpt_dir: str | None = None,
    resume: bool = True,
    keep_checkpoints: int | None = None,
    fail_at_segment: int | None = None,
    shard: int = 0,
    n_shards: int = 1,
    doc_id_offset: int = 0,
    use_kernel: bool = False,
    device: jax.Device | None = None,
    pipelined: bool = True,
    prefetch_depth: int | None = None,
    faults: FaultSchedule | None = None,
    attempt: int = 0,
    cancel: threading.Event | None = None,
    tuning: TuningConfig | None = None,
    first_segment: Any | None = None,
    writer: ckpt.AsyncCheckpointer | None = None,
) -> ScanJobResult:
    """Run (or resume) one shard's checkpointed multi-scorer scan — the map
    task of the sharded job, and the whole job when the plan has one shard.

    ``ckpt_dir=None`` degrades to a plain uncheckpointed single pass. The
    checkpoint step number is "segments completed", so ``latest_step`` *is*
    the resume point; ``keep_checkpoints`` bounds disk via ``ckpt.prune``.
    ``device`` pins the shard's fold (and its restored state) to one device —
    how :func:`run_sharded_scan_job` spreads shards over a mesh's devices.

    ``pipelined=True`` (default) runs the overlapped executor: segments
    stream to the device ``prefetch_depth`` ahead of the fold
    (`pipeline.prefetch_segments`) and checkpoint commits run on an async
    writer with a drain barrier (`checkpoint.AsyncCheckpointer`);
    ``pipelined=False`` is the fully synchronous reference executor.
    Both fold through the shared compiled program (`segment_fold`) and
    produce byte-identical states, checkpoints, and resume points.

    ``faults`` is the deterministic injection schedule consulted at each
    point of the per-segment loop (see :mod:`repro.cluster.faults`);
    ``attempt`` is this execution's attempt number for transient-fault
    matching (0 = first try). ``cancel`` is the scheduler's cooperative stop
    signal: when a rival attempt commits first, the event is set and this
    run raises :class:`ShardCancelled` at the next segment boundary.
    ``fail_at_segment`` is a deprecated alias for one transient post-commit
    crash at exactly that segment.

    ``tuning`` picks the execution-only knobs (explicit arg > the
    process-active :class:`repro.tune.TuningConfig`): ``prefetch_depth`` and
    ``keep_checkpoints`` default from it when passed as ``None``, and the
    kernel block geometry flows into the shared fold. ``first_segment`` is
    an already-staged (device-resident) copy of segment 0's docs — the
    cross-shard prefetch handoff from :func:`run_sharded_scan_job` — used
    only on a fresh pipelined start (a resumed job ignores it; the staged
    rows were already folded). ``writer`` is an externally-owned
    :class:`checkpoint.AsyncCheckpointer` to reuse across shards: the job
    drains it at the usual barriers but never closes it; ownership (and
    discarding it if this attempt fails) stays with the caller.
    """
    scorers = tuple(scorers)
    cfg = tune_config.resolve(tuning)
    if keep_checkpoints is None:
        keep_checkpoints = cfg.keep_checkpoints
    if prefetch_depth is None:
        prefetch_depth = cfg.prefetch_depth
    if fail_at_segment is not None:
        if faults is not None:
            raise ValueError(
                "pass the crash as a FaultSpec in `faults`, not via the "
                "deprecated fail_at_segment kwarg"
            )
        warnings.warn(
            "fail_at_segment is deprecated; use faults=FaultSchedule([...])",
            DeprecationWarning,
            stacklevel=2,
        )
        faults = FaultSchedule.from_legacy(fail_at_segment, shard)
    n_rows = jax.tree.leaves(docs)[0].shape[0]
    n_q = jax.tree.leaves(queries)[0].shape[0]
    segs = pipeline.segments(n_rows, chunk_size, segment_chunks)

    # host-built init state (no device dispatch): concurrent shard workers
    # would serialize on eager op dispatches, and the batched device_put
    # below ships it with the queries/stats in one transfer
    state = topk.init_host(k, (len(scorers), n_q))
    if device is not None:
        # one batched transfer (a device_put per leaf costs a dispatch each,
        # which concurrent shards would serialize on)
        queries, stats, state = jax.device_put((queries, stats, state), device)
        if not pipelined:
            # legacy eager staging: the whole shard slice moves up front;
            # the pipelined path streams per-segment instead
            docs = jax.device_put(docs, device)

    fingerprint = None
    if ckpt_dir:
        fingerprint = _job_fingerprint(
            queries, docs, scorers, k, chunk_size, segment_chunks, doc_id_offset, stats
        )
    start_seg = 0
    if ckpt_dir and resume:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            prev = read_progress(ckpt_dir)
            if prev is not None and prev.get("fingerprint") != fingerprint:
                raise ValueError(
                    f"checkpoint dir {ckpt_dir!r} belongs to a different job "
                    f"(scorers {prev.get('scorers')}, fingerprint "
                    f"{prev.get('fingerprint')} != {fingerprint}); use a fresh "
                    "dir or resume=False"
                )
            if latest > len(segs):
                raise ValueError(
                    f"checkpoint at segment {latest} but job has {len(segs)} segments"
                )
            state = ckpt.restore(ckpt_dir, latest, state)
            if device is not None:
                state = jax.device_put(state, device)
            start_seg = latest
    elif ckpt_dir:
        # fresh start over a dirty dir: drop stale commits so they can never
        # masquerade as this run's progress (or out-survive it via prune)
        for s in ckpt.all_steps(ckpt_dir):
            shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
        stale = os.path.join(ckpt_dir, "progress.json")
        if os.path.exists(stale):
            os.remove(stale)

    # the one compiled program every shard/segment/job of this config shares
    fold = segment_fold(
        scorers, k=k, chunk_size=chunk_size, use_kernel=use_kernel, tuning=cfg
    )

    def progress(done: int) -> dict:
        return {
            "fingerprint": fingerprint,
            "n_segments": len(segs),
            "chunk_size": chunk_size,
            "segment_chunks": segment_chunks,
            "k": k,
            "scorers": [s.name for s in scorers],
            "shards": {
                str(shard): {
                    "n_shards": n_shards,
                    "doc_id_offset": doc_id_offset,
                    "segments_done": done,
                    "rows_done": segs[done - 1][1] if done else 0,
                    "n_rows": n_rows,
                    "complete": done == len(segs),
                }
            },
        }

    def check_cancel() -> None:
        if cancel is not None and cancel.is_set():
            raise ShardCancelled(
                f"shard {shard} attempt {attempt} cancelled by the scheduler"
            )

    ran = 0
    tr = obs.tracer()
    met = obs.metrics()
    if pipelined:
        stream_segs = segs[start_seg:]
        if first_segment is not None and start_seg == 0 and stream_segs:
            # cross-shard prefetch handoff: segment 0 was staged on this
            # device while the previous shard was still folding — start the
            # background stream at segment 1
            rest = pipeline.prefetch_segments(
                docs, stream_segs[1:], device=device, depth=prefetch_depth,
                cancel=cancel,
            )
            seg_stream = _chain_first(first_segment, rest)
        else:
            seg_stream = pipeline.prefetch_segments(
                docs, stream_segs, device=device, depth=prefetch_depth,
                cancel=cancel,
            )
    else:
        seg_stream = (
            jax.tree.map(lambda x: x[a:b], docs) for a, b in segs[start_seg:]
        )
    seg_iter = iter(seg_stream)
    writer_owned = writer is None
    if not (pipelined and ckpt_dir):
        writer = None  # the sync / uncheckpointed paths never touch a writer
    elif writer is None:
        writer = ckpt.AsyncCheckpointer()
    shard_span = tr.span(
        "shard.run", "job", shard=shard, attempt=attempt,
        resumed_from=start_seg, n_segments=len(segs),
    )
    with shard_span:
        try:
            for seg_idx in range(start_seg, len(segs)):
                check_cancel()
                # time spent waiting on the segment stream = pipeline-stall
                # time (prefetch not keeping up with the fold) made visible
                with tr.span(
                    "segment.prefetch_wait", "pipeline", shard=shard, segment=seg_idx
                ):
                    seg_docs = next(seg_iter, _STREAM_ENDED)
                if seg_docs is _STREAM_ENDED:
                    break  # the prefetch stream ends early on a cancel
                if faults is not None:
                    faults.maybe_delay(shard, seg_idx, attempt, cancel=cancel)
                    check_cancel()  # a cancelled straggler stops mid-nap
                    if faults.crash_at(shard, seg_idx, attempt, "pre_commit"):
                        # die *before* the commit: work since the last committed
                        # segment is lost and must be re-folded by the retry
                        raise WorkerCrash(
                            f"injected failure before segment {seg_idx} commit"
                        )
                a, _ = segs[seg_idx]
                t_fold = time.monotonic()
                with tr.span("segment.fold", "job", shard=shard, segment=seg_idx):
                    state = fold(
                        state, queries, seg_docs, stats, np.int32(doc_id_offset + a)
                    )
                met.histogram("job.segment_fold_s").observe(time.monotonic() - t_fold)
                ran += 1
                if ckpt_dir:
                    on_commit = (
                        faults.commit_hook(shard, seg_idx, attempt) if faults else None
                    )
                    save_kw = {} if on_commit is None else {"on_commit": on_commit}
                    if writer is not None:
                        # commit off the critical path; submission order keeps
                        # the on-disk sequence identical to the sync path's
                        # (an injected writer error poisons this writer exactly
                        # like a real I/O failure: later tasks skipped, error
                        # re-raised at the next drain). The actual save/rename
                        # spans appear on the writer thread (ckpt.save).
                        with tr.span(
                            "segment.commit_submit", "ckpt",
                            shard=shard, segment=seg_idx,
                        ):
                            writer.submit(
                                ckpt.save, ckpt_dir, seg_idx + 1, state, **save_kw
                            )
                            writer.submit(
                                _write_progress, ckpt_dir, progress(seg_idx + 1)
                            )
                            writer.submit(ckpt.prune, ckpt_dir, keep_checkpoints)
                    else:
                        with tr.span(
                            "segment.commit", "ckpt", shard=shard, segment=seg_idx
                        ):
                            state = jax.block_until_ready(state)
                            ckpt.save(ckpt_dir, seg_idx + 1, state, **save_kw)
                            _write_progress(ckpt_dir, progress(seg_idx + 1))
                            ckpt.prune(ckpt_dir, keep_checkpoints)
                if faults is not None and faults.crash_at(
                    shard, seg_idx, attempt, "post_commit"
                ):
                    # die *after* the commit: the canonical lost-ack kill point
                    if writer is not None:
                        writer.drain()
                    raise WorkerCrash(f"injected failure after segment {seg_idx}")
            check_cancel()  # cooperative stop observed at the segment boundary
            if writer is not None:
                # barrier: every commit durable before we report done; waiting
                # here = the writer is the bottleneck, visible in the trace
                with tr.span("ckpt.drain_wait", "ckpt", shard=shard):
                    writer.drain()
        except BaseException:
            if writer is not None:
                # an external writer is only drained (no in-flight commit may
                # outlive this attempt); closing/discarding it is its owner's
                # call. The in-flight error (e.g. the injected kill) wins
                # over any writer error either way.
                with contextlib.suppress(BaseException):
                    writer.close() if writer_owned else writer.drain()
                writer = None
            raise
        finally:
            if pipelined:
                seg_stream.close()  # stop the prefetch thread on any exit path
            if writer is not None and writer_owned:
                writer.close()
    if ckpt_dir and start_seg == len(segs):
        _write_progress(ckpt_dir, progress(len(segs)))  # idempotent re-run
    return ScanJobResult(
        state=state,
        segments_run=ran,
        segments_total=len(segs),
        resumed_from=start_seg,
    )


def shard_ckpt_dir(ckpt_dir: str, plan: ShardPlan, index: int) -> str:
    """Shard ``index``'s checkpoint directory under the job's ``ckpt_dir``.

    The one-shard plan *is* the classic single-host job, flat layout and all
    — the special case the sharded job degrades to, not a parallel code path.
    """
    if plan.n_shards == 1:
        return ckpt_dir
    return os.path.join(ckpt_dir, f"shard_{index:04d}")


def read_cluster_manifest(ckpt_dir: str) -> dict | None:
    path = os.path.join(ckpt_dir, "cluster.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def spec_ckpt_dir(primary: str) -> str:
    """A speculative attempt's private checkpoint dir, next to the primary's."""
    return primary + ".spec"


def _seed_spec_dir(primary: str, spec_dir: str) -> None:
    """Seed a speculative clone's checkpoint dir from the primary's last
    committed segment, so the clone re-executes only the shard's tail.

    The primary attempt is still running (that's the point), so its commits
    and prunes race with this copy; any I/O error falls back to an empty
    dir — a full re-execution, slower but still byte-identical.
    """
    shutil.rmtree(spec_dir, ignore_errors=True)
    os.makedirs(spec_dir, exist_ok=True)
    try:
        latest = ckpt.latest_step(primary)
        if latest is not None:
            step = f"step_{latest:08d}"
            shutil.copytree(
                os.path.join(primary, step), os.path.join(spec_dir, step)
            )
            prog = os.path.join(primary, "progress.json")
            if os.path.exists(prog):
                shutil.copy(prog, os.path.join(spec_dir, "progress.json"))
    except OSError:
        shutil.rmtree(spec_dir, ignore_errors=True)
        os.makedirs(spec_dir, exist_ok=True)


class _ShardStager:
    """Cross-shard prefetch: stage the *next* queued shard's first segment
    while the current one is still folding.

    `pipeline.prefetch_segments` overlaps transfers *within* a shard but
    goes cold at shard boundaries — a worker picking up its next shard
    stalls on segment 0's host slice + device transfer. A worker entering a
    shard therefore asks the stager to start staging the lowest-index
    still-queued shard's first segment onto that shard's home device, on a
    background thread; whichever worker later claims that shard collects
    the staged segment with :meth:`take` and hands it to
    :func:`run_scan_job` as ``first_segment``.

    Purely an optimization, never a correctness dependency: a device
    mismatch (the shard was stolen onto another worker's device), a staging
    error, or a claim that raced the staging thread all degrade to ``None``
    — the job re-slices segment 0 itself, byte-identical either way.
    """

    def __init__(self, docs, plan: ShardPlan, devices, seg_rows: int):
        self._docs = docs
        self._plan = plan
        self._devices = list(devices)
        self._seg_rows = seg_rows
        self._lock = threading.Lock()
        self._pending = set(range(plan.n_shards))  # not yet claimed by a worker
        self._staged: dict[int, tuple[threading.Thread, list, Any]] = {}

    def take(self, index: int, device):
        """Claim shard ``index``; return its staged first segment if it was
        prefetched onto ``device``, else None."""
        with self._lock:
            self._pending.discard(index)
            entry = self._staged.pop(index, None)
        if entry is None:
            return None
        thread, box, dev = entry
        thread.join()
        if dev is not device or not box:
            return None
        return box[0]

    def stage_next(self) -> None:
        """Kick off staging for the lowest-index queued, un-staged shard
        (onto its round-robin home device). No-op when nothing is queued."""
        with self._lock:
            todo = sorted(i for i in self._pending if i not in self._staged)
            if not todo:
                return
            idx = todo[0]
            shard = self._plan.shards[idx]
            dev = self._devices[idx % len(self._devices)]
            box: list = []

            def _stage():
                try:
                    with obs.tracer().span(
                        "prefetch.stage_shard", "pipeline", shard=idx
                    ):
                        a = shard.start
                        b = min(shard.stop, a + self._seg_rows)
                        seg = jax.tree.map(lambda x: x[a:b], self._docs)
                        box.append(jax.device_put(seg, dev))
                except BaseException:  # noqa: BLE001 — a miss, not a failure
                    box.clear()

            t = threading.Thread(target=_stage, name=f"shard-stage-{idx}", daemon=True)
            self._staged[idx] = (t, box, dev)
        t.start()


class _WriterPool:
    """Per-worker `checkpoint.AsyncCheckpointer` reuse for a sharded job.

    Spinning up a writer thread per shard attempt is pure overhead when one
    worker runs many shards back to back; the pool hands each worker thread
    one long-lived writer (``threading.local``) that successive
    `run_scan_job` calls drain-but-don't-close. A writer error poisons the
    writer permanently (by design — see `AsyncCheckpointer`), so a failed
    attempt must :meth:`discard` its worker's writer rather than return it.
    """

    def __init__(self):
        self._local = threading.local()
        self._all: list = []
        self._lock = threading.Lock()

    def get(self) -> ckpt.AsyncCheckpointer:
        w = getattr(self._local, "writer", None)
        if w is None:
            w = ckpt.AsyncCheckpointer()
            self._local.writer = w
            with self._lock:
                self._all.append(w)
        return w

    def discard(self) -> None:
        """Drop (and close) the calling worker's writer — it may be poisoned."""
        w = getattr(self._local, "writer", None)
        if w is None:
            return
        self._local.writer = None
        with self._lock:
            if w in self._all:
                self._all.remove(w)
        with contextlib.suppress(BaseException):
            w.close()

    def close_all(self) -> None:
        with self._lock:
            writers, self._all = self._all, []
        for w in writers:
            with contextlib.suppress(BaseException):
                w.close()


def run_sharded_scan_job(
    queries: Any,
    docs: Any,
    scorers: Sequence[Scorer],
    *,
    k: int,
    chunk_size: int,
    segment_chunks: int,
    plan: ShardPlan | None = None,
    n_shards: int = 1,
    stats: CollectionStats | None = None,
    ckpt_dir: str | None = None,
    resume: bool = True,
    keep_checkpoints: int | None = None,
    fail_at_segment: int | None = None,
    fail_at_shard: int = 0,
    use_kernel: bool = False,
    devices: Sequence[jax.Device] | None = None,
    pipelined: bool = True,
    max_workers: int | None = None,
    faults: FaultSchedule | None = None,
    max_retries: int = 0,
    backoff_base: float | None = None,
    backoff_cap: float | None = None,
    speculative: bool = False,
    tuning: TuningConfig | None = None,
) -> ShardedScanResult:
    """Run (or resume) a full sharded scan job: map every shard, reduce once.

    Pass a :class:`ShardPlan` (e.g. from ``plan_for_mesh``) or just
    ``n_shards`` to cut one here. Each shard runs :func:`run_scan_job` in its
    own checkpoint directory (``<ckpt_dir>/shard_NNNN``; the one-shard plan
    uses ``ckpt_dir`` itself — the classic single-host layout), so shards
    fail and resume independently; completed shards replay as no-op restores.
    ``devices`` spreads shards round-robin (``jax.devices()`` for the
    virtual-device smoke grid; real meshes at multi-process scale).

    ``pipelined=True`` (default) is the overlapped executor: shards become a
    work queue drained by :class:`repro.cluster.scheduler.ShardScheduler`
    with one worker per assigned device (override with ``max_workers``) — so
    a 4-device host actually scans 4 shards at once, and an idle worker
    steals whatever shard is queued instead of waiting for its round-robin
    assignment. Each shard's job streams segments and commits checkpoints
    asynchronously (see :func:`run_scan_job`). With no ``devices`` (or
    ``max_workers=1``) shards run in plan order on one worker, which
    preserves the sequential executor's exact failure ordering (shards after
    a permanently-failed shard never start).

    ``max_retries`` re-runs a failed shard from its last committed segment
    checkpoint with capped exponential backoff (``backoff_base``/
    ``backoff_cap``); once a shard exhausts its retries the job drain-stops
    and raises that shard's *original* error. ``speculative=True`` clones
    the slowest in-flight shard when the queue drains (first-committed-wins;
    the winning clone's checkpoint dir is promoted over the primary's).
    ``faults`` injects deterministic failures for all of the above (see
    :mod:`repro.cluster.faults`); the legacy ``fail_at_segment``/
    ``fail_at_shard`` kwargs are deprecated aliases for one transient
    post-commit crash. Scheduler counters (retries, steals, speculation,
    dead workers) come back on ``ShardedScanResult.scheduler``.

    The final merged state is byte-identical for every shard count *and*
    both executors — chunk alignment keeps per-chunk score bytes equal, the
    shared fold is one compiled program, and the lexicographic reduce is
    value-deterministic and applied in plan order whatever order shards
    finish — so run files written from it satisfy the same fingerprint
    contract as the single-host job.

    ``tuning`` (explicit arg > process-active config) supplies defaults for
    ``max_workers``/``keep_checkpoints``/``backoff_base``/``backoff_cap``
    when those are ``None``, flows the kernel block geometry into the shared
    fold, and gates two boundary optimizations: ``cross_shard_prefetch``
    (stage the next queued shard's first segment while the current shard
    folds — see :class:`_ShardStager`) and ``writer_reuse`` (one async
    checkpoint writer per worker across shards, only engaged when no fault
    injection or speculation could poison a shared writer). All of it is
    execution geometry: byte-identical artifacts under every config.
    """
    if fail_at_segment is not None:
        warnings.warn(
            "fail_at_segment/fail_at_shard are deprecated; use "
            "faults=FaultSchedule([FaultSpec(kind='crash', ...)])",
            DeprecationWarning,
            stacklevel=2,
        )
        legacy = FaultSchedule.from_legacy(fail_at_segment, fail_at_shard)
        if faults is None:
            faults = legacy
        else:
            faults.add(legacy.specs[0])

    cfg = tune_config.resolve(tuning)
    if backoff_base is None:
        backoff_base = cfg.backoff_base
    if backoff_cap is None:
        backoff_cap = cfg.backoff_cap
    n_rows = jax.tree.leaves(docs)[0].shape[0]
    if plan is None:
        plan = plan_shards(n_rows, n_shards=n_shards, chunk_size=chunk_size)
    if plan.n_docs != n_rows:
        raise ValueError(f"docs have {n_rows} rows but plan covers {plan.n_docs}")
    if plan.chunk_size != chunk_size:
        raise ValueError(
            f"plan chunk_size {plan.chunk_size} != job chunk_size {chunk_size}"
        )

    if ckpt_dir and plan.n_shards > 1:
        manifest = read_cluster_manifest(ckpt_dir)
        if manifest is not None and resume and manifest["plan"] != plan.describe():
            raise ValueError(
                f"checkpoint dir {ckpt_dir!r} holds a different shard plan "
                f"({manifest['plan']['n_shards']} shards over "
                f"{manifest['plan']['n_docs']} docs); use a fresh dir or "
                "resume=False"
            )
        os.makedirs(ckpt_dir, exist_ok=True)
        _write_json(
            os.path.join(ckpt_dir, "cluster.json"),
            {"plan": plan.describe(), "scorers": [s.name for s in scorers], "k": k},
        )

    workers = 1
    if pipelined:
        workers = max_workers if max_workers else (
            cfg.max_workers or (len(devices) if devices else 1)
        )
        workers = max(1, min(workers, plan.n_shards))
        if devices and len(devices) > workers:
            # only `workers` threads ever execute, and each folds on
            # devices[worker % len(devices)] — staging queries/stats (and
            # prefetching shards) onto devices no worker drives is pure
            # waste (the anti-scaling seen on thin hosts: 4 shards staged
            # to 4 devices with 2 workers ran *slower* than 2 shards)
            devices = list(devices)[:workers]

    # stage the replicated inputs once per assigned device, outside the
    # worker pool: shards on the same device share the transfer, and the
    # in-job device_put then short-circuits instead of re-copying while
    # other workers hold the dispatch path
    staged: dict = {}
    if devices:
        for shard in plan.shards:
            dev = devices[shard.index % len(devices)]
            if dev not in staged:
                staged[dev] = jax.device_put((queries, stats), dev)

    # cross-shard prefetch: stage the next queued shard's first segment
    # while the current one folds (worthless — and unconsumed — for the
    # one-shard plan or the eager-staging sequential path)
    stager = None
    if pipelined and cfg.cross_shard_prefetch and devices and plan.n_shards > 1:
        stager = _ShardStager(
            docs, plan, devices, seg_rows=chunk_size * segment_chunks
        )

    # one checkpoint writer per worker across its shards, only when no
    # speculation/fault-injection could leave a poisoned or racing writer
    # shared between attempts
    writer_pool = None
    if (
        pipelined and ckpt_dir and cfg.writer_reuse
        and faults is None and not speculative
    ):
        writer_pool = _WriterPool()

    def run_attempt(
        shard, *, worker=None, attempt=0, cancel=None, speculative=False
    ) -> ScanJobResult:
        device = None
        q, st = queries, stats
        if devices:
            # the executing worker's device, not the shard's round-robin
            # home — a stolen shard folds wherever it was picked up (byte
            # identity doesn't care: same compiled program, same bits)
            owner = shard.index if worker is None else worker
            device = devices[owner % len(devices)]
            q, st = staged[device]
        sdir = shard_ckpt_dir(ckpt_dir, plan, shard.index) if ckpt_dir else None
        if speculative and sdir is not None:
            primary, sdir = sdir, spec_ckpt_dir(sdir)
            _seed_spec_dir(primary, sdir)
        first_seg = None
        if stager is not None and not speculative:
            first_seg = stager.take(shard.index, device)
            stager.stage_next()  # overlap the *next* shard with this fold
        ext_writer = writer_pool.get() if writer_pool is not None else None
        try:
            return run_scan_job(
                q,
                shard.take(docs),
                scorers,
                k=k,
                chunk_size=chunk_size,
                segment_chunks=segment_chunks,
                stats=st,
                ckpt_dir=sdir,
                # retries and speculative clones always resume: the last
                # committed segment checkpoint is the unit of re-execution
                resume=resume or attempt > 0 or speculative,
                keep_checkpoints=keep_checkpoints,
                shard=shard.index,
                n_shards=plan.n_shards,
                doc_id_offset=shard.doc_id_offset,
                use_kernel=use_kernel,
                device=device,
                pipelined=pipelined,
                faults=faults,
                attempt=attempt,
                cancel=cancel,
                tuning=cfg,
                first_segment=first_seg,
                writer=ext_writer,
            )
        except BaseException:
            if writer_pool is not None:
                writer_pool.discard()  # a failed attempt may have poisoned it
            raise

    def finalize_spec(index: int, won: bool) -> None:
        # both attempts have stopped (scheduler invariant), so nothing is
        # writing to either dir: promote the winning clone's lineage over
        # the primary's, or drop the losing clone's
        if not ckpt_dir:
            return
        primary = shard_ckpt_dir(ckpt_dir, plan, index)
        sdir = spec_ckpt_dir(primary)
        if won and os.path.exists(sdir):
            ckpt.replace_dir(sdir, primary)
        else:
            shutil.rmtree(sdir, ignore_errors=True)

    if not pipelined:
        # the synchronous reference executor: plan order, one attempt in
        # flight, retries inline (no threads, no stealing, no speculation)
        results: list[ScanJobResult] = []
        attempts: list[int] = []
        retries = 0
        for s in plan.shards:
            failures = 0
            while True:
                try:
                    results.append(run_attempt(s, attempt=failures))
                    attempts.append(failures + 1)
                    break
                except ShardCancelled:
                    raise  # no scheduler to cancel us — never expected
                except BaseException:
                    failures += 1
                    if failures > max_retries:
                        raise
                    retries += 1
                    time.sleep(
                        min(backoff_cap, backoff_base * (2 ** (failures - 1)))
                    )
        stats_out = SchedulerStats(
            n_workers=1,
            attempts=tuple(attempts),
            retries=retries,
            steals=0,
            speculative_launched=0,
            speculative_won=0,
            dead_workers=(),
        )
    else:
        # the reliability layer: work queue + stealing + backoff retries +
        # speculation; results (and any failure) come back in plan order
        # however shards interleave, so the reduce below and the raised
        # error are deterministic
        sched = ShardScheduler(
            plan,
            run_attempt,
            n_workers=workers,
            max_retries=max_retries,
            backoff_base=backoff_base,
            backoff_cap=backoff_cap,
            speculative=speculative,
            faults=faults,
            finalize_spec=finalize_spec if speculative else None,
        )
        try:
            results, stats_out = sched.run()
        finally:
            if writer_pool is not None:
                writer_pool.close_all()

    states = [r.state for r in results]
    if devices:
        # reduce on one device: shard states live where their folds ran
        # (one batched transfer — k-bounded payloads, the paper's shuffle)
        states = jax.device_put(states, devices[0])
    merged = reduce_states(states)
    return ShardedScanResult(
        state=merged, plan=plan, shard_results=tuple(results), scheduler=stats_out
    )
