"""Checkpointed sharded scan jobs — MIREX's cluster, kill/resume per shard.

The Hadoop property the paper leans on (any split can be re-executed and
re-reduced without changing the answer) holds here at two nested levels:

* **within a shard** — the corpus folds one chunk-aligned *segment* at a
  time through a single jitted multi-scorer fold; after every segment the
  stacked ``TopKState`` commits via the atomic-rename checkpointer and a
  ``progress.json`` manifest is rewritten, so a killed shard restarts from
  its last committed segment and replays the exact per-chunk instruction
  stream of an uninterrupted run (bit-identical, test-enforced);
* **across shards** — each shard owns its own checkpoint directory and
  progress manifest, fails and resumes independently, and the final
  :func:`repro.cluster.mapreduce.reduce_states` merge is value-deterministic,
  so the merged state (and every TREC run file written from it) is
  byte-identical whatever subset of shards died, resumed, or ran on which
  device — and byte-identical to the one-shard job, which is literally this
  code with a trivial plan.

Failure injection mirrors `launch/train.py`: ``fail_at_segment=s`` raises
after segment ``s``'s checkpoint commits on shard ``fail_at_shard`` — the
canonical lost-ack kill point.

**The pipelined executor** (``pipeline=True``, the default) overlaps
everything the sequential path serializes, without changing a byte of any
artifact:

* one compiled fold — `cluster.mapreduce.segment_fold` is jit-cached per
  (grid, k, chunk, kernel) configuration, so all shards and segments of a
  job (and every later job with the same config) share one program instead
  of re-tracing per ``run_scan_job`` call;
* double-buffered segments — `pipeline.prefetch_segments` stages segment
  *s+1*'s host→device transfer while segment *s* folds, and stops eagerly
  staging a shard's whole doc slice on its device up front;
* async checkpoints — the ``save → progress → prune`` commit sequence runs
  on a `checkpoint.AsyncCheckpointer` writer thread in submission order,
  with a drain barrier before any reported kill/completion, so kill/resume
  disk states are exactly the synchronous path's;
* concurrent shards — ``run_sharded_scan_job`` runs shards on a
  device-aware thread pool (one worker per assigned device, round-robin
  placement preserved), then reduces through the same value-deterministic
  merge, so merged states stay byte-identical to the sequential executor
  and the single-host oracle.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Sequence

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro.core import pipeline, topk
from repro.core.scoring import CollectionStats, Scorer

from repro.cluster.mapreduce import reduce_states, segment_fold
from repro.cluster.plan import ShardPlan, plan_shards


@dataclasses.dataclass(frozen=True)
class ScanJobResult:
    state: topk.TopKState  # stacked [n_models, n_q, k]
    segments_run: int  # segments executed by *this* invocation
    segments_total: int
    resumed_from: int  # segment index the run started at (0 = fresh)


@dataclasses.dataclass(frozen=True)
class ShardedScanResult:
    """Merged result of a sharded job + each shard's own job result."""

    state: topk.TopKState  # merged [n_models, n_q, k]
    plan: ShardPlan
    shard_results: tuple[ScanJobResult, ...]

    @property
    def segments_run(self) -> int:
        return sum(r.segments_run for r in self.shard_results)

    @property
    def segments_total(self) -> int:
        return sum(r.segments_total for r in self.shard_results)

    @property
    def resumed(self) -> bool:
        return any(r.resumed_from for r in self.shard_results)


def _job_fingerprint(
    queries, docs, scorers, k: int, chunk_size: int, segment_chunks: int,
    doc_id_offset: int, stats,
) -> str:
    """Cheap identity of (data, grid, chunking, segmentation) — guards resume.

    A checkpointed TopKState from a *different* job can have exactly the same
    array shapes (same model count / query count / k), so shape checks alone
    would silently resume the wrong experiment. Hash the configuration, the
    full query set (small) and a strided row sample of the corpus instead.
    ``segment_chunks`` matters because the checkpoint step counts *segments*:
    reinterpreting it under a different segmentation would skip or double-fold
    corpus rows without any shape mismatch. ``doc_id_offset`` makes every
    shard of a sharded job a *distinct* job, so shard checkpoints can never
    be cross-adopted (e.g. after re-planning the same dir at a different
    shard count).
    """
    h = hashlib.sha256()
    h.update(
        repr(
            (k, chunk_size, segment_chunks, doc_id_offset, [s.name for s in scorers])
        ).encode()
    )
    for leaf in jax.tree.leaves(queries):
        h.update(np.asarray(leaf).tobytes())
    for leaf in jax.tree.leaves(docs):
        h.update(repr(tuple(leaf.shape)).encode())
        stride = max(1, leaf.shape[0] // 64)
        h.update(np.asarray(leaf[::stride][:64]).tobytes())
    # stats shape the scores: resuming under different collection statistics
    # would merge incompatible partial scores without any shape mismatch
    if stats is not None:
        for leaf in jax.tree.leaves(stats):
            h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()[:16]


def _write_json(path: str, payload: dict) -> None:
    tmp = os.path.join(os.path.dirname(path), ".tmp-" + os.path.basename(path))
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)


def _write_progress(ckpt_dir: str, payload: dict) -> None:
    _write_json(os.path.join(ckpt_dir, "progress.json"), payload)


def read_progress(ckpt_dir: str) -> dict | None:
    path = os.path.join(ckpt_dir, "progress.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run_scan_job(
    queries: Any,
    docs: Any,
    scorers: Sequence[Scorer],
    *,
    k: int,
    chunk_size: int,
    segment_chunks: int,
    stats: CollectionStats | None = None,
    ckpt_dir: str | None = None,
    resume: bool = True,
    keep_checkpoints: int = 2,
    fail_at_segment: int | None = None,
    shard: int = 0,
    n_shards: int = 1,
    doc_id_offset: int = 0,
    use_kernel: bool = False,
    device: jax.Device | None = None,
    pipelined: bool = True,
    prefetch_depth: int = 2,
) -> ScanJobResult:
    """Run (or resume) one shard's checkpointed multi-scorer scan — the map
    task of the sharded job, and the whole job when the plan has one shard.

    ``ckpt_dir=None`` degrades to a plain uncheckpointed single pass. The
    checkpoint step number is "segments completed", so ``latest_step`` *is*
    the resume point; ``keep_checkpoints`` bounds disk via ``ckpt.prune``.
    ``device`` pins the shard's fold (and its restored state) to one device —
    how :func:`run_sharded_scan_job` spreads shards over a mesh's devices.

    ``pipelined=True`` (default) runs the overlapped executor: segments
    stream to the device ``prefetch_depth`` ahead of the fold
    (`pipeline.prefetch_segments`) and checkpoint commits run on an async
    writer with a drain barrier (`checkpoint.AsyncCheckpointer`);
    ``pipelined=False`` is the fully synchronous reference executor.
    Both fold through the shared compiled program (`segment_fold`) and
    produce byte-identical states, checkpoints, and resume points.
    """
    scorers = tuple(scorers)
    n_rows = jax.tree.leaves(docs)[0].shape[0]
    n_q = jax.tree.leaves(queries)[0].shape[0]
    segs = pipeline.segments(n_rows, chunk_size, segment_chunks)

    # host-built init state (no device dispatch): concurrent shard workers
    # would serialize on eager op dispatches, and the batched device_put
    # below ships it with the queries/stats in one transfer
    state = topk.init_host(k, (len(scorers), n_q))
    if device is not None:
        # one batched transfer (a device_put per leaf costs a dispatch each,
        # which concurrent shards would serialize on)
        queries, stats, state = jax.device_put((queries, stats, state), device)
        if not pipelined:
            # legacy eager staging: the whole shard slice moves up front;
            # the pipelined path streams per-segment instead
            docs = jax.device_put(docs, device)

    fingerprint = None
    if ckpt_dir:
        fingerprint = _job_fingerprint(
            queries, docs, scorers, k, chunk_size, segment_chunks, doc_id_offset, stats
        )
    start_seg = 0
    if ckpt_dir and resume:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            prev = read_progress(ckpt_dir)
            if prev is not None and prev.get("fingerprint") != fingerprint:
                raise ValueError(
                    f"checkpoint dir {ckpt_dir!r} belongs to a different job "
                    f"(scorers {prev.get('scorers')}, fingerprint "
                    f"{prev.get('fingerprint')} != {fingerprint}); use a fresh "
                    "dir or resume=False"
                )
            if latest > len(segs):
                raise ValueError(
                    f"checkpoint at segment {latest} but job has {len(segs)} segments"
                )
            state = ckpt.restore(ckpt_dir, latest, state)
            if device is not None:
                state = jax.device_put(state, device)
            start_seg = latest
    elif ckpt_dir:
        # fresh start over a dirty dir: drop stale commits so they can never
        # masquerade as this run's progress (or out-survive it via prune)
        for s in ckpt.all_steps(ckpt_dir):
            shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
        stale = os.path.join(ckpt_dir, "progress.json")
        if os.path.exists(stale):
            os.remove(stale)

    # the one compiled program every shard/segment/job of this config shares
    fold = segment_fold(scorers, k=k, chunk_size=chunk_size, use_kernel=use_kernel)

    def progress(done: int) -> dict:
        return {
            "fingerprint": fingerprint,
            "n_segments": len(segs),
            "chunk_size": chunk_size,
            "segment_chunks": segment_chunks,
            "k": k,
            "scorers": [s.name for s in scorers],
            "shards": {
                str(shard): {
                    "n_shards": n_shards,
                    "doc_id_offset": doc_id_offset,
                    "segments_done": done,
                    "rows_done": segs[done - 1][1] if done else 0,
                    "n_rows": n_rows,
                    "complete": done == len(segs),
                }
            },
        }

    ran = 0
    if pipelined:
        seg_stream = pipeline.prefetch_segments(
            docs, segs[start_seg:], device=device, depth=prefetch_depth
        )
    else:
        seg_stream = (
            jax.tree.map(lambda x: x[a:b], docs) for a, b in segs[start_seg:]
        )
    writer = ckpt.AsyncCheckpointer() if (pipelined and ckpt_dir) else None
    try:
        for seg_idx, seg_docs in zip(range(start_seg, len(segs)), seg_stream):
            a, _ = segs[seg_idx]
            state = fold(state, queries, seg_docs, stats, np.int32(doc_id_offset + a))
            ran += 1
            if ckpt_dir:
                if writer is not None:
                    # commit off the critical path; submission order keeps
                    # the on-disk sequence identical to the sync path's
                    writer.submit(ckpt.save, ckpt_dir, seg_idx + 1, state)
                    writer.submit(_write_progress, ckpt_dir, progress(seg_idx + 1))
                    writer.submit(ckpt.prune, ckpt_dir, keep_checkpoints)
                else:
                    state = jax.block_until_ready(state)
                    ckpt.save(ckpt_dir, seg_idx + 1, state)
                    _write_progress(ckpt_dir, progress(seg_idx + 1))
                    ckpt.prune(ckpt_dir, keep_checkpoints)
            if fail_at_segment is not None and seg_idx >= fail_at_segment:
                # die *after* the commit: the canonical lost-ack kill point
                if writer is not None:
                    writer.drain()
                raise RuntimeError(f"injected failure after segment {seg_idx}")
        if writer is not None:
            writer.drain()  # barrier: every commit durable before we report done
    except BaseException:
        if writer is not None:
            try:
                writer.close()
            except BaseException:
                pass  # the in-flight error (e.g. the injected kill) wins
            writer = None
        raise
    finally:
        if pipelined:
            seg_stream.close()  # stop the prefetch thread on any exit path
        if writer is not None:
            writer.close()
    if ckpt_dir and start_seg == len(segs):
        _write_progress(ckpt_dir, progress(len(segs)))  # idempotent re-run
    return ScanJobResult(
        state=state,
        segments_run=ran,
        segments_total=len(segs),
        resumed_from=start_seg,
    )


def shard_ckpt_dir(ckpt_dir: str, plan: ShardPlan, index: int) -> str:
    """Shard ``index``'s checkpoint directory under the job's ``ckpt_dir``.

    The one-shard plan *is* the classic single-host job, flat layout and all
    — the special case the sharded job degrades to, not a parallel code path.
    """
    if plan.n_shards == 1:
        return ckpt_dir
    return os.path.join(ckpt_dir, f"shard_{index:04d}")


def read_cluster_manifest(ckpt_dir: str) -> dict | None:
    path = os.path.join(ckpt_dir, "cluster.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run_sharded_scan_job(
    queries: Any,
    docs: Any,
    scorers: Sequence[Scorer],
    *,
    k: int,
    chunk_size: int,
    segment_chunks: int,
    plan: ShardPlan | None = None,
    n_shards: int = 1,
    stats: CollectionStats | None = None,
    ckpt_dir: str | None = None,
    resume: bool = True,
    keep_checkpoints: int = 2,
    fail_at_segment: int | None = None,
    fail_at_shard: int = 0,
    use_kernel: bool = False,
    devices: Sequence[jax.Device] | None = None,
    pipelined: bool = True,
    max_workers: int | None = None,
) -> ShardedScanResult:
    """Run (or resume) a full sharded scan job: map every shard, reduce once.

    Pass a :class:`ShardPlan` (e.g. from ``plan_for_mesh``) or just
    ``n_shards`` to cut one here. Each shard runs :func:`run_scan_job` in its
    own checkpoint directory (``<ckpt_dir>/shard_NNNN``; the one-shard plan
    uses ``ckpt_dir`` itself — the classic single-host layout), so shards
    fail and resume independently; completed shards replay as no-op restores.
    ``devices`` spreads shards round-robin (``jax.devices()`` for the
    virtual-device smoke grid; real meshes at multi-process scale).

    ``pipelined=True`` (default) is the overlapped executor: shards run
    concurrently on a thread pool sized one worker per assigned device
    (override with ``max_workers``) — so a 4-device host actually scans 4
    shards at once — and each shard's job streams segments and commits
    checkpoints asynchronously (see :func:`run_scan_job`). With no
    ``devices`` (or ``max_workers=1``) shards run in plan order on one
    worker, which preserves the sequential executor's exact failure
    ordering (shards after a killed shard never start).

    The final merged state is byte-identical for every shard count *and*
    both executors — chunk alignment keeps per-chunk score bytes equal, the
    shared fold is one compiled program, and the lexicographic reduce is
    value-deterministic and applied in plan order whatever order shards
    finish — so run files written from it satisfy the same fingerprint
    contract as the single-host job.
    """
    n_rows = jax.tree.leaves(docs)[0].shape[0]
    if plan is None:
        plan = plan_shards(n_rows, n_shards=n_shards, chunk_size=chunk_size)
    if plan.n_docs != n_rows:
        raise ValueError(f"docs have {n_rows} rows but plan covers {plan.n_docs}")
    if plan.chunk_size != chunk_size:
        raise ValueError(
            f"plan chunk_size {plan.chunk_size} != job chunk_size {chunk_size}"
        )

    if ckpt_dir and plan.n_shards > 1:
        manifest = read_cluster_manifest(ckpt_dir)
        if manifest is not None and resume and manifest["plan"] != plan.describe():
            raise ValueError(
                f"checkpoint dir {ckpt_dir!r} holds a different shard plan "
                f"({manifest['plan']['n_shards']} shards over "
                f"{manifest['plan']['n_docs']} docs); use a fresh dir or "
                "resume=False"
            )
        os.makedirs(ckpt_dir, exist_ok=True)
        _write_json(
            os.path.join(ckpt_dir, "cluster.json"),
            {"plan": plan.describe(), "scorers": [s.name for s in scorers], "k": k},
        )

    # stage the replicated inputs once per assigned device, outside the
    # worker pool: shards on the same device share the transfer, and the
    # in-job device_put then short-circuits instead of re-copying while
    # other workers hold the dispatch path
    staged: dict = {}
    if devices:
        for shard in plan.shards:
            dev = devices[shard.index % len(devices)]
            if dev not in staged:
                staged[dev] = jax.device_put((queries, stats), dev)

    def run_one(shard) -> ScanJobResult:
        device = None
        q, st = queries, stats
        if devices:
            device = devices[shard.index % len(devices)]
            q, st = staged[device]
        return run_scan_job(
            q,
            shard.take(docs),
            scorers,
            k=k,
            chunk_size=chunk_size,
            segment_chunks=segment_chunks,
            stats=st,
            ckpt_dir=shard_ckpt_dir(ckpt_dir, plan, shard.index) if ckpt_dir else None,
            resume=resume,
            keep_checkpoints=keep_checkpoints,
            fail_at_segment=fail_at_segment if shard.index == fail_at_shard else None,
            shard=shard.index,
            n_shards=plan.n_shards,
            doc_id_offset=shard.doc_id_offset,
            use_kernel=use_kernel,
            device=device,
            pipelined=pipelined,
        )

    workers = 1
    if pipelined:
        workers = max_workers if max_workers else (len(devices) if devices else 1)
        workers = max(1, min(workers, plan.n_shards))

    if workers == 1:
        # one worker = the sequential executor's shard ordering (a killed
        # shard stops the job before later shards ever start)
        results: list[ScanJobResult] = [run_one(s) for s in plan.shards]
    else:
        # device-aware concurrent executor: results (and any failure) are
        # reported in plan order however shards interleave, so the reduce
        # below and the raised error are deterministic
        with ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="scan-shard"
        ) as ex:
            futures = [ex.submit(run_one, s) for s in plan.shards]
        results = []
        errors: dict[int, BaseException] = {}
        for i, fut in enumerate(futures):
            try:
                results.append(fut.result())
            except BaseException as e:  # noqa: BLE001 — re-raised below
                errors[i] = e
        if errors:
            raise errors[min(errors)]

    states = [r.state for r in results]
    if devices:
        # reduce on one device: shard states live where their folds ran
        # (one batched transfer — k-bounded payloads, the paper's shuffle)
        states = jax.device_put(states, devices[0])
    merged = reduce_states(states)
    return ShardedScanResult(state=merged, plan=plan, shard_results=tuple(results))
