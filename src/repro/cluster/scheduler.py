"""Straggler-tolerant shard scheduler — the MapReduce reliability layer.

The paper runs 15 cheap machines for days and leans entirely on Hadoop to
survive them: failed tasks are re-executed from their input split, idle
machines steal queued work, and near the end of a job the slowest running
tasks are *speculatively* duplicated, first copy to finish wins. This
module is that layer for `cluster.run_sharded_scan_job`:

* **work queue, not static assignment** — shards are a queue; ``n_workers``
  threads (one per assigned device) pull from it, so an idle worker steals
  whatever shard is next instead of idling behind its round-robin
  assignment, and a dead worker's backlog drains through the survivors.
* **retry with capped exponential backoff** — a failed shard attempt is
  re-enqueued (``backoff_base * 2**(failures-1)``, capped) and *resumes
  from its last committed segment checkpoint*: the chunk-aligned per-shard
  checkpoint dirs from the plan layer are the unit of re-execution, so a
  retry replays only the lost tail. After ``max_retries`` re-runs the
  shard is declared dead and the job surfaces the shard's *original*
  error (deterministically: the lowest-indexed failed shard's).
* **speculative execution** — when the queue drains, idle workers clone
  the longest-running in-flight shard: the clone seeds its own checkpoint
  dir from the primary's last committed segment and re-executes the tail.
  First attempt to finish commits its result; the rival is cancelled (a
  cooperative per-segment check) and, if the clone won, its checkpoint dir
  is promoted over the primary's via the atomic dir replace — so the
  on-disk state always describes the winning lineage.

Byte-identity survives all of it by construction: every attempt of a shard
folds the same chunk-aligned segment stream through the same compiled
program, so whichever attempt wins produces the same ``TopKState`` bits,
and the plan-ordered value-deterministic reduce erases scheduling history
from the merged result. The chaos suite (`tests/test_faults.py`) pins that
equality against the fault-free single-host oracle under seeded schedules.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable

from repro import obs
from repro.cluster.faults import FaultSchedule, ShardCancelled
from repro.cluster.plan import ShardPlan
from repro.tune import config as tune_config
from repro.tune.config import TuningConfig


@dataclasses.dataclass(frozen=True)
class SchedulerStats:
    """What the reliability layer actually did — for reports and tests."""

    n_workers: int
    attempts: tuple[int, ...]  # executions per shard (primary + speculative)
    retries: int  # failed attempts that were re-enqueued
    steals: int  # shards run by a worker other than their round-robin home
    speculative_launched: int
    speculative_won: int
    dead_workers: tuple[int, ...]

    def describe(self) -> dict:
        d = dataclasses.asdict(self)
        d["attempts"] = list(self.attempts)
        d["dead_workers"] = list(self.dead_workers)
        return d


@dataclasses.dataclass
class _Task:
    shard: int
    attempt: int
    speculative: bool
    ready_at: float  # monotonic deadline for backoff re-runs


@dataclasses.dataclass
class _Running:
    attempt: int
    speculative: bool
    cancel: threading.Event
    started_at: float


class ShardScheduler:
    """Run every shard of ``plan`` through ``run_attempt`` with retries,
    work stealing, and optional speculation.

    ``run_attempt(shard, worker=, attempt=, cancel=, speculative=)`` must
    return the shard's result, raise :class:`ShardCancelled` when it
    observes its cancel event, or raise anything else to mean "this attempt
    failed". ``finalize_spec(shard_index, won)`` is called exactly once for
    every shard that had a speculative clone, after *both* attempts have
    stopped — the hook promotes or discards the clone's checkpoint dir.
    """

    def __init__(
        self,
        plan: ShardPlan,
        run_attempt: Callable[..., Any],
        *,
        n_workers: int,
        max_retries: int = 0,
        backoff_base: float | None = None,
        backoff_cap: float | None = None,
        speculative: bool = False,
        faults: FaultSchedule | None = None,
        finalize_spec: Callable[[int, bool], None] | None = None,
        tuning: TuningConfig | None = None,
    ):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        cfg = tune_config.resolve(tuning)
        self.plan = plan
        self.run_attempt = run_attempt
        self.n_workers = n_workers
        self.max_retries = max_retries
        self.backoff_base = cfg.backoff_base if backoff_base is None else backoff_base
        self.backoff_cap = cfg.backoff_cap if backoff_cap is None else backoff_cap
        self.speculative = speculative
        self.faults = faults
        self.finalize_spec = finalize_spec

        self._cond = threading.Condition()
        self._queue: list[_Task] = [
            _Task(shard=s.index, attempt=0, speculative=False, ready_at=0.0)
            for s in plan.shards
        ]
        self._running: dict[int, list[_Running]] = {}
        self._results: dict[int, Any] = {}
        self._spec_won: dict[int, bool] = {}
        self._failures: dict[int, int] = {}
        self._first_error: dict[int, BaseException] = {}
        self._failed: set[int] = set()
        self._attempt_counter: dict[int, int] = {s.index: 1 for s in plan.shards}
        self._attempts_run: dict[int, int] = {s.index: 0 for s in plan.shards}
        self._speculated: set[int] = set()
        self._abort = False
        self._retries = 0
        self._steals = 0
        self._spec_launched = 0
        self._dead_workers: list[int] = []

    # -- public -------------------------------------------------------------

    def run(self) -> tuple[list[Any], SchedulerStats]:
        """Block until every shard is committed or the job has failed; return
        plan-ordered results. Raises the lowest-indexed failed shard's
        original error, or RuntimeError when shards were left unscanned
        (e.g. every worker died)."""
        obs.metrics().gauge("sched.queue_depth").set(len(self._queue))
        threads = [
            threading.Thread(
                target=self._worker_loop, args=(w,), name=f"shard-sched-{w}"
            )
            for w in range(self.n_workers)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = self.stats()
        if self._failed:
            raise self._first_error[min(self._failed)]
        missing = [s.index for s in self.plan.shards if s.index not in self._results]
        if missing:
            raise RuntimeError(
                f"scheduler finished with unscanned shards {missing} "
                f"(dead workers: {stats.dead_workers})"
            )
        return [self._results[s.index] for s in self.plan.shards], stats

    def stats(self) -> SchedulerStats:
        return SchedulerStats(
            n_workers=self.n_workers,
            attempts=tuple(
                self._attempts_run[s.index] for s in self.plan.shards
            ),
            retries=self._retries,
            steals=self._steals,
            speculative_launched=self._spec_launched,
            speculative_won=sum(1 for won in self._spec_won.values() if won),
            dead_workers=tuple(self._dead_workers),
        )

    # -- worker side ---------------------------------------------------------

    def _worker_loop(self, w: int) -> None:
        shards_done = 0
        while True:
            if self.faults is not None and self.faults.worker_dead(w, shards_done):
                with self._cond:
                    self._dead_workers.append(w)
                    self._cond.notify_all()
                obs.tracer().instant(
                    "sched.dead_worker", "sched",
                    worker=w, shards_done=shards_done,
                )
                return
            task = self._next_task(w)
            if task is None:
                return
            wait = task.ready_at - time.monotonic()
            if wait > 0:
                time.sleep(wait)
            try:
                self._execute(task, w)
            except BaseException as e:  # noqa: BLE001 — scheduler-internal bug
                # an error escaping _execute is a bug in the scheduler
                # itself (run_attempt errors are caught inside): fail the
                # job loudly instead of leaving a half-registered attempt
                # deadlocking the other workers
                self._crash(task, e)
                return
            shards_done += 1

    def _crash(self, task: _Task, err: BaseException) -> None:
        with self._cond:
            runs = self._running.get(task.shard)
            if runs is not None:
                runs[:] = [r for r in runs if r.attempt != task.attempt]
                if not runs:
                    del self._running[task.shard]
            self._first_error.setdefault(task.shard, err)
            self._failed.add(task.shard)
            self._abort = True
            self._cond.notify_all()

    def _next_task(self, w: int) -> _Task | None:
        with self._cond:
            while True:
                if self._abort:
                    # drain-stop: no new work after a permanent shard failure;
                    # in-flight attempts run to completion (their checkpoints
                    # make the eventual resume cheap)
                    self._queue.clear()
                if self._queue:
                    now = time.monotonic()
                    ready = [t for t in self._queue if t.ready_at <= now]
                    if ready:
                        # deterministic preference: lowest shard index first
                        task = min(ready, key=lambda t: t.shard)
                        self._queue.remove(task)
                        obs.metrics().gauge("sched.queue_depth").set(
                            len(self._queue)
                        )
                        if task.shard % self.n_workers != w:
                            self._steals += 1
                            obs.tracer().instant(
                                "sched.steal", "sched",
                                shard=task.shard, worker=w,
                                home=task.shard % self.n_workers,
                            )
                        self._register(task)
                        return task
                    self._cond.wait(
                        timeout=min(t.ready_at for t in self._queue) - now
                    )
                    continue
                if self.speculative and not self._abort:
                    task = self._speculation_candidate()
                    if task is not None:
                        self._register(task)
                        return task
                if any(self._running.values()):
                    self._cond.wait()
                    continue
                return None

    def _register(self, task: _Task) -> None:
        self._running.setdefault(task.shard, []).append(
            _Running(
                attempt=task.attempt,
                speculative=task.speculative,
                cancel=threading.Event(),
                started_at=time.monotonic(),
            )
        )
        self._attempts_run[task.shard] += 1

    def _speculation_candidate(self) -> _Task | None:
        # the longest-running shard with exactly one in-flight attempt and
        # no prior clone: the classic "slowest task near the end of the job"
        candidates = [
            (runs[0].started_at, shard)
            for shard, runs in self._running.items()
            if len(runs) == 1
            and shard not in self._results
            and shard not in self._speculated
        ]
        if not candidates:
            return None
        _, shard = min(candidates)
        self._speculated.add(shard)
        self._spec_launched += 1
        attempt = self._attempt_counter[shard]
        self._attempt_counter[shard] = attempt + 1
        obs.tracer().instant(
            "sched.speculate", "sched", shard=shard, attempt=attempt
        )
        return _Task(shard=shard, attempt=attempt, speculative=True, ready_at=0.0)

    def _execute(self, task: _Task, w: int) -> None:
        shard_obj = self.plan.shards[task.shard]
        run = self._find_running(task)
        span = obs.tracer().span(
            "shard.attempt", "sched",
            shard=task.shard, attempt=task.attempt, worker=w,
            speculative=task.speculative,
        )
        with span:
            try:
                result = self.run_attempt(
                    shard_obj,
                    worker=w,
                    attempt=task.attempt,
                    cancel=run.cancel,
                    speculative=task.speculative,
                )
            except ShardCancelled:
                span.set(outcome="cancelled")
                self._on_cancelled(task)
            except BaseException as e:  # noqa: BLE001 — scheduler owns retry policy
                span.set(outcome="failed")
                self._on_failure(task, e)
            else:
                span.set(outcome="ok")
                self._on_success(task, result)

    def _find_running(self, task: _Task) -> _Running:
        with self._cond:
            for run in self._running[task.shard]:
                if run.attempt == task.attempt:
                    return run
        raise AssertionError(f"attempt {task.attempt} of shard {task.shard} not registered")

    # -- attempt outcomes ----------------------------------------------------

    def _unregister(self, task: _Task) -> list[_Running]:
        """Drop the finished attempt; returns the shard's remaining runs."""
        runs = self._running[task.shard]
        runs[:] = [r for r in runs if r.attempt != task.attempt]
        if not runs:
            del self._running[task.shard]
        return self._running.get(task.shard, [])

    def _maybe_finalize(self, shard: int) -> None:
        """Promote/discard the speculative clone's dir once the shard has no
        in-flight attempts left — called with the lock held."""
        if (
            shard in self._speculated
            and shard not in self._running
            and self.finalize_spec is not None
        ):
            self._speculated.discard(shard)  # exactly-once
            self.finalize_spec(shard, self._spec_won.get(shard, False))

    def _on_success(self, task: _Task, result: Any) -> None:
        with self._cond:
            remaining = self._unregister(task)
            if task.shard not in self._results:
                # first committed attempt wins; rivals get cancelled and
                # their (identical) results discarded
                self._results[task.shard] = result
                self._spec_won[task.shard] = task.speculative
                for rival in remaining:
                    rival.cancel.set()
                    obs.tracer().instant(
                        "sched.cancel", "sched",
                        shard=task.shard, rival_attempt=rival.attempt,
                        winner_attempt=task.attempt,
                    )
            self._maybe_finalize(task.shard)
            self._cond.notify_all()

    def _on_cancelled(self, task: _Task) -> None:
        with self._cond:
            self._unregister(task)
            self._maybe_finalize(task.shard)
            self._cond.notify_all()

    def _on_failure(self, task: _Task, err: BaseException) -> None:
        with self._cond:
            remaining = self._unregister(task)
            if task.shard in self._results:
                # a rival already committed; this late failure is moot
                self._maybe_finalize(task.shard)
                self._cond.notify_all()
                return
            self._failures[task.shard] = self._failures.get(task.shard, 0) + 1
            self._first_error.setdefault(task.shard, err)
            if self._failures[task.shard] > self.max_retries:
                if not remaining:
                    # out of attempts and no rival in flight: the shard is
                    # dead, and with it the job (drain-stop)
                    self._failed.add(task.shard)
                    self._abort = True
                # else: a rival is still running; its outcome decides
            elif not remaining:
                # resume-from-checkpoint retry after capped backoff; any
                # idle worker may pick it up (stealing)
                failures = self._failures[task.shard]
                delay = min(
                    self.backoff_cap, self.backoff_base * (2 ** (failures - 1))
                )
                self._queue.append(
                    _Task(
                        shard=task.shard,
                        attempt=self._attempt_counter[task.shard],
                        speculative=False,
                        ready_at=time.monotonic() + delay,
                    )
                )
                obs.metrics().gauge("sched.queue_depth").set(len(self._queue))
                obs.tracer().instant(
                    "sched.retry", "sched",
                    shard=task.shard, failures=failures, backoff_s=delay,
                    error=type(err).__name__,
                )
                self._attempt_counter[task.shard] += 1
                self._retries += 1
            # else: a rival attempt is in flight — it *is* the retry
            self._maybe_finalize(task.shard)
            self._cond.notify_all()
