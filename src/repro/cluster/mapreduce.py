"""The cluster's map and reduce: per-shard scan folds + the one merge.

Paper §2, literally: **map** = sequentially scan one shard of the collection
against the full query (and model-grid) block; **reduce** = merge per-shard
top-k lists, at most ``k`` entries per query per shard ever crossing a
shard boundary. Both halves are the *same code* on every execution substrate:

* :func:`map_shard` is the single fold every shard runs — multi-model
  single-pass (`scan.search_local_multi`), fused Pallas lexical kernel under
  ``use_kernel``, sentinel-preserving global doc ids via the shard's offset.
* :func:`reduce_states` is the k-bounded lexicographic bitonic merge
  (`topk.reduce_lex`): value-deterministic, so 1/2/4/N shards merge to the
  same bits, which is the shard-count-invariance contract jobs and serve
  both inherit.
* :func:`search_mesh` stamps the two onto a JAX mesh with ``shard_map`` —
  corpus sharded over the scan axes, queries/stats replicated, local map,
  hierarchical lexicographic reduce — for one-shot and serve-path scans.
  Checkpointed jobs use the host-loop driver in `cluster.job` instead (a
  shard that lives inside one XLA program can't kill/resume independently).
"""

from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat
from repro.core import scan, topk
from repro.core.scoring import CollectionStats, Scorer

from repro.cluster.plan import ShardPlan, mesh_scan_axes


def map_shard(
    queries: Any,
    shard_docs: Any,
    scorers: Sequence[Scorer],
    *,
    k: int,
    chunk_size: int,
    stats: CollectionStats | None = None,
    doc_id_offset: jax.Array | int = 0,
    init_state: topk.TopKState | None = None,
    use_kernel: bool = False,
) -> topk.TopKState:
    """The map task: fold one shard into a stacked ``[n_models, n_q, k]`` state.

    A thin, named seam over `scan.search_local_multi` — jobs, the mesh path,
    and serve sessions all dispatch the same fold, so "works under sharding"
    is one property proven once. Dense single-model kernel scans route
    through `scan.search_local` (the fused dense kernel has no grid axis) and
    are re-stacked to the grid shape.
    """
    scorers = tuple(scorers)
    if use_kernel and len(scorers) == 1 and scorers[0].kind == "dense":
        flat = scan.search_local(
            queries, shard_docs, scorers[0], k=k, chunk_size=chunk_size,
            stats=stats, doc_id_offset=doc_id_offset, use_kernel=True,
        )
        state = topk.TopKState(scores=flat.scores[None], ids=flat.ids[None])
        return state if init_state is None else topk.merge(init_state, state)
    return scan.search_local_multi(
        queries,
        shard_docs,
        scorers,
        k=k,
        chunk_size=chunk_size,
        stats=stats,
        doc_id_offset=doc_id_offset,
        init_state=init_state,
        use_kernel=use_kernel,
    )


def reduce_states(states: Sequence[topk.TopKState]) -> topk.TopKState:
    """The reduce task: lexicographic k-bounded merge of per-shard states.

    Order- and grouping-free (`topk.reduce_lex`), so the host loop, the mesh
    all-gather, and a future multi-process tree all produce the same bits.
    """
    return topk.reduce_lex(states)


def scan_shards(
    plan: ShardPlan,
    queries: Any,
    docs: Any,
    scorers: Sequence[Scorer],
    *,
    k: int,
    stats: CollectionStats | None = None,
    use_kernel: bool = False,
    devices: Sequence[jax.Device] | None = None,
) -> topk.TopKState:
    """Uncheckpointed host-driven sharded scan: map every shard, reduce once.

    ``devices`` places shard ``i`` on ``devices[i % len(devices)]``
    (round-robin over the mesh's devices when the plan came from a mesh) —
    the degenerate None runs every shard on the default device, which is the
    substrate the shard-count-invariance tests pin down. Checkpointed /
    resumable execution lives in `cluster.job.run_sharded_scan_job`.
    """
    n_rows = jax.tree.leaves(docs)[0].shape[0]
    if n_rows != plan.n_docs:
        raise ValueError(f"docs have {n_rows} rows but plan covers {plan.n_docs}")
    states = []
    for shard in plan.shards:
        shard_docs = shard.take(docs)
        q = queries
        if devices:
            dev = devices[shard.index % len(devices)]
            shard_docs = jax.device_put(shard_docs, dev)
            q = jax.device_put(queries, dev)
        states.append(
            map_shard(
                q, shard_docs, scorers,
                k=k, chunk_size=plan.chunk_size, stats=stats,
                doc_id_offset=shard.doc_id_offset, use_kernel=use_kernel,
            )
        )
    if devices:
        states = [jax.device_put(s, devices[0]) for s in states]
    return reduce_states(states)


def search_mesh(
    mesh: Mesh,
    queries: Any,
    docs: Any,
    scorers: Sequence[Scorer] | Scorer,
    *,
    k: int,
    chunk_size: int,
    stats: CollectionStats | None = None,
    axis_names: tuple[str, ...] | None = None,
    use_kernel: bool = False,
):
    """Full MIREX job as one XLA program: ``shard_map`` over the mesh.

    Corpus sharded over ``axis_names`` (default: every mesh axis — the
    logical "scan" axis), queries and stats replicated; each shard runs
    :func:`map_shard` (multi-model, kernel-dispatched), then the
    hierarchical lexicographic reduce replicates the merged state.

    Returns a jitted ``(queries, docs, stats) -> TopKState`` with stacked
    ``[n_models, n_q, k]`` shapes (``n_models == 1`` for a single scorer —
    callers index ``[0]`` or keep the grid axis).
    """
    scorers = (scorers,) if isinstance(scorers, Scorer) else tuple(scorers)
    if axis_names is None:
        axis_names = mesh_scan_axes(mesh)
    doc_spec = P(axis_names)  # shard the leading (document) dim
    docs_specs = jax.tree.map(lambda _: doc_spec, docs)
    q_specs = jax.tree.map(lambda _: P(), queries)
    stats_specs = None if stats is None else jax.tree.map(lambda _: P(), stats)

    n_shards = 1
    for a in axis_names:
        n_shards *= mesh.shape[a]
    n_docs_total = jax.tree.leaves(docs)[0].shape[0]
    if n_docs_total % n_shards:
        raise ValueError(f"{n_docs_total} docs not divisible by {n_shards} shards")
    per_shard = n_docs_total // n_shards

    def local_job(queries, docs, stats):
        # global shard index = flattened index over the sharding axes
        idx = 0
        for a in axis_names:
            idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
        state = map_shard(
            queries,
            docs,
            scorers,
            k=k,
            chunk_size=chunk_size,
            stats=stats,
            doc_id_offset=idx * per_shard,
            use_kernel=use_kernel,
        )
        return topk.merge_across_lex(state, axis_names)

    sharded = shard_map(
        local_job,
        mesh=mesh,
        in_specs=(q_specs, docs_specs, stats_specs),
        out_specs=topk.TopKState(P(), P()),
        check_rep=False,
    )
    return jax.jit(functools.partial(sharded))
