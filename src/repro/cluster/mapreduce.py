"""The cluster's map and reduce: per-shard scan folds + the one merge.

Paper §2, literally: **map** = sequentially scan one shard of the collection
against the full query (and model-grid) block; **reduce** = merge per-shard
top-k lists, at most ``k`` entries per query per shard ever crossing a
shard boundary. Both halves are the *same code* on every execution substrate:

* :func:`map_shard` is the single fold every shard runs — multi-model
  single-pass (`scan.search_local_multi`), fused Pallas lexical kernel under
  ``use_kernel``, sentinel-preserving global doc ids via the shard's offset.
  :func:`segment_fold` is that fold compiled *once per configuration* and
  shared by every shard, segment, job, and session with the same grid — the
  retrace fix that lets a sharded job scale instead of re-compiling per
  shard (`FOLD_TRACE_COUNTS` makes the compile count testable).
* :func:`reduce_states` is the k-bounded lexicographic bitonic merge
  (`topk.reduce_lex`): value-deterministic, so 1/2/4/N shards merge to the
  same bits, which is the shard-count-invariance contract jobs and serve
  both inherit.
* :func:`search_mesh` stamps the two onto a JAX mesh with ``shard_map`` —
  corpus sharded over the scan axes, queries/stats replicated, local map,
  hierarchical lexicographic reduce — for one-shot and serve-path scans.
  Checkpointed jobs use the host-loop driver in `cluster.job` instead (a
  shard that lives inside one XLA program can't kill/resume independently).
"""

from __future__ import annotations

import collections
import functools
import threading
from typing import Any, Callable, Sequence

import jax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat
from repro.core import scan, topk
from repro.core.scoring import CollectionStats, Scorer
from repro.tune import config as tune_config
from repro.tune.config import TuningConfig

from repro.cluster.plan import ShardPlan, mesh_scan_axes


def map_shard(
    queries: Any,
    shard_docs: Any,
    scorers: Sequence[Scorer],
    *,
    k: int,
    chunk_size: int,
    stats: CollectionStats | None = None,
    doc_id_offset: jax.Array | int = 0,
    init_state: topk.TopKState | None = None,
    use_kernel: bool = False,
    tuning: TuningConfig | None = None,
) -> topk.TopKState:
    """The map task: fold one shard into a stacked ``[n_models, n_q, k]`` state.

    A thin, named seam over `scan.search_local_multi` — jobs, the mesh path,
    and serve sessions all dispatch the same fold, so "works under sharding"
    is one property proven once. Dense single-model kernel scans route
    through `scan.search_local` (the fused dense kernel has no grid axis) and
    are re-stacked to the grid shape. ``tuning`` picks kernel block geometry
    (byte-identical under any config; see `repro.tune`).
    """
    scorers = tuple(scorers)
    if use_kernel and len(scorers) == 1 and scorers[0].kind == "dense":
        flat = scan.search_local(
            queries, shard_docs, scorers[0], k=k, chunk_size=chunk_size,
            stats=stats, doc_id_offset=doc_id_offset, use_kernel=True,
            tuning=tuning,
        )
        state = topk.TopKState(scores=flat.scores[None], ids=flat.ids[None])
        return state if init_state is None else topk.merge(init_state, state)
    return scan.search_local_multi(
        queries,
        shard_docs,
        scorers,
        k=k,
        chunk_size=chunk_size,
        stats=stats,
        doc_id_offset=doc_id_offset,
        init_state=init_state,
        use_kernel=use_kernel,
        tuning=tuning,
    )


def _scorer_key(scorers: Sequence[Scorer]) -> tuple:
    """Hashable identity of a scorer grid — the model-config part of
    `cluster.job._job_fingerprint`, kept as a plain tuple so it can key the
    shared fold cache (name encodes base + bound params for grid variants;
    ``params`` guards explicitly-renamed variants that reuse a name). The
    *underlying* score function's identity rides along so a re-registered
    or hand-built scorer that reuses a name can never inherit another
    scorer's compiled program — while `make_variant` grid points, whose
    ``functools.partial`` wrappers are fresh objects but share the registry
    base function, still share one compile."""

    def fn_id(s: Scorer):
        return s.fn.func if isinstance(s.fn, functools.partial) else s.fn

    return tuple((s.kind, s.name, s.base, s.params, fn_id(s)) for s in scorers)


# One compiled fold per (scorer grid, k, chunk_size, use_kernel) — shapes and
# dtypes are jax.jit's own cache key, so every equal-shaped shard and segment
# of a job (and of every job sharing the config) reuses one compiled program
# instead of re-tracing per `run_scan_job` call. `FOLD_TRACE_COUNTS` records
# actual traces per config key; tests pin "a 4-shard job compiles once" on it.
# Both module caches are FIFO-bounded so a long-lived process churning
# through configs (e.g. sessions over a growing corpus) can't accumulate
# traced programs forever; eviction is safe because callers keep their own
# reference to the program they were handed.
_FOLD_CACHE: dict[tuple, "_SharedFold"] = {}
_FOLD_CACHE_MAX = 128
_FOLD_CACHE_LOCK = threading.Lock()
FOLD_TRACE_COUNTS: collections.Counter = collections.Counter()


def _fifo_insert(cache: dict, key, value, max_entries: int):
    value = cache.setdefault(key, value)  # first builder wins
    while len(cache) > max_entries:
        cache.pop(next(iter(cache)))  # dicts iterate in insertion order
    return value


class _SharedFold:
    """A jit-cached segment fold whose *first* call (the trace+compile) is
    serialized, so a concurrent-shard executor hitting a cold cache compiles
    the program once instead of racing N identical traces."""

    def __init__(self, fn: Callable, key: tuple):
        self.key = key
        self._fn = fn
        self._lock = threading.Lock()
        self._warm = False

    def __call__(self, state, queries, seg_docs, stats, offset):
        if not self._warm:
            with self._lock:
                out = self._fn(state, queries, seg_docs, stats, offset)
                self._warm = True
                return out
        return self._fn(state, queries, seg_docs, stats, offset)


def segment_fold(
    scorers: Sequence[Scorer], *, k: int, chunk_size: int, use_kernel: bool = False,
    tuning: TuningConfig | None = None,
) -> _SharedFold:
    """The one compiled per-segment fold all shards/segments/jobs share.

    Returns a callable ``fold(state, queries, seg_docs, stats, offset) ->
    TopKState`` — :func:`map_shard` under ``jax.jit`` with the *data* as
    traced arguments, so the program is keyed by configuration here and by
    shapes inside jit. Every equal-shaped shard of a sharded job (the
    `cluster.plan` equal-shards invariant) therefore folds through one
    compiled program; a resumed job re-traces nothing; two sessions or jobs
    with the same grid share the compile. All args must live on one device —
    callers pin ``state``/``queries``/``stats``/segments to the shard's
    device (``offset`` may stay an uncommitted scalar; it follows).

    ``tuning`` is resolved *here*, at fold-build time (drivers resolve on
    their own thread; worker threads get the captured config), and the
    kernel-shaping knobs join the cache key via
    :meth:`TuningConfig.fold_key` — two tunings that would trace different
    Pallas programs can never alias one cache entry. Host folds ignore the
    block knobs, so their key component is empty and all tunings share the
    one host program.
    """
    scorers = tuple(scorers)
    cfg = tune_config.resolve(tuning)
    key = (
        _scorer_key(scorers), k, chunk_size, bool(use_kernel),
        cfg.fold_key(bool(use_kernel)),
    )
    with _FOLD_CACHE_LOCK:
        fold = _FOLD_CACHE.get(key)
        if fold is None:

            def _fold(state, queries, seg_docs, stats, offset):
                FOLD_TRACE_COUNTS[key] += 1  # trace-time side effect, on purpose
                return map_shard(
                    queries,
                    seg_docs,
                    scorers,
                    k=k,
                    chunk_size=chunk_size,
                    stats=stats,
                    doc_id_offset=offset,
                    init_state=state,
                    use_kernel=use_kernel,
                    tuning=cfg,
                )

            fold = _fifo_insert(
                _FOLD_CACHE, key, _SharedFold(jax.jit(_fold), key), _FOLD_CACHE_MAX
            )
    return fold


@jax.jit
def _reduce_states_jit(states: list[topk.TopKState]) -> topk.TopKState:
    return topk.reduce_lex(states)


def reduce_states(states: Sequence[topk.TopKState]) -> topk.TopKState:
    """The reduce task: lexicographic k-bounded merge of per-shard states.

    Order- and grouping-free (`topk.reduce_lex`), so the host loop, the mesh
    all-gather, and a future multi-process tree all produce the same bits.
    Jitted (cached per shard count + shapes): the bitonic merge network is
    dozens of tiny ops per pair, which dispatched eagerly would cost more
    than a whole shard's fold on a fast host.
    """
    states = list(states)
    if len(states) == 1:
        return states[0]
    return _reduce_states_jit(states)


def scan_shards(
    plan: ShardPlan,
    queries: Any,
    docs: Any,
    scorers: Sequence[Scorer],
    *,
    k: int,
    stats: CollectionStats | None = None,
    use_kernel: bool = False,
    devices: Sequence[jax.Device] | None = None,
    tuning: TuningConfig | None = None,
) -> topk.TopKState:
    """Uncheckpointed host-driven sharded scan: map every shard, reduce once.

    ``devices`` places shard ``i`` on ``devices[i % len(devices)]``
    (round-robin over the mesh's devices when the plan came from a mesh) —
    the degenerate None runs every shard on the default device, which is the
    substrate the shard-count-invariance tests pin down. Every shard folds
    through the shared :func:`segment_fold` program (equal shard shapes ⇒
    one compile for the whole plan, and for every later plan with the same
    grid/geometry). Checkpointed / resumable execution — and the concurrent
    pipelined executor — live in `cluster.job.run_sharded_scan_job`.
    """
    n_rows = jax.tree.leaves(docs)[0].shape[0]
    if n_rows != plan.n_docs:
        raise ValueError(f"docs have {n_rows} rows but plan covers {plan.n_docs}")
    scorers = tuple(scorers)
    n_q = jax.tree.leaves(queries)[0].shape[0]
    fold = segment_fold(
        scorers, k=k, chunk_size=plan.chunk_size, use_kernel=use_kernel,
        tuning=tuning,
    )
    state_init = topk.init_host(k, (len(scorers), n_q))
    states = []
    for shard in plan.shards:
        shard_docs = shard.take(docs)
        # host-built init state + one batched transfer per shard
        state0 = state_init
        q, st = queries, stats
        if devices:
            dev = devices[shard.index % len(devices)]
            q, st, state0, shard_docs = jax.device_put(
                (queries, stats, state0, shard_docs), dev
            )
        states.append(fold(state0, q, shard_docs, st, shard.doc_id_offset))
    if devices:
        states = jax.device_put(states, devices[0])
    return reduce_states(states)


# Mesh programs are memoized the same way the segment fold is: the program
# depends only on (mesh, axes, grid config, corpus size, tree structures) —
# data arrives as call arguments — so two ShardedLexicalSessions over the
# same resident corpus, or a rebuilt session after a service restart, share
# one traced shard_map program instead of compiling their own. FIFO-bounded
# like the fold cache (sessions hold their own reference, so eviction only
# forgets, never breaks).
_MESH_CACHE: dict[tuple, Callable] = {}
_MESH_CACHE_MAX = 64
_MESH_CACHE_LOCK = threading.Lock()


def search_mesh(
    mesh: Mesh,
    queries: Any,
    docs: Any,
    scorers: Sequence[Scorer] | Scorer,
    *,
    k: int,
    chunk_size: int,
    stats: CollectionStats | None = None,
    axis_names: tuple[str, ...] | None = None,
    use_kernel: bool = False,
    tuning: TuningConfig | None = None,
):
    """Full MIREX job as one XLA program: ``shard_map`` over the mesh.

    Corpus sharded over ``axis_names`` (default: every mesh axis — the
    logical "scan" axis), queries and stats replicated; each shard runs
    :func:`map_shard` (multi-model, kernel-dispatched), then the
    hierarchical lexicographic reduce replicates the merged state.

    Returns a jitted ``(queries, docs, stats) -> TopKState`` with stacked
    ``[n_models, n_q, k]`` shapes (``n_models == 1`` for a single scorer —
    callers index ``[0]`` or keep the grid axis). The returned program is
    memoized on (mesh, axes, grid config, corpus size, pytree structures):
    ``queries``/``docs``/``stats`` here are *prototypes* — only their tree
    structure (and the corpus row count, which fixes shard id offsets) is
    baked in, so equal-config callers get the same compiled program.
    """
    scorers = (scorers,) if isinstance(scorers, Scorer) else tuple(scorers)
    if axis_names is None:
        axis_names = mesh_scan_axes(mesh)
    cfg = tune_config.resolve(tuning)
    n_docs_total = jax.tree.leaves(docs)[0].shape[0]
    cache_key = (
        mesh,
        tuple(axis_names),
        _scorer_key(scorers),
        k,
        chunk_size,
        bool(use_kernel),
        cfg.fold_key(bool(use_kernel)),
        n_docs_total,
        jax.tree.structure(queries),
        jax.tree.structure(docs),
        None if stats is None else jax.tree.structure(stats),
    )
    with _MESH_CACHE_LOCK:
        cached = _MESH_CACHE.get(cache_key)
    if cached is not None:
        return cached
    doc_spec = P(axis_names)  # shard the leading (document) dim
    docs_specs = jax.tree.map(lambda _: doc_spec, docs)
    q_specs = jax.tree.map(lambda _: P(), queries)
    stats_specs = None if stats is None else jax.tree.map(lambda _: P(), stats)

    n_shards = 1
    for a in axis_names:
        n_shards *= mesh.shape[a]
    if n_docs_total % n_shards:
        raise ValueError(f"{n_docs_total} docs not divisible by {n_shards} shards")
    per_shard = n_docs_total // n_shards

    def local_job(queries, docs, stats):
        # global shard index = flattened index over the sharding axes
        idx = 0
        for a in axis_names:
            idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
        state = map_shard(
            queries,
            docs,
            scorers,
            k=k,
            chunk_size=chunk_size,
            stats=stats,
            doc_id_offset=idx * per_shard,
            use_kernel=use_kernel,
            tuning=cfg,
        )
        return topk.merge_across_lex(state, axis_names)

    sharded = shard_map(
        local_job,
        mesh=mesh,
        in_specs=(q_specs, docs_specs, stats_specs),
        out_specs=topk.TopKState(P(), P()),
        check_rep=False,
    )
    fn = jax.jit(sharded)
    with _MESH_CACHE_LOCK:
        # first builder wins (a concurrent builder's fn is equivalent)
        fn = _fifo_insert(_MESH_CACHE, cache_key, fn, _MESH_CACHE_MAX)
    return fn
