"""Experiment declaration: scorer grids + the named-experiment registry.

A *grid* is the cartesian product of parameter values over one base scorer
(``bm25 × {k1} × {b}``); an *experiment* is a set of grids plus the collection
shape and scan-job knobs. Expansion produces plain ``scoring.Scorer`` objects,
so the whole grid rides the multi-scorer single-pass scan
(`scan.search_local_multi`) — the paper's economics (claim C1/C2: one corpus
stream amortized over a batch) applied to the *model* axis instead of the
query axis.
"""

from __future__ import annotations

import dataclasses
import itertools

from repro.core import scoring


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Parameter grid over one base scorer; empty ``params`` = the base."""

    base: str
    params: tuple[tuple[str, tuple], ...] = ()  # (param name, values)

    def expand(self) -> list[scoring.Scorer]:
        if not self.params:
            return [scoring.make_variant(self.base)]
        names = [n for n, _ in self.params]
        values = [v for _, v in self.params]
        return [
            scoring.make_variant(self.base, **dict(zip(names, combo)))
            for combo in itertools.product(*values)
        ]


def parse_grid(spec: str) -> GridSpec:
    """Parse ``"bm25:k1=0.9|1.2,b=0.4|0.75"`` CLI syntax into a GridSpec."""
    base, _, params_s = spec.partition(":")
    if not base:
        raise ValueError(f"empty scorer in grid spec {spec!r}")
    scoring.get_scorer(base)  # fail fast on unknown scorers
    params = []
    if params_s:
        for item in params_s.split(","):
            name, _, vals = item.partition("=")
            if not vals:
                raise ValueError(f"malformed grid param {item!r} in {spec!r}")
            parsed = []
            for v in vals.split("|"):
                if v in ("true", "false"):
                    parsed.append(v == "true")
                else:
                    parsed.append(int(v) if v.lstrip("+-").isdigit() else float(v))
            params.append((name, tuple(parsed)))
    return GridSpec(base=base, params=tuple(params))


def expand_grids(grids: tuple[GridSpec, ...]) -> list[scoring.Scorer]:
    """Flatten grids to a model stack, rejecting duplicates and mixed kinds."""
    scorers: list[scoring.Scorer] = []
    seen = set()
    for g in grids:
        for s in g.expand():
            if s.name in seen:
                raise ValueError(f"duplicate scorer variant {s.name!r} in grid")
            seen.add(s.name)
            scorers.append(s)
    kinds = {s.kind for s in scorers}
    if len(kinds) > 1:
        raise ValueError(
            f"an experiment scans one corpus representation; got kinds {sorted(kinds)}"
        )
    return scorers


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """A named, fully-declared experiment: grids + collection + job knobs."""

    name: str
    grids: tuple[GridSpec, ...]
    n_docs: int = 8192
    n_queries: int = 64
    vocab: int = 8192
    max_doc_len: int = 64
    k: int = 20
    chunk_size: int = 512
    segment_chunks: int = 4  # chunks per checkpoint segment
    n_shards: int = 1  # corpus scan shards (repro.cluster sharded job)
    use_kernel: bool = False  # fused Pallas lexical kernel for the scan job
    eval_ks: tuple[int, ...] = (5, 10, 20)
    baseline: str | None = None  # variant name significance is tested against

    def scorers(self) -> list[scoring.Scorer]:
        return expand_grids(self.grids)


EXPERIMENTS: dict[str, ExperimentSpec] = {}


def register_experiment(spec: ExperimentSpec) -> ExperimentSpec:
    if spec.name in EXPERIMENTS:
        raise ValueError(f"experiment {spec.name!r} already registered")
    EXPERIMENTS[spec.name] = spec
    return spec


def get_experiment(name: str) -> ExperimentSpec:
    try:
        return EXPERIMENTS[name]
    except KeyError:
        raise KeyError(
            f"unknown experiment {name!r}; available: {sorted(EXPERIMENTS)}"
        ) from None


# -- built-in experiments ---------------------------------------------------

register_experiment(
    ExperimentSpec(
        name="smoke",
        # 2 models, tiny corpus: the CI smoke grid (seconds on a CPU host)
        grids=(GridSpec("ql_lm"), GridSpec("bm25")),
        n_docs=512,
        n_queries=16,
        vocab=2048,
        k=10,
        chunk_size=128,
        segment_chunks=2,
        eval_ks=(5, 10),
        baseline="ql_lm",
    )
)

register_experiment(
    ExperimentSpec(
        name="bm25-grid",
        # the classic Okapi parameter sweep: 2×2 grid + the paper's QL LM
        grids=(
            GridSpec("bm25", (("k1", (0.9, 1.2)), ("b", (0.4, 0.75)))),
            GridSpec("ql_lm"),
        ),
        baseline="ql_lm",
    )
)

register_experiment(
    ExperimentSpec(
        name="lm-grid",
        # the paper's own model family: smoothing × length-prior ablation
        grids=(
            GridSpec(
                "ql_lm",
                (("lam", (0.05, 0.15, 0.5)), ("length_prior", (True, False))),
            ),
        ),
        baseline="ql_lm(lam=0.15,length_prior=True)",
    )
)
