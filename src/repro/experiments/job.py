"""Resumable multi-scorer scan jobs — Hadoop-style fault tolerance for scans.

The MapReduce lineage of the paper (and of Goodrich et al.'s simulation
framework) gets its fault tolerance from one property: map outputs fold into
an **associative combiner**, so any split can be re-executed and re-reduced
without changing the result. `core/pipeline.py` already guarantees that for
the top-k state; this module turns it into an operational contract:

  * the corpus is folded one chunk-aligned *segment* at a time
    (`pipeline.segments`), through a single jitted multi-scorer fold;
  * after every segment the stacked ``TopKState`` is committed with the
    atomic-rename checkpointer (`repro.checkpoint`) and a ``progress.json``
    per-shard manifest is rewritten;
  * a killed job restarts from its last committed segment and produces a
    **bit-identical** final state (and therefore a byte-identical TREC run
    file) — checkpoints store exact f32/int32 bytes and every segment
    boundary is a chunk boundary, so the resumed fold replays the exact
    per-chunk instruction stream of an uninterrupted run (test-enforced).

Failure injection mirrors `launch/train.py`: ``fail_at_segment=s`` raises
after segment ``s``'s checkpoint commits, which is exactly the worst-case
kill point (work done, acknowledgment lost).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.core import pipeline, scan, topk
from repro.core.scoring import CollectionStats, Scorer


@dataclasses.dataclass(frozen=True)
class ScanJobResult:
    state: topk.TopKState  # stacked [n_models, n_q, k]
    segments_run: int  # segments executed by *this* invocation
    segments_total: int
    resumed_from: int  # segment index the run started at (0 = fresh)


def _job_fingerprint(
    queries, docs, scorers, k: int, chunk_size: int, segment_chunks: int,
    doc_id_offset: int, stats,
) -> str:
    """Cheap identity of (data, grid, chunking, segmentation) — guards resume.

    A checkpointed TopKState from a *different* job can have exactly the same
    array shapes (same model count / query count / k), so shape checks alone
    would silently resume the wrong experiment. Hash the configuration, the
    full query set (small) and a strided row sample of the corpus instead.
    ``segment_chunks`` matters because the checkpoint step counts *segments*:
    reinterpreting it under a different segmentation would skip or double-fold
    corpus rows without any shape mismatch.
    """
    h = hashlib.sha256()
    h.update(
        repr(
            (k, chunk_size, segment_chunks, doc_id_offset, [s.name for s in scorers])
        ).encode()
    )
    for leaf in jax.tree.leaves(queries):
        h.update(np.asarray(leaf).tobytes())
    for leaf in jax.tree.leaves(docs):
        h.update(repr(tuple(leaf.shape)).encode())
        stride = max(1, leaf.shape[0] // 64)
        h.update(np.asarray(leaf[::stride][:64]).tobytes())
    # stats shape the scores: resuming under different collection statistics
    # would merge incompatible partial scores without any shape mismatch
    if stats is not None:
        for leaf in jax.tree.leaves(stats):
            h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()[:16]


def _write_progress(ckpt_dir: str, payload: dict) -> None:
    tmp = os.path.join(ckpt_dir, ".tmp-progress.json")
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, os.path.join(ckpt_dir, "progress.json"))


def read_progress(ckpt_dir: str) -> dict | None:
    path = os.path.join(ckpt_dir, "progress.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run_scan_job(
    queries: Any,
    docs: Any,
    scorers: Sequence[Scorer],
    *,
    k: int,
    chunk_size: int,
    segment_chunks: int,
    stats: CollectionStats | None = None,
    ckpt_dir: str | None = None,
    resume: bool = True,
    keep_checkpoints: int = 2,
    fail_at_segment: int | None = None,
    shard: int = 0,
    n_shards: int = 1,
    doc_id_offset: int = 0,
    use_kernel: bool = False,
) -> ScanJobResult:
    """Run (or resume) a checkpointed multi-scorer scan over a corpus shard.

    ``ckpt_dir=None`` degrades to a plain uncheckpointed single pass. The
    checkpoint step number is "segments completed", so ``latest_step`` *is*
    the resume point; ``keep_checkpoints`` bounds disk via ``ckpt.prune``.
    """
    scorers = tuple(scorers)
    n_rows = jax.tree.leaves(docs)[0].shape[0]
    n_q = jax.tree.leaves(queries)[0].shape[0]
    segs = pipeline.segments(n_rows, chunk_size, segment_chunks)

    fingerprint = _job_fingerprint(
        queries, docs, scorers, k, chunk_size, segment_chunks, doc_id_offset, stats
    )
    state = topk.init(k, (len(scorers), n_q))
    start_seg = 0
    if ckpt_dir and resume:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            prev = read_progress(ckpt_dir)
            if prev is not None and prev.get("fingerprint") != fingerprint:
                raise ValueError(
                    f"checkpoint dir {ckpt_dir!r} belongs to a different job "
                    f"(scorers {prev.get('scorers')}, fingerprint "
                    f"{prev.get('fingerprint')} != {fingerprint}); use a fresh "
                    "dir or resume=False"
                )
            if latest > len(segs):
                raise ValueError(
                    f"checkpoint at segment {latest} but job has {len(segs)} segments"
                )
            state = ckpt.restore(ckpt_dir, latest, state)
            start_seg = latest
    elif ckpt_dir:
        # fresh start over a dirty dir: drop stale commits so they can never
        # masquerade as this run's progress (or out-survive it via prune)
        for s in ckpt.all_steps(ckpt_dir):
            shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
        stale = os.path.join(ckpt_dir, "progress.json")
        if os.path.exists(stale):
            os.remove(stale)

    @jax.jit
    def fold_segment(state, seg_docs, offset):
        return scan.search_local_multi(
            queries,
            seg_docs,
            scorers,
            k=k,
            chunk_size=chunk_size,
            stats=stats,
            doc_id_offset=offset,
            init_state=state,
            use_kernel=use_kernel,
        )

    def progress(done: int) -> dict:
        return {
            "fingerprint": fingerprint,
            "n_segments": len(segs),
            "chunk_size": chunk_size,
            "segment_chunks": segment_chunks,
            "k": k,
            "scorers": [s.name for s in scorers],
            "shards": {
                str(shard): {
                    "n_shards": n_shards,
                    "segments_done": done,
                    "rows_done": segs[done - 1][1] if done else 0,
                    "n_rows": n_rows,
                    "complete": done == len(segs),
                }
            },
        }

    ran = 0
    for seg_idx in range(start_seg, len(segs)):
        a, b = segs[seg_idx]
        seg_docs = jax.tree.map(lambda x: x[a:b], docs)
        state = fold_segment(state, seg_docs, jnp.int32(doc_id_offset + a))
        ran += 1
        if ckpt_dir:
            state = jax.block_until_ready(state)
            ckpt.save(ckpt_dir, seg_idx + 1, state)
            _write_progress(ckpt_dir, progress(seg_idx + 1))
            ckpt.prune(ckpt_dir, keep_checkpoints)
        if fail_at_segment is not None and seg_idx >= fail_at_segment:
            # die *after* the commit: the canonical lost-ack kill point
            raise RuntimeError(f"injected failure after segment {seg_idx}")
    if ckpt_dir and start_seg == len(segs):
        _write_progress(ckpt_dir, progress(len(segs)))  # idempotent re-run
    return ScanJobResult(
        state=state,
        segments_run=ran,
        segments_total=len(segs),
        resumed_from=start_seg,
    )
