"""Resumable scan jobs — now the one-shard special case of `repro.cluster`.

The checkpointed multi-scorer scan engine that lived here moved to
`repro.cluster.job` when jobs grew mesh-sharded execution (PR 4): a
single-host scan job is exactly a sharded job with a trivial one-shard plan,
so `run_scan_job` *is* the cluster engine's shard runner, re-exported with
its original signature. Sharded jobs (per-shard checkpoints + kill/resume,
byte-identical merged run files at any shard count) are
`repro.cluster.run_sharded_scan_job`.

This module stays as the experiments-facing import path; everything here is
a re-export.
"""

from __future__ import annotations

from repro.cluster.job import (  # noqa: F401
    ScanJobResult,
    ShardedScanResult,
    read_progress,
    run_scan_job,
    run_sharded_scan_job,
)

__all__ = [
    "ScanJobResult",
    "ShardedScanResult",
    "read_progress",
    "run_scan_job",
    "run_sharded_scan_job",
]
