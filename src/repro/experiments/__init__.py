"""Batch experiment engine: grids, resumable scan jobs, lifecycle runner.

MIREX's purpose is to *quickly test new retrieval approaches*; this package
is the machinery that makes a whole grid of approaches one cheap batch:

  * `grid`   — scorer-variant grids + the named-experiment registry;
  * `job`    — chunk-checkpointed, kill/resume-bit-identical scan jobs
               folding every grid point in a single corpus pass
               (`core.scan.search_local_multi`);
  * `runner` — prepare → scan → TREC run files → `repro.eval` report;
  * `bench`  — the models-per-pass amortization curve
               (``BENCH_experiments.json``).

`launch/experiment.py` is the CLI over all of it.
"""

from repro.experiments import bench, grid, job, runner
from repro.experiments.grid import (
    EXPERIMENTS,
    ExperimentSpec,
    GridSpec,
    expand_grids,
    get_experiment,
    parse_grid,
    register_experiment,
)
from repro.experiments.job import ScanJobResult, read_progress, run_scan_job
from repro.experiments.runner import prepare_collection, run_experiment

__all__ = [
    "bench",
    "grid",
    "job",
    "runner",
    "EXPERIMENTS",
    "ExperimentSpec",
    "GridSpec",
    "expand_grids",
    "get_experiment",
    "parse_grid",
    "register_experiment",
    "ScanJobResult",
    "read_progress",
    "run_scan_job",
    "prepare_collection",
    "run_experiment",
]
