"""Models-per-pass amortization: the experiment engine's C1-shaped claim.

The paper amortizes one corpus pass over a *query* batch; the experiment
engine amortizes it over a *model grid*. This module measures that curve:
wall-clock of one multi-scorer pass at grid sizes 1, 2, 4, … versus the cost
of running the same models as independent single-scorer passes. Per-model
cost should fall with grid size because the corpus chunk stream (and, for
lexical grids, the shared term-frequency reduction) is paid once per pass.
Persisted as ``BENCH_experiments.json`` so successive PRs can diff it.
"""

from __future__ import annotations

import time
from typing import Any, Sequence

import jax
import numpy as np

from repro.core import scan
from repro.core.scoring import CollectionStats, Scorer
from repro.serve.bench import write_bench_json


def amortization_curve(
    queries: Any,
    docs: Any,
    scorers: Sequence[Scorer],
    *,
    k: int,
    chunk_size: int,
    stats: CollectionStats | None = None,
    sizes: Sequence[int] = (1, 2, 4, 8),
    repeats: int = 3,
    warmup: int = 1,
) -> dict:
    """Time one multi-scorer pass at each grid size; median of ``repeats``.

    ``scorers`` must hold at least ``max(sizes)`` variants; size ``m`` scans
    the first ``m``. ``speedup_vs_independent`` at size ``m`` is
    ``m * t(1) / t(m)`` — how much wall-clock the single-pass grid saves
    over ``m`` independent scans of the same corpus.
    """
    scorers = tuple(scorers)
    sizes = tuple(sorted(set(sizes)))  # ascending: t(1) must exist before speedups
    if max(sizes) > len(scorers):
        raise ValueError(f"need {max(sizes)} scorer variants, got {len(scorers)}")

    def time_grid(m: int) -> float:
        stack = scorers[:m]

        @jax.jit
        def pass_(q, d):
            return scan.search_local_multi(
                q, d, stack, k=k, chunk_size=chunk_size, stats=stats
            )

        times = []
        for rep in range(warmup + repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(pass_(queries, docs))
            if rep >= warmup:
                times.append(time.perf_counter() - t0)
        return float(np.median(times))

    curve = []
    t1 = None
    for m in sizes:
        t = time_grid(m)
        if m == 1:
            t1 = t
        point = {
            "models": m,
            "wall_s": t,
            "s_per_model": t / m,
        }
        if t1 is not None:
            point["speedup_vs_independent"] = m * t1 / t
        curve.append(point)

    n_docs = jax.tree.leaves(docs)[0].shape[0]
    n_q = jax.tree.leaves(queries)[0].shape[0]
    payload = {
        "benchmark": "experiments_amortization",
        "kind": scorers[0].kind,
        "models": [s.name for s in scorers[: max(sizes)]],
        "n_docs": int(n_docs),
        "n_queries": int(n_q),
        "k": k,
        "chunk_size": chunk_size,
        "sizes": list(sizes),
        "curve": curve,
    }
    if len(curve) >= 2 and "s_per_model" in curve[0]:
        payload["amortization_x"] = curve[0]["s_per_model"] / curve[-1]["s_per_model"]
    return payload


__all__ = ["amortization_curve", "write_bench_json"]
