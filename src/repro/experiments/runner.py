"""Experiment orchestration: prepare → scan job → run files → eval report.

One call runs the whole MIREX experiment lifecycle for a declared grid:

  1. **prepare** — deterministic synthetic collection + collection-statistics
     job (the paper's preprocessing MapReduce) + queries + graded qrels;
  2. **scan** — one resumable multi-scorer corpus pass
     (`job.run_scan_job`): every grid point shares the corpus stream;
  3. **report** — per-model TREC run files, the `repro.eval` report card
     (MAP / P@k / NDCG / MRR / recall), and paired-randomization
     significance of every variant against the declared baseline.

Everything is keyed by ``seed``, so a re-run (or a kill/resume, see
`job.py`) regenerates byte-identical artifacts.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, tune
from repro.cluster import FaultSchedule, plan_shards, run_sharded_scan_job
from repro.core import anchors, packing, topk
from repro.data import synthetic
from repro.eval import evaluate_run, paired_randomization_test, trec
from repro.experiments.grid import ExperimentSpec
from repro.tune import TuningConfig


@dataclasses.dataclass(frozen=True)
class Collection:
    corpus: synthetic.Corpus
    stats: Any  # CollectionStats of jnp arrays
    queries: np.ndarray
    qrels: np.ndarray  # graded [n_q, n_docs] int8


def prepare_collection(spec: ExperimentSpec, *, seed: int = 0) -> Collection:
    """The prepare stage: corpus, stats job, queries, graded qrels."""
    corpus = synthetic.make_corpus(
        n_docs=spec.n_docs, vocab=spec.vocab, max_len=spec.max_doc_len, seed=seed
    )
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens),
        jnp.asarray(corpus.lengths),
        vocab=spec.vocab,
        chunk_size=min(spec.chunk_size, spec.n_docs),
    )
    queries = synthetic.make_queries(corpus, n_queries=spec.n_queries, seed=seed + 1)
    qrels = synthetic.make_graded_qrels(corpus, queries, per_query=25, seed=seed + 2)
    return Collection(corpus=corpus, stats=stats, queries=queries, qrels=qrels)


def run_filename(variant: str) -> str:
    """Filesystem-safe run-file name for a scorer variant."""
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", variant).strip("_") + ".run"


def write_run_files(
    out_dir: str, scorers, state: topk.TopKState, *, tag_prefix: str
) -> dict[str, str]:
    """One TREC run file per model from the stacked job state."""
    os.makedirs(out_dir, exist_ok=True)
    valid = np.asarray(topk.valid_mask(state))
    ids = np.asarray(state.ids)
    scores = np.asarray(state.scores)
    paths = {}
    for m, s in enumerate(scorers):
        path = os.path.join(out_dir, run_filename(s.name))
        trec.write_run(
            path, ids[m], scores[m], run_tag=f"{tag_prefix}/{s.name}", valid=valid[m]
        )
        paths[s.name] = path
    return paths


def run_experiment(
    spec: ExperimentSpec,
    *,
    out_dir: str,
    seed: int = 0,
    resume: bool = True,
    fail_at_segment: int | None = None,
    fail_at_shard: int = 0,
    collection: Collection | None = None,
    pipelined: bool = True,
    max_workers: int | None = None,
    faults: Any | None = None,
    max_retries: int = 0,
    speculative: bool = False,
    trace_out: str | None = None,
    tuning: TuningConfig | None = None,
    tune_lookup: bool = False,
    tune_cache: str | None = None,
) -> dict:
    """Execute the full lifecycle; returns (and writes) the report dict.

    Artifacts under ``out_dir``: ``runs/<variant>.run``, ``qrels.txt``,
    ``ckpt/`` (segment checkpoints + progress manifests; per-shard subdirs
    when ``spec.n_shards > 1``), ``report.json``. Run files are byte-
    identical at every shard count (the `repro.cluster` merge contract), so
    shard count is an execution knob, not part of the experiment identity —
    as are ``pipelined`` (the overlapped executor: concurrent shards,
    segment prefetch, async checkpoints; byte-identical artifacts either
    way) and ``max_workers`` (caps the shard thread pool; default one
    worker per visible device).

    ``faults`` (a ``repro.cluster.FaultSchedule``), ``max_retries``, and
    ``speculative`` drive the reliability layer: injected failures are
    retried from their shard's last committed segment checkpoint and the
    slowest in-flight shard is speculatively duplicated when the queue
    drains — run files stay byte-identical regardless, and the report's
    ``job`` section records what the scheduler did (retries, steals,
    speculation, fired faults).

    ``tuning`` runs the scan under an explicit :class:`repro.tune.
    TuningConfig`; ``tune_lookup=True`` instead looks the spec's shape
    signature up in the persistent autotune winner cache (``tune_cache``
    path, default resolution in `repro.tune.cache`) and runs under the
    recorded winner — falling back to the defaults on a miss. Either way
    the report's ``job.tuning`` block records the config hash, source, and
    whether the cache hit; run files are byte-identical under every config
    (the `repro.tune` contract).

    ``trace_out`` enables the observability layer for this run: a fresh
    tracer + metrics registry are installed for the lifecycle, the Chrome
    ``trace_event`` JSON lands at that path (with the JSONL event log next
    to it), and the report's ``job.obs`` block carries the trace paths, the
    metrics rollup, and the per-shard time-per-phase summary. Tracing only
    observes — run files are byte-identical with it on or off
    (chaos-suite-enforced).
    """
    if fail_at_segment is not None:
        # convert here rather than forwarding, so the DeprecationWarning
        # points at *this function's caller*, not at the forwarding call
        # inside this module (test-pinned via warning filename)
        warnings.warn(
            "fail_at_segment/fail_at_shard are deprecated; use "
            "faults=FaultSchedule([FaultSpec(kind='crash', ...)])",
            DeprecationWarning,
            stacklevel=2,
        )
        legacy = FaultSchedule.from_legacy(fail_at_segment, fail_at_shard)
        if faults is None:
            faults = legacy
        else:
            faults.add(legacy.specs[0])
        fail_at_segment = None

    if tuning is not None and tune_lookup:
        raise ValueError("pass either tuning= or tune_lookup=True, not both")
    tuning_source = "explicit" if tuning is not None else "default"
    cache_hit = False
    if tune_lookup:
        tuning, cache_hit = tune.best_config(
            "scan_job",
            shape=tune.scan_shape_sig_for(spec),
            backend=tune.backend_sig(use_kernel=spec.use_kernel),
            path=tune_cache,
        )
        tuning_source = "cache"

    prev_obs = None
    if trace_out is not None:
        prev_obs = obs.install(obs.Tracer(), obs.Metrics())
    try:
        # install as the process-active config too, so knobs resolved off
        # the explicit path (serve helpers, direct kernel calls inside the
        # lifecycle) see the same tuning the job runs under
        with tune.use(tuning, source=tuning_source, cache_hit=cache_hit):
            return _run_experiment_traced(
                spec,
                out_dir=out_dir,
                seed=seed,
                resume=resume,
                collection=collection,
                pipelined=pipelined,
                max_workers=max_workers,
                faults=faults,
                max_retries=max_retries,
                speculative=speculative,
                trace_out=trace_out,
                tuning=tuning,
                tuning_source=tuning_source,
                cache_hit=cache_hit,
            )
    finally:
        if prev_obs is not None:
            obs.install(*prev_obs)


def _run_experiment_traced(
    spec: ExperimentSpec,
    *,
    out_dir: str,
    seed: int,
    resume: bool,
    collection: Collection | None,
    pipelined: bool,
    max_workers: int | None,
    faults: Any | None,
    max_retries: int,
    speculative: bool,
    trace_out: str | None,
    tuning: TuningConfig | None = None,
    tuning_source: str = "default",
    cache_hit: bool = False,
) -> dict:
    """The lifecycle body, running under whatever instruments are installed."""
    tr = obs.tracer()
    met = obs.metrics()
    cfg = tune.resolve(tuning)
    # clamp eval cutoffs to the run depth up front — failing in evaluation
    # after the whole scan job ran would discard all the work
    if spec.k < max(spec.eval_ks):
        ks = tuple(c for c in spec.eval_ks if c <= spec.k) or (spec.k,)
        spec = dataclasses.replace(spec, eval_ks=ks)
    with tr.span("experiment.prepare", "experiment", experiment=spec.name, seed=seed):
        coll = (
            collection if collection is not None else prepare_collection(spec, seed=seed)
        )
    scorers = spec.scorers()
    docs = (jnp.asarray(coll.corpus.tokens), jnp.asarray(coll.corpus.lengths))
    # pack on the producer: token segments shrink to the tuned width here,
    # before sharding/staging, and every consumer decodes exactly — run
    # files stay byte-identical to the unpacked oracle (the pack contract)
    pack_resolved = "none"
    if cfg.token_pack != "none" and all(s.kind == "lexical" for s in scorers):
        packed = packing.pack_corpus(
            np.asarray(coll.corpus.tokens),
            np.asarray(coll.corpus.lengths),
            vocab=spec.vocab,
            mode=cfg.token_pack,
        )
        if isinstance(packed, packing.PackedCorpus):
            pack_resolved = packed.spec.mode
            docs = jax.tree.map(jnp.asarray, packed)

    # the tuned chunk replaces the spec's *for the scan fold only* (stats
    # preparation keeps the declared chunking — stats bytes depend on it);
    # a tuned chunk the plan can't cut falls back to the declared one: a
    # knob may be ignored, never fail a job. Chunk regrouping is byte-safe
    # (per-doc scores are chunk-independent; the top-k combiner's
    # positional tie-break is lexicographic on monotone id streams).
    chunk = spec.chunk_size
    if cfg.chunk_size is not None:
        per_shard = spec.n_docs // max(1, spec.n_shards)
        if spec.n_docs % max(1, spec.n_shards) == 0 and per_shard % cfg.chunk_size == 0:
            chunk = cfg.chunk_size

    # the scan is a cluster job at every shard count: n_shards=1 is the
    # classic single-host layout, >1 adds per-shard checkpoints/kill/resume
    # and a merge whose output is byte-identical to the one-shard run.
    # shards spread round-robin over the visible devices (one device = a
    # host-sequential cluster, the paper's own execution model).
    plan = plan_shards(spec.n_docs, n_shards=spec.n_shards, chunk_size=chunk)
    devices = jax.devices() if spec.n_shards > 1 else None
    with tr.span(
        "experiment.scan", "experiment", n_shards=plan.n_shards, pipelined=pipelined
    ):
        job = run_sharded_scan_job(
            jnp.asarray(coll.queries),
            docs,
            scorers,
            k=spec.k,
            chunk_size=chunk,
            segment_chunks=spec.segment_chunks,
            plan=plan,
            stats=coll.stats,
            ckpt_dir=os.path.join(out_dir, "ckpt"),
            resume=resume,
            use_kernel=spec.use_kernel,
            devices=devices,
            pipelined=pipelined,
            max_workers=max_workers,
            faults=faults,
            max_retries=max_retries,
            speculative=speculative,
            tuning=cfg,
        )

    with tr.span("experiment.run_files", "experiment"):
        run_paths = write_run_files(
            os.path.join(out_dir, "runs"), scorers, job.state, tag_prefix=spec.name
        )
        trec.write_qrels(os.path.join(out_dir, "qrels.txt"), coll.qrels)

    with tr.span("experiment.eval", "experiment"):
        reports = {}
        per_query_ap = {}
        for m, s in enumerate(scorers):
            rep = evaluate_run(
                np.asarray(job.state.ids)[m], coll.qrels, ks=spec.eval_ks
            )
            reports[s.name] = rep["aggregate"]
            per_query_ap[s.name] = rep["per_query"]["ap"]

        significance = {}
        baseline = spec.baseline if spec.baseline in per_query_ap else scorers[0].name
        for name, ap in per_query_ap.items():
            if name == baseline:
                continue
            res = paired_randomization_test(ap, per_query_ap[baseline], seed=seed)
            significance[name] = {
                "vs": baseline,
                "metric": "ap",
                "diff": res.diff,
                "p_value": res.p_value,
            }

    obs_block = None
    if trace_out is not None:
        # the trace lives *outside* runs/ so artifact byte-identity checks
        # (traced run vs tracing-off oracle) diff the run dirs untouched
        trace_dir = os.path.dirname(trace_out)
        if trace_dir:
            os.makedirs(trace_dir, exist_ok=True)
        jsonl_path = os.path.splitext(trace_out)[0] + ".jsonl"
        obs.export.write_chrome_trace(trace_out, tr, metrics=met)
        obs.export.write_jsonl(jsonl_path, tr)
        obs_block = {
            "trace": trace_out,
            "events_jsonl": jsonl_path,
            "n_events": len(tr),
            "metrics": met.summary(),
            "phases": obs.export.phase_rollup(tr),
        }

    report = {
        "experiment": spec.name,
        "seed": seed,
        "n_docs": spec.n_docs,
        "n_queries": spec.n_queries,
        "k": spec.k,
        "models": [s.name for s in scorers],
        "job": {
            "n_shards": job.plan.n_shards,
            "pipelined": pipelined,
            "segments_total": job.segments_total,
            "segments_run": job.segments_run,
            "resumed_from": max(r.resumed_from for r in job.shard_results),
            "max_retries": max_retries,
            "speculative": speculative,
            "scheduler": job.scheduler.describe() if job.scheduler else None,
            "faults_fired": faults.fired if faults is not None else [],
            "tuning": {
                "config_hash": cfg.config_hash(),
                "source": tuning_source,
                "cache_hit": cache_hit,
                "overrides": cfg.overrides(),
                "chunk_size": chunk,
                "token_pack": cfg.token_pack,
                "pack_resolved": pack_resolved,
            },
            "obs": obs_block,
            "shards": [
                {
                    "segments_total": r.segments_total,
                    "segments_run": r.segments_run,
                    "resumed_from": r.resumed_from,
                }
                for r in job.shard_results
            ],
        },
        "runs": run_paths,
        "metrics": reports,
        "baseline": baseline,
        "significance": significance,
    }
    with open(os.path.join(out_dir, "report.json"), "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return report
