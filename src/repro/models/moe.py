"""Mixture-of-Experts FFN: expert-parallel shard_map with capacity dispatch.

Design (DESIGN §5): activations arrive **data-sharded, tp-replicated**, so
every model shard sees the full local token set and can gather the tokens
routed to *its* experts directly — dispatch needs **no all_to_all**; the only
communication is one ``psum`` of partial outputs over the ``tp`` axis (same
volume as a row-parallel dense FFN), plus the expert-weight strategy below.
Routing: top-k with renormalization, capacity ``C = round8(T_loc·k/E·cf)``
(static shapes), position-in-expert by stable sort (memory O(T·k), never
O(T·E·C)). Dispatch is gather-only (int scatter builds slot→token map).

Expert weights that don't fit tp-sharded (dbrx: 254 GB) are additionally
sharded over ``dp`` on the ``d_ff`` dim:

* ``mode="train"`` — tokens differ per dp shard, so weights are all-gathered
  just-in-time per layer (ZeRO-3; autodiff transposes the gather to the
  reduce-scatter of expert grads).
* ``mode="replicated"`` — decode with batch too small to dp-shard: tokens are
  dp-replicated, so instead of gathering weights we run **tensor parallelism
  over d_ff on the dp axis** (partial down-proj + psum) — no weight movement
  at all.

The router's top-k + "keep what fits, reconcile later" is the same mergeable
top-k idea as the MIREX combiner — both are "score, keep k, merge".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.common import activation_fn


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def router_topk(logits: jax.Array, k: int):
    """Softmax → top-k → renormalize. logits [T, E] → (weights, ids) [T, k]."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ids = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return probs, w, ids.astype(jnp.int32)


def load_balance_loss(probs: jax.Array, ids: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * P_e (over the local token set)."""
    f = jnp.mean(
        jax.nn.one_hot(ids, n_experts, dtype=jnp.float32).sum(1), axis=0
    ) / ids.shape[-1]
    p = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * p)


def _positions_in_expert(flat_ids: jax.Array) -> jax.Array:
    """Rank of each assignment within its expert group (stable-sort based)."""
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    group_start = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    rank_sorted = jnp.arange(flat_ids.shape[0], dtype=jnp.int32) - group_start.astype(
        jnp.int32
    )
    return jnp.zeros_like(flat_ids).at[order].set(rank_sorted)


def _dispatch(x, flat_ids, pos, e0, e_loc, capacity, top_k):
    """Gather-only dispatch: x [T,D] -> h [E_loc, C, D] + slot map."""
    t, d = x.shape
    local = (flat_ids >= e0) & (flat_ids < e0 + e_loc)
    keep = local & (pos < capacity)
    slot = jnp.where(keep, (flat_ids - e0) * capacity + pos, e_loc * capacity)
    token_of = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    src = jnp.full((e_loc * capacity + 1,), t, jnp.int32).at[slot].set(token_of)
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)])
    h = x_pad[src[:-1]].reshape(e_loc, capacity, d)
    return h, slot, keep


def moe_ffn_local(
    x: jax.Array,  # [T_loc, D] — this shard's tokens (tp-replicated)
    router_w: jax.Array,  # [D, E]
    w_gate: jax.Array,  # [E_loc, D, F or F_loc]
    w_up: jax.Array,
    w_down: jax.Array,  # [E_loc, F or F_loc, D]
    *,
    n_experts: int,
    top_k: int,
    capacity: int,
    tp_axis: str,
    out_psum_axes,
    activation: str = "silu",
):
    """Per-shard MoE body. Returns (y_local, aux_loss)."""
    t, d = x.shape
    e_loc = w_gate.shape[0]
    e0 = jax.lax.axis_index(tp_axis) * e_loc
    act = activation_fn(activation)

    # bf16 inputs, f32 accumulation: avoids materializing a f32 copy of x
    logits = jnp.einsum(
        "td,de->te", x, router_w.astype(x.dtype), preferred_element_type=jnp.float32
    )
    probs, weights, ids = router_topk(logits, top_k)
    aux = load_balance_loss(probs, ids, n_experts)

    flat_ids = ids.reshape(-1)
    flat_w = weights.reshape(-1)
    pos = _positions_in_expert(flat_ids)
    h, slot, keep = _dispatch(x, flat_ids, pos, e0, e_loc, capacity, top_k)

    # bf16 grouped GEMMs (f32 outputs would materialize [E,C,F] f32 buffers)
    g = jnp.einsum("ecd,edf->ecf", h, w_gate)
    u = jnp.einsum("ecd,edf->ecf", h, w_up)
    y = jnp.einsum("ecf,efd->ecd", (act(g) * u).astype(x.dtype), w_down).astype(x.dtype)

    # combine: per-k gather+weight keeps the intermediate at [T, D]
    y_flat = jnp.concatenate([y.reshape(e_loc * capacity, d), jnp.zeros((1, d), y.dtype)])
    slot_k = slot.reshape(t, top_k)
    w_k = (flat_w * keep).astype(y.dtype).reshape(t, top_k)
    out = jnp.zeros((t, d), x.dtype)
    for j in range(top_k):
        out = out + y_flat[slot_k[:, j]] * w_k[:, j : j + 1]
    if out_psum_axes is not None:
        out = jax.lax.psum(out, out_psum_axes)
    return out, aux


def make_moe_layer(
    mesh: Mesh,
    dp_axes: tuple[str, ...],
    tp_axis: str,
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float,
    tokens_per_shard: int,
    activation: str = "silu",
    fsdp_experts: bool = False,
    mode: str = "train",  # "train" | "replicated"
):
    """Build the shard_map'd MoE FFN: (x, router, gate, up, down) ->
    (y, aux scalar).

    Modes:
      * ``seq``        — train path. x arrives **sequence-sharded over tp**
        (``[B_loc, S/tp, D]`` locally): the shard_map boundary then matches
        the Megatron-SP layer carry, so shard_map-AD's saved input stack is
        tp-fraction-sized (shard_map residuals ignore the outer remat
        policy — measured 2.4× activation-stack blowup when the input was
        tp-replicated). Inside: all-gather S → route/dispatch/compute →
        **reduce-scatter** partial outputs back to S-sharded.
      * ``train``      — x dp-sharded, tp-replicated (used when S doesn't
        divide tp); output psum over tp.
      * ``replicated`` — decode with batch too small to dp-shard; under
        ``fsdp_experts`` runs TP-over-d_ff on the dp axes (no weight
        gather), output psum over (dp, tp).
    """
    assert mode in ("train", "seq", "replicated"), mode
    capacity = _round_up(
        max(int(tokens_per_shard * top_k / n_experts * capacity_factor), 8), 8
    )
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    if mode == "seq":
        x_spec = P(dp_spec, tp_axis, None)
    elif mode == "train":
        x_spec = P(dp_spec, None, None)
    else:
        x_spec = P(None, None, None)

    def local(x, router_w, w_gate, w_up, w_down):
        out_axes = tp_axis
        if fsdp_experts:
            if mode in ("train", "seq"):
                # ZeRO-3: gather F-sharded expert weights just-in-time
                w_gate = jax.lax.all_gather(w_gate, dp_axes, axis=2, tiled=True)
                w_up = jax.lax.all_gather(w_up, dp_axes, axis=2, tiled=True)
                w_down = jax.lax.all_gather(w_down, dp_axes, axis=1, tiled=True)
            else:
                # replicated tokens: TP over d_ff on the dp axes — partial
                # down-proj summed in the same psum as the tp reduction.
                out_axes = (*dp_axes, tp_axis)
        if mode == "seq":
            x = jax.lax.all_gather(x, tp_axis, axis=1, tiled=True)
        b, s, d = x.shape

        def ffn(x2d):
            return moe_ffn_local(
                x2d,
                router_w,
                w_gate,
                w_up,
                w_down,
                n_experts=n_experts,
                top_k=top_k,
                capacity=capacity,
                tp_axis=tp_axis,
                out_psum_axes=None if mode == "seq" else out_axes,
                activation=activation,
            )

        # checkpoint *inside* the shard_map: shard_map residuals don't obey
        # the outer layer-level remat policy, so force recompute here.
        ffn = jax.checkpoint(
            ffn, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False
        )
        y, aux = ffn(x.reshape(b * s, d))
        y = y.reshape(b, s, d)
        if mode == "seq":
            # partial expert outputs: reduce-scatter back to S-sharded
            y = jax.lax.psum_scatter(y, tp_axis, scatter_dimension=1, tiled=True)
        if mode in ("train", "seq"):
            aux = jax.lax.pmean(aux, dp_axes)
        return y, aux

    gate_spec = P(tp_axis, None, dp_spec) if fsdp_experts else P(tp_axis, None, None)
    down_spec = P(tp_axis, dp_spec, None) if fsdp_experts else P(tp_axis, None, None)
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(x_spec, P(), gate_spec, gate_spec, down_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )
