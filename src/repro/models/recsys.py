"""RecSys models: DCN-v2, FM, MIND, SASRec — sparse tables + interactions.

The hot path is the embedding lookup (``models/embedding.py``); interactions:

* **FM** — pairwise ⟨vᵢ,vⱼ⟩xᵢxⱼ via the O(nk) sum-square trick [Rendle'10].
* **DCN-v2** — cross layers ``x_{l+1} = x₀ ⊙ (W xₗ + b) + xₗ`` then MLP
  [arXiv:2008.13535] (stacked form).
* **MIND** — multi-interest capsule routing (B2I dynamic routing)
  [arXiv:1904.08030]; serving scores a candidate with max over interests.
* **SASRec** — causal self-attention over the item history [arXiv:1808.09781].

``retrieval_cand`` (score one user against 10⁶ candidates) is the MIREX scan
verbatim: candidates are the corpus, the model's user representation is the
query, the per-variant ``score_block`` plugs into ``core/scan.py`` and the
distributed top-k combiner does the rest. For FM the candidate score is
*linear* in the candidate embedding, so retrieval reduces exactly to the
dense dot-product scan (DESIGN §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.distributed.sharding import AxisRules
from repro.models.common import init_dense
from repro.models.embedding import embedding_bag, field_embed
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def param_shapes(cfg: RecsysConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)

    def s(*shape):
        return jax.ShapeDtypeStruct(shape, dt)

    d = cfg.embed_dim
    if cfg.variant == "fm":
        return {
            "tables": s(cfg.n_sparse, cfg.vocab_per_field, d),
            "linear": s(cfg.n_sparse, cfg.vocab_per_field),
            "bias": s(),
        }
    if cfg.variant == "dcn-v2":
        x0 = cfg.n_dense + cfg.n_sparse * d
        p = {
            "tables": s(cfg.n_sparse, cfg.vocab_per_field, d),
            "cross_w": s(cfg.n_cross_layers, x0, x0),
            "cross_b": s(cfg.n_cross_layers, x0),
        }
        dims = (x0, *cfg.mlp_dims)
        for i in range(len(cfg.mlp_dims)):
            p[f"mlp_w{i}"] = s(dims[i], dims[i + 1])
            p[f"mlp_b{i}"] = s(dims[i + 1])
        p["head_w"] = s(dims[-1], 1)
        p["head_b"] = s(1)
        return p
    if cfg.variant == "mind":
        return {
            "items": s(cfg.n_items, d),
            "bilinear": s(d, d),  # B2I routing map
            "out_w": s(d, d),
            "out_b": s(d),
        }
    if cfg.variant == "sasrec":
        hd = d
        return {
            "items": s(cfg.n_items, d),
            "pos": s(cfg.seq_len, d),
            "blocks": {
                "ln1": s(cfg.n_blocks, d),
                "wq": s(cfg.n_blocks, d, hd),
                "wk": s(cfg.n_blocks, d, hd),
                "wv": s(cfg.n_blocks, d, hd),
                "wo": s(cfg.n_blocks, hd, d),
                "ln2": s(cfg.n_blocks, d),
                "w1": s(cfg.n_blocks, d, 4 * d),
                "b1": s(cfg.n_blocks, 4 * d),
                "w2": s(cfg.n_blocks, 4 * d, d),
                "b2": s(cfg.n_blocks, d),
            },
            "ln_f": s(d),
        }
    raise ValueError(cfg.variant)


def init_params(cfg: RecsysConfig, key: jax.Array) -> dict:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(key, len(flat))
    leaves = [
        init_dense(k, sds.shape, sds.dtype, scale=0.05)
        if sds.ndim >= 2
        else jnp.zeros(sds.shape, sds.dtype)
        for k, sds in zip(keys, flat)
    ]
    params = jax.tree.unflatten(treedef, leaves)
    if cfg.variant == "sasrec":
        for n in ("ln1", "ln2"):
            params["blocks"][n] = jnp.ones_like(params["blocks"][n])
        params["ln_f"] = jnp.ones_like(params["ln_f"])
    return params


def param_specs(cfg: RecsysConfig, rules: AxisRules) -> dict:
    """Baseline: tables replicated (they fit: ≤1.7 GB); batch over the whole
    mesh. Vocab-sharded tables are the §Perf alternative (embedding.py)."""
    return jax.tree.map(
        lambda s: P(*([None] * s.ndim)),
        param_shapes(cfg),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


# ---------------------------------------------------------------------------
# forwards
# ---------------------------------------------------------------------------

def _rms(x, w):
    v = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(v + 1e-6) * w).astype(x.dtype)


def fm_forward(params, batch, cfg: RecsysConfig) -> jax.Array:
    """FM with the sum-square trick: O(F·d) per example. Returns logits [B]."""
    ids = batch["sparse_ids"]  # [B, F]
    e = field_embed(params["tables"], ids)  # [B, F, d]
    f = params["linear"].shape[0]
    lin = params["linear"][jnp.arange(f)[None, :], ids].sum(-1)  # [B]
    s1 = e.sum(1)  # [B, d]
    s2 = (e * e).sum(1)
    pair = 0.5 * (s1 * s1 - s2).sum(-1)
    return params["bias"] + lin + pair


def dcn_forward(params, batch, cfg: RecsysConfig) -> jax.Array:
    e = field_embed(params["tables"], batch["sparse_ids"])  # [B, F, d]
    b = e.shape[0]
    x0 = jnp.concatenate([batch["dense"], e.reshape(b, -1)], axis=-1)
    x = x0
    for i in range(cfg.n_cross_layers):
        x = x0 * (x @ params["cross_w"][i] + params["cross_b"][i]) + x
    for i in range(len(cfg.mlp_dims)):
        x = jax.nn.relu(x @ params[f"mlp_w{i}"] + params[f"mlp_b{i}"])
    return (x @ params["head_w"] + params["head_b"])[:, 0]


def mind_interests(params, history, cfg: RecsysConfig) -> jax.Array:
    """B2I dynamic routing -> interest capsules [B, n_interests, d]."""
    mask = history > 0
    u = embedding_bag(
        params["items"], history, mode="sum", mask=mask
    )  # warm start unused; we need per-item embeds:
    e = params["items"][jnp.clip(history, 0, None)] * mask[..., None]  # [B, L, d]
    u_hat = e @ params["bilinear"]  # [B, L, d]
    b_logit = jnp.zeros((*history.shape, cfg.n_interests), jnp.float32)  # [B, L, I]

    def squash(v):
        n2 = jnp.sum(jnp.square(v), -1, keepdims=True)
        return v * (n2 / (1.0 + n2)) / jnp.sqrt(n2 + 1e-9)

    caps = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(b_logit, axis=-1) * mask[..., None]  # [B, L, I]
        z = jnp.einsum("bli,bld->bid", w, u_hat)
        caps = squash(z)  # [B, I, d]
        b_logit = b_logit + jnp.einsum("bid,bld->bli", caps, u_hat)
    del u
    return jax.nn.relu(caps @ params["out_w"] + params["out_b"])


def mind_train_logits(params, batch, cfg: RecsysConfig) -> jax.Array:
    """Label-aware attention over interests vs the target item (training)."""
    caps = mind_interests(params, batch["history"], cfg)  # [B, I, d]
    tgt = params["items"][batch["target"][:, -1]]  # [B, d]
    att = jax.nn.softmax(jnp.einsum("bid,bd->bi", caps, tgt) * 2.0, axis=-1)
    user = jnp.einsum("bi,bid->bd", att, caps)
    return user, tgt


def sasrec_forward(params, history, cfg: RecsysConfig) -> jax.Array:
    """history [B, S] -> hidden states [B, S, d] (causal)."""
    b, s = history.shape
    d = cfg.embed_dim
    x = params["items"][jnp.clip(history, 0, None)] + params["pos"][None, :s]
    mask = jnp.tril(jnp.ones((s, s), bool))
    for i in range(cfg.n_blocks):
        blk = jax.tree.map(lambda p, i=i: p[i], params["blocks"])
        y = _rms(x, blk["ln1"])
        q, k, v = y @ blk["wq"], y @ blk["wk"], y @ blk["wv"]
        a = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(float(d))
        a = jnp.where(mask[None], a, -1e30)
        o = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(a, -1), v) @ blk["wo"]
        x = x + o
        y = _rms(x, blk["ln2"])
        x = x + jax.nn.relu(y @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]
    return _rms(x, params["ln_f"])


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------

def bce_loss(logits, labels):
    logits = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def in_batch_softmax_loss(user, items):
    """user [B,d] vs items [B,d] (positives); in-batch negatives."""
    logits = user @ items.T / jnp.sqrt(float(user.shape[-1]))
    labels = jnp.arange(user.shape[0])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], -1))


def train_logits(params, batch, cfg: RecsysConfig):
    if cfg.variant == "fm":
        return bce_loss(fm_forward(params, batch, cfg), batch["labels"])
    if cfg.variant == "dcn-v2":
        return bce_loss(dcn_forward(params, batch, cfg), batch["labels"])
    if cfg.variant == "mind":
        user, tgt = mind_train_logits(params, batch, cfg)
        return in_batch_softmax_loss(user, tgt)
    if cfg.variant == "sasrec":
        h = sasrec_forward(params, batch["history"], cfg)
        pos = params["items"][batch["target"]]
        neg = params["items"][(batch["target"] + 1_234_567) % cfg.n_items]
        pos_lg = jnp.einsum("bsd,bsd->bs", h, pos)
        neg_lg = jnp.einsum("bsd,bsd->bs", h, neg)
        valid = batch["history"] > 0
        return bce_loss(
            jnp.where(valid, pos_lg, 0.0), valid.astype(jnp.float32)
        ) + bce_loss(jnp.where(valid, neg_lg, 0.0), jnp.zeros_like(neg_lg))
    raise ValueError(cfg.variant)


# ---------------------------------------------------------------------------
# retrieval: per-variant score_block for the MIREX scan
# ---------------------------------------------------------------------------

def user_query_vector(params, batch, cfg: RecsysConfig):
    """Collapse the user side to the representation the scan scores against."""
    if cfg.variant == "fm":
        e = field_embed(params["tables"], batch["sparse_ids"])
        return e.sum(1)  # score(c) = const + lin_c + v_c · Σvᵢ  (linear!)
    if cfg.variant == "mind":
        return mind_interests(params, batch["history"], cfg)  # [B, I, d]
    if cfg.variant == "sasrec":
        return sasrec_forward(params, batch["history"], cfg)[:, -1]  # [B, d]
    raise ValueError(f"{cfg.variant} uses full-forward retrieval")


def score_block_dot(user_vec, cand_embeds):
    return jnp.einsum("bd,nd->bn", user_vec, cand_embeds)


def score_block_multi_interest(user_caps, cand_embeds):
    """MIND serving: max over interest capsules [B,I,d] × [N,d] -> [B,N]."""
    s = jnp.einsum("bid,nd->bin", user_caps, cand_embeds)
    return s.max(axis=1)


def score_block_dcn(params, user_batch, cand_ids, cfg: RecsysConfig):
    """Honest DCN retrieval: full forward per (user, candidate-block).

    The candidate id replaces the last sparse field; this is the
    sequential-scan spirit — the 'index-free' model evaluated per candidate.
    user_batch must have batch size 1 (retrieval_cand).
    """
    n = cand_ids.shape[0]
    dense = jnp.broadcast_to(user_batch["dense"], (n, user_batch["dense"].shape[-1]))
    ids = jnp.broadcast_to(user_batch["sparse_ids"], (n, user_batch["sparse_ids"].shape[-1]))
    ids = ids.at[:, -1].set(cand_ids)
    return dcn_forward(params, {"dense": dense, "sparse_ids": ids}, cfg)[None, :]
