"""Shared model pieces: RMSNorm, RoPE, softcap, init helpers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, *, one_plus: bool = False, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if one_plus else w.astype(jnp.float32)
    return (x * scale).astype(dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding. positions [...,] -> [..., hd/2]."""
    freq = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., seq, heads, hd]; cos/sin [..., seq, hd/2] (broadcast over heads)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def activation_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_dense(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))
