"""Embedding lookup primitives — JAX has no EmbeddingBag; this is it.

Built per the brief from ``jnp.take`` + ``jax.ops.segment_sum``. Two layouts:

* padded bags (fixed ``[B, L]`` ids + mask) — the recsys batch layout;
* ragged bags (``values [nnz]`` + ``segment_ids``) — the general form.

Plus a **vocab-sharded** lookup (shard_map): each tp shard owns a contiguous
row range of the table, resolves the ids that fall in its range and psums —
one ``[B, F, D]`` all-reduce, no table movement. This is the embedding analog
of the MIREX combiner bound: shards exchange results, never raw data.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def field_embed(tables: jax.Array, ids: jax.Array) -> jax.Array:
    """Per-field lookup. tables [F, V, D], ids [B, F] -> [B, F, D]."""
    f = tables.shape[0]
    return tables[jnp.arange(f)[None, :], ids]


def embedding_bag(
    table: jax.Array,  # [V, D]
    ids: jax.Array,  # [B, L]
    *,
    mode: str = "mean",
    mask: jax.Array | None = None,  # [B, L] bool; default: ids >= 0
    weights: jax.Array | None = None,  # [B, L] per-sample weights
) -> jax.Array:
    """Padded-bag EmbeddingBag: gather + masked reduce -> [B, D]."""
    if mask is None:
        mask = ids >= 0
    e = table[jnp.clip(ids, 0, table.shape[0] - 1)]  # [B, L, D]
    w = mask.astype(e.dtype)
    if weights is not None:
        w = w * weights.astype(e.dtype)
    e = e * w[..., None]
    if mode == "sum":
        return e.sum(1)
    if mode == "mean":
        return e.sum(1) / jnp.maximum(w.sum(1, keepdims=True), 1.0)
    if mode == "max":
        neg = jnp.finfo(e.dtype).min
        return jnp.max(jnp.where(mask[..., None], e, neg), axis=1)
    raise ValueError(mode)


def embedding_bag_ragged(
    table: jax.Array,  # [V, D]
    values: jax.Array,  # [nnz] ids
    segment_ids: jax.Array,  # [nnz] bag index, sorted
    num_bags: int,
    *,
    mode: str = "sum",
) -> jax.Array:
    """Ragged EmbeddingBag via segment reduce -> [num_bags, D]."""
    e = table[values]
    if mode == "sum":
        return jax.ops.segment_sum(e, segment_ids, num_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(e, segment_ids, num_bags)
        n = jax.ops.segment_sum(jnp.ones_like(segment_ids, e.dtype), segment_ids, num_bags)
        return s / jnp.maximum(n, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(e, segment_ids, num_bags)
    raise ValueError(mode)


def make_sharded_field_embed(mesh: Mesh, tp_axis: str, batch_axes: tuple[str, ...]):
    """Vocab-sharded per-field lookup.

    tables stored P(None, tp, None) ([F, V, D], rows split over tp); ids
    sharded over ``batch_axes``. Returns fn(tables, ids) -> [B, F, D].
    """
    b_spec = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def local(tables_loc, ids):
        f, v_loc, d = tables_loc.shape
        v0 = jax.lax.axis_index(tp_axis) * v_loc
        local_ids = ids - v0
        in_range = (local_ids >= 0) & (local_ids < v_loc)
        e = tables_loc[
            jnp.arange(f)[None, :], jnp.clip(local_ids, 0, v_loc - 1)
        ]  # [B, F, D]
        e = jnp.where(in_range[..., None], e, 0)
        return jax.lax.psum(e, tp_axis)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, tp_axis, None), P(b_spec, None)),
        out_specs=P(b_spec, None, None),
        check_rep=False,
    )
