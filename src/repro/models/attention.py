"""Attention: chunked (flash-style) training path + cache decode paths.

Train/prefill uses an online **chunked** attention: an outer ``lax.scan`` over
query blocks keeps the live score tile at ``[B, H, q_block, S]`` instead of
``[B, H, S, S]`` — the pure-JAX analogue of the Pallas ``flash_attn`` kernel
(which replaces it on real TPUs; this HLO is what the dry-run lowers).

Decode over a **sequence-sharded KV cache** is the MIREX pattern as attention
(DESIGN §3): each shard scores the new token against its KV chunk (map), keeps
``(max, sum, weighted-value)`` — a mergeable summary (combine) — and shards
merge with a log-sum-exp reduction (reduce). Implemented in ``shard_map`` so
locality is by construction.

``window_active`` is a *traced* boolean (per-layer, from the scan over
stacked layers) so gemma2's local/global alternation lives in one compiled
layer body.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat
from repro.models.common import softcap

NEG = -1e30


def _mask_ok(pos_q, pos_k, *, causal: bool, window: int | None, window_active):
    """Bool mask [len(pos_q), len(pos_k)] from global positions.

    ``window_active`` may be a traced scalar bool; the window constraint is
    OR-ed away when inactive so one HLO serves local and global layers.
    """
    ok = jnp.ones((pos_q.shape[0], pos_k.shape[0]), bool)
    if causal:
        ok &= pos_k[None, :] <= pos_q[:, None]
    if window is not None:
        in_window = pos_q[:, None] - pos_k[None, :] < window
        if window_active is None:
            ok &= in_window
        else:
            ok &= in_window | ~window_active
    return ok


def chunked_attention(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Skv, H, hd] — GQA pre-expanded by the caller
    v: jax.Array,  # [B, Skv, H, hd]
    *,
    q_block: int,
    causal: bool = True,
    window: int | None = None,
    window_active=None,
    cap: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    assert sq % q_block == 0, (sq, q_block)
    nqb = sq // q_block
    scale = hd**-0.5

    qb = jnp.moveaxis(q.reshape(b, nqb, q_block, h, hd), 1, 0)
    pos_k = jnp.arange(skv)

    def one_block(carry, xs):
        qi, q_blk = xs  # [B, q_block, H, hd]
        pos_q = q_offset + qi * q_block + jnp.arange(q_block)
        s = jnp.einsum("bqhd,bshd->bhqs", q_blk, k, preferred_element_type=jnp.float32)
        s = softcap(s * scale, cap)
        ok = _mask_ok(pos_q, pos_k, causal=causal, window=window, window_active=window_active)
        s = jnp.where(ok[None, None], s, NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)
        return carry, o

    # remat per block: backward recomputes the block's scores instead of
    # stacking [B,H,S,S] fp32 across the scan — flash-attention's memory
    # contract, expressed at the JAX level.
    one_block = jax.checkpoint(
        one_block, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False
    )
    _, outs = jax.lax.scan(one_block, None, (jnp.arange(nqb), qb))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


def attend_cache(
    q: jax.Array,  # [B, H, hd] — one new token
    k_cache: jax.Array,  # [B, S, KV, hd]
    v_cache: jax.Array,  # [B, S, KV, hd]
    t: jax.Array,  # position of the new token (scalar int32)
    *,
    window: int | None = None,
    window_active=None,
    cap: float | None = None,
    pos_k: jax.Array | None = None,
) -> jax.Array:
    """Full-cache decode attention (replicated/small-cache path + oracle)."""
    b, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = hd**-0.5
    if pos_k is None:
        pos_k = jnp.arange(k_cache.shape[1])
    s = jnp.einsum(
        "bkgd,bskd->bkgs",
        q.reshape(b, kv, g, hd),
        k_cache,
        preferred_element_type=jnp.float32,
    )
    s = softcap(s * scale, cap)
    ok = pos_k <= t
    if window is not None:
        in_w = t - pos_k < window
        ok &= in_w if window_active is None else (in_w | ~window_active)
    s = jnp.where(ok[None, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, h, hd)


def _partial_attend(q, k_loc, v_loc, pos_loc, t, *, window, window_active, cap,
                    pos_limit=None):
    """Per-shard partial softmax summary: (m, l, o~) — the mergeable combiner.

    ``pos_limit`` (inclusive) defaults to ``t``; pass ``t-1`` when position t
    is handled out-of-band (decode's new-token term). The window is always
    relative to the query position ``t``.
    """
    b, h, hd = q.shape
    kv = k_loc.shape[2]
    g = h // kv
    scale = hd**-0.5
    s = jnp.einsum(
        "bkgd,bskd->bkgs", q.reshape(b, kv, g, hd), k_loc,
        preferred_element_type=jnp.float32,
    )
    s = softcap(s * scale, cap)
    ok = pos_loc <= (t if pos_limit is None else pos_limit)
    if window is not None:
        in_w = t - pos_loc < window
        ok &= in_w if window_active is None else (in_w | ~window_active)
    s = jnp.where(ok[None, None, None, :], s, NEG)
    m = jnp.max(s, axis=-1)  # [b,kv,g]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_loc.dtype), v_loc).astype(jnp.float32)
    return m, l, o


def lse_merge(m, l, o, axes):
    """Merge per-shard (m, l, o~) across mesh axes — the reduce step."""
    m_g = jax.lax.pmax(m, axes)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, axes)
    o_g = jax.lax.psum(o * corr[..., None], axes)
    return o_g / jnp.maximum(l_g[..., None], 1e-30)


def decode_attend_seqsharded(
    mesh: Mesh,
    *,
    seq_axes: tuple[str, ...],
    batch_spec,
    window: int | None = None,
    cap: float | None = None,
):
    """Build a shard_map'd decode attention over a sequence-sharded cache.

    The cache is **read-only** here (positions < t); the new token's (kn, vn)
    enter as a separate mergeable term folded in after the cross-shard LSE
    reduce — so the serve scan never rewrites the cache per layer (which on
    the dry-run host materialized 14 unaliased copies of it; the single
    in-place update happens once, outside the layer scan).

    Returns ``fn(q [B,H,hd], kn [B,KV,hd], vn [B,KV,hd],
    k_cache [B,S,KV,hd], v_cache, t, window_active) -> [B,H,hd] (fp32)``.
    """

    def local(q, kn, vn, k_loc, v_loc, t, window_active):
        s_loc = k_loc.shape[1]
        idx = 0
        for a in seq_axes:
            idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
        pos_loc = idx * s_loc + jnp.arange(s_loc)
        # cache term: strictly pos < t (position t lives in kn/vn)
        m, l, o = _partial_attend(
            q, k_loc, v_loc, pos_loc, t,
            window=window, window_active=window_active, cap=cap,
            pos_limit=t - 1,
        )
        m_g = jax.lax.pmax(m, seq_axes)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, seq_axes)
        o_g = jax.lax.psum(o * corr[..., None], seq_axes)
        # new-token term (self-attention to position t, always in-window)
        b, h, hd = q.shape
        kv = kn.shape[1]
        g = h // kv
        s_new = jnp.einsum(
            "bkgd,bkd->bkg", q.reshape(b, kv, g, hd), kn,
            preferred_element_type=jnp.float32,
        ) * (hd**-0.5)
        s_new = softcap(s_new, cap)
        m_f = jnp.maximum(m_g, s_new)
        w_c = jnp.exp(m_g - m_f)
        w_n = jnp.exp(s_new - m_f)
        num = o_g * w_c[..., None] + vn[:, :, None].astype(jnp.float32) * w_n[..., None]
        den = l_g * w_c + w_n
        out = num / jnp.maximum(den[..., None], 1e-30)
        return out.reshape(b, kv * g, hd)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(batch_spec),  # q [B,H,hd]
            P(batch_spec),  # kn [B,KV,hd]
            P(batch_spec),  # vn
            P(batch_spec, seq_axes),  # k cache [B,S,KV,hd]
            P(batch_spec, seq_axes),  # v cache
            P(),
            P(),
        ),
        out_specs=P(batch_spec),
        check_rep=False,
    )
