"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718].

Message passing is ``jax.ops.segment_*`` over an edge list (JAX has no sparse
message-passing; the brief makes this part of the system). Multi-aggregator
(mean/max/min/std) × degree scalers (identity/amplification/attenuation).

Edge-sharded distribution is the MIREX dataflow verbatim (DESIGN §3): each
shard owns an edge slab, computes *partial* segment aggregates for all nodes
(map+combine: sums, counts, maxima are all mergeable monoids), and shards
merge with ``psum``/``pmax``/``pmin`` (reduce). The combiner state is
``O(N·d)`` regardless of how many edges a shard processed.

Three input regimes (one per assigned shape):
  * full-graph: edge list sharded over the whole mesh;
  * sampled minibatch: fixed-fanout computation trees (GraphSAGE-style) from
    ``data/sampler.py``, batch-sharded;
  * batched molecules: vmap over per-graph edge lists.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat
from repro.configs.base import GNNConfig
from repro.distributed.sharding import AxisRules
from repro.models.common import init_dense

EPS = 1e-5


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def n_agg_feats(cfg: GNNConfig) -> int:
    return len(cfg.aggregators) * len(cfg.scalers)


def param_shapes(cfg: GNNConfig, d_feat: int) -> dict:
    d = cfg.d_hidden
    dt = jnp.dtype(cfg.dtype)

    def s(*shape):
        return jax.ShapeDtypeStruct(shape, dt)

    l = cfg.n_layers
    return {
        "w_in": s(d_feat, d),
        "b_in": s(d),
        "layers": {
            "w_src": s(l, d, d),
            "w_dst": s(l, d, d),
            "b_msg": s(l, d),
            "w_upd": s(l, (1 + n_agg_feats(cfg)) * d, d),
            "b_upd": s(l, d),
        },
        "w_out": s(d, cfg.n_classes),
        "b_out": s(cfg.n_classes),
    }


def init_params(cfg: GNNConfig, d_feat: int, key: jax.Array) -> dict:
    shapes = param_shapes(cfg, d_feat)
    flat, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(key, len(flat))
    return jax.tree.unflatten(
        treedef,
        [
            init_dense(k, s.shape, s.dtype) if s.ndim >= 2 else jnp.zeros(s.shape, s.dtype)
            for k, s in zip(keys, flat)
        ],
    )


def param_specs(cfg: GNNConfig, rules: AxisRules) -> dict:
    """PNA is tiny (d=75): replicate params; parallelism is over edges."""
    return jax.tree.map(
        lambda s: P(*([None] * s.ndim)), param_shapes(cfg, 1),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


# ---------------------------------------------------------------------------
# aggregation core (partial → merge), shared by every regime
# ---------------------------------------------------------------------------

def partial_aggregates(m: jax.Array, dst: jax.Array, n_nodes: int) -> dict:
    """Mergeable combiner state from one edge slab: Σm, max, min, count.

    The second moment is *not* accumulated here: variance must use the
    two-pass form Σ(m−μ)² (sqdev below) — E[x²]−E[x]² amplifies f32
    reduction-order noise through the sqrt at near-zero variance (observed
    0.16 output drift between fusion schedules)."""
    return {
        "sum": jax.ops.segment_sum(m, dst, n_nodes),
        "max": jax.ops.segment_max(m, dst, n_nodes, indices_are_sorted=False),
        "min": jax.ops.segment_min(m, dst, n_nodes),
        "cnt": jax.ops.segment_sum(jnp.ones_like(dst, m.dtype), dst, n_nodes),
    }


def sqdev_aggregate(m: jax.Array, dst: jax.Array, mean: jax.Array, n_nodes: int) -> jax.Array:
    """Second pass: Σ(m − μ_dst)² per destination (stable variance)."""
    mu = mean[jnp.clip(dst, 0, mean.shape[0] - 1)]
    return jax.ops.segment_sum(jnp.square(m - mu), dst, n_nodes)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def pmax_diff(x, axes):
    """Differentiable cross-shard max: grads split equally among the shards
    that attain the max (pmax itself has no AD rule)."""
    return jax.lax.pmax(x, axes)


def _pmax_fwd(x, axes):
    m = jax.lax.pmax(x, axes)
    return m, (x, m)


def _pmax_bwd(axes, res, g):
    x, m = res
    mask = (x == m).astype(g.dtype)
    cnt = jax.lax.psum(mask, axes)
    return (g * mask / jnp.maximum(cnt, 1.0),)


pmax_diff.defvjp(_pmax_fwd, _pmax_bwd)


def pmin_diff(x, axes):
    return -pmax_diff(-x, axes)


def merge_aggregates(agg: dict, axes) -> dict:
    return {
        "sum": jax.lax.psum(agg["sum"], axes),
        "max": pmax_diff(agg["max"], axes),
        "min": pmin_diff(agg["min"], axes),
        "cnt": jax.lax.psum(agg["cnt"], axes),
    }


def finish_aggregates(agg: dict, cfg: GNNConfig) -> jax.Array:
    """Combiner state (+ two-pass sqdev) -> scaled features [N, A*S*d]."""
    cnt = jnp.maximum(agg["cnt"], 1.0)[:, None]
    has = (agg["cnt"] > 0)[:, None]
    mean = agg["sum"] / cnt
    std = jnp.sqrt(agg["sqdev"] / cnt + EPS)
    by_name = {
        "mean": mean,
        "max": jnp.where(has, agg["max"], 0.0),
        "min": jnp.where(has, agg["min"], 0.0),
        "std": std,
    }
    deg = jnp.log1p(agg["cnt"])[:, None]
    scaler = {
        "identity": jnp.ones_like(deg),
        "amplification": deg / cfg.delta,
        "attenuation": cfg.delta / jnp.maximum(deg, EPS),
    }
    feats = [by_name[a] * scaler[s] for a in cfg.aggregators for s in cfg.scalers]
    return jnp.concatenate(feats, axis=-1)


def _message(h_src, h_dst, lp):
    return jax.nn.relu(h_src @ lp["w_src"] + h_dst @ lp["w_dst"] + lp["b_msg"])


def pna_layer_local(h, src, dst, lp, cfg, n_nodes, merge_axes=None):
    """One PNA layer on a (possibly partial) edge slab. h is replicated."""
    m = _message(h[src], h[dst], lp)
    agg = partial_aggregates(m, dst, n_nodes)
    if merge_axes is not None:
        agg = merge_aggregates(agg, merge_axes)
    mean = agg["sum"] / jnp.maximum(agg["cnt"], 1.0)[:, None]
    sqdev = sqdev_aggregate(m, dst, mean, n_nodes)
    agg["sqdev"] = jax.lax.psum(sqdev, merge_axes) if merge_axes is not None else sqdev
    feats = jnp.concatenate([h, finish_aggregates(agg, cfg)], axis=-1)
    out = jax.nn.relu(feats @ lp["w_upd"] + lp["b_upd"])
    return out + h  # residual


# ---------------------------------------------------------------------------
# full-graph forward (optionally edge-sharded over the whole mesh)
# ---------------------------------------------------------------------------

def forward_full_graph(params, x, src, dst, cfg: GNNConfig, *, merge_axes=None):
    """x [N, d_feat]; src/dst [E_local]. Returns logits [N, n_classes]."""
    n = x.shape[0]
    h = jax.nn.relu(x @ params["w_in"] + params["b_in"])
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda p, i=i: p[i], params["layers"])
        h = pna_layer_local(h, src, dst, lp, cfg, n, merge_axes=merge_axes)
    return h @ params["w_out"] + params["b_out"]


def pna_layer_sharded(h, src, dst, lp, cfg, n_nodes, axes, n_shards, idx):
    """Edge-sharded layer with node-sharded finish (reduce-scatter merge).

    Additive combiner states merge with ``psum_scatter`` directly onto node
    shards (same payload as psum, 1/n_shards output); max/min merge with the
    differentiable pmax and are sliced. The concat+update runs on the local
    node slab — the full ``[N, (1+A·S)·d]`` feature tensor (9.6 GiB on
    ogb_products) never exists. h returns replicated via all_gather (edge
    endpoints are random-access).
    """
    n_loc = n_nodes // n_shards
    m = _message(h[src], h[dst], lp)
    agg = partial_aggregates(m, dst, n_nodes)
    agg_loc = {
        k: jax.lax.psum_scatter(agg[k], axes, scatter_dimension=0, tiled=True)
        for k in ("sum", "cnt")
    }
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * n_loc, n_loc, 0)
    agg_loc["max"] = sl(pmax_diff(agg["max"], axes))
    agg_loc["min"] = sl(pmin_diff(agg["min"], axes))
    # stable variance: second pass against the merged mean (gathered so every
    # shard can deviate its own edges' messages)
    mean_loc = agg_loc["sum"] / jnp.maximum(agg_loc["cnt"], 1.0)[:, None]
    mean = jax.lax.all_gather(mean_loc, axes, axis=0, tiled=True)
    agg_loc["sqdev"] = jax.lax.psum_scatter(
        sqdev_aggregate(m, dst, mean, n_nodes), axes, scatter_dimension=0, tiled=True
    )
    h_loc = sl(h)
    feats = jnp.concatenate([h_loc, finish_aggregates(agg_loc, cfg)], axis=-1)
    out = jax.nn.relu(feats @ lp["w_upd"] + lp["b_upd"]) + h_loc
    return jax.lax.all_gather(out, axes, axis=0, tiled=True)


def pna_layer_bucketed(h, src, dst, lp, cfg, n_loc, idx):
    """Layer over **dst-bucketed** edges: this shard's slab contains exactly
    the edges whose destination lies in its node range (data/graph_prep.py
    pads buckets to uniform size with ghost edges dst=n_nodes). Aggregates
    are [N_loc, d] from the start — no full-[N] partials, no psum; the only
    communication is the all_gather that re-replicates h for random-access
    edge gathers. 1D graph partitioning, TPU-native."""
    m = _message(h[jnp.clip(src, 0, h.shape[0] - 1)], h[jnp.clip(dst, 0, h.shape[0] - 1)], lp)
    dst_local = dst - idx * n_loc  # ghosts fall outside [0, n_loc) and drop
    agg = partial_aggregates(m, dst_local, n_loc)
    mean = agg["sum"] / jnp.maximum(agg["cnt"], 1.0)[:, None]
    agg["sqdev"] = sqdev_aggregate(m, dst_local, mean, n_loc)
    h_loc = jax.lax.dynamic_slice_in_dim(h, idx * n_loc, n_loc, 0)
    feats = jnp.concatenate([h_loc, finish_aggregates(agg, cfg)], axis=-1)
    return jax.nn.relu(feats @ lp["w_upd"] + lp["b_upd"]) + h_loc


def make_sharded_full_graph(mesh: Mesh, rules: AxisRules, cfg: GNNConfig, *, mode: str = "bucketed"):
    """Full-graph forward, edges over every mesh axis (DESIGN §5).

    ``mode="bucketed"`` (default): dst-bucketed edges, local aggregation,
    one all_gather per layer. ``mode="scatter"``: arbitrary edge sharding,
    full-[N] partial aggregates merged by psum_scatter/pmax — the §Perf
    baseline this replaced (~10× more live node-sized buffers).
    Requires n_nodes divisible by the mesh size (shapes.py pads)."""
    axes = rules.all_axes

    def local(params, x, src, dst):
        n_shards = 1
        for a in axes:
            n_shards *= compat.axis_size(a)
        idx = 0
        for a in axes:
            idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
        n = x.shape[0]
        n_loc = n // n_shards
        h = jax.nn.relu(x @ params["w_in"] + params["b_in"])

        if mode == "bucketed":
            def one(h, lp):
                h_loc = pna_layer_bucketed(h, src, dst, lp, cfg, n_loc, idx)
                return jax.lax.all_gather(h_loc, axes, axis=0, tiled=True)
        else:
            def one(h, lp):
                return pna_layer_sharded(h, src, dst, lp, cfg, n, axes, n_shards, idx)

        layer = jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p, i=i: p[i], params["layers"])
            h = layer(h, lp)
        return h @ params["w_out"] + params["b_out"]

    pspecs = jax.tree.map(lambda _: P(), param_shapes(cfg, 1),
                          is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(pspecs, P(None, None), P(axes), P(axes)),
        out_specs=P(None, None),
        check_rep=False,
    )


# ---------------------------------------------------------------------------
# sampled-minibatch forward (fixed-fanout computation tree)
# ---------------------------------------------------------------------------

def forward_sampled(params, seed_x, hop1_x, hop2_x, cfg: GNNConfig):
    """GraphSAGE-style 2-hop tree: hop2 -> hop1 -> seed.

    seed_x [B, F], hop1_x [B, K1, F], hop2_x [B, K1, K2, F]. PNA aggregation
    over the fixed fanout (degree == fanout, so scalers are constants).
    """
    b, k1, k2, _ = hop2_x.shape

    def enc(x):
        return jax.nn.relu(x @ params["w_in"] + params["b_in"])

    h_seed, h1, h2 = enc(seed_x), enc(hop1_x), enc(hop2_x)

    def tree_layer(h_dst, h_src, lp, fanout):
        # h_dst [..., d]; h_src [..., fanout, d]
        m = _message(h_src, jnp.broadcast_to(h_dst[..., None, :], h_src.shape), lp)
        mean = m.mean(-2)
        std = m.std(-2) + EPS
        mx = m.max(-2)
        mn = m.min(-2)
        by_name = {"mean": mean, "max": mx, "min": mn, "std": std}
        deg = jnp.log1p(jnp.asarray(float(fanout), m.dtype))
        scaler = {
            "identity": 1.0,
            "amplification": deg / cfg.delta,
            "attenuation": cfg.delta / deg,
        }
        feats = jnp.concatenate(
            [h_dst] + [by_name[a] * scaler[s] for a in cfg.aggregators for s in cfg.scalers],
            axis=-1,
        )
        return jax.nn.relu(feats @ lp["w_upd"] + lp["b_upd"]) + h_dst

    lp0 = jax.tree.map(lambda p: p[0], params["layers"])
    lp1 = jax.tree.map(lambda p: p[min(1, cfg.n_layers - 1)], params["layers"])
    h1 = tree_layer(h1, h2, lp0, k2)  # [B, K1, d]
    h_seed = tree_layer(h_seed, h1, lp1, k1)  # [B, d]
    return h_seed @ params["w_out"] + params["b_out"]


# ---------------------------------------------------------------------------
# batched small graphs (molecules): vmap over graphs
# ---------------------------------------------------------------------------

def forward_batched_graphs(params, x, src, dst, cfg: GNNConfig):
    """x [B, N, F], src/dst [B, E] -> per-graph logits [B, n_classes]."""
    n = x.shape[1]

    def one(xg, sg, dg):
        logits = forward_full_graph(params, xg, sg, dg, cfg)
        return logits.mean(0)  # mean-pool readout

    return jax.vmap(one)(x, src, dst)


def xent_loss(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))
