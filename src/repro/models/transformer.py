"""Decoder-only transformer (dense + MoE) with production sharding.

One model definition serves all five assigned LM architectures (dbrx-132b,
qwen3-moe-30b-a3b, h2o-danube-1.8b, gemma2-27b, gemma2-2b): GQA, RoPE,
sliding-window / gemma2 local-global alternation, logit soft-capping, SwiGLU
or GeGLU FFNs, and top-k MoE (``models/moe.py``). Layers are **stacked and
scanned** so the compiled HLO is one layer's program — essential both for
compile time on the 1-core dry-run host and for HLO-size sanity at 512 chips.

Sharding (DESIGN §5): Megatron TP over ``tp`` for attention heads + FFN,
expert parallelism over ``tp`` for MoE, DP over ``dp`` (pod composes),
vocab-sharded loss in shard_map, optional ZeRO-3 expert weights. When a
config's head count does not divide the tp axis (gemma2-2b: 8 heads on a
16-way axis) attention falls back to dp-only compute with replicated attn
weights — recorded in the roofline; the FFN stays TP over d_ff.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat
from repro.configs.base import TransformerConfig
from repro.distributed.sharding import AxisRules
from repro.models import moe as moe_lib
from repro.models.attention import chunked_attention, decode_attend_seqsharded
from repro.models.common import apply_rope, init_dense, rms_norm, rope_angles, softcap

FSDP_EXPERT_BYTES = 2**33  # >8 GiB of expert weights -> ZeRO-3 them over dp


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def _dtype(cfg: TransformerConfig):
    return jnp.dtype(cfg.dtype)


def uses_fsdp_experts(cfg: TransformerConfig) -> bool:
    if not cfg.is_moe:
        return False
    expert_bytes = 3 * cfg.n_layers * cfg.n_experts * cfg.d_model * cfg.d_ff * 2
    return expert_bytes > FSDP_EXPERT_BYTES


def heads_divisible(cfg: TransformerConfig, tp_size: int) -> bool:
    return cfg.n_heads % tp_size == 0


def param_shapes(cfg: TransformerConfig) -> dict:
    """Shape/dtype tree (ShapeDtypeStructs) — the dry-run currency."""
    d, hd, h, kv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    l, f, v = cfg.n_layers, cfg.d_ff, cfg.vocab
    dt = _dtype(cfg)

    def s(*shape):
        return jax.ShapeDtypeStruct(shape, dt)

    layers: dict[str, Any] = {
        "attn_norm": s(l, d),
        "wq": s(l, d, h * hd),
        "wk": s(l, d, kv * hd),
        "wv": s(l, d, kv * hd),
        "wo": s(l, h * hd, d),
        "ffn_norm": s(l, d),
    }
    if cfg.is_moe:
        layers.update(
            router=s(l, d, cfg.n_experts),
            w_gate=s(l, cfg.n_experts, d, f),
            w_up=s(l, cfg.n_experts, d, f),
            w_down=s(l, cfg.n_experts, f, d),
        )
    else:
        layers.update(w_gate=s(l, d, f), w_up=s(l, d, f), w_down=s(l, f, d))
    return {
        "embed": s(v, d),
        "layers": layers,
        "final_norm": s(d),
        "unembed": s(d, v),
    }


def init_params(cfg: TransformerConfig, key: jax.Array) -> dict:
    """Real parameter init (smoke tests / the 100M example train)."""
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(key, len(flat))
    leaves = [
        init_dense(k, sds.shape, sds.dtype, scale=0.02) if sds.ndim >= 2
        else jnp.ones(sds.shape, sds.dtype)
        for k, sds in zip(keys, flat)
    ]
    params = jax.tree.unflatten(treedef, leaves)
    if cfg.rms_one_plus:  # gemma (1+w) convention: init scales at 0
        for name in ("attn_norm", "ffn_norm"):
            params["layers"][name] = jnp.zeros_like(params["layers"][name])
        params["final_norm"] = jnp.zeros_like(params["final_norm"])
    return params


def param_specs(cfg: TransformerConfig, rules: AxisRules, tp_size: int) -> dict:
    """PartitionSpec tree matching param_shapes."""
    tp = rules.tp
    dp = rules.dp if len(rules.dp) > 1 else rules.dp[0]
    attn_tp = heads_divisible(cfg, tp_size)
    layers = {
        "attn_norm": P(None, None),
        "wq": P(None, None, tp) if attn_tp else P(None, None, None),
        "wk": P(None, None, None),
        "wv": P(None, None, None),
        "wo": P(None, tp, None) if attn_tp else P(None, None, None),
        "ffn_norm": P(None, None),
    }
    if cfg.is_moe:
        fsdp = uses_fsdp_experts(cfg)
        layers.update(
            router=P(None, None, None),
            w_gate=P(None, tp, None, dp) if fsdp else P(None, tp, None, None),
            w_up=P(None, tp, None, dp) if fsdp else P(None, tp, None, None),
            w_down=P(None, tp, dp, None) if fsdp else P(None, tp, None, None),
        )
    else:
        layers.update(
            w_gate=P(None, None, tp), w_up=P(None, None, tp), w_down=P(None, tp, None)
        )
    return {
        "embed": P(None, tp),
        "layers": layers,
        "final_norm": P(None),
        "unembed": P(None, tp),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_windows(cfg: TransformerConfig) -> jax.Array:
    """Per-layer bool: does layer ℓ apply the sliding window?"""
    l = cfg.n_layers
    if cfg.local_global_alternating:
        return jnp.arange(l) % 2 == 0  # gemma2: even layers local
    if cfg.sliding_window is not None:
        return jnp.ones((l,), bool)
    return jnp.zeros((l,), bool)


def _window(cfg: TransformerConfig) -> int:
    return cfg.sliding_window if cfg.sliding_window is not None else 4096


def _dense_ffn(x, w_gate, w_up, w_down, cfg, mesh=None, rules=None):
    act = {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True)}[
        cfg.activation
    ]
    # bf16 intermediates: dot-internal accumulation is f32 on the MXU; f32
    # *outputs* here would materialize [B,S,F]/[B,S,D] f32 buffers and double
    # the row-parallel psum payload.
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    if mesh is not None:
        # pin Megatron column-parallel: with an S-sharded x the partitioner
        # otherwise prefers replicating the weights (S-sharded tokens ×
        # full-F intermediates), which makes every dW full-F and f32
        # (33×648 MiB on the gemma2-27b dry-run).
        g = jax.lax.with_sharding_constraint(g, rules.shard(mesh, "dp", None, "tp"))
        u = jax.lax.with_sharding_constraint(u, rules.shard(mesh, "dp", None, "tp"))
    return jnp.einsum("bsf,fd->bsd", (act(g) * u).astype(x.dtype), w_down).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class ModelContext:
    """Everything the forward pass needs besides params + inputs."""

    cfg: TransformerConfig
    mesh: Mesh
    rules: AxisRules
    moe_layer: Any = None


def make_context(
    cfg: TransformerConfig,
    mesh: Mesh,
    rules: AxisRules,
    *,
    tokens_per_shard: int | None = None,
    moe_mode: str = "train",
) -> ModelContext:
    moe_layer = None
    if cfg.is_moe and tokens_per_shard is not None:
        moe_layer = moe_lib.make_moe_layer(
            mesh,
            rules.dp,
            rules.tp,
            n_experts=cfg.n_experts,
            top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
            tokens_per_shard=tokens_per_shard,
            activation=cfg.activation,
            fsdp_experts=uses_fsdp_experts(cfg),
            mode=moe_mode,
        )
    return ModelContext(cfg=cfg, mesh=mesh, rules=rules, moe_layer=moe_layer)


def _attn_block(x, lp, cfg, *, window_active, q_offset=0, kv_out: bool = False,
                mesh=None, rules=None, attn_tp=False, seq_spec=None):
    """Norm → QKV → RoPE → chunked attention → out-proj. x [B,S,D]."""
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    y = rms_norm(x, lp["attn_norm"], one_plus=cfg.rms_one_plus)
    if mesh is not None and seq_spec is not None:
        # keep the norm S-sharded: otherwise the partitioner gathers x first
        # and the f32 norm internals balloon to full-seq [B,S,D] buffers
        # (52×1.15 GiB on gemma2-27b); the gather then happens on bf16 y.
        y = jax.lax.with_sharding_constraint(y, rules.shard(mesh, *seq_spec))
    q = jnp.einsum("bsd,dh->bsh", y, lp["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dh->bsh", y, lp["wk"]).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,dh->bsh", y, lp["wv"]).reshape(b, s, kv, hd)
    if attn_tp and mesh is not None:
        # pin head-TP (full tokens × local heads); see _dense_ffn note
        q = jax.lax.with_sharding_constraint(q, rules.shard(mesh, "dp", None, "tp", None))
    pos = q_offset + jnp.arange(s)
    cos, sin = rope_angles(pos, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    k_exp = jnp.repeat(k, h // kv, axis=2)
    v_exp = jnp.repeat(v, h // kv, axis=2)
    o = chunked_attention(
        q,
        k_exp,
        v_exp,
        q_block=min(cfg.q_block, s),
        causal=True,
        window=_window(cfg),
        window_active=window_active,
        cap=cfg.attn_softcap,
    )
    # bf16 output: the tp partial-sum (and its psum) stays bf16 — f32 here
    # materializes a full [B,S,D] f32 buffer per layer instance and doubles
    # the all-reduce payload.
    o = jnp.einsum("bsh,hd->bsd", o.reshape(b, s, h * hd), lp["wo"]).astype(x.dtype)
    if kv_out:
        return o, (k, v)
    return o


def forward_hidden(params: dict, tokens: jax.Array, ctx: ModelContext):
    """tokens [B,S] → (hidden x [B,S,D] after final norm, moe aux).

    The unembed projection is *not* applied here — training fuses it into the
    chunked cross-entropy (the [B,S,V] logits tensor never exists), serving
    applies it to last positions only.
    """
    cfg, mesh, rules = ctx.cfg, ctx.mesh, ctx.rules
    dt = _dtype(cfg)
    x = params["embed"][tokens].astype(dt)  # table is D-sharded; gather local
    # pin the gather output to the table's D-sharding: the backward scatter
    # then stays tp-sharded instead of materializing a replicated f32 [V,D]
    # gradient (5×2.5 GiB on the dbrx dry-run). Skipped under grad
    # accumulation: the constraint inside the microbatch scan trips an XLA
    # SPMD partitioner verifier bug (invalid dynamic-slice after
    # partitioning); the f32 accumulator tree carries the sharding instead.
    if cfg.grad_accum == 1:
        x = jax.lax.with_sharding_constraint(x, rules.shard(mesh, "dp", None, "tp"))
    if cfg.rms_one_plus:
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    windows = _layer_windows(cfg)

    # Sequence parallelism (Megatron-SP): the layer-boundary carry — and with
    # it the remat residual stack [L,B,S,D] — is sharded over ``tp`` on the
    # sequence dim (16× smaller stack). The partitioner turns the layer-entry
    # resharding into an all-gather and the exit into a reduce-scatter, which
    # together replace the plain TP all-reduce. The MoE shard_map's in_specs
    # (tp-replicated x) trigger the gather automatically for MoE layers.
    # The carry itself is f32: XLA:CPU float-normalization turns a bf16
    # dynamic-update-slice into convert→f32-DUS→convert, which materializes
    # several unaliasable copies of the residual stack on the dry-run host;
    # a f32 stack is DUS'd natively and aliases in place. (buffer-assignment
    # dump, dbrx train_4k).
    seq_par = tokens.shape[1] % mesh.shape[rules.tp] == 0
    attn_tp = heads_divisible(cfg, mesh.shape[rules.tp])
    carry_spec = ("dp", "tp", None) if seq_par else ("dp", None, None)
    x = jax.lax.with_sharding_constraint(x, rules.shard(mesh, *carry_spec))

    def layer(carry, xs):
        x32, aux = carry
        x = x32.astype(dt)
        lp, window_active = xs
        # barrier: XLA:CPU float-normalizes bf16 dot operands to f32 and
        # hoists the conversion of loop-invariant weight stacks out of the
        # while loop (full f32 copies of every stacked weight — 5.6 GiB on
        # gemma2-27b). The barrier keeps the convert per-slice. No-op on TPU.
        lp = compat.optimization_barrier(lp)
        x = x + _attn_block(x, lp, cfg, window_active=window_active,
                            mesh=mesh, rules=rules, attn_tp=attn_tp,
                            seq_spec=carry_spec)
        # back to S-sharded before the FFN: the MoE shard_map consumes the
        # S-sharded layout directly, the dense FFN gathers what it needs.
        x = jax.lax.with_sharding_constraint(x, rules.shard(mesh, *carry_spec))
        y = rms_norm(x, lp["ffn_norm"], one_plus=cfg.rms_one_plus)
        if cfg.is_moe:
            f, aux_l = ctx.moe_layer(y, lp["router"], lp["w_gate"], lp["w_up"], lp["w_down"])
            aux = aux + aux_l
        else:
            f = _dense_ffn(y, lp["w_gate"], lp["w_up"], lp["w_down"], cfg,
                           mesh=mesh, rules=rules)
        x = x + f
        x = jax.lax.with_sharding_constraint(x, rules.shard(mesh, *carry_spec))
        return (x.astype(jnp.float32), aux), None

    policy = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]
    carry0 = (x.astype(jnp.float32), jnp.zeros((), jnp.float32))
    xs = (params["layers"], windows)
    ck = cfg.remat_chunk if cfg.n_layers % max(cfg.remat_chunk, 1) == 0 else 1
    if cfg.remat and ck > 1:
        # two-level checkpointing: the outer scan saves one carry per CHUNK
        # of ck layers (residual stack ÷ ck); the chunk forward — including
        # the per-layer carries and the MoE shard_map residuals — is
        # recomputed during that chunk's backward. ~1 extra forward of
        # compute for a ck× smaller activation stack.
        nck = cfg.n_layers // ck
        xs_c = jax.tree.map(lambda p: p.reshape(nck, ck, *p.shape[1:]), xs)

        def chunk_body(carry, xs_chunk):
            carry, _ = jax.lax.scan(
                jax.checkpoint(layer, policy=policy, prevent_cse=False),
                carry,
                xs_chunk,
            )
            return carry, None

        (x, aux), _ = jax.lax.scan(
            jax.checkpoint(
                chunk_body,
                policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=False,
            ),
            carry0,
            xs_c,
        )
    else:
        body = layer
        if cfg.remat:
            body = jax.checkpoint(layer, policy=policy, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, carry0, xs)
    x = rms_norm(x.astype(dt), params["final_norm"], one_plus=cfg.rms_one_plus)
    return x, aux


def apply_unembed(params: dict, x: jax.Array, cfg: TransformerConfig):
    logits = jnp.einsum(
        "...d,dv->...v", x, params["unembed"], preferred_element_type=jnp.float32
    )
    return softcap(logits, cfg.final_softcap)


def forward(params: dict, tokens: jax.Array, ctx: ModelContext):
    """Full logits (tests / small models only — [B,S,V] materializes)."""
    x, aux = forward_hidden(params, tokens, ctx)
    return apply_unembed(params, x, ctx.cfg), aux


# ---------------------------------------------------------------------------
# loss: fused, chunked, vocab-parallel cross-entropy in shard_map
# (the [B,S,V] logits tensor never exists; per-chunk recompute in backward)
# ---------------------------------------------------------------------------

def make_loss_fn(ctx: ModelContext, aux_weight: float = 0.01, chunk: int = 256):
    cfg, mesh, rules = ctx.cfg, ctx.mesh, ctx.rules
    tp = rules.tp
    dp = rules.dp if len(rules.dp) > 1 else rules.dp[0]
    v_loc = cfg.vocab // mesh.shape[tp]

    def local_xent(x, unembed_loc, labels):
        """x [B_loc, S, D] (tp-replicated), unembed_loc [D, V_loc],
        labels [B_loc, S] -> mean xent (replicated scalar)."""
        b, s, d = x.shape
        ck = min(chunk, s)
        nc = s // ck
        v0 = jax.lax.axis_index(tp) * v_loc
        xc = jnp.moveaxis(x.reshape(b, nc, ck, d), 1, 0)
        lc = jnp.moveaxis(labels.reshape(b, nc, ck), 1, 0)

        def one_chunk(total, xs):
            xck, lck = xs  # [B, ck, D], [B, ck]
            logits = jnp.einsum(
                "bcd,dv->bcv", xck, unembed_loc, preferred_element_type=jnp.float32
            )
            logits = softcap(logits, cfg.final_softcap)
            m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
            m_g = jax.lax.pmax(m, tp)  # shift only; exact under stop_gradient
            se = jnp.sum(jnp.exp(logits - m_g[..., None]), axis=-1)
            lse = jnp.log(jax.lax.psum(se, tp)) + m_g
            lab = lck - v0
            in_range = (lab >= 0) & (lab < v_loc)
            lab_logit = jnp.take_along_axis(
                logits, jnp.clip(lab, 0, v_loc - 1)[..., None], axis=-1
            )[..., 0]
            lab_logit = jax.lax.psum(jnp.where(in_range, lab_logit, 0.0), tp)
            return total + jnp.sum(lse - lab_logit), None

        body = jax.checkpoint(
            one_chunk, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False
        )
        # carry is [1], not scalar: rank-0 scan carries inside shard_map hit
        # a transpose _SpecError on the pinned JAX (bisected in PR 2)
        total, _ = jax.lax.scan(body, jnp.zeros((1,), jnp.float32), (xc, lc))
        return jax.lax.pmean(total[0] / (b * s), dp)

    xent = shard_map(
        local_xent,
        mesh=mesh,
        in_specs=(P(dp, None, None), P(None, tp), P(dp, None)),
        out_specs=P(),
        check_rep=False,
    )

    def loss_fn(params, batch):
        x, aux = forward_hidden(params, batch["tokens"], ctx)
        loss = xent(x, params["unembed"], batch["labels"])
        return loss + aux_weight * aux, {"loss": loss, "aux": aux}

    return loss_fn


# ---------------------------------------------------------------------------
# decode: cache shapes / specs, prefill_step, serve_step
# ---------------------------------------------------------------------------

def cache_shapes(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    dt = _dtype(cfg)
    shp = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jax.ShapeDtypeStruct(shp, dt), "v": jax.ShapeDtypeStruct(shp, dt)}


def init_cache(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_shapes(cfg, batch, max_len)
    )


def decode_layout(cfg: TransformerConfig, rules: AxisRules, batch: int):
    """(seq_axes, batch_spec): batch=1 shards the sequence over *all* axes."""
    if batch == 1:
        return (*rules.dp, rules.tp), None
    return (rules.tp,), rules.dp if len(rules.dp) > 1 else rules.dp[0]


def cache_specs(cfg: TransformerConfig, rules: AxisRules, batch: int) -> dict:
    seq_axes, batch_spec = decode_layout(cfg, rules, batch)
    spec = P(None, batch_spec, seq_axes, None, None)
    return {"k": spec, "v": spec}


def make_serve_step(ctx: ModelContext, *, batch: int):
    """One-token decode over a sequence-sharded KV cache (MIREX-as-attention).

    serve_step(params, cache, tokens [B], t) -> (logits [B,V], cache')
    """
    cfg, mesh, rules = ctx.cfg, ctx.mesh, ctx.rules
    seq_axes, batch_spec = decode_layout(cfg, rules, batch)
    windows = _layer_windows(cfg)
    dt = _dtype(cfg)
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    attend = decode_attend_seqsharded(
        mesh,
        seq_axes=seq_axes,
        batch_spec=batch_spec,
        window=_window(cfg),
        cap=cfg.attn_softcap,
    )

    def serve_step(params, cache, tokens, t):
        b = tokens.shape[0]
        x = params["embed"][tokens].astype(dt)  # [B, D]
        if cfg.rms_one_plus:
            x = x * jnp.asarray(cfg.d_model**0.5, dt)
        cos, sin = rope_angles(t[None], hd, cfg.rope_theta)

        def layer(x, xs):
            lp, window_active, k_cache, v_cache = xs
            # see forward_hidden: block hoisted f32 copies of weights+cache
            lp, k_cache, v_cache = compat.optimization_barrier((lp, k_cache, v_cache))
            y = rms_norm(x, lp["attn_norm"], one_plus=cfg.rms_one_plus)
            q = jnp.einsum("bd,dh->bh", y, lp["wq"]).reshape(b, h, hd)
            kn = jnp.einsum("bd,dh->bh", y, lp["wk"]).reshape(b, kv, hd)
            vn = jnp.einsum("bd,dh->bh", y, lp["wv"]).reshape(b, kv, hd)
            q = apply_rope(q[:, None], cos, sin)[:, 0]
            kn = apply_rope(kn[:, None], cos, sin)[:, 0]
            # the cache is read-only inside the scan; kn/vn are folded into
            # the attention as a separate merge term and written once below
            o = attend(q, kn, vn, k_cache, v_cache, t, window_active).astype(dt)
            o = jnp.einsum("bh,hd->bd", o.reshape(b, h * hd), lp["wo"]).astype(dt)
            x = x + o
            y2 = rms_norm(x, lp["ffn_norm"], one_plus=cfg.rms_one_plus)
            if cfg.is_moe:
                f, _ = ctx.moe_layer(
                    y2[:, None], lp["router"], lp["w_gate"], lp["w_up"], lp["w_down"]
                )
                f = f[:, 0]
            else:
                f = _dense_ffn(y2[:, None], lp["w_gate"], lp["w_up"], lp["w_down"], cfg)[:, 0]
            return x + f, (kn.astype(cache["k"].dtype), vn.astype(cache["v"].dtype))

        x, (k_new, v_new) = jax.lax.scan(
            layer, x, (params["layers"], windows, cache["k"], cache["v"])
        )
        # single in-place cache write for all layers (donated buffer aliases)
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_new[:, :, None], (0, 0, t, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_new[:, :, None], (0, 0, t, 0, 0)
        )
        x = rms_norm(x, params["final_norm"], one_plus=cfg.rms_one_plus)
        logits = jnp.einsum(
            "bd,dv->bv", x, params["unembed"], preferred_element_type=jnp.float32
        )
        return softcap(logits, cfg.final_softcap), {"k": k_cache, "v": v_cache}

    return serve_step


def make_prefill_step(ctx: ModelContext):
    """Process a full prompt: last-position logits + the filled KV cache."""
    cfg, mesh, rules = ctx.cfg, ctx.mesh, ctx.rules
    dt = _dtype(cfg)

    def prefill(params, tokens):
        x = params["embed"][tokens].astype(dt)
        seq_par = tokens.shape[1] % mesh.shape[rules.tp] == 0
        attn_tp = heads_divisible(cfg, mesh.shape[rules.tp])
        carry_spec = ("dp", "tp", None) if seq_par else ("dp", None, None)
        # emitted KV cache: batch over dp, sequence over tp (decode layout)
        kv_spec = ("dp", "tp", None, None) if seq_par else ("dp", None, None, None)
        x = jax.lax.with_sharding_constraint(x, rules.shard(mesh, *carry_spec))
        if cfg.rms_one_plus:
            x = x * jnp.asarray(cfg.d_model**0.5, dt)
        windows = _layer_windows(cfg)

        def layer(x, xs):
            lp, window_active = xs
            lp = compat.optimization_barrier(lp)  # see forward_hidden
            o, (k, v) = _attn_block(x, lp, cfg, window_active=window_active, kv_out=True,
                                    mesh=mesh, rules=rules, attn_tp=attn_tp,
                                    seq_spec=carry_spec)
            k = jax.lax.with_sharding_constraint(k, rules.shard(mesh, *kv_spec))
            v = jax.lax.with_sharding_constraint(v, rules.shard(mesh, *kv_spec))
            x = x + o
            x = jax.lax.with_sharding_constraint(x, rules.shard(mesh, *carry_spec))
            y = rms_norm(x, lp["ffn_norm"], one_plus=cfg.rms_one_plus)
            if cfg.is_moe:
                f, _ = ctx.moe_layer(y, lp["router"], lp["w_gate"], lp["w_up"], lp["w_down"])
            else:
                f = _dense_ffn(y, lp["w_gate"], lp["w_up"], lp["w_down"], cfg,
                               mesh=mesh, rules=rules)
            x = x + f
            x = jax.lax.with_sharding_constraint(x, rules.shard(mesh, *carry_spec))
            return x, (k, v)

        body = layer
        if cfg.remat:
            body = jax.checkpoint(
                layer, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False
            )
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows))
        x = rms_norm(x, params["final_norm"], one_plus=cfg.rms_one_plus)
        logits = jnp.einsum(
            "bd,dv->bv", x[:, -1], params["unembed"], preferred_element_type=jnp.float32
        )
        return softcap(logits, cfg.final_softcap), {"k": ks, "v": vs}

    return prefill
