from repro.models import attention, common, embedding, gnn, moe, recsys, transformer

__all__ = ["attention", "common", "embedding", "gnn", "moe", "recsys", "transformer"]
