"""Thread-safe span tracing with a bounded in-memory buffer.

The cluster job's whole argument is operational — "sequential scanning is
viable" means knowing where an 11-hour job spends its time — so every hot
layer (scan job, scheduler, prefetch pipeline, checkpoint writer, serve
dispatch) emits *spans*: named intervals on the shared monotonic clock,
tagged with the emitting thread, a category, and ``key=value`` attributes.
Overlap and nesting need no parent bookkeeping: spans carry wall-clock
extent + thread id, which is exactly the Chrome ``trace_event`` model
(`repro.obs.export` renders the buffer for ``chrome://tracing``/Perfetto —
same-thread spans nest by time containment, cross-thread work lines up on
the common timebase).

Design constraints, both load-bearing:

* **disabled ⇒ near-zero cost** — :meth:`Tracer.span` is guard-checked:
  one attribute read, then a shared no-op singleton. No locks, no
  allocation, no clock read. Instrumentation can therefore live
  permanently in per-segment loops and scheduler internals.
* **enabled ⇒ lock-free fast path** — events land in a
  ``collections.deque(maxlen=...)`` whose ``append`` is atomic under the
  GIL, so concurrent shard workers, the prefetch producer, and the
  checkpoint writer thread all record without serializing on a tracer
  lock. The bound makes the buffer safe to leave on for long jobs: old
  events fall off the front.

Tracing observes; it never participates. No instrumented code path reads
tracer state to make a decision, so a traced run executes the exact
instruction stream of an untraced one — the chaos suite pins run-file
byte-identity with tracing ON.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Mapping

__all__ = ["SpanEvent", "Tracer", "NULL_SPAN"]


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One finished span (``ph="X"``) or instant marker (``ph="i"``).

    Timestamps are seconds on the tracer's clock (monotonic by default);
    ``dur`` is 0.0 for instants. ``attrs`` is the span's final attribute
    mapping — an exception inside a ``with tracer.span(...)`` block lands
    here as ``error=<type name>`` before propagating.
    """

    name: str
    cat: str
    ph: str  # "X" complete span | "i" instant
    ts: float  # start, seconds (tracer clock)
    dur: float  # seconds ("X" only)
    tid: int  # emitting thread id
    attrs: Mapping[str, Any]
    tname: str = ""  # emitting thread's name (trace viewer lane label)


class _NullSpan:
    """The shared disabled-tracer span: every operation is a no-op."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class _Span:
    """A live span: context manager that records itself on exit.

    The span is recorded even when the body raises (with the exception
    type under ``attrs["error"]``) and the exception propagates — so a
    fold that dies mid-segment still leaves its span in the timeline,
    and enclosing spans close in LIFO order with correct extents.
    """

    __slots__ = ("_tracer", "name", "cat", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.attrs = attrs
        self._t0 = 0.0

    def set(self, **attrs: Any) -> "_Span":
        """Attach/overwrite attributes before the span closes."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        t1 = self._tracer._clock()
        thread = threading.current_thread()
        self._tracer._events.append(
            SpanEvent(
                name=self.name,
                cat=self.cat,
                ph="X",
                ts=self._t0,
                dur=t1 - self._t0,
                tid=thread.ident or 0,
                attrs=self.attrs,
                tname=thread.name,
            )
        )
        return False  # never swallow


class Tracer:
    """Span/instant recorder over a bounded thread-safe buffer.

    ``enabled=False`` (the module default in `repro.obs`) short-circuits
    every entry point before any clock read or allocation. ``max_events``
    bounds memory for long-lived jobs — the deque drops the *oldest*
    events, so the tail of a run (usually where the trouble is) survives.
    ``clock`` is injectable for deterministic trigger tests; production
    uses the monotonic clock, immune to wall-clock steps.
    """

    def __init__(
        self,
        *,
        enabled: bool = True,
        max_events: int = 200_000,
        clock=time.monotonic,
    ):
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {max_events}")
        self.enabled = enabled
        self.max_events = max_events
        self._clock = clock
        self._events: collections.deque[SpanEvent] = collections.deque(
            maxlen=max_events
        )
        # stable small ints for thread ids at export time (get_ident values
        # are reused by the OS; we only need a per-trace label)
        self._t_origin = clock()

    # -- recording (the fast paths) -----------------------------------------

    def span(self, name: str, cat: str = "", **attrs: Any):
        """Context manager timing its body; records on exit (even on error)."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, cat, attrs)

    def instant(self, name: str, cat: str = "", **attrs: Any) -> None:
        """A zero-duration marker (fault fired, retry enqueued, ...)."""
        if not self.enabled:
            return
        thread = threading.current_thread()
        self._events.append(
            SpanEvent(
                name=name,
                cat=cat,
                ph="i",
                ts=self._clock(),
                dur=0.0,
                tid=thread.ident or 0,
                attrs=attrs,
                tname=thread.name,
            )
        )

    def record(
        self,
        name: str,
        t0: float,
        t1: float,
        cat: str = "",
        *,
        tid: int | None = None,
        **attrs: Any,
    ) -> None:
        """Record a span with an explicit ``[t0, t1]`` window on the tracer
        clock — for intervals whose start predates the recording site (a
        serve request's enqueue→reply life, measured at reply time)."""
        if not self.enabled:
            return
        thread = threading.current_thread()
        self._events.append(
            SpanEvent(
                name=name,
                cat=cat,
                ph="X",
                ts=t0,
                dur=max(0.0, t1 - t0),
                tid=(thread.ident or 0) if tid is None else tid,
                attrs=attrs,
                tname=thread.name if tid is None else "",
            )
        )

    # -- readout -------------------------------------------------------------

    def events(self) -> list[SpanEvent]:
        """Snapshot of the buffer, oldest first (safe during recording)."""
        return list(self._events)

    def spans(self, name: str | None = None, cat: str | None = None) -> list[SpanEvent]:
        """Complete spans, optionally filtered by exact name and/or category."""
        return [
            e
            for e in self._events
            if e.ph == "X"
            and (name is None or e.name == name)
            and (cat is None or e.cat == cat)
        ]

    def instants(self, name: str | None = None) -> list[SpanEvent]:
        return [
            e for e in self._events if e.ph == "i" and (name is None or e.name == name)
        ]

    def clear(self) -> None:
        self._events.clear()

    def __len__(self) -> int:
        return len(self._events)
