"""Counters, gauges, and fixed-bucket latency histograms.

The numeric half of the observability layer: where `repro.obs.trace` keeps
a *timeline*, this module keeps *aggregates* — monotone counters
(attempts, steals, requests), last-value gauges (queue depths, prefetch
buffer occupancy), and fixed-bucket histograms with quantile readout
(p50/p95/p99 of serve queue-wait, batch size, checkpoint-save duration).
The serve layer's histograms are the live latency/QPS surface the
ROADMAP's SLO-driven adaptive microbatching will consume.

Histograms are *fixed-bucket* on purpose: observation cost is a bisect +
one increment under a per-instrument lock (no reservoir, no sort at
readout), memory is constant however many observations arrive, and two
histograms with the same bounds merge by adding counts — the same
mergeable-combiner discipline as the paper's top-k states. Quantiles are
read out by linear interpolation inside the bucket that crosses the
cumulative rank, so p50/p95/p99 are deterministic functions of the counts.

Instruments are created through a :class:`Metrics` registry
(get-or-create by name, thread-safe); `repro.obs` holds the process
default. All mutation is lock-protected per instrument — cross-thread
increments never lose updates (test-pinned) — and locks are held for a
few arithmetic ops only, nowhere near any fold or dispatch critical path.
"""

from __future__ import annotations

import bisect
import threading
from typing import Sequence

__all__ = ["Counter", "Gauge", "Histogram", "Metrics", "latency_buckets"]


def latency_buckets(
    lo: float = 1e-5, hi: float = 60.0, factor: float = 2.0
) -> tuple[float, ...]:
    """Geometric bucket bounds for duration-in-seconds histograms.

    Default spans 10µs → 60s at 2× resolution (~23 buckets) — wide enough
    for everything from a checkpoint rename to a straggling shard, cheap
    enough to keep per instrument.
    """
    bounds = []
    b = lo
    while b < hi:
        bounds.append(b)
        b *= factor
    bounds.append(hi)
    return tuple(bounds)


class Counter:
    """A monotone counter. ``inc`` is lock-protected: concurrent workers
    never lose increments (the ``+=`` read-modify-write is not atomic)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def describe(self) -> int:
        return self._value


class Gauge:
    """A last-value-wins instrument (queue depth, buffer occupancy).

    Tracks the max ever set alongside the current value — for bounded
    queues, "how full did it get" is the number that matters after the
    fact.
    """

    __slots__ = ("name", "_lock", "_value", "_max")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0
        self._max = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value
            if value > self._max:
                self._max = value

    @property
    def value(self) -> float:
        return self._value

    @property
    def max(self) -> float:
        return self._max

    def describe(self) -> dict:
        return {"value": self._value, "max": self._max}


class Histogram:
    """Fixed-bucket histogram with interpolated quantile readout.

    ``bounds`` are ascending bucket upper edges; observations above the
    last edge land in a +inf overflow bucket. ``observe`` is a bisect +
    increment under the instrument lock; ``quantile`` interpolates
    linearly within the crossing bucket (clamped to the observed min/max,
    so a one-element histogram reads back that element exactly).
    """

    __slots__ = ("name", "bounds", "_lock", "_counts", "_n", "_sum", "_min", "_max")

    def __init__(self, name: str, bounds: Sequence[float] | None = None):
        self.name = name
        self.bounds = tuple(bounds) if bounds is not None else latency_buckets()
        if not self.bounds or list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(f"histogram bounds must be ascending+unique: {bounds}")
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.bounds) + 1)  # +1 overflow bucket
        self._n = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self._counts[i] += 1
            self._n += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._n if self._n else 0.0

    def quantile(self, q: float) -> float:
        """Interpolated quantile in [0, 1]; 0.0 on an empty histogram."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            if self._n == 0:
                return 0.0
            rank = q * self._n
            cum = 0
            for i, c in enumerate(self._counts):
                if c == 0:
                    continue
                if cum + c >= rank:
                    lo = self.bounds[i - 1] if i > 0 else min(self._min, self.bounds[0])
                    hi = self.bounds[i] if i < len(self.bounds) else self._max
                    frac = (rank - cum) / c
                    val = lo + (hi - lo) * max(0.0, min(1.0, frac))
                    # never report outside the observed range
                    return max(self._min, min(self._max, val))
                cum += c
            return self._max  # pragma: no cover — rank <= n always crosses

    def summary(self) -> dict:
        """The rollup exported into reports: count/mean/min/max + p50/95/99."""
        if self._n == 0:
            return {"count": 0}
        return {
            "count": self._n,
            "mean": self.mean,
            "min": self._min,
            "max": self._max,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }

    describe = summary


class Metrics:
    """Get-or-create registry of named instruments (one per process area).

    A name is permanently bound to its first-created instrument kind;
    asking for the same name as a different kind is a bug and raises.
    ``summary()`` renders everything into plain dicts for ``report.json``
    (the ``job.obs.metrics`` block) and the JSONL exporter.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, kind, factory):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = factory()
            elif not isinstance(inst, kind):
                raise TypeError(
                    f"metric {name!r} is a {type(inst).__name__}, not a {kind.__name__}"
                )
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str, bounds: Sequence[float] | None = None) -> Histogram:
        return self._get(name, Histogram, lambda: Histogram(name, bounds))

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._instruments)

    def summary(self) -> dict:
        """Plain-dict rollup of every instrument, grouped by kind."""
        with self._lock:
            items = list(self._instruments.items())
        out: dict[str, dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, inst in sorted(items):
            if isinstance(inst, Counter):
                out["counters"][name] = inst.describe()
            elif isinstance(inst, Gauge):
                out["gauges"][name] = inst.describe()
            else:
                out["histograms"][name] = inst.summary()
        return out
