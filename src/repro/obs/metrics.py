"""Counters, gauges, and fixed-bucket latency histograms.

The numeric half of the observability layer: where `repro.obs.trace` keeps
a *timeline*, this module keeps *aggregates* — monotone counters
(attempts, steals, requests), last-value gauges (queue depths, prefetch
buffer occupancy), and fixed-bucket histograms with quantile readout
(p50/p95/p99 of serve queue-wait, batch size, checkpoint-save duration).
The serve layer's histograms are the live latency/QPS surface the
SLO-driven adaptive microbatch policy (`repro.serve.policy`) consumes;
for that consumer histograms also offer a *windowed-decay* mode (a ring
of fixed-time sub-windows) so the policy reads recent quantiles rather
than the run-lifetime distribution.

Histograms are *fixed-bucket* on purpose: observation cost is a bisect +
one increment under a per-instrument lock (no reservoir, no sort at
readout), memory is constant however many observations arrive, and two
histograms with the same bounds merge by adding counts — the same
mergeable-combiner discipline as the paper's top-k states. Quantiles are
read out by linear interpolation inside the bucket that crosses the
cumulative rank, so p50/p95/p99 are deterministic functions of the counts.

Instruments are created through a :class:`Metrics` registry
(get-or-create by name, thread-safe); `repro.obs` holds the process
default. All mutation is lock-protected per instrument — cross-thread
increments never lose updates (test-pinned) — and locks are held for a
few arithmetic ops only, nowhere near any fold or dispatch critical path.
"""

from __future__ import annotations

import bisect
import threading
import time
from typing import Callable, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "Metrics", "latency_buckets"]


def latency_buckets(
    lo: float = 1e-5, hi: float = 60.0, factor: float = 2.0
) -> tuple[float, ...]:
    """Geometric bucket bounds for duration-in-seconds histograms.

    Default spans 10µs → 60s at 2× resolution (~23 buckets) — wide enough
    for everything from a checkpoint rename to a straggling shard, cheap
    enough to keep per instrument.
    """
    bounds = []
    b = lo
    while b < hi:
        bounds.append(b)
        b *= factor
    bounds.append(hi)
    return tuple(bounds)


class Counter:
    """A monotone counter. ``inc`` is lock-protected: concurrent workers
    never lose increments (the ``+=`` read-modify-write is not atomic)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def describe(self) -> int:
        return self._value


class Gauge:
    """A last-value-wins instrument (queue depth, buffer occupancy).

    Tracks the max ever set alongside the current value — for bounded
    queues, "how full did it get" is the number that matters after the
    fact.
    """

    __slots__ = ("name", "_lock", "_value", "_max")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0
        self._max = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value
            if value > self._max:
                self._max = value

    @property
    def value(self) -> float:
        return self._value

    @property
    def max(self) -> float:
        return self._max

    def describe(self) -> dict:
        return {"value": self._value, "max": self._max}


class _Window:
    """One sub-window of a windowed histogram: a full bucket-count vector
    plus its own n/sum/min/max so aggregates merge exactly."""

    __slots__ = ("counts", "n", "sum", "min", "max")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets
        self.clear()

    def clear(self) -> None:
        for i in range(len(self.counts)):
            self.counts[i] = 0
        self.n = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")


class Histogram:
    """Fixed-bucket histogram with interpolated quantile readout.

    ``bounds`` are ascending bucket upper edges; observations above the
    last edge land in a +inf overflow bucket. ``observe`` is a bisect +
    increment under the instrument lock; ``quantile`` interpolates
    linearly within the crossing bucket (clamped to the observed min/max,
    so a one-element histogram reads back that element exactly).

    **Windowed-decay mode** (``window_s`` set): instead of one cumulative
    count vector, the histogram keeps a ring of ``n_windows`` fixed-time
    sub-windows spanning ``window_s`` seconds in total. Observations land
    in the current sub-window; as the injected ``clock`` advances past a
    sub-window boundary the ring rotates, dropping the oldest sub-window —
    so every readout (count/quantile/summary) reflects only roughly the
    last ``window_s`` seconds. This is the surface the serve layer's
    adaptive policy reads: *recent* p99, not the run-lifetime distribution.
    The default (``window_s=None``) stays cumulative. A clock that reads
    earlier than the current sub-window start (injected test clocks may be
    stamped backwards) never rotates — observations just land in the
    current sub-window.
    """

    __slots__ = (
        "name", "bounds", "_lock", "_counts", "_n", "_sum", "_min", "_max",
        "window_s", "_clock", "_wins", "_win_idx", "_win_start", "_sub",
    )

    def __init__(
        self,
        name: str,
        bounds: Sequence[float] | None = None,
        *,
        window_s: float | None = None,
        n_windows: int = 8,
        clock: Callable[[], float] | None = None,
    ):
        self.name = name
        self.bounds = tuple(bounds) if bounds is not None else latency_buckets()
        if not self.bounds or list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(f"histogram bounds must be ascending+unique: {bounds}")
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.bounds) + 1)  # +1 overflow bucket
        self._n = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self.window_s = window_s
        if window_s is not None:
            if window_s <= 0:
                raise ValueError(f"window_s must be positive, got {window_s}")
            if not isinstance(n_windows, int) or n_windows < 1:
                raise ValueError(f"n_windows must be a positive int, got {n_windows}")
            self._clock = clock if clock is not None else time.monotonic
            self._sub = window_s / n_windows
            self._wins = [_Window(len(self.bounds) + 1) for _ in range(n_windows)]
            self._win_idx = 0
            self._win_start = self._clock()
        else:
            self._clock = None
            self._wins = None

    def _rotate(self) -> None:
        """Advance the ring to the clock's current sub-window (lock held).
        A gap longer than the whole window clears every sub-window."""
        now = self._clock()
        if now < self._win_start + self._sub:
            return  # still inside the current sub-window (or clock rewound)
        k = int((now - self._win_start) // self._sub)
        if k >= len(self._wins):
            for w in self._wins:
                w.clear()
        else:
            for _ in range(k):
                self._win_idx = (self._win_idx + 1) % len(self._wins)
                self._wins[self._win_idx].clear()
        self._win_start += k * self._sub

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            if self._wins is not None:
                self._rotate()
                w = self._wins[self._win_idx]
                w.counts[i] += 1
                w.n += 1
                w.sum += value
                if value < w.min:
                    w.min = value
                if value > w.max:
                    w.max = value
                return
            self._counts[i] += 1
            self._n += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    def _agg(self) -> tuple[list[int], int, float, float, float]:
        """(counts, n, sum, min, max) over the live data (lock held):
        the cumulative fields, or the merged ring in windowed mode."""
        if self._wins is None:
            return self._counts, self._n, self._sum, self._min, self._max
        self._rotate()
        counts = [0] * (len(self.bounds) + 1)
        n, s = 0, 0.0
        mn, mx = float("inf"), float("-inf")
        for w in self._wins:
            if not w.n:
                continue
            for i, c in enumerate(w.counts):
                counts[i] += c
            n += w.n
            s += w.sum
            mn = min(mn, w.min)
            mx = max(mx, w.max)
        return counts, n, s, mn, mx

    @property
    def count(self) -> int:
        with self._lock:
            return self._agg()[1]

    @property
    def sum(self) -> float:
        with self._lock:
            return self._agg()[2]

    @property
    def mean(self) -> float:
        with self._lock:
            _, n, s, _, _ = self._agg()
        return s / n if n else 0.0

    def _quantile_from(
        self, counts: Sequence[int], n: int, mn: float, mx: float, q: float
    ) -> float:
        rank = q * n
        cum = 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else min(mn, self.bounds[0])
                hi = self.bounds[i] if i < len(self.bounds) else mx
                frac = (rank - cum) / c
                val = lo + (hi - lo) * max(0.0, min(1.0, frac))
                # never report outside the observed range
                return max(mn, min(mx, val))
            cum += c
        return mx  # pragma: no cover — rank <= n always crosses

    def quantile(self, q: float) -> float:
        """Interpolated quantile in [0, 1]; 0.0 on an empty histogram."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            counts, n, _, mn, mx = self._agg()
            if n == 0:
                return 0.0
            return self._quantile_from(counts, n, mn, mx, q)

    def summary(self) -> dict:
        """The rollup exported into reports: count/mean/min/max + p50/95/99."""
        with self._lock:
            counts, n, s, mn, mx = self._agg()
            if n == 0:
                out = {"count": 0}
                if self.window_s is not None:
                    out["window_s"] = self.window_s
                return out
            out = {
                "count": n,
                "mean": s / n,
                "min": mn,
                "max": mx,
                "p50": self._quantile_from(counts, n, mn, mx, 0.50),
                "p95": self._quantile_from(counts, n, mn, mx, 0.95),
                "p99": self._quantile_from(counts, n, mn, mx, 0.99),
            }
        if self.window_s is not None:
            out["window_s"] = self.window_s
        return out

    describe = summary


class Metrics:
    """Get-or-create registry of named instruments (one per process area).

    A name is permanently bound to its first-created instrument kind;
    asking for the same name as a different kind is a bug and raises.
    ``summary()`` renders everything into plain dicts for ``report.json``
    (the ``job.obs.metrics`` block) and the JSONL exporter.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, kind, factory):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = factory()
            elif not isinstance(inst, kind):
                raise TypeError(
                    f"metric {name!r} is a {type(inst).__name__}, not a {kind.__name__}"
                )
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name))

    def histogram(
        self,
        name: str,
        bounds: Sequence[float] | None = None,
        *,
        window_s: float | None = None,
        n_windows: int = 8,
        clock: Callable[[], float] | None = None,
    ) -> Histogram:
        """Get-or-create; creation kwargs (bounds, windowing, clock) apply
        on first creation only — later lookups return the existing
        instrument unchanged (same contract as ``bounds`` always had)."""
        return self._get(
            name,
            Histogram,
            lambda: Histogram(
                name, bounds, window_s=window_s, n_windows=n_windows, clock=clock
            ),
        )

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._instruments)

    def summary(self) -> dict:
        """Plain-dict rollup of every instrument, grouped by kind."""
        with self._lock:
            items = list(self._instruments.items())
        out: dict[str, dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, inst in sorted(items):
            if isinstance(inst, Counter):
                out["counters"][name] = inst.describe()
            elif isinstance(inst, Gauge):
                out["gauges"][name] = inst.describe()
            else:
                out["histograms"][name] = inst.summary()
        return out
