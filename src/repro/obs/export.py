"""Trace/metrics exporters: Chrome ``trace_event`` JSON, JSONL, text tree.

Three renderings of one span buffer, for three audiences:

* :func:`to_chrome_trace` / :func:`write_chrome_trace` — the Chrome
  ``trace_event`` "JSON object format": open the file in
  ``chrome://tracing`` or https://ui.perfetto.dev and every shard worker,
  the prefetch producer, the checkpoint writer, and the scheduler appear
  as labelled thread lanes; spans nest by time containment, fault
  injections and scheduler decisions show as instant markers. Timestamps
  are re-based to the earliest event and converted to microseconds (the
  format's unit). The metrics rollup rides along under ``otherData`` so a
  trace file is self-contained.
* :func:`write_jsonl` — one event per line, machine-grep-able: the
  scheduler event log (`sched.*` instants), fault firings (`fault.*`),
  and every span with its raw monotonic timestamps. The format CI
  artifacts and ad-hoc ``jq`` analysis consume.
* :func:`summary_tree` — a plain-text time-per-phase rollup, grouped by
  the ``shard`` attribute then span name (count, total, mean): the
  at-a-glance "where did this job spend its time" answer, printed by the
  experiment CLI after a traced run.
"""

from __future__ import annotations

import json
from typing import Iterable, Sequence

from repro.obs.metrics import Metrics
from repro.obs.trace import SpanEvent, Tracer

__all__ = [
    "to_chrome_trace",
    "write_chrome_trace",
    "write_jsonl",
    "summary_tree",
]

_PID = 0  # single-process; multi-host traces would key pid by host rank


def _events_of(source: Tracer | Iterable[SpanEvent]) -> list[SpanEvent]:
    return source.events() if isinstance(source, Tracer) else list(source)


def to_chrome_trace(
    source: Tracer | Iterable[SpanEvent],
    *,
    metrics: Metrics | None = None,
) -> dict:
    """Render events as a Chrome ``trace_event`` JSON object (not yet a file).

    Complete spans become ``ph="X"`` events, instants ``ph="i"`` with
    thread scope; per-thread ``thread_name`` metadata events label the
    lanes. All timestamps shift so the trace starts at t=0 (viewers dislike
    raw monotonic offsets) and scale to integer-friendly microseconds.
    """
    events = _events_of(source)
    t0 = min((e.ts for e in events), default=0.0)
    out = []
    seen_threads: dict[int, str] = {}
    for e in events:
        if e.tid not in seen_threads and e.tname:
            seen_threads[e.tid] = e.tname
        rec = {
            "name": e.name,
            "cat": e.cat or "default",
            "ph": e.ph,
            "ts": (e.ts - t0) * 1e6,
            "pid": _PID,
            "tid": e.tid,
            "args": dict(e.attrs),
        }
        if e.ph == "X":
            rec["dur"] = e.dur * 1e6
        else:
            rec["s"] = "t"  # instant scoped to its thread
        out.append(rec)
    for tid, tname in sorted(seen_threads.items()):
        out.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": _PID,
                "tid": tid,
                "args": {"name": tname},
            }
        )
    payload: dict = {"traceEvents": out, "displayTimeUnit": "ms"}
    if metrics is not None:
        payload["otherData"] = {"metrics": metrics.summary()}
    return payload


def write_chrome_trace(
    path: str,
    source: Tracer | Iterable[SpanEvent],
    *,
    metrics: Metrics | None = None,
) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(source, metrics=metrics), f)
        f.write("\n")
    return path


def write_jsonl(path: str, source: Tracer | Iterable[SpanEvent]) -> str:
    """One JSON object per event, raw tracer-clock timestamps preserved."""
    with open(path, "w") as f:
        for e in _events_of(source):
            json.dump(
                {
                    "name": e.name,
                    "cat": e.cat,
                    "ph": e.ph,
                    "ts": e.ts,
                    "dur": e.dur,
                    "tid": e.tid,
                    "thread": e.tname,
                    "attrs": dict(e.attrs),
                },
                f,
            )
            f.write("\n")
    return path


def _fmt_s(seconds: float) -> str:
    if seconds >= 1.0:
        return f"{seconds:.2f}s"
    if seconds >= 1e-3:
        return f"{seconds * 1e3:.1f}ms"
    return f"{seconds * 1e6:.0f}µs"


def phase_rollup(source: Tracer | Iterable[SpanEvent]) -> dict:
    """``{shard_label: {span_name: {count, total_s, mean_s}}}`` rollup.

    Spans carrying a ``shard`` attribute group under ``shard <i>``;
    everything else (serve dispatch, scheduler internals, experiment
    phases) lands under ``(global)``. This is the dict embedded in
    ``report.json`` under ``job.obs.phases``.
    """
    groups: dict[str, dict[str, list[float]]] = {}
    for e in _events_of(source):
        if e.ph != "X":
            continue
        shard = e.attrs.get("shard")
        label = "(global)" if shard is None else f"shard {shard}"
        groups.setdefault(label, {}).setdefault(e.name, []).append(e.dur)
    out: dict[str, dict] = {}
    for label in sorted(groups, key=lambda s: (s != "(global)", s)):
        out[label] = {
            name: {
                "count": len(durs),
                "total_s": sum(durs),
                "mean_s": sum(durs) / len(durs),
            }
            for name, durs in sorted(groups[label].items())
        }
    return out


def summary_tree(
    source: Tracer | Iterable[SpanEvent],
    *,
    metrics: Metrics | None = None,
) -> str:
    """Human-readable time-per-phase tree (per shard, then per span name)."""
    events = _events_of(source)
    spans = [e for e in events if e.ph == "X"]
    instants = [e for e in events if e.ph == "i"]
    if not events:
        return "trace: no events recorded"
    t_lo = min(e.ts for e in events)
    t_hi = max(e.ts + e.dur for e in events)
    lines = [
        f"trace: {len(spans)} spans, {len(instants)} instants over "
        f"{_fmt_s(t_hi - t_lo)} wall"
    ]
    rollup = phase_rollup(spans)
    for g, (label, names) in enumerate(rollup.items()):
        last_group = g == len(rollup) - 1
        lines.append(f"{'└─' if last_group else '├─'} {label}")
        stem = "   " if last_group else "│  "
        items = list(names.items())
        for i, (name, agg) in enumerate(items):
            tee = "└─" if i == len(items) - 1 else "├─"
            lines.append(
                f"{stem}{tee} {name:<28} ×{agg['count']:<4} "
                f"{_fmt_s(agg['total_s']):>9} total  "
                f"{_fmt_s(agg['mean_s']):>9} mean"
            )
    if instants:
        by_name: dict[str, int] = {}
        for e in instants:
            by_name[e.name] = by_name.get(e.name, 0) + 1
        marks = ", ".join(f"{n}×{c}" for n, c in sorted(by_name.items()))
        lines.append(f"instants: {marks}")
    if metrics is not None:
        hists = metrics.summary()["histograms"]
        for name, s in hists.items():
            if s.get("count"):
                lines.append(
                    f"hist {name}: n={s['count']} p50={s['p50']:.4g} "
                    f"p95={s['p95']:.4g} p99={s['p99']:.4g}"
                )
    return "\n".join(lines)
