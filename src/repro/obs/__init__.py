"""Cluster-wide tracing & metrics — the observability layer.

MIREX's viability argument is operational, so the framework's hot layers
(scan jobs, the shard scheduler, the prefetch pipeline, the checkpoint
writer, serve dispatch) are permanently instrumented against one
process-wide pair of instruments:

* :func:`tracer` — the active :class:`~repro.obs.trace.Tracer` (span
  timelines + instant markers; **disabled by default** and near-zero-cost
  while disabled, so instrumentation lives inside per-segment loops);
* :func:`metrics` — the active :class:`~repro.obs.metrics.Metrics`
  registry (counters / gauges / p50-p95-p99 histograms; always on — an
  observation is a couple of arithmetic ops under a short lock).

Enable tracing by installing an enabled tracer for a scope::

    from repro import obs
    with obs.session() as (tr, met):          # fresh enabled pair
        job = cluster.run_sharded_scan_job(...)
    obs.export.write_chrome_trace("trace.json", tr, metrics=met)

or pass ``--trace-out trace.json`` to ``repro.launch.experiment``, which
wraps the whole lifecycle and writes the Chrome trace, the JSONL event
log, and the ``report.json`` ``job.obs`` rollup.

The globals are plain module state, not contextvars, on purpose: the
instrumented layers hand work to long-lived helper threads (scheduler
workers, the checkpoint writer, the prefetch producer) that must record
into the *same* buffer as the thread that installed it — which contextvar
propagation across threads would silently break.

Tracing observes and never decides: no instrumented code path branches on
tracer state (beyond skipping the recording itself), so traced runs are
byte-identical to untraced ones — asserted by the chaos suite, which runs
with tracing ON.
"""

from __future__ import annotations

import contextlib
import platform
import sys

from repro.obs import export
from repro.obs.metrics import Counter, Gauge, Histogram, Metrics, latency_buckets
from repro.obs.trace import NULL_SPAN, SpanEvent, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Metrics",
    "NULL_SPAN",
    "SpanEvent",
    "Tracer",
    "export",
    "install",
    "latency_buckets",
    "metrics",
    "provenance",
    "session",
    "tracer",
]

# the process defaults: tracing off (guard-checked no-op), metrics on
_TRACER = Tracer(enabled=False)
_METRICS = Metrics()


def tracer() -> Tracer:
    """The active tracer (instrumented layers call this per operation, so
    an `install` mid-process takes effect everywhere immediately)."""
    return _TRACER


def metrics() -> Metrics:
    """The active metrics registry."""
    return _METRICS


def install(
    tracer: Tracer | None = None, metrics: Metrics | None = None
) -> tuple[Tracer, Metrics]:
    """Swap the active instruments; returns the previous pair (for restore).

    ``None`` leaves that instrument unchanged. Prefer :func:`session` in
    tests — it restores on exit.
    """
    global _TRACER, _METRICS
    prev = (_TRACER, _METRICS)
    if tracer is not None:
        _TRACER = tracer
    if metrics is not None:
        _METRICS = metrics
    return prev


@contextlib.contextmanager
def session(tracer: Tracer | None = None, metrics: Metrics | None = None):
    """Scoped observability: install a (default: fresh, enabled) tracer and
    a fresh metrics registry, restore the previous pair on exit. Yields
    ``(tracer, metrics)``."""
    tr = Tracer() if tracer is None else tracer
    met = Metrics() if metrics is None else metrics
    prev = install(tr, met)
    try:
        yield tr, met
    finally:
        install(*prev)


def provenance() -> dict:
    """Where a measurement was taken: host, platform, backend, versions.

    Stamped into every ``BENCH_*.json`` so perf trajectories recorded on
    different machines/backends are comparable (or visibly not).
    """
    import jax  # deferred: obs must import without initializing backends

    return {
        "host": platform.node(),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "jax_version": jax.__version__,
    }
