"""JAX version-compatibility shims.

The repo targets the current JAX API but must run on the container's pinned
release. Policy: call sites use the *new* spelling; this module backfills it
when the installed JAX predates it.

``set_mesh(mesh)`` — context manager activating ``mesh`` as the ambient mesh.
Resolution order: native ``jax.set_mesh`` → ``jax.sharding.use_mesh`` →
``Mesh`` itself as a context manager (the legacy global-mesh context, which
is what pjit-era JAX used for exactly this purpose). Importing this module
also installs the fallback *as* ``jax.set_mesh`` so existing
``jax.set_mesh(...)`` call sites (tests, examples) work unmodified.
"""

from __future__ import annotations

import jax

_NATIVE_SET_MESH = getattr(jax, "set_mesh", None)


def set_mesh(mesh):
    """``jax.set_mesh`` with fallbacks for older JAX releases."""
    if _NATIVE_SET_MESH is not None:
        return _NATIVE_SET_MESH(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager


if _NATIVE_SET_MESH is None:
    jax.set_mesh = set_mesh


_NATIVE_AXIS_SIZE = getattr(jax.lax, "axis_size", None)


def axis_size(axis_name):
    """``jax.lax.axis_size`` with a fallback for older JAX.

    ``psum`` of a Python literal constant-folds, so the fallback returns the
    same concrete int as the native call (usable in Python control flow).
    """
    if _NATIVE_AXIS_SIZE is not None:
        return _NATIVE_AXIS_SIZE(axis_name)
    return jax.lax.psum(1, axis_name)


if _NATIVE_AXIS_SIZE is None:
    jax.lax.axis_size = axis_size


def _barrier_differentiable() -> bool:
    try:  # abstract trace only — no compile, no device work
        jax.jvp(jax.lax.optimization_barrier, (0.0,), (0.0,))
        return True
    except Exception:
        return False


if _barrier_differentiable():
    optimization_barrier = jax.lax.optimization_barrier
else:
    # Older JAX has no differentiation rule for optimization_barrier; the
    # barrier is identity-valued, so its JVP is the identity on tangents.
    @jax.custom_jvp
    def optimization_barrier(x):
        """``jax.lax.optimization_barrier`` usable under autodiff."""
        return jax.lax.optimization_barrier(x)

    @optimization_barrier.defjvp
    def _optimization_barrier_jvp(primals, tangents):
        (x,), (t,) = primals, tangents
        return jax.lax.optimization_barrier(x), t
