"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run entry point must set XLA_FLAGS first).
"""

from __future__ import annotations

import jax

from repro.compat import set_mesh  # noqa: F401  (re-export + installs jax.set_mesh shim)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    axis. "pod" composes with "data" for all data-parallel math (the DCN-side
    axis); "model" stays intra-pod (ICI-side) for TP/EP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Degenerate mesh for CPU smoke tests (same axis names)."""
    return jax.make_mesh((data, model), ("data", "model"))
