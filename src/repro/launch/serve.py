"""Serving driver: batched MIREX search requests or LM decode.

    PYTHONPATH=src python -m repro.launch.serve --mode search --n-queries 256
    PYTHONPATH=src python -m repro.launch.serve --mode decode --tokens 32

Search mode runs the paper's system as an online service: requests are
batched into query blocks (the amortization lever of claim C1 — bigger
batches, cheaper per query) against a resident corpus. Decode mode runs
autoregressive generation with the split-KV serve_step. Reduced configs so
it runs on the CPU host; the same code paths are what the dry-run lowers at
production scale.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import anchors, scan, scoring
from repro.data import synthetic
from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as tfm


def serve_search(n_queries: int, n_docs: int = 8192, batches: int = 4):
    cfg = reduced_config("mirex")
    corpus = synthetic.make_corpus(n_docs=n_docs, vocab=cfg.vocab, max_len=cfg.max_doc_len, seed=0)
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=cfg.vocab, chunk_size=512
    )
    d_tokens, d_len = jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths)
    scorer = scoring.get_scorer(cfg.scorer)

    @jax.jit
    def handle(q):
        return scan.search_local(
            q, (d_tokens, d_len), scorer, k=cfg.k, chunk_size=cfg.chunk_size, stats=stats
        )

    for b in range(batches):
        q = jnp.asarray(synthetic.make_queries(corpus, n_queries=n_queries, seed=10 + b))
        t0 = time.perf_counter()
        state = jax.block_until_ready(handle(q))
        dt = time.perf_counter() - t0
        print(f"batch {b}: {n_queries} queries in {dt*1e3:.1f} ms "
              f"({dt/n_queries*1e6:.0f} µs/query), top-1 of q0 = doc {int(state.ids[0,0])}")


def serve_decode(n_tokens: int, arch: str = "gemma2-2b", batch: int = 4):
    cfg = reduced_config(arch)
    mesh = make_test_mesh(1, 1)
    rules = rules_for_mesh(mesh)
    params = tfm.init_params(cfg, jax.random.key(0))
    with jax.set_mesh(mesh):
        ctx = tfm.make_context(cfg, mesh, rules, tokens_per_shard=batch)
        step = tfm.make_serve_step(ctx, batch=batch)
        cache = tfm.init_cache(cfg, batch, n_tokens + 8)
        tok = jnp.ones((batch,), jnp.int32)
        t0 = time.perf_counter()
        outs = []
        for t in range(n_tokens):
            logits, cache = step(params, cache, tok, jnp.asarray(t))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append(int(tok[0]))
        dt = time.perf_counter() - t0
    print(f"decoded {n_tokens} tokens × {batch} sequences in {dt:.2f}s "
          f"({dt/n_tokens*1e3:.1f} ms/token); seq0: {outs}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("search", "decode"), default="search")
    ap.add_argument("--n-queries", type=int, default=256)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--arch", default="gemma2-2b")
    args = ap.parse_args()
    if args.mode == "search":
        serve_search(args.n_queries)
    else:
        serve_decode(args.tokens, args.arch)


if __name__ == "__main__":
    main()
