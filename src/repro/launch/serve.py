"""Serving driver: thin CLI over the ``repro.serve`` subsystem (or LM decode).

    PYTHONPATH=src python -m repro.launch.serve --mode search --n-queries 256
    PYTHONPATH=src python -m repro.launch.serve --mode search --slo-p99-ms 50
    PYTHONPATH=src python -m repro.launch.serve --mode decode --tokens 32

Search mode runs the paper's system as an online service: queries are
admitted to the :class:`repro.serve.RetrievalService`, microbatched into
query blocks (the amortization lever of claim C1 — bigger blocks, cheaper
per query) and scanned against a resident corpus; per-batch latency is
printed and a batch-size/latency sweep is written to ``BENCH_serve.json``.
Decode mode runs autoregressive generation with the split-KV serve_step.
Reduced configs so it runs on the CPU host; the same code paths are what
the dry-run lowers at production scale.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.core import anchors
from repro.data import synthetic
from repro.obs import Metrics
from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_test_mesh, set_mesh
from repro.models import transformer as tfm
from repro.serve import (
    AdaptiveBatchPolicy,
    AdmissionController,
    LexicalSession,
    RetrievalService,
)
from repro.serve.bench import sweep_batch_sizes, write_bench_json


def serve_search(
    n_queries: int,
    n_docs: int = 8192,
    batches: int = 4,
    *,
    max_batch: int | None = None,
    max_delay_ms: float = 5.0,
    scorer: str | None = None,
    sweep_sizes: tuple[int, ...] = (32, 128, 512),
    bench_out: str = "BENCH_serve.json",
    slo_p99_ms: float | None = None,
    queue_limit: int = 256,
):
    cfg = reduced_config("mirex")
    corpus = synthetic.make_corpus(
        n_docs=n_docs, vocab=cfg.vocab, max_len=cfg.max_doc_len, seed=0
    )
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths),
        vocab=cfg.vocab, chunk_size=512,
    )
    session = LexicalSession(
        corpus.tokens,
        corpus.lengths,
        scorer or cfg.scorer,
        k=cfg.k,
        chunk_size=cfg.chunk_size,
        stats=stats,
    )
    registry = Metrics()  # this service's own histograms (shutdown summary)
    policy = admission = None
    if slo_p99_ms is not None:
        # closed-loop serving: the adaptive policy re-picks the microbatch
        # triggers against the p99 SLO, and admission bounds the queue
        policy = AdaptiveBatchPolicy(slo_p99_s=slo_p99_ms * 1e-3)
        admission = AdmissionController(queue_limit=queue_limit, on_full="shed")
    service = RetrievalService(
        {"lexical": session},
        max_batch=max_batch or n_queries,
        max_delay=max_delay_ms * 1e-3,
        registry=registry,
        admission=admission,
        policy=policy,
    )

    slo_note = f", slo p99 {slo_p99_ms:.0f}ms" if slo_p99_ms is not None else ""
    print(f"== streaming {batches} request waves of {n_queries} queries "
          f"(corpus: {session.n_docs} docs, scorer {session.scorer.name}, "
          f"k={session.k}{slo_note}) ==")
    n_shed = 0
    for b in range(batches):
        queries = synthetic.make_queries(corpus, n_queries=n_queries, seed=10 + b)
        n_seen = len(service.metrics)
        rids = []
        for q in queries:
            outcome = service.try_submit(q, "lexical")
            if outcome.admitted:
                rids.append(outcome.rid)
            else:
                n_shed += 1
        results = service.poll()
        results.update(service.drain())  # deadline not yet due -> flush the tail
        assert len(results) == len(rids)
        for blk, rec in enumerate(service.metrics[n_seen:]):
            print(
                f"wave {b} block {blk}: {rec.n_real} queries (padded {rec.n_padded}, "
                f"trigger={rec.trigger}) in {rec.latency_s*1e3:.1f} ms "
                f"({rec.us_per_query:.0f} µs/query)"
            )
        print(f"wave {b}: top-1 of q0 = doc {int(results[rids[0]].ids[0])}")

    # shutdown rollup: full latency/queue-wait/batch-size distributions,
    # not just the per-block means printed above
    summary = registry.summary()
    n_req = summary["counters"].get("serve.requests", 0)
    n_blk = summary["counters"].get("serve.batches", 0)
    print(f"== service summary: {n_req} requests over {n_blk} blocks ==")
    for name, label, scale, unit in (
        ("serve.queue_wait_s", "queue wait", 1e3, "ms"),
        ("serve.latency_s", "scan latency", 1e3, "ms"),
        ("serve.batch_size", "batch size", 1, ""),
    ):
        h = summary["histograms"].get(name)
        if h and h.get("count"):
            print(
                f"  {label:<12} p50={h['p50'] * scale:8.2f}{unit}  "
                f"p95={h['p95'] * scale:8.2f}{unit}  "
                f"p99={h['p99'] * scale:8.2f}{unit}  "
                f"max={h['max'] * scale:8.2f}{unit}"
            )
    if policy is not None:
        d = policy.describe()
        print(
            f"== adaptive policy: {d['adjustments']} adjustments, "
            f"{d['flips']} flips, {d['damped']} damped, "
            f"{d['oscillation_violations']} oscillation violations; "
            f"effective knobs {d['effective']} =="
        )
        print(
            f"   admitted {summary['counters'].get('serve.admitted', 0)}, "
            f"shed {n_shed} (queue_limit {queue_limit})"
        )

    print(f"== C1 sweep: batch sizes {sweep_sizes} ==")
    payload = sweep_batch_sizes(
        session,
        lambda n, seed: synthetic.make_queries(corpus, n_queries=n, seed=100 + seed),
        sweep_sizes,
    )
    for pt in payload["curve"]:
        print(f"  batch {pt['batch']:5d}: {pt['latency_ms']:8.1f} ms "
              f"({pt['us_per_query']:8.0f} µs/query, {pt['qps']:8.1f} qps)")
    path = write_bench_json(payload, bench_out)
    print(f"amortization {payload.get('amortization_x', 1.0):.2f}x "
          f"({sweep_sizes[0]} -> {sweep_sizes[-1]}); wrote {path}")


def serve_decode(n_tokens: int, arch: str = "gemma2-2b", batch: int = 4):
    cfg = reduced_config(arch)
    mesh = make_test_mesh(1, 1)
    rules = rules_for_mesh(mesh)
    params = tfm.init_params(cfg, jax.random.key(0))
    with set_mesh(mesh):
        ctx = tfm.make_context(cfg, mesh, rules, tokens_per_shard=batch)
        step = tfm.make_serve_step(ctx, batch=batch)
        cache = tfm.init_cache(cfg, batch, n_tokens + 8)
        tok = jnp.ones((batch,), jnp.int32)
        t0 = time.perf_counter()
        outs = []
        for t in range(n_tokens):
            logits, cache = step(params, cache, tok, jnp.asarray(t))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append(int(tok[0]))
        dt = time.perf_counter() - t0
    print(f"decoded {n_tokens} tokens × {batch} sequences in {dt:.2f}s "
          f"({dt/n_tokens*1e3:.1f} ms/token); seq0: {outs}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("search", "decode"), default="search")
    ap.add_argument("--n-queries", type=int, default=256)
    ap.add_argument("--n-docs", type=int, default=8192)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="microbatch size trigger (default: --n-queries)")
    ap.add_argument("--max-delay-ms", type=float, default=5.0,
                    help="microbatch deadline trigger")
    ap.add_argument("--scorer", default=None, help="lexical scorer (default: config)")
    ap.add_argument("--sweep-sizes", type=int, nargs="+", default=[32, 128, 512],
                    help="batch sizes for the C1 latency sweep")
    ap.add_argument("--bench-out", default="BENCH_serve.json")
    ap.add_argument("--slo-p99-ms", type=float, default=None,
                    help="enable the adaptive serving loop: hold request p99 "
                    "to this SLO (closed-loop microbatch control + admission)")
    ap.add_argument("--queue-limit", type=int, default=256,
                    help="admission queue bound when --slo-p99-ms is set")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--arch", default="gemma2-2b")
    args = ap.parse_args()
    if args.mode == "search":
        serve_search(
            args.n_queries,
            args.n_docs,
            args.batches,
            max_batch=args.max_batch,
            max_delay_ms=args.max_delay_ms,
            scorer=args.scorer,
            sweep_sizes=tuple(args.sweep_sizes),
            bench_out=args.bench_out,
            slo_p99_ms=args.slo_p99_ms,
            queue_limit=args.queue_limit,
        )
    else:
        serve_decode(args.tokens, args.arch)


if __name__ == "__main__":
    main()
