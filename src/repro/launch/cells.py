"""Cell builder: (architecture × input-shape × mesh) → a compilable step.

One place defines, for every assigned cell, the step function, the abstract
inputs (ShapeDtypeStructs — no allocation) and the in-shardings. The dry-run
lowers+compiles cells; smoke tests and drivers run (reduced) cells with real
arrays. Donation of params/opt/cache is part of the contract (the
memory_analysis must reflect steady-state, not double-buffered, footprints).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat
from repro.configs import get_config, input_specs, shapes_for
from repro.configs.base import GNNConfig, MirexConfig, RecsysConfig, TransformerConfig
from repro.core import scoring, topk
from repro.core.scan import search_local
from repro.distributed.sharding import AxisRules, rules_for_mesh
from repro.models import gnn, recsys
from repro.models import transformer as tfm
from repro.optim.adamw import (
    adamw_state_shapes,
    adamw_update,
    cosine_schedule,
    opt_state_specs,
)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable  # positional args matching abstract_inputs
    abstract_inputs: tuple  # pytrees of ShapeDtypeStruct
    in_shardings: tuple  # matching pytrees of NamedSharding
    donate_argnums: tuple[int, ...] = ()
    note: str = ""


def _ns(mesh: Mesh, spec_tree, shape_tree):
    """NamedShardings from a PartitionSpec tree (broadcasting scalars to P())."""
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, sp),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def _dp_spec(rules: AxisRules):
    return rules.dp if len(rules.dp) > 1 else rules.dp[0]


def _all_spec(rules: AxisRules):
    return rules.all_axes


LR = cosine_schedule(3e-4, warmup=100, total=10_000)


def make_train_step(loss_fn, accum_steps: int = 1, reduce_dtype=None):
    """One optimizer step; ``accum_steps>1`` scans microbatches and
    accumulates grads in f32 (peak activation memory ÷ accum_steps).
    ``reduce_dtype`` casts grads before the DP all-reduce (bf16 halves the
    payload; §Perf hillclimb on the collective-bound recsys cells)."""

    def train_step(params, opt, batch):
        if accum_steps == 1:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            if reduce_dtype is not None:
                grads = jax.tree.map(lambda g: g.astype(reduce_dtype), grads)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:]),
                batch,
            )

            def micro(acc, mbatch):
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mbatch)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype), acc, g)
                return acc, m

            # accumulate in param dtype: the accumulator is a scan carry and
            # XLA:CPU keeps ~4 phi copies of it — f32 doubles that cost. The
            # few-microbatch bf16 sum costs <0.5 bits of gradient precision.
            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            grads, ms = jax.lax.scan(micro, acc0, mb)
            grads = jax.tree.map(lambda g: (g.astype(jnp.float32) / accum_steps).astype(g.dtype), grads)
            metrics = jax.tree.map(lambda m: m.mean(), ms)
        params, opt, gnorm = adamw_update(grads, opt, params, lr=LR)
        return params, opt, {**metrics, "gnorm": gnorm}

    return train_step


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch: str, shape_name: str, mesh: Mesh, rules: AxisRules) -> Cell:
    cfg: TransformerConfig = get_config(arch)
    spec = shapes_for(arch)[shape_name]
    kind = spec.kind
    b, s = spec.dims["global_batch"], spec.dims["seq_len"]
    tp_size = mesh.shape[rules.tp]
    dp_size = 1
    for a in rules.dp:
        dp_size *= mesh.shape[a]
    pshapes = tfm.param_shapes(cfg)
    pspecs = tfm.param_specs(cfg, rules, tp_size)
    pshard = _ns(mesh, pspecs, pshapes)
    batch_abs = input_specs(arch, shape_name)
    dp = _dp_spec(rules)

    if kind == "train":
        moe_mode = "seq" if s % tp_size == 0 else "train"
        accum = cfg.grad_accum if b % (cfg.grad_accum * dp_size) == 0 else 1
        tokens_per_shard = (b // (dp_size * accum)) * s
        ctx = tfm.make_context(
            cfg, mesh, rules, tokens_per_shard=tokens_per_shard, moe_mode=moe_mode
        )
        loss_fn = tfm.make_loss_fn(ctx)
        step = make_train_step(loss_fn, accum_steps=accum)
        opt_abs = adamw_state_shapes(pshapes, moment_dtype=cfg.opt_dtype)
        ospecs = opt_state_specs(pspecs, pshapes, rules, dp_size)
        return Cell(
            arch,
            shape_name,
            step,
            (pshapes, opt_abs, batch_abs),
            (pshard, _ns(mesh, ospecs, opt_abs), {
                "tokens": NamedSharding(mesh, P(dp, None)),
                "labels": NamedSharding(mesh, P(dp, None)),
            }),
            donate_argnums=(0, 1),
        )

    if kind == "prefill":
        tokens_per_shard = (b // dp_size) * s
        moe_mode = "seq" if s % tp_size == 0 else "train"
        ctx = tfm.make_context(
            cfg, mesh, rules, tokens_per_shard=tokens_per_shard, moe_mode=moe_mode
        )
        prefill = tfm.make_prefill_step(ctx)
        return Cell(
            arch,
            shape_name,
            prefill,
            (pshapes, batch_abs["tokens"]),
            (pshard, NamedSharding(mesh, P(dp, None))),
        )

    # decode: one new token against a seq_len cache
    moe_mode = "train" if b > 1 else "replicated"
    tokens_per_shard = max(b // dp_size, 1) if b > 1 else 1
    ctx = tfm.make_context(
        cfg, mesh, rules, tokens_per_shard=tokens_per_shard, moe_mode=moe_mode
    )
    serve = tfm.make_serve_step(ctx, batch=b)
    cache_abs = tfm.cache_shapes(cfg, b, s)
    cspec = tfm.cache_specs(cfg, rules, b)
    tok_shard = NamedSharding(mesh, P(dp) if b > 1 else P())
    return Cell(
        arch,
        shape_name,
        serve,
        (pshapes, cache_abs, batch_abs["tokens"], batch_abs["t"]),
        (pshard, _ns(mesh, cspec, cache_abs), tok_shard, NamedSharding(mesh, P())),
        donate_argnums=(1,),
        note=f"moe_mode={moe_mode}" if cfg.is_moe else "",
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_cell(arch: str, shape_name: str, mesh: Mesh, rules: AxisRules) -> Cell:
    cfg: GNNConfig = get_config(arch)
    spec = shapes_for(arch)[shape_name]
    d = spec.dims
    batch_abs = input_specs(arch, shape_name)
    cfg = dataclasses.replace(cfg, n_classes=d["n_classes"])
    pshapes = gnn.param_shapes(cfg, d["d_feat"])
    pshard = _replicated(mesh, pshapes)
    opt_abs = adamw_state_shapes(pshapes)
    all_axes = _all_spec(rules)
    dp = _dp_spec(rules)

    if spec.kind == "full_graph":
        fwd = gnn.make_sharded_full_graph(mesh, rules, cfg)

        def loss_fn(params, batch):
            logits = fwd(params, batch["x"], batch["src"], batch["dst"])
            loss = gnn.xent_loss(logits, batch["labels"])
            return loss, {"loss": loss}

        step = make_train_step(loss_fn)
        bshard = {
            "x": NamedSharding(mesh, P(None, None)),
            "src": NamedSharding(mesh, P(all_axes)),
            "dst": NamedSharding(mesh, P(all_axes)),
            "labels": NamedSharding(mesh, P(None)),
        }
    elif spec.kind == "minibatch":

        def loss_fn(params, batch):
            logits = gnn.forward_sampled(
                params, batch["seed_x"], batch["hop1_x"], batch["hop2_x"], cfg
            )
            loss = gnn.xent_loss(logits, batch["labels"])
            return loss, {"loss": loss}

        step = make_train_step(loss_fn)
        bshard = jax.tree.map(
            lambda _: NamedSharding(mesh, P(all_axes)), batch_abs
        )
    else:  # batched_graphs

        def loss_fn(params, batch):
            logits = gnn.forward_batched_graphs(
                params, batch["x"], batch["src"], batch["dst"], cfg
            )
            loss = gnn.xent_loss(logits, batch["labels"])
            return loss, {"loss": loss}

        step = make_train_step(loss_fn)
        bshard = jax.tree.map(lambda _: NamedSharding(mesh, P(dp)), batch_abs)

    dp_size = 1
    for a in rules.dp:
        dp_size *= mesh.shape[a]
    ospecs = opt_state_specs(
        jax.tree.map(lambda s: P(*([None] * s.ndim)), pshapes,
                     is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
        pshapes, rules, dp_size,
    )
    return Cell(
        arch,
        shape_name,
        step,
        (pshapes, opt_abs, batch_abs),
        (pshard, _ns(mesh, ospecs, opt_abs), bshard),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_cell(arch: str, shape_name: str, mesh: Mesh, rules: AxisRules) -> Cell:
    cfg: RecsysConfig = get_config(arch)
    spec = shapes_for(arch)[shape_name]
    batch_abs = input_specs(arch, shape_name)
    pshapes = recsys.param_shapes(cfg)
    pshard = _replicated(mesh, pshapes)
    all_axes = _all_spec(rules)
    n_all = 1
    for a in rules.all_axes:
        n_all *= mesh.shape[a]

    if spec.kind == "rec_train":

        def loss_fn(params, batch):
            loss = recsys.train_logits(params, batch, cfg)
            return loss, {"loss": loss}

        # NOTE §Perf: casting grads to bf16 post-grad does NOT shrink the
        # all-reduce (the partitioner reduces where grads materialize, before
        # the cast) — measured identical collective term; hypothesis refuted.
        step = make_train_step(loss_fn)
        opt_abs = adamw_state_shapes(pshapes)
        ospecs = opt_state_specs(
            jax.tree.map(lambda s: P(*([None] * s.ndim)), pshapes,
                         is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
            pshapes, rules, max(mesh.shape[a] for a in rules.dp),
        )
        bshard = jax.tree.map(lambda _: NamedSharding(mesh, P(all_axes)), batch_abs)
        return Cell(
            arch, shape_name, step,
            (pshapes, opt_abs, batch_abs),
            (pshard, _ns(mesh, ospecs, opt_abs), bshard),
            donate_argnums=(0, 1),
        )

    if spec.kind == "rec_serve":

        def serve(params, batch):
            if cfg.variant == "fm":
                return recsys.fm_forward(params, batch, cfg)
            if cfg.variant == "dcn-v2":
                return recsys.dcn_forward(params, batch, cfg)
            if cfg.variant == "mind":
                return recsys.mind_interests(params, batch["history"], cfg)
            return recsys.sasrec_forward(params, batch["history"], cfg)[:, -1]

        bshard = jax.tree.map(lambda _: NamedSharding(mesh, P(all_axes)), batch_abs)
        return Cell(arch, shape_name, serve, (pshapes, batch_abs), (pshard, bshard))

    # retrieval: the MIREX scan — candidates sharded over the whole mesh,
    # per-shard score + local top-k, k-bounded all-gather merge.
    k = 1000
    n_cand = spec.dims["n_candidates"]
    n_loc = n_cand // n_all

    def local_retrieve(params, user_batch, cand_ids):
        idx = 0
        for a in rules.all_axes:
            idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
        if cfg.variant == "dcn-v2":
            scores = recsys.score_block_dcn(params, user_batch, cand_ids, cfg)
        else:
            cand_e = params["tables"][-1][cand_ids] if cfg.variant == "fm" else params["items"][cand_ids]
            if cfg.variant == "fm":
                # FM score is linear in the candidate: q·v_c + w_c (+ user const)
                q = recsys.user_query_vector(params, user_batch, cfg)
                scores = recsys.score_block_dot(q, cand_e) + params["linear"][-1][cand_ids][None, :]
            elif cfg.variant == "mind":
                caps = recsys.mind_interests(params, user_batch["history"], cfg)
                scores = recsys.score_block_multi_interest(caps, cand_e)
            else:
                q = recsys.user_query_vector(params, user_batch, cfg)
                scores = recsys.score_block_dot(q, cand_e)
        state = topk.topk_dense(scores, min(k, scores.shape[-1]))
        state = topk.TopKState(state.scores, state.ids + idx * n_loc)
        # tree merge: §Perf — 3.8× less merge traffic than staged gather
        return topk.merge_across(state, rules.all_axes, method="tree")

    pspecs_tree = jax.tree.map(
        lambda s: P(*([None] * s.ndim)), pshapes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    user_abs = {kk: v for kk, v in batch_abs.items() if kk != "cand_ids"}
    user_specs = jax.tree.map(lambda _: P(), user_abs)
    retrieve = shard_map(
        local_retrieve,
        mesh=mesh,
        in_specs=(pspecs_tree, user_specs, P(all_axes)),
        out_specs=topk.TopKState(P(), P()),
        check_rep=False,
    )
    bshard = {
        **jax.tree.map(lambda _: NamedSharding(mesh, P()), user_abs),
        "cand_ids": NamedSharding(mesh, P(all_axes)),
    }
    return Cell(
        arch, shape_name, lambda p, u, c: retrieve(p, u, c),
        (pshapes, user_abs, batch_abs["cand_ids"]),
        (pshard, jax.tree.map(lambda _: NamedSharding(mesh, P()), user_abs),
         NamedSharding(mesh, P(all_axes))),
    )


# ---------------------------------------------------------------------------
# MIREX cells (the paper system itself)
# ---------------------------------------------------------------------------

def _mirex_cell(arch: str, shape_name: str, mesh: Mesh, rules: AxisRules) -> Cell:
    cfg: MirexConfig = get_config(arch)
    spec = shapes_for(arch)[shape_name]
    batch_abs = input_specs(arch, shape_name)
    all_axes = _all_spec(rules)
    n_all = 1
    for a in rules.all_axes:
        n_all *= mesh.shape[a]

    if spec.kind == "scan":
        scorer = scoring.get_scorer(cfg.scorer)
        n_loc = spec.dims["n_docs"] // n_all
        stats_abs = scoring.CollectionStats(
            cf=jax.ShapeDtypeStruct((cfg.vocab,), jnp.int32),
            df=jax.ShapeDtypeStruct((cfg.vocab,), jnp.int32),
            total_terms=jax.ShapeDtypeStruct((), jnp.int32),
            n_docs=jax.ShapeDtypeStruct((), jnp.int32),
            avg_doc_len=jax.ShapeDtypeStruct((), jnp.float32),
        )

        n_q = spec.dims["n_queries"]
        q_chunk = min(n_q, 512)  # bound the [q, L_q, d, L_d] match tensor
        assert n_q % q_chunk == 0

        def local_scan(q_tokens, d_tokens, d_len, stats):
            idx = 0
            for a in rules.all_axes:
                idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)

            # lexical chunk: bounded by the [q_chunk, L_q, chunk, L_d]
            # match tensor and by the per-shard doc count
            lex_chunk = min(1024, n_loc)

            def one_q_block(qb):
                return search_local(
                    qb, (d_tokens, d_len), scorer,
                    k=cfg.k, chunk_size=lex_chunk, stats=stats,
                    doc_id_offset=idx * n_loc,
                )

            states = jax.lax.map(
                one_q_block, q_tokens.reshape(n_q // q_chunk, q_chunk, -1)
            )
            state = topk.TopKState(
                states.scores.reshape(n_q, cfg.k), states.ids.reshape(n_q, cfg.k)
            )
            return topk.merge_across(state, rules.all_axes, method="tree")

        fn = shard_map(
            local_scan,
            mesh=mesh,
            in_specs=(P(), P(all_axes), P(all_axes),
                      jax.tree.map(lambda _: P(), stats_abs)),
            out_specs=topk.TopKState(P(), P()),
            check_rep=False,
        )
        shardings = (
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P(all_axes)),
            NamedSharding(mesh, P(all_axes)),
            jax.tree.map(lambda _: NamedSharding(mesh, P()), stats_abs),
        )
        return Cell(
            arch, shape_name, fn,
            (batch_abs["q_tokens"], batch_abs["d_tokens"], batch_abs["d_len"], stats_abs),
            shardings,
        )

    # dense_scan
    n_loc = spec.dims["n_docs"] // n_all
    k = cfg.k

    def local_dense(q_vecs, d_vecs):
        idx = 0
        for a in rules.all_axes:
            idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
        state = search_local(
            q_vecs, d_vecs, scoring.get_scorer("dense_dot"),
            k=k, chunk_size=min(cfg.chunk_size, n_loc), doc_id_offset=idx * n_loc,
        )
        return topk.merge_across(state, rules.all_axes, method="tree")

    fn = shard_map(
        local_dense,
        mesh=mesh,
        in_specs=(P(), P(all_axes)),
        out_specs=topk.TopKState(P(), P()),
        check_rep=False,
    )
    return Cell(
        arch, shape_name, fn,
        (batch_abs["q_vecs"], batch_abs["d_vecs"]),
        (NamedSharding(mesh, P()), NamedSharding(mesh, P(all_axes))),
    )


# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh: Mesh) -> Cell:
    rules = rules_for_mesh(mesh)
    cfg = get_config(arch)
    if isinstance(cfg, TransformerConfig):
        return _lm_cell(arch, shape_name, mesh, rules)
    if isinstance(cfg, GNNConfig):
        return _gnn_cell(arch, shape_name, mesh, rules)
    if isinstance(cfg, RecsysConfig):
        return _recsys_cell(arch, shape_name, mesh, rules)
    return _mirex_cell(arch, shape_name, mesh, rules)
