"""Experiment driver: declare a grid, run the lifecycle, print the report.

    # a registered experiment (see repro/experiments/grid.py)
    PYTHONPATH=src python -m repro.launch.experiment --experiment bm25-grid

    # or an ad-hoc grid: base:param=v1|v2,... (repeatable)
    PYTHONPATH=src python -m repro.launch.experiment \
        --grid "bm25:k1=0.9|1.2,b=0.4|0.75" --grid ql_lm --n-docs 4096

The lifecycle is prepare → scan job → run files → eval (see
`repro.experiments.runner`). The scan job checkpoints per corpus segment
under ``<out>/ckpt`` — kill the process mid-run and re-invoke with the same
``--out`` to resume bit-identically. ``--bench`` additionally sweeps the
models-per-pass amortization curve into ``BENCH_experiments.json``.

Chaos testing goes through the reliability layer: ``--fault-spec`` injects
deterministic faults (repeatable; ``crash:shard=1,segment=0``,
``straggler:shard=2,delay=0.01``, ``writer_error:shard=0,segment=1``,
``dead_worker:worker=0``), ``--fault-seed`` derives a whole seeded schedule,
and ``--max-retries``/``--speculative`` turn on checkpoint-resumed retries
and speculative re-execution. Run files are byte-identical to the
fault-free run under any schedule. (``--fail-at-segment`` is the deprecated
single-crash alias.)
"""

from __future__ import annotations

import argparse
import dataclasses

import jax.numpy as jnp

from repro import tune
from repro.cluster import FaultSchedule, build_schedule
from repro.core import scoring
from repro.experiments import bench as exp_bench
from repro.experiments import grid as exp_grid
from repro.experiments import runner


def _spec_from_args(args) -> exp_grid.ExperimentSpec:
    if args.experiment:
        if args.grid:
            raise SystemExit(
                "--experiment and --grid are mutually exclusive; add the grid "
                "to the registry (repro/experiments/grid.py) or run it ad-hoc"
            )
        spec = exp_grid.get_experiment(args.experiment)
    else:
        if not args.grid:
            raise SystemExit("need --experiment or at least one --grid")
        spec = exp_grid.ExperimentSpec(
            name="adhoc", grids=tuple(exp_grid.parse_grid(g) for g in args.grid)
        )
    overrides = {
        k: v
        for k, v in (
            ("n_docs", args.n_docs),
            ("n_queries", args.n_queries),
            ("k", args.k),
            ("chunk_size", args.chunk_size),
            ("segment_chunks", args.segment_chunks),
            ("n_shards", args.n_shards),
            ("use_kernel", args.use_kernel or None),
        )
        if v is not None
    }
    # (a small --k is fine: run_experiment clamps eval_ks to the run depth)
    return dataclasses.replace(spec, **overrides) if overrides else spec


def print_report(report: dict) -> None:
    job = report["job"]
    resumed = f", resumed from segment {job['resumed_from']}" if job["resumed_from"] else ""
    shards = f", {job['n_shards']} shards" if job.get("n_shards", 1) > 1 else ""
    print(
        f"== experiment {report['experiment']}: {len(report['models'])} models, "
        f"one pass over {report['n_docs']} docs × {report['n_queries']} queries "
        f"({job['segments_total']} checkpointed segments{shards}{resumed}) =="
    )
    sched = job.get("scheduler")
    if sched and (
        sched["retries"] or sched["steals"] or sched["speculative_launched"]
        or sched["dead_workers"] or job.get("faults_fired")
    ):
        fired = job.get("faults_fired") or []
        print(
            f"   reliability: {len(fired)} faults fired, "
            f"{sched['retries']} retries, {sched['steals']} steals, "
            f"{sched['speculative_launched']} speculative "
            f"({sched['speculative_won']} won), "
            f"dead workers {list(sched['dead_workers'])}"
        )
    t = job.get("tuning")
    if t and (t.get("source") != "default" or t.get("overrides")):
        hit = ", cache hit" if t.get("cache_hit") else ""
        print(
            f"   tuning: {t['config_hash']} ({t['source']}{hit}) "
            f"overrides={t.get('overrides') or {}}"
        )
    o = job.get("obs")
    if o:
        print(f"   trace: {o['n_events']} events -> {o['trace']}")
        for label, names in o.get("phases", {}).items():
            parts = ", ".join(
                f"{name} ×{agg['count']} {agg['total_s'] * 1e3:.1f}ms"
                for name, agg in names.items()
            )
            print(f"     {label}: {parts}")
    metric_names = list(next(iter(report["metrics"].values())))
    header = "model".ljust(34) + "".join(m.rjust(10) for m in metric_names)
    print(header)
    for model, agg in report["metrics"].items():
        sig = report["significance"].get(model)
        star = " *" if sig and sig["p_value"] < 0.05 else ""
        print(
            model.ljust(34)
            + "".join(f"{agg[m]:10.4f}" for m in metric_names)
            + star
        )
    base = report["baseline"]
    print(f"(* = p<0.05 vs baseline {base}, paired randomization on AP)")
    for model, sig in report["significance"].items():
        print(f"  {model}: ΔAP={sig['diff']:+.4f}  p={sig['p_value']:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--experiment", default=None,
                    help=f"registered experiment: {sorted(exp_grid.EXPERIMENTS)}")
    ap.add_argument("--grid", action="append", default=[],
                    help='ad-hoc grid "base:param=v1|v2,..." (repeatable)')
    ap.add_argument("--out", default="results/experiments",
                    help="artifact dir (runs/, qrels.txt, ckpt/, report.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-docs", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=None)
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--chunk-size", type=int, default=None)
    ap.add_argument("--segment-chunks", type=int, default=None,
                    help="corpus chunks per checkpoint segment")
    ap.add_argument("--n-shards", type=int, default=None,
                    help="corpus scan shards (repro.cluster sharded job; run "
                         "files are byte-identical at every shard count)")
    ap.add_argument("--fail-at-shard", type=int, default=0,
                    help="shard the injected failure fires on (testing)")
    ap.add_argument("--pipeline", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="overlapped scan executor: concurrent shards, "
                         "double-buffered segment prefetch, async checkpoints "
                         "(--no-pipeline = synchronous reference executor; "
                         "artifacts are byte-identical either way)")
    ap.add_argument("--max-workers", type=int, default=None,
                    help="cap the concurrent-shard thread pool (default: one "
                         "worker per visible device)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="scan through the fused Pallas lexical kernel")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore existing segment checkpoints")
    ap.add_argument("--fail-at-segment", type=int, default=None,
                    help="deprecated alias: one crash after this segment "
                         "commits on --fail-at-shard (use --fault-spec)")
    ap.add_argument("--fault-spec", action="append", default=[],
                    help='inject a fault "kind:key=val,..." (repeatable), e.g. '
                         '"crash:shard=1,segment=0,phase=pre_commit", '
                         '"straggler:shard=2,delay=0.01", '
                         '"writer_error:shard=0,segment=1", '
                         '"dead_worker:worker=0"')
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="derive a whole seeded chaos schedule (crashes × "
                         "stragglers × writer errors) from this seed")
    ap.add_argument("--max-retries", type=int, default=0,
                    help="re-run a failed shard from its last committed "
                         "segment checkpoint up to this many times")
    ap.add_argument("--speculative", action="store_true",
                    help="speculatively re-execute the slowest in-flight "
                         "shard when the work queue drains")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace_event JSON here (open in "
                         "chrome://tracing or ui.perfetto.dev; the JSONL "
                         "event log lands next to it). Default: "
                         "<out>/trace.json unless --no-trace")
    ap.add_argument("--no-trace", action="store_true",
                    help="disable tracing (metrics-only run)")
    ap.add_argument("--tune", action="store_true",
                    help="look this job's shape up in the autotune winner "
                         "cache and run under the recorded TuningConfig "
                         "(defaults on a miss; artifacts byte-identical "
                         "either way — tuning changes speed, never bytes)")
    ap.add_argument("--tune-cache", default=None,
                    help="autotune winner-cache path (default: "
                         "$REPRO_TUNE_CACHE or results/tune_cache.json)")
    ap.add_argument("--tuning-config", default=None,
                    help="run under an explicit TuningConfig JSON file "
                         "(flat knob dict, see repro.tune.save); mutually "
                         "exclusive with --tune")
    ap.add_argument("--token-pack", default=None,
                    choices=["none", "auto", "8", "16", "bitpack"],
                    help="packed corpus segments (core.packing): store scan "
                         "tokens at this width and decode on the consumer — "
                         "fewer bytes staged/streamed, run files byte-"
                         "identical to the unpacked run. Overrides the "
                         "tuning config's token_pack knob")
    ap.add_argument("--bench", action="store_true",
                    help="also sweep the models-per-pass amortization curve")
    ap.add_argument("--bench-sizes", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--bench-out", default="BENCH_experiments.json")
    args = ap.parse_args()

    spec = _spec_from_args(args)
    out_dir = args.out if args.experiment is None else f"{args.out}/{spec.name}"
    trace_out = None
    if not args.no_trace:
        trace_out = args.trace_out or f"{out_dir}/trace.json"
    elif args.trace_out:
        raise SystemExit("--trace-out and --no-trace are mutually exclusive")

    faults = build_schedule(args.fault_spec) if args.fault_spec else None
    if args.fault_seed is not None:
        # schedule geometry from the job's own: segments per shard
        shard_rows = spec.n_docs // max(1, spec.n_shards)
        n_segments = max(
            1, shard_rows // (spec.chunk_size * spec.segment_chunks)
        )
        seeded = FaultSchedule.random(
            args.fault_seed, n_shards=spec.n_shards, n_segments=n_segments
        )
        if faults is None:
            faults = seeded
        else:
            for s in seeded.specs:
                faults.add(s)

    if args.tune and args.tuning_config:
        raise SystemExit("--tune and --tuning-config are mutually exclusive")
    tuning = tune.load(args.tuning_config) if args.tuning_config else None
    if args.token_pack is not None:
        if args.tune:
            raise SystemExit("--token-pack and --tune are mutually exclusive "
                             "(the cached winner already fixes token_pack)")
        base = tuning if tuning is not None else tune.TuningConfig()
        tuning = base.replace(token_pack=args.token_pack)

    coll = runner.prepare_collection(spec, seed=args.seed)  # shared with --bench
    report = runner.run_experiment(
        spec,
        out_dir=out_dir,
        seed=args.seed,
        resume=not args.no_resume,
        fail_at_segment=args.fail_at_segment,
        fail_at_shard=args.fail_at_shard,
        collection=coll,
        pipelined=args.pipeline,
        max_workers=args.max_workers,
        faults=faults,
        max_retries=args.max_retries,
        speculative=args.speculative,
        trace_out=trace_out,
        tuning=tuning,
        tune_lookup=args.tune,
        tune_cache=args.tune_cache,
    )
    print_report(report)
    print(f"wrote {out_dir}/report.json")

    if args.bench:
        # bench grid: enough QL-LM smoothing points for the largest size
        lams = [0.05 + 0.9 * i / max(args.bench_sizes) for i in range(max(args.bench_sizes))]
        scorers = [scoring.make_variant("ql_lm", lam=round(l, 4)) for l in lams]
        payload = exp_bench.amortization_curve(
            jnp.asarray(coll.queries),
            (jnp.asarray(coll.corpus.tokens), jnp.asarray(coll.corpus.lengths)),
            scorers,
            k=spec.k,
            chunk_size=spec.chunk_size,
            stats=coll.stats,
            sizes=tuple(args.bench_sizes),
        )
        path = exp_bench.write_bench_json(payload, args.bench_out)
        for pt in payload["curve"]:
            speedup = pt.get("speedup_vs_independent")
            extra = f"  {speedup:5.2f}x vs independent passes" if speedup else ""
            print(f"  {pt['models']:3d} models/pass: {pt['wall_s']*1e3:8.1f} ms "
                  f"({pt['s_per_model']*1e3:7.1f} ms/model){extra}")
        print(f"amortization {payload.get('amortization_x', 1.0):.2f}x "
              f"({payload['sizes'][0]} -> {payload['sizes'][-1]} models); wrote {path}")


if __name__ == "__main__":
    main()
