"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` visits a while body **once**, but our models scan
over layers (and chunked attention scans over query blocks), so its FLOPs are
off by ~n_layers. This parser rebuilds the cost from the HLO text itself:

  * splits the module into computations and builds a per-computation symbol
    table (every ``%name = type[shape]`` definition);
  * costs ``dot``/``convolution``/oneDNN-matmul custom-calls analytically
    (2 · prod(out) · prod(contracted));
  * charges every top-level op's operand+output bytes as HBM traffic —
    *top-level* because optimized HLO has already fused elementwise chains,
    so fusion internals correctly don't count;
  * collects collective payloads (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute) with their replica-group sizes;
  * resolves the call graph: ``while`` multiplies its body+condition by the
    trip count (largest s32 constant in the condition — exact for lax.scan /
    fori_loop), fusions/calls recurse once.

Everything is per-device (the SPMD module is the per-device program).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"((?:\([^)]*\)|\S+))\s+([\w\-]+)\(")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body)=%([\w.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%([\w.\-]+)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_MAT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

# ops that move no real data / are layout-only
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> tuple[list[int], str] | None:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return ([int(d) for d in dims.split(",") if d], dt)


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_type: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    symbols: dict[str, str]  # %name -> type string


def split_computations(txt: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in txt.splitlines():
        s = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->.*\{$", s)
        if header:
            cur = Computation(name=header.group(1), ops=[], symbols={})
            comps[cur.name] = cur
            # parameters declared in the header: name: type
            for pname, ptype in re.findall(r"([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\],]+))", header.group(2)):
                cur.symbols[pname] = ptype
            if "ENTRY" in s:
                comps["__entry__"] = cur
            continue
        if s == "}" or s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        d = _DEF_RE.match(s)
        if not d:
            continue
        name, rhs = d.groups()
        m = _OP_RE.match(rhs)
        if not m:
            continue
        out_type, kind = m.groups()
        cur.symbols[name] = out_type
        cur.ops.append(Op(name=name, kind=kind, out_type=out_type, line=s))
    return comps


def _trip_count(cond: Computation) -> int:
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _operand_names(line: str) -> list[str]:
    m = re.search(r"\w+\(([^)]*)", line)
    if not m:
        return []
    return re.findall(r"%([\w.\-]+)", m.group(1))


def _dot_flops(op: Op, symbols: dict[str, str]) -> float:
    out = _shape_dims(op.out_type)
    if out is None:
        return 0.0
    out_dims, _ = out
    operands = _operand_names(op.line)
    lhs_type = symbols.get(operands[0], "") if operands else ""
    lhs = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contracted = 1
    if lhs and m and m.group(1):
        lhs_dims, _ = lhs
        for i in m.group(1).split(","):
            contracted *= lhs_dims[int(i)]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * contracted


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_MAT_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    collective_counts: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    collective_groups: dict = dataclasses.field(default_factory=dict)  # max group size

    def scaled(self, k: float) -> "Costs":
        c = Costs(self.flops * k, self.bytes_accessed * k)
        for kk, v in self.collective_bytes.items():
            c.collective_bytes[kk] = v * k
        for kk, v in self.collective_counts.items():
            c.collective_counts[kk] = int(v * k)
        c.collective_groups = dict(self.collective_groups)  # sizes don't scale
        return c

    def add(self, o: "Costs"):
        self.flops += o.flops
        self.bytes_accessed += o.bytes_accessed
        for kk, v in o.collective_bytes.items():
            self.collective_bytes[kk] += v
        for kk, v in o.collective_counts.items():
            self.collective_counts[kk] += v
        for kk, v in o.collective_groups.items():
            self.collective_groups[kk] = max(self.collective_groups.get(kk, 1), v)


def _comp_cost(comp: Computation, comps: dict[str, Computation], memo: dict) -> Costs:
    if comp.name in memo:
        return memo[comp.name]
    total = Costs()
    for op in comp.ops:
        if op.kind in _FREE_OPS:
            continue
        out_bytes = _shape_bytes(op.out_type)
        opnd_bytes = sum(_shape_bytes(comp.symbols.get(o, "")) for o in _operand_names(op.line))
        if op.kind == "while":
            body = _CALL_ATTR_RE.search(op.line)
            cond = _COND_ATTR_RE.search(op.line)
            trip = 1
            if cond and cond.group(1) in comps:
                trip = _trip_count(comps[cond.group(1)])
            if body and body.group(1) in comps:
                inner = _comp_cost(comps[body.group(1)], comps, memo)
                total.add(inner.scaled(trip))
            continue
        if op.kind in ("fusion", "call", "async-start", "conditional"):
            callee = _CALL_ATTR_RE.search(op.line)
            if callee and callee.group(1) in comps:
                total.add(_comp_cost(comps[callee.group(1)], comps, memo))
            total.bytes_accessed += out_bytes + opnd_bytes
            continue
        if op.kind == "dot" or op.kind == "convolution":
            total.flops += _dot_flops(op, comp.symbols)
            total.bytes_accessed += out_bytes + opnd_bytes
            continue
        if op.kind == "custom-call" and "matmul" in op.line:
            # oneDNN matmul: infer K from operand 0 last dim
            operands = _operand_names(op.line)
            lhs = _shape_dims(comp.symbols.get(operands[0], "")) if operands else None
            out = _shape_dims(op.out_type)
            if lhs and out:
                n_out = 1
                for d in out[0]:
                    n_out *= d
                total.flops += 2.0 * n_out * (lhs[0][-1] if lhs[0] else 1)
            total.bytes_accessed += out_bytes + opnd_bytes
            continue
        if op.kind in COLLECTIVES:
            total.collective_bytes[op.kind] += out_bytes
            total.collective_counts[op.kind] += 1
            total.collective_groups[op.kind] = max(
                total.collective_groups.get(op.kind, 1), _group_size(op.line)
            )
            total.bytes_accessed += out_bytes + opnd_bytes
            continue
        total.bytes_accessed += out_bytes + opnd_bytes
    memo[comp.name] = total
    return total


def entry_f32_upcast_bytes(comps: dict[str, Computation]) -> int:
    """Bytes of whole-array bf16→f32 copies XLA:CPU makes of inputs.

    XLA:CPU float-normalizes bf16 dot operands to f32 and hoists the
    conversion of loop-invariant stacks (weights, KV caches) out of while
    loops — materializing full f32 copies that a native-bf16 TPU never
    creates. Detected as entry-scope convert/convert-fusion ops producing
    f32[dims] from a bf16[dims] value. Used to report a TPU-projected peak
    alongside the raw CPU number (methodology in EXPERIMENTS §Dry-run).
    """
    entry = comps.get("__entry__")
    if entry is None:
        return 0
    total = 0
    for op in entry.ops:
        if op.kind not in ("convert", "fusion"):
            continue
        out = _shape_dims(op.out_type)
        if out is None or out[1] != "f32":
            continue
        if op.kind == "fusion" and "convert" not in op.line:
            continue
        operands = _operand_names(op.line)
        if len(operands) != 1:
            continue
        src = _shape_dims(entry.symbols.get(operands[0], ""))
        if src is None or src[1] != "bf16" or src[0] != out[0]:
            continue
        n = 1
        for dim in out[0]:
            n *= dim
        if n * 4 >= 2**27:  # only count ≥128 MiB copies (whole stacks)
            total += n * 4
    return total


def analyze_hlo(txt: str) -> dict:
    """Parse optimized HLO -> per-device costs dict (trip-count aware)."""
    comps = split_computations(txt)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: dict[str, Costs] = {}
    c = _comp_cost(entry, comps, memo)
    return {
        "flops": c.flops,
        "bytes_accessed": c.bytes_accessed,
        "collective_bytes": dict(c.collective_bytes),
        "collective_counts": dict(c.collective_counts),
        "collective_group_sizes": dict(c.collective_groups),
        "cpu_upcast_artifact_bytes": entry_f32_upcast_bytes(comps),
    }
