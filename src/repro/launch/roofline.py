"""§Roofline: three-term roofline per (arch × shape) from the dry-run JSONs.

    compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
    collective = Σ_type ring_traffic(type) / link_bw        (50 GB/s/link)

HLO_FLOPs/bytes come from the trip-count-aware HLO parse (hloparse.py), not
``cost_analysis()`` (which counts while bodies once). Ring formulas per
collective type with the recorded group size n:
    all-reduce 2(n-1)/n·B, all-gather (n-1)/n·B_out, reduce-scatter
    (n-1)·B_out, all-to-all (n-1)/n·B, collective-permute B.

MODEL_FLOPS is the *useful* work: 6·N_active·T for LM training, 2·N_active·T
for inference, analytic per-family formulas otherwise (functions below). The
MODEL/HLO ratio exposes remat recompute and padding waste.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--mesh 16x16]
writes results/roofline_<mesh>.md + .json.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results")


def collective_time(hlo: dict) -> float:
    t = 0.0
    groups = hlo.get("collective_group_sizes", {})
    for kind, bytes_ in hlo.get("collective_bytes", {}).items():
        n = max(groups.get(kind, 2), 2)
        if kind == "all-reduce":
            eff = 2 * (n - 1) / n * bytes_
        elif kind == "all-gather":
            eff = (n - 1) / n * bytes_
        elif kind == "reduce-scatter":
            eff = (n - 1) * bytes_  # recorded bytes are the scattered output
        elif kind == "all-to-all":
            eff = (n - 1) / n * bytes_
        else:  # collective-permute
            eff = bytes_
        t += eff / LINK_BW
    return t


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful work) per family
# ---------------------------------------------------------------------------

def _lm_model_flops(arch: str, shape: str, n_devices: int) -> float:
    from repro.configs import get_config, shapes_for

    cfg = get_config(arch)
    spec = shapes_for(arch)[shape]
    b, s = spec.dims["global_batch"], spec.dims["seq_len"]
    n_active = cfg.active_param_count()
    l, h, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    if spec.kind == "train":
        tokens = b * s
        attn = 12 * l * b * s * s * h * hd  # fwd 4·L·B·S²·H·hd, ×3 fwd+bwd
        return (6 * n_active * tokens + attn) / n_devices
    if spec.kind == "prefill":
        tokens = b * s
        attn = 4 * l * b * s * s * h * hd / 2  # causal half
        return (2 * n_active * tokens + attn) / n_devices
    # decode: one token over an S-long cache
    attn = 4 * l * b * s * h * hd
    return (2 * n_active * b + attn) / n_devices


def _gnn_model_flops(arch: str, shape: str, n_devices: int) -> float:
    from repro.configs import get_config, shapes_for

    cfg = get_config(arch)
    spec = shapes_for(arch)[shape]
    d = cfg.d_hidden
    a = (1 + len(cfg.aggregators) * len(cfg.scalers)) * d
    dims = spec.dims
    if spec.kind == "full_graph":
        n, e, f = dims["n_nodes"], dims["n_edges"], dims["d_feat"]
        per_layer = 2 * e * (2 * d * d) + 2 * n * (a * d)
        fwd = 2 * n * f * d + cfg.n_layers * per_layer + 2 * n * d * dims["n_classes"]
        return 3 * fwd / n_devices  # train step
    if spec.kind == "minibatch":
        bsz = dims["batch_nodes"]
        k1, k2 = dims["fanout"]
        f = dims["d_feat"]
        n_tree = bsz * (1 + k1 + k1 * k2)
        fwd = 2 * n_tree * f * d + 2 * (bsz * k1 * k2 + bsz * k1) * 2 * d * d \
            + 2 * (bsz + bsz * k1) * a * d
        return 3 * fwd / n_devices
    bsz, n, e, f = dims["batch"], dims["n_nodes"], dims["n_edges"], dims["d_feat"]
    per_layer = 2 * e * 2 * d * d + 2 * n * a * d
    fwd = bsz * (2 * n * f * d + cfg.n_layers * per_layer)
    return 3 * fwd / n_devices


def _recsys_model_flops(arch: str, shape: str, n_devices: int) -> float:
    from repro.configs import get_config, shapes_for

    cfg = get_config(arch)
    spec = shapes_for(arch)[shape]
    d = cfg.embed_dim
    dims = spec.dims

    def fwd_per_example() -> float:
        if cfg.variant == "fm":
            return 4.0 * cfg.n_sparse * d
        if cfg.variant == "dcn-v2":
            x0 = cfg.n_dense + cfg.n_sparse * d
            cross = cfg.n_cross_layers * 2 * x0 * x0
            mlp_dims = (x0, *cfg.mlp_dims, 1)
            mlp = sum(2 * a * b for a, b in zip(mlp_dims[:-1], mlp_dims[1:]))
            return cross + mlp
        if cfg.variant == "mind":
            l = cfg.seq_len
            return 2 * l * d * d + cfg.capsule_iters * 4 * l * cfg.n_interests * d
        # sasrec
        l = cfg.seq_len
        per_blk = 8 * l * d * d + 4 * l * l * d + 16 * l * d * d
        return cfg.n_blocks * per_blk

    if spec.kind == "rec_train":
        return 3 * dims["batch"] * fwd_per_example() / n_devices
    if spec.kind == "rec_serve":
        return dims["batch"] * fwd_per_example() / n_devices
    # retrieval: per-candidate score
    n_c = dims["n_candidates"]
    if cfg.variant == "dcn-v2":
        return n_c * fwd_per_example() / n_devices
    if cfg.variant == "mind":
        return 2.0 * n_c * cfg.n_interests * d / n_devices
    return 2.0 * n_c * d / n_devices


def _mirex_model_flops(arch: str, shape: str, n_devices: int) -> float:
    from repro.configs import get_config, shapes_for

    cfg = get_config(arch)
    spec = shapes_for(arch)[shape]
    dims = spec.dims
    if spec.kind == "dense_scan":
        return 2.0 * dims["n_queries"] * dims["n_docs"] * dims["dim"] / n_devices
    # lexical scan: 1 "op" per (query-term, doc-token) comparison
    return (
        dims["n_queries"] * cfg.max_q_len * dims["n_docs"] * dims["doc_len"] / n_devices
    )


def model_flops(arch: str, shape: str, n_devices: int) -> float:
    from repro.configs import family

    fam = family(arch)
    return {
        "lm": _lm_model_flops,
        "gnn": _gnn_model_flops,
        "recsys": _recsys_model_flops,
        "mirex": _mirex_model_flops,
    }[fam](arch, shape, n_devices)


# ---------------------------------------------------------------------------

FIX_HINTS = {
    "compute": "raise useful-FLOP share (MODEL/HLO ratio): lighter remat policy / fused kernels to remove recompute and masked-block waste",
    "memory": "fuse the streaming hot loop (Pallas kernel keeps the working set in VMEM; on this cell most bytes are re-read activations)",
    "collective": "shrink/overlap the dominant collective: bf16 payloads, reduce-scatter instead of all-reduce, async overlap with compute",
}


def analyze(mesh: str = "16x16") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "dryrun", mesh, "*.json"))):
        r = json.load(open(f))
        if not r.get("ok"):
            continue
        hlo = r["hlo"]
        n_dev = r["n_devices"]
        t_c = hlo["flops"] / PEAK
        t_m = hlo["bytes_accessed"] / HBM_BW
        t_x = collective_time(hlo)
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)), key=lambda kv: kv[1])[0]
        mf = model_flops(r["arch"], r["shape"], n_dev)
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "mesh": mesh,
            "compute_s": t_c,
            "memory_s": t_m,
            "collective_s": t_x,
            "bottleneck": dom,
            "model_flops_per_dev": mf,
            "hlo_flops_per_dev": hlo["flops"],
            "useful_ratio": mf / hlo["flops"] if hlo["flops"] else float("nan"),
            "roofline_fraction": (
                mf / PEAK / max(t_c, t_m, t_x) if max(t_c, t_m, t_x) > 0 else 0.0
            ),
            "peak_gib": r["memory"]["peak_bytes"] / 2**30,
            "peak_gib_tpu": r["memory"].get("peak_bytes_tpu_projected", r["memory"]["peak_bytes"]) / 2**30,
            "hint": FIX_HINTS[dom],
        })
    return rows


def emit(rows: list[dict], mesh: str):
    out_json = os.path.join(RESULTS, f"roofline_{mesh}.json")
    with open(out_json, "w") as f:
        json.dump(rows, f, indent=1)
    lines = [
        f"### Roofline — {mesh} mesh (per device; 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| arch | shape | compute (s) | memory (s) | collective (s) | bottleneck | MODEL/HLO | roofline frac | mem GiB (raw/proj) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['bottleneck']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2%} | {r['peak_gib']:.1f}/{r['peak_gib_tpu']:.1f} |"
        )
    md = "\n".join(lines) + "\n"
    with open(os.path.join(RESULTS, f"roofline_{mesh}.md"), "w") as f:
        f.write(md)
    return md


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = analyze(args.mesh)
    print(emit(rows, args.mesh))


if __name__ == "__main__":
    main()
