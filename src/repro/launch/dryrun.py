import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -------------------------------------
"""Multi-pod dry-run: lower + compile every (architecture × input-shape) on
the production meshes, prove it fits, and harvest roofline inputs.

The two lines above MUST stay the first statements of this module: jax locks
the device count at first init, and the dry-run (and only the dry-run) needs
512 placeholder host devices so ``jax.make_mesh((2,16,16))`` can build the
production mesh. Run as::

    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # orchestrates
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

``--all`` runs each cell in a fresh subprocess (compile arenas on a 1-core
host don't fragment across cells; one bad cell can't take down the sweep) and
caches per-cell JSON under results/dryrun/<mesh>/<arch>__<shape>.json. A
second sweep re-runs only missing/failed cells.

Per cell the JSON records: memory_analysis (must fit 16 GB/chip),
cost_analysis (XLA's own numbers), and the trip-count-aware HLO parse
(hloparse.py) that §Roofline consumes: per-device FLOPs, bytes, collective
payloads + group sizes.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

HBM_PER_CHIP = 16 * 2**30  # v5e


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    import jax

    from repro.launch.cells import build_cell
    from repro.launch.hloparse import analyze_hlo
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.size,
        "ok": False,
    }
    t0 = time.time()
    with jax.set_mesh(mesh):
        cell = build_cell(arch, shape, mesh)
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            donate_argnums=cell.donate_argnums,
        )
        t_build = time.time()
        lowered = jitted.lower(*cell.abstract_inputs)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        # donated (aliased) buffers are not double counted
        mem["peak_bytes"] = (
            mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"] - mem["alias_bytes"]
        )
        mem["fits_16GB"] = mem["peak_bytes"] <= HBM_PER_CHIP
        rec["memory"] = mem

        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        txt = compiled.as_text()
        rec["hlo"] = analyze_hlo(txt)
        # TPU projection: subtract whole-stack f32 copies of bf16 inputs that
        # exist only because XLA:CPU has no native bf16 dot (hloparse docs).
        artifact = rec["hlo"]["cpu_upcast_artifact_bytes"]
        mem["peak_bytes_tpu_projected"] = mem["peak_bytes"] - artifact
        mem["fits_16GB_tpu_projected"] = mem["peak_bytes_tpu_projected"] <= HBM_PER_CHIP
        rec["hlo_lines"] = txt.count("\n")
        rec["note"] = cell.note
        rec["timing_s"] = {
            "build": round(t_build - t0, 2),
            "lower": round(t_lower - t_build, 2),
            "compile": round(t_compile - t_lower, 2),
        }
        rec["ok"] = True
    return rec


def result_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    d = os.path.join(RESULTS_DIR, mesh_name)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape}.json")


def run_and_save(arch: str, shape: str, multi_pod: bool) -> dict:
    path = result_path(arch, shape, multi_pod)
    try:
        rec = run_cell(arch, shape, multi_pod)
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        rec = {
            "arch": arch,
            "shape": shape,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "ok": False,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
        }
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def orchestrate(multi_pod: bool, *, force: bool = False, include_mirex: bool = True):
    """Run every cell in its own subprocess; skip cached successes."""
    from repro.configs import all_cells

    cells = all_cells(include_mirex=include_mirex)
    failures = []
    for arch, shape in cells:
        path = result_path(arch, shape, multi_pod)
        if not force and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("ok"):
                    print(f"[cached ] {arch} × {shape}")
                    continue
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape,
        ]
        if multi_pod:
            cmd.append("--multi-pod")
        t0 = time.time()
        proc = subprocess.run(cmd, capture_output=True, text=True)
        with open(path) as f:
            rec = json.load(f) if os.path.exists(path) else {"ok": False}
        status = "ok" if rec.get("ok") else "FAIL"
        print(f"[{status:6s}] {arch} × {shape}  ({time.time()-t0:.0f}s)")
        if not rec.get("ok"):
            failures.append((arch, shape, rec.get("error", proc.stderr[-500:])))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for a, s, e in failures:
            print(f"  {a} × {s}: {e}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.all:
        fails = orchestrate(args.multi_pod, force=args.force)
        sys.exit(1 if fails else 0)
    rec = run_and_save(args.arch, args.shape, args.multi_pod)
    print(json.dumps({k: v for k, v in rec.items() if k != "traceback"}, indent=1))
    if rec["ok"]:
        print(f"memory per device: {rec['memory']['peak_bytes']/2**30:.2f} GiB "
              f"(fits 16GB: {rec['memory']['fits_16GB']})")
    sys.exit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
