"""Fault-tolerant training driver.

Production contract (DESIGN §5):
  * step-checkpointed (atomic rename commits; `checkpoint/`),
  * restart-safe data (batches are pure functions of the step),
  * elastic (restore reshards to the *current* mesh),
  * failure injection (`--fail-at-step`) for the fault-tolerance tests,
  * optional error-feedback gradient compression for the DP all-reduce
    (`--grad-compress {topk,sign}` — shard_map DP ring; `optim/compress.py`).

Runs any LM arch (reduced config by default so it trains on the CPU host;
``--full`` uses the production config — only sensible on a real pod) and the
recsys archs. Example end-to-end run: ``examples/train_lm.py`` drives this.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import checkpoint as ckpt
from repro.configs import get_config, reduced_config
from repro.configs.base import RecsysConfig, TransformerConfig
from repro.data import synthetic
from repro.data.loader import ShardedBatchLoader
from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_test_mesh, set_mesh
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.optim import compress
from repro.optim.adamw import adamw_init, adamw_update, cosine_schedule


def build_lm(cfg: TransformerConfig, mesh, rules, *, batch: int, seq: int, seed: int):
    ctx = tfm.make_context(cfg, mesh, rules, tokens_per_shard=batch * seq)
    loss_fn = tfm.make_loss_fn(ctx, chunk=min(256, seq))

    def make_batch(step: int):
        return synthetic.make_lm_batch(
            batch=batch, seq_len=seq, vocab=cfg.vocab, seed=seed, chunk=step
        )

    def init(key):
        return tfm.init_params(cfg, key)

    return loss_fn, make_batch, init


def build_recsys(cfg: RecsysConfig, mesh, rules, *, batch: int, seed: int):
    def loss_fn(params, b):
        loss = recsys_lib.train_logits(params, b, cfg)
        return loss, {"loss": loss}

    if cfg.variant in ("fm", "dcn-v2"):
        def make_batch(step: int):
            return synthetic.make_recsys_batch(
                batch=batch, n_dense=cfg.n_dense, n_sparse=cfg.n_sparse,
                vocab_per_field=cfg.vocab_per_field, seed=seed, chunk=step,
            )
    else:
        def make_batch(step: int):
            return synthetic.make_item_sequences(
                batch=batch, seq_len=max(cfg.seq_len, 12), n_items=cfg.n_items,
                seed=seed, chunk=step,
            )

    def init(key):
        return recsys_lib.init_params(cfg, key)

    return loss_fn, make_batch, init


def train(
    arch: str = "h2o-danube-1.8b",
    *,
    steps: int = 20,
    batch: int = 8,
    seq: int = 64,
    ckpt_dir: str | None = None,
    ckpt_every: int = 5,
    resume: bool = True,
    fail_at_step: int | None = None,
    reduced: bool = True,
    mesh=None,
    lr: float = 1e-3,
    grad_compress: str | None = None,
    seed: int = 0,
) -> dict:
    cfg = reduced_config(arch) if reduced else get_config(arch)
    mesh = mesh or make_test_mesh(1, 1)
    rules = rules_for_mesh(mesh)

    if isinstance(cfg, TransformerConfig):
        loss_fn, make_batch, init = build_lm(cfg, mesh, rules, batch=batch, seq=seq, seed=seed)
    elif isinstance(cfg, RecsysConfig):
        loss_fn, make_batch, init = build_recsys(cfg, mesh, rules, batch=batch, seed=seed)
    else:
        raise ValueError(f"train driver supports lm/recsys archs, got {arch}")

    schedule = cosine_schedule(lr, warmup=max(steps // 10, 1), total=steps)
    dp = rules.dp if len(rules.dp) > 1 else rules.dp[0]

    if grad_compress:
        from jax.experimental.shard_map import shard_map

        compressor = {
            "topk": lambda g, ef: compress.topk_allreduce(g, ef, rules.dp, frac=0.05),
            "sign": lambda g, ef: compress.sign_allreduce(g, ef, rules.dp),
        }[grad_compress]

        def train_step(params, opt, ef, b):
            (_, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
            # compressed DP reduction with error feedback: collectives need a
            # shard_map scope (grads/residual replicated in this DP layout)
            reduce_fn = shard_map(
                lambda gg, rr: compressor(gg, compress.ErrorFeedbackState(rr)),
                mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P(), g),
                          jax.tree.map(lambda _: P(), ef.residual)),
                out_specs=(jax.tree.map(lambda _: P(), g),
                           compress.ErrorFeedbackState(
                               jax.tree.map(lambda _: P(), ef.residual))),
                check_rep=False,
            )
            g, ef = reduce_fn(g, ef.residual)
            params, opt, gnorm = adamw_update(g, opt, params, lr=schedule)
            return params, opt, ef, {**metrics, "gnorm": gnorm}
    else:
        def train_step(params, opt, ef, b):
            (_, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
            params, opt, gnorm = adamw_update(g, opt, params, lr=schedule)
            return params, opt, ef, {**metrics, "gnorm": gnorm}

    loader = ShardedBatchLoader(mesh, rules.dp, make_batch)
    start_step = 0
    params = opt = ef = None
    if ckpt_dir and resume:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            with set_mesh(mesh):
                params = init(jax.random.key(seed))
                opt = adamw_init(params, jnp.dtype(getattr(cfg, "opt_dtype", "float32")))
                ef = compress.ef_init(params) if grad_compress else jnp.zeros(())
                tree = {"params": params, "opt": opt, "ef": ef}
                tree = ckpt.restore(ckpt_dir, latest, tree)
                params, opt, ef = tree["params"], tree["opt"], tree["ef"]
            start_step = latest
    if params is None:
        with set_mesh(mesh):
            params = init(jax.random.key(seed))
            opt = adamw_init(params, jnp.dtype(getattr(cfg, "opt_dtype", "float32")))
            ef = compress.ef_init(params) if grad_compress else jnp.zeros(())

    jitted = jax.jit(train_step, donate_argnums=(0, 1, 2))
    history = []
    with set_mesh(mesh):
        for step in range(start_step, steps):
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            b = loader.get(step)
            t0 = time.time()
            params, opt, ef, metrics = jitted(params, opt, ef, b)
            loss = float(metrics["loss"])
            history.append({"step": step, "loss": loss, "dt": time.time() - t0})
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                ckpt.save(ckpt_dir, step + 1, {"params": params, "opt": opt, "ef": ef})
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, {"params": params, "opt": opt, "ef": ef})
    return {"history": history, "params": params, "final_loss": history[-1]["loss"] if history else None}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--grad-compress", choices=("topk", "sign"), default=None)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    out = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, fail_at_step=args.fail_at_step,
        reduced=not args.full, grad_compress=args.grad_compress,
    )
    for h in out["history"][-5:]:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  {h['dt']*1e3:.0f} ms")


if __name__ == "__main__":
    main()
