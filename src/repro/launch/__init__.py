# Launchers: mesh construction, multi-pod dry-run, training/serving drivers.
# NOTE: dryrun.py must be the process entry point for 512-device runs — it
# sets XLA_FLAGS before any jax import (see its header).
