"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps with
checkpoint/restart, then decode from the trained weights.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses the gemma2-2b *architecture family* scaled to ~100M params (same local/
global attention, softcaps, GeGLU) — the reduced-config machinery keeps the
structure; dims here are chosen for ~100M. Demonstrates: fault-tolerant loop
(kill it mid-run and re-run the command — it resumes), deterministic data,
cosine schedule, serve_step decode at the end.
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.train import train
from repro.distributed.sharding import rules_for_mesh
from repro.models import transformer as tfm
import repro.configs as configs_mod


_BASE = get_config("gemma2-2b")  # capture before any registry patching


def cfg_100m(wide: bool = False):
    """wide=True is the honest ~130M config (12L, d=768) — use it on real
    hardware; the CPU-host default is the same family at ~32M so 300 steps
    finish in minutes."""
    base = _BASE
    if wide:
        return dataclasses.replace(
            base,
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
            d_ff=3072, vocab=16384, sliding_window=256,
            dtype="float32", remat_chunk=1, grad_accum=1, opt_dtype="float32",
            q_block=64,
        )
    return dataclasses.replace(
        base,
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab=8192, sliding_window=128,
        dtype="float32", remat_chunk=1, grad_accum=1, opt_dtype="float32",
        q_block=64,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--wide", action="store_true", help="~130M config (real hardware)")
    args = ap.parse_args()

    cfg = cfg_100m(args.wide)
    n_params = cfg.param_count()
    print(f"training {cfg.name}-100m: {n_params/1e6:.0f}M params, {args.steps} steps")

    # monkeypatch the registry entry so the driver picks up the 100M config
    mod = configs_mod._MODULES["gemma2-2b"]
    orig = mod.config
    mod.config = lambda: cfg
    try:
        out = train(
            "gemma2-2b", steps=args.steps, batch=8, seq=256,
            ckpt_dir=args.ckpt_dir, ckpt_every=50, reduced=False,
            lr=3e-3, seed=0,
        )
    finally:
        mod.config = orig
    hist = out["history"]
    for h in hist[:: max(len(hist) // 10, 1)]:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}")
    print(f"final loss: {out['final_loss']:.4f} (from {hist[0]['loss']:.4f})")

    print("== decode 16 tokens from the trained model ==")
    mesh = make_test_mesh(1, 1)
    rules = rules_for_mesh(mesh)
    params = out["params"]
    with jax.set_mesh(mesh):
        ctx = tfm.make_context(cfg, mesh, rules, tokens_per_shard=1)
        serve = tfm.make_serve_step(ctx, batch=1)
        cache = tfm.init_cache(cfg, 1, 64)
        tok = jnp.asarray([1], jnp.int32)
        out_toks = []
        for t in range(16):
            logits, cache = serve(params, cache, tok, jnp.asarray(t))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out_toks.append(int(tok[0]))
    print(f"greedy tokens: {out_toks}")


if __name__ == "__main__":
    main()
