"""Quickstart: the MIREX loop end-to-end on a synthetic web collection.

    PYTHONPATH=src python examples/quickstart.py

1. build a corpus + anchor-text representation (the paper's prep jobs),
2. run the collection-statistics job,
3. sequential-scan 16 queries with the paper's QL language model,
4. cross-check the top-10 against the inverted-index baseline.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import anchors, invindex, scan, scoring
from repro.data import synthetic

VOCAB = 4096


def main():
    print("== corpus + links ==")
    corpus = synthetic.make_corpus(n_docs=2048, vocab=VOCAB, max_len=48, seed=0)
    dst, anchor_toks = synthetic.make_links(
        n_docs=2048, n_links=8192, vocab=VOCAB, seed=1
    )

    print("== job 1: anchor-text extraction (paper §3.2) ==")
    anchor_repr, anchor_lens = anchors.extract_anchors(
        jnp.asarray(dst), jnp.asarray(anchor_toks), n_docs=2048, max_anchor_len=64
    )
    print(f"   anchor docs: {int((anchor_lens > 0).sum())} non-empty")

    print("== job 2: collection statistics ==")
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
        chunk_size=256,
    )
    print(f"   |C| = {int(stats.total_terms)} terms, avg doc len {float(stats.avg_doc_len):.1f}")

    print("== job 3: sequential-scan search (QL language model, k=10) ==")
    queries = synthetic.make_queries(corpus, n_queries=16, seed=2)
    state = scan.search_local(
        jnp.asarray(queries),
        (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths)),
        scoring.get_scorer("ql_lm"),
        k=10, chunk_size=256, stats=stats,
    )
    print(f"   top-1 ids: {np.asarray(state.ids[:, 0])}")

    print("== cross-check vs the inverted-index baseline ==")
    idx = invindex.build_index(corpus.tokens, corpus.lengths, vocab=VOCAB)
    ref_scores, ref_ids = invindex.search(
        idx, queries, invindex.stats_from_index(idx), k=10
    )
    np.testing.assert_allclose(np.asarray(state.scores), ref_scores, rtol=3e-5, atol=3e-5)
    print("   scan == index scores ✓ (same model, no index needed)")

    print("== swapping in a 'radical new approach' is one function ==")
    bm25_state = scan.search_local(
        jnp.asarray(queries),
        (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths)),
        scoring.get_scorer("bm25"),  # <- the whole experiment change
        k=10, chunk_size=256, stats=stats,
    )
    overlap = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / 10
        for a, b in zip(np.asarray(state.ids), np.asarray(bm25_state.ids))
    ])
    print(f"   QL vs BM25 top-10 overlap: {overlap:.2f}")


if __name__ == "__main__":
    main()
