"""MIREX as a recsys retrieval service: score users against 200k candidates
with MIND's multi-interest model, served through ``repro.serve``.

    PYTHONPATH=src python examples/candidate_retrieval.py

Shows the retrieval_cand integration (DESIGN §3): the candidate corpus is
the "document collection" held resident by a :class:`DenseSession`, each
user representation is a "query" admitted to the :class:`RetrievalService`,
and the microbatcher forms the query blocks that the Pallas score_topk
kernel scans (dense dispatch). Multi-interest scoring stays model-side —
each interest capsule is submitted as its own query and the per-interest
top-k lists are max-merged client-side.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import scan
from repro.models import recsys
from repro.serve import DenseSession, RetrievalService

N_CANDIDATES = 200_000
K = 50


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-users", type=int, default=4)
    ap.add_argument("--n-candidates", type=int, default=N_CANDIDATES)
    ap.add_argument("--k", type=int, default=K)
    args = ap.parse_args()

    cfg = reduced_config("mind")
    params = recsys.init_params(cfg, jax.random.key(0))
    # fake users with 12-item histories
    history = jnp.asarray(
        np.random.default_rng(1).integers(1, cfg.n_items, (args.n_users, 12)), jnp.int32
    )
    caps = recsys.mind_interests(params, history, cfg)  # [U, I, d]
    n_users, n_interests, dim = caps.shape
    print(f"user interests: {caps.shape}")

    cand = np.random.default_rng(2).standard_normal(
        (args.n_candidates, cfg.embed_dim)
    ).astype(np.float32)

    # resident candidate corpus + service; dense blocks go to the Pallas kernel
    session = DenseSession(cand, "dense_dot", k=args.k, chunk_size=1000, use_kernel=True)
    service = RetrievalService({"dense": session}, max_batch=64, max_delay=2e-3)

    t0 = time.perf_counter()
    rids = np.empty((n_users, n_interests), np.int64)
    for u in range(n_users):
        for i in range(n_interests):  # one query per interest capsule
            rids[u, i] = service.submit(np.asarray(caps[u, i]), "dense")
    results = service.poll()
    results.update(service.drain())
    dt = time.perf_counter() - t0
    rec = service.metrics[-1]
    print(f"served {n_users * n_interests} interest queries in {dt:.3f}s "
          f"(last block: {rec.n_real} queries, {rec.us_per_query:.0f} µs/query)")

    # client-side multi-interest reduce: max over the user's interest lists
    for u in range(min(n_users, 2)):
        per_interest = [results[rids[u, i]] for i in range(n_interests)]
        flat_s = np.concatenate([r.scores for r in per_interest])
        flat_i = np.concatenate([r.ids for r in per_interest])
        order = np.argsort(-flat_s, kind="stable")
        seen, merged = set(), []
        for j in order:
            if flat_i[j] not in seen:
                seen.add(flat_i[j])
                merged.append(j)
            if len(merged) == args.k:
                break
        print(f"user {u}: best candidate {flat_i[merged[0]]} score {flat_s[merged[0]]:.3f}")

    # cross-check the service's dense dispatch against the scan engine
    q0 = caps[:, 0]  # [U, dim] — first interest of every user
    ref = scan.search_local(
        q0, jnp.asarray(cand),
        session.scorer, k=args.k, chunk_size=1000,
    )
    got_s = np.stack([results[rids[u, 0]].scores for u in range(n_users)])
    got_i = np.stack([results[rids[u, 0]].ids for u in range(n_users)])
    np.testing.assert_allclose(got_s, np.asarray(ref.scores), rtol=1e-5)
    np.testing.assert_array_equal(got_i, np.asarray(ref.ids))
    print("service (Pallas kernel) == scan engine ✓")


if __name__ == "__main__":
    main()
