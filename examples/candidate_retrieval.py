"""MIREX as a recsys retrieval engine: score one user against 200k candidates
with MIND's multi-interest model, fused scan + top-k.

    PYTHONPATH=src python examples/candidate_retrieval.py

Shows the retrieval_cand integration (DESIGN §3): the candidate corpus is the
"document collection", the user representation is the "query", the per-model
score_block plugs into the same scan engine, and the Pallas score_topk kernel
is the drop-in dense hot path.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import scan, scoring, topk
from repro.kernels import ops
from repro.models import recsys

N_CANDIDATES = 200_000
K = 50


def main():
    cfg = reduced_config("mind")
    params = recsys.init_params(cfg, jax.random.key(0))
    # fake a user with a 12-item history
    history = jnp.asarray(np.random.default_rng(1).integers(1, cfg.n_items, (1, 12)), jnp.int32)
    caps = recsys.mind_interests(params, history, cfg)  # [1, I, d]
    print(f"user interests: {caps.shape}")

    cand = jnp.asarray(
        np.random.default_rng(2).standard_normal((N_CANDIDATES, cfg.embed_dim)), jnp.float32
    )

    # path 1: multi-interest scoring through the generic scan engine
    t0 = time.perf_counter()
    scores = recsys.score_block_multi_interest(caps, cand)
    state = topk.topk_dense(scores, K)
    jax.block_until_ready(state.scores)
    print(f"multi-interest scan: top-{K} in {time.perf_counter()-t0:.3f}s; "
          f"best id {int(state.ids[0,0])} score {float(state.scores[0,0]):.3f}")

    # path 2: the fused Pallas kernel on the best single interest (dense path)
    q = caps[:, 0]
    t0 = time.perf_counter()
    s, i = ops.score_topk(q, cand, k=K, block_d=1000)
    jax.block_until_ready(s)
    print(f"pallas score_topk (interpret): top-{K} in {time.perf_counter()-t0:.3f}s")

    # cross-check against the engine
    ref = scan.search_local(q, cand, scoring.get_scorer("dense_dot"), k=K, chunk_size=1000)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref.scores), rtol=1e-5)
    print("kernel == scan engine ✓")


if __name__ == "__main__":
    main()
