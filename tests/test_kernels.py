"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("nq,nd,dim", [(8, 256, 64), (16, 512, 128), (128, 1024, 256)])
@pytest.mark.parametrize("k", [5, 32])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_score_topk_sweep(rng, nq, nd, dim, k, dtype):
    q = _rand(rng, (nq, dim), dtype)
    d = _rand(rng, (nd, dim), dtype)
    s, i = ops.score_topk(q, d, k=k, block_d=128)
    rs, ri = ref.score_topk_ref(q, d, k=k)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=tol, atol=tol)
    # discrete boundary: compare as sets (ties may permute)
    for a, b in zip(np.asarray(i), np.asarray(ri)):
        assert len(set(a.tolist()) & set(b.tolist())) >= k - 1


@pytest.mark.parametrize("s,h,kv,hd", [(128, 4, 4, 32), (256, 4, 2, 64), (256, 8, 1, 32)])
@pytest.mark.parametrize("window,cap", [(None, None), (64, None), (None, 30.0), (32, 50.0)])
def test_flash_attention_sweep(rng, s, h, kv, hd, window, cap):
    b = 2
    q = _rand(rng, (b, s, h, hd), jnp.float32)
    k = _rand(rng, (b, s, kv, hd), jnp.float32)
    v = _rand(rng, (b, s, kv, hd), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=True, window=window, cap=cap,
                            block_q=64, block_k=64)
    r = ref.flash_attention_ref(q, k, v, causal=True, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(rng, dtype):
    q = _rand(rng, (1, 128, 4, 32), dtype)
    k = _rand(rng, (1, 128, 2, 32), dtype)
    v = _rand(rng, (1, 128, 2, 32), dtype)
    o = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    r = ref.flash_attention_ref(q, k, v)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("s,kv,g,t", [(512, 2, 2, 300), (1024, 4, 1, 1023), (512, 1, 8, 0)])
@pytest.mark.parametrize("window", [None, 128])
def test_flash_decode_sweep(rng, s, kv, g, t, window):
    b, hd = 2, 32
    h = kv * g
    q = _rand(rng, (b, h, hd), jnp.float32)
    kc = _rand(rng, (b, s, kv, hd), jnp.float32)
    vc = _rand(rng, (b, s, kv, hd), jnp.float32)
    o = ops.flash_decode(q, kc, vc, jnp.asarray(t), window=window, block_s=128)
    r = ref.flash_decode_ref(q, kc, vc, t, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=3e-4, atol=3e-5)


def test_score_topk_matches_scan_engine(rng):
    """The kernel is a drop-in for the scan engine's dense path."""
    from repro.core import scan, scoring

    q = _rand(rng, (8, 128), jnp.float32)
    d = _rand(rng, (512, 128), jnp.float32)
    state = scan.search_local(q, d, scoring.get_scorer("dense_dot"), k=9, chunk_size=128)
    s, i = ops.score_topk(q, d, k=9, block_d=128)
    np.testing.assert_allclose(np.asarray(s), np.asarray(state.scores), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(state.ids))
