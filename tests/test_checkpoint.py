"""Checkpoint/restart + fault tolerance + elastic restore."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.launch.train import train


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16), "step": jnp.asarray(7)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    assert ckpt.latest_step(str(tmp_path)) == 3
    out = ckpt.restore(str(tmp_path), 3, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_no_tmp_left(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    ckpt.save(str(tmp_path), 2, _tree())
    entries = os.listdir(tmp_path)
    assert not any(e.startswith(".tmp") for e in entries)
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_prune_keeps_newest(tmp_path):
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, _tree())
    assert ckpt.all_steps(str(tmp_path)) == [1, 2, 3, 4]
    removed = ckpt.prune(str(tmp_path), keep=2)
    assert removed == [1, 2]
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]
    assert ckpt.prune(str(tmp_path), keep=2) == []  # idempotent
    ckpt.restore(str(tmp_path), 4, _tree())  # survivors still loadable
    assert ckpt.latest_step(str(tmp_path)) == 4
    with pytest.raises(ValueError, match="keep"):
        ckpt.prune(str(tmp_path), keep=0)


def test_structure_mismatch_detected(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    wrong = {"a": jnp.zeros((3, 4)), "nested": {"c": jnp.zeros((5,))}}
    with pytest.raises(ValueError, match="structure mismatch"):
        ckpt.restore(str(tmp_path), 1, wrong)


def test_elastic_restore_resharding(tmp_path, mesh11):
    """Restore under explicit NamedShardings of the current mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh11, P()), t)
    out = ckpt.restore(str(tmp_path), 5, t, shardings=shardings)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_failure_injection_and_resume_bit_identical(tmp_path):
    """Paper-grade fault tolerance: a job killed mid-run and restarted from
    its checkpoint produces the same final state as an uninterrupted run
    (deterministic step-keyed data + checkpointed optimizer)."""
    uninterrupted = train(
        "h2o-danube-1.8b", steps=8, batch=2, seq=16,
        ckpt_dir=str(tmp_path / "a"), ckpt_every=4,
    )
    with pytest.raises(RuntimeError, match="injected failure"):
        train(
            "h2o-danube-1.8b", steps=8, batch=2, seq=16,
            ckpt_dir=str(tmp_path / "b"), ckpt_every=4, fail_at_step=6,
        )
    resumed = train(
        "h2o-danube-1.8b", steps=8, batch=2, seq=16,
        ckpt_dir=str(tmp_path / "b"), ckpt_every=4, resume=True,
    )
    assert resumed["history"][0]["step"] == 4  # resumed from the step-4 ckpt
    np.testing.assert_allclose(
        resumed["final_loss"], uninterrupted["final_loss"], rtol=1e-6
    )
    for a, b in zip(
        jax.tree.leaves(uninterrupted["params"]), jax.tree.leaves(resumed["params"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpointer_preserves_order_and_contents(tmp_path):
    """The writer thread replays the exact synchronous commit sequence:
    save -> prune, in submission order, same bytes on disk."""
    t = _tree()
    with ckpt.AsyncCheckpointer() as w:
        for step in (1, 2, 3, 4):
            w.submit(ckpt.save, str(tmp_path), step, t)
            w.submit(ckpt.prune, str(tmp_path), 2)
        w.drain()
        assert ckpt.all_steps(str(tmp_path)) == [3, 4]
    out = ckpt.restore(str(tmp_path), 4, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpointer_poisons_after_error(tmp_path):
    """First failure skips every later task (a manifest must never claim a
    commit that failed) and re-raises on drain — and keeps re-raising."""
    ran = []

    def boom():
        raise OSError("no space (injected)")

    w = ckpt.AsyncCheckpointer()
    w.submit(ran.append, "a")
    w.submit(boom)
    w.submit(ran.append, "b")  # must never run
    with pytest.raises(OSError, match="no space"):
        w.drain()
    assert ran == ["a"]
    with pytest.raises(OSError, match="no space"):  # poison is permanent
        w.submit(ran.append, "c")
    assert ran == ["a"]


def test_async_checkpointer_mid_write_kill_atomic(tmp_path, monkeypatch):
    """A kill mid-write on the writer thread (simulated: np.save dies while
    step 2 streams out) leaves only fully-committed steps visible — the
    atomic rename-commit survives the move off the main thread."""
    t = _tree()
    real_save = np.save
    calls = {"n": 0}

    def dying_save(path, arr):
        calls["n"] += 1
        if calls["n"] > len(jax.tree.leaves(t)):  # die inside step 2's write
            raise KeyboardInterrupt("killed mid-write")
        return real_save(path, arr)

    monkeypatch.setattr(np, "save", dying_save)
    w = ckpt.AsyncCheckpointer()
    w.submit(ckpt.save, str(tmp_path), 1, t)
    w.submit(ckpt.save, str(tmp_path), 2, t)
    with pytest.raises(KeyboardInterrupt):
        w.drain()
    monkeypatch.setattr(np, "save", real_save)
    # step 1 committed whole; step 2's partial write never got renamed in
    assert ckpt.all_steps(str(tmp_path)) == [1]
    ckpt.restore(str(tmp_path), 1, t)  # and is loadable


def test_async_writer_shared_by_two_shards_poison_propagates(tmp_path):
    """Two scan shards sharing one writer (the pipelined executor's
    shared-writer configuration): a commit failure on shard A's step poisons
    the queue for BOTH shards — shard B can neither sneak a later save past
    the failure nor drain without seeing the original error."""
    a_dir, b_dir = str(tmp_path / "a"), str(tmp_path / "b")
    a_submitted = threading.Event()
    errs = []

    def failing_commit(step, tmp):
        raise OSError("disk full (injected)")

    w = ckpt.AsyncCheckpointer()

    def shard_a():
        w.submit(ckpt.save, a_dir, 1, _tree())
        w.submit(ckpt.save, a_dir, 2, _tree(), on_commit=failing_commit)
        a_submitted.set()
        try:
            w.drain()
        except OSError as e:
            errs.append(("a", str(e)))

    def shard_b():
        a_submitted.wait()
        try:
            # poison may land at submit time or at drain time depending on
            # how far the writer has gotten — either way it must surface
            w.submit(ckpt.save, b_dir, 1, _tree())
            w.drain()
        except OSError as e:
            errs.append(("b", str(e)))

    ta = threading.Thread(target=shard_a)
    tb = threading.Thread(target=shard_b)
    ta.start(), tb.start()
    ta.join(timeout=30), tb.join(timeout=30)
    assert not ta.is_alive() and not tb.is_alive()
    assert sorted(s for s, _ in errs) == ["a", "b"]
    assert all("disk full" in m for _, m in errs)
    # shard A: step 1 committed whole, step 2's aborted commit left as tmp
    assert ckpt.all_steps(a_dir) == [1]
    assert any(e.startswith(".tmp") for e in os.listdir(a_dir))
    # shard B's save was queued after the failure: skipped, never written
    assert ckpt.all_steps(b_dir) == []
    with pytest.raises(OSError, match="disk full"):  # poison survives close
        w.close()


def test_async_writer_kill_while_draining_unblocks_and_stays_atomic(tmp_path):
    """A kill landing on the writer thread while another thread is blocked
    in drain() must unblock that drain with the error (skipped tasks still
    count toward the queue join), leaving only whole checkpoints on disk —
    and a retry of the failed step on a fresh writer commits cleanly over
    the stale tmp dir."""
    release = threading.Event()
    caught = []

    def killed_commit(step, tmp):
        raise KeyboardInterrupt("killed mid-commit")

    w = ckpt.AsyncCheckpointer()
    w.submit(ckpt.save, str(tmp_path), 1, _tree())
    w.submit(release.wait)  # parks the writer until the drainer is running
    w.submit(ckpt.save, str(tmp_path), 2, _tree(), on_commit=killed_commit)
    w.submit(ckpt.save, str(tmp_path), 3, _tree())  # must be skipped

    def drainer():
        try:
            w.drain()
        except KeyboardInterrupt as e:
            caught.append(str(e))

    t = threading.Thread(target=drainer)
    t.start()
    release.set()
    t.join(timeout=30)
    assert not t.is_alive(), "drain() hung after a writer-thread kill"
    assert caught == ["killed mid-commit"]
    # only step 1 committed; step 2 aborted pre-rename; step 3 skipped
    assert ckpt.all_steps(str(tmp_path)) == [1]
    entries = os.listdir(tmp_path)
    assert ".tmp-step_00000002" in entries
    assert not any("00000003" in e for e in entries)
    # retry of the failed step (fresh writer, as the scheduler does after a
    # backoff) re-opens the poisoned dir and commits over the stale tmp
    with ckpt.AsyncCheckpointer() as w2:
        w2.submit(ckpt.save, str(tmp_path), 2, _tree())
        w2.drain()
    assert ckpt.all_steps(str(tmp_path)) == [1, 2]
    assert not any(e.startswith(".tmp") for e in os.listdir(tmp_path))
    ckpt.restore(str(tmp_path), 2, _tree())


def test_async_checkpointer_close_idempotent():
    w = ckpt.AsyncCheckpointer()
    w.submit(lambda: None)
    w.close()
    w.close()  # second close is a no-op
    with pytest.raises(RuntimeError, match="closed"):
        w.submit(lambda: None)


def test_grad_compression_training_converges(tmp_path):
    """Error-feedback top-k compression still reduces the loss."""
    out = train(
        "h2o-danube-1.8b", steps=12, batch=2, seq=16, grad_compress="topk",
        ckpt_dir=None, lr=3e-3,
    )
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]
