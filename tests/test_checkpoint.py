"""Checkpoint/restart + fault tolerance + elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.launch.train import train


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16), "step": jnp.asarray(7)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    assert ckpt.latest_step(str(tmp_path)) == 3
    out = ckpt.restore(str(tmp_path), 3, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_no_tmp_left(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    ckpt.save(str(tmp_path), 2, _tree())
    entries = os.listdir(tmp_path)
    assert not any(e.startswith(".tmp") for e in entries)
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_prune_keeps_newest(tmp_path):
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, _tree())
    assert ckpt.all_steps(str(tmp_path)) == [1, 2, 3, 4]
    removed = ckpt.prune(str(tmp_path), keep=2)
    assert removed == [1, 2]
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]
    assert ckpt.prune(str(tmp_path), keep=2) == []  # idempotent
    ckpt.restore(str(tmp_path), 4, _tree())  # survivors still loadable
    assert ckpt.latest_step(str(tmp_path)) == 4
    with pytest.raises(ValueError, match="keep"):
        ckpt.prune(str(tmp_path), keep=0)


def test_structure_mismatch_detected(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    wrong = {"a": jnp.zeros((3, 4)), "nested": {"c": jnp.zeros((5,))}}
    with pytest.raises(ValueError, match="structure mismatch"):
        ckpt.restore(str(tmp_path), 1, wrong)


def test_elastic_restore_resharding(tmp_path, mesh11):
    """Restore under explicit NamedShardings of the current mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh11, P()), t)
    out = ckpt.restore(str(tmp_path), 5, t, shardings=shardings)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_failure_injection_and_resume_bit_identical(tmp_path):
    """Paper-grade fault tolerance: a job killed mid-run and restarted from
    its checkpoint produces the same final state as an uninterrupted run
    (deterministic step-keyed data + checkpointed optimizer)."""
    uninterrupted = train(
        "h2o-danube-1.8b", steps=8, batch=2, seq=16,
        ckpt_dir=str(tmp_path / "a"), ckpt_every=4,
    )
    with pytest.raises(RuntimeError, match="injected failure"):
        train(
            "h2o-danube-1.8b", steps=8, batch=2, seq=16,
            ckpt_dir=str(tmp_path / "b"), ckpt_every=4, fail_at_step=6,
        )
    resumed = train(
        "h2o-danube-1.8b", steps=8, batch=2, seq=16,
        ckpt_dir=str(tmp_path / "b"), ckpt_every=4, resume=True,
    )
    assert resumed["history"][0]["step"] == 4  # resumed from the step-4 ckpt
    np.testing.assert_allclose(
        resumed["final_loss"], uninterrupted["final_loss"], rtol=1e-6
    )
    for a, b in zip(
        jax.tree.leaves(uninterrupted["params"]), jax.tree.leaves(resumed["params"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_compression_training_converges(tmp_path):
    """Error-feedback top-k compression still reduces the loss."""
    out = train(
        "h2o-danube-1.8b", steps=12, batch=2, seq=16, grad_compress="topk",
        ckpt_dir=None, lr=3e-3,
    )
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]
