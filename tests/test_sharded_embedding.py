"""Vocab-sharded embedding lookup (§Perf hillclimb B2's primitive)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.embedding import field_embed, make_sharded_field_embed


def test_sharded_field_embed_equals_local(mesh11):
    r = np.random.default_rng(0)
    tables = jnp.asarray(r.standard_normal((4, 32, 8)), jnp.float32)
    ids = jnp.asarray(r.integers(0, 32, (16, 4)), jnp.int32)
    fn = make_sharded_field_embed(mesh11, "model", ("data",))
    with jax.set_mesh(mesh11):
        out = fn(tables, ids)
    want = field_embed(tables, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def test_sharded_field_embed_gradients(mesh11):
    r = np.random.default_rng(1)
    tables = jnp.asarray(r.standard_normal((2, 16, 4)), jnp.float32)
    ids = jnp.asarray(r.integers(0, 16, (8, 2)), jnp.int32)
    fn = make_sharded_field_embed(mesh11, "model", ("data",))

    def loss_sharded(t):
        return jnp.sum(jnp.square(fn(t, ids)))

    def loss_local(t):
        return jnp.sum(jnp.square(field_embed(t, ids)))

    with jax.set_mesh(mesh11):
        g1 = jax.grad(loss_sharded)(tables)
    g2 = jax.grad(loss_local)(tables)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-6)
    # only touched rows get gradient
    touched = np.zeros((2, 16), bool)
    for f in range(2):
        touched[f, np.asarray(ids)[:, f]] = True
    zero_rows = ~touched
    assert np.allclose(np.asarray(g1)[zero_rows], 0.0)
