"""Optional-dependency shim for hypothesis.

The tier-1 environment does not ship hypothesis; property tests should
degrade to skips, not collection errors. Test modules import ``given``,
``settings``, and ``st`` from here: when hypothesis is installed they are
the real thing, otherwise ``@given`` marks the test skipped and ``st``
returns inert placeholder strategies. Install ``requirements-dev.txt`` to
run the full property suites.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _PlaceholderStrategies:
        """Accepts any strategy constructor call and returns None."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None

            return strategy

    st = _PlaceholderStrategies()
