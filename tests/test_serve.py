"""Serve subsystem: microbatch triggers + padding, dispatch parity against
the scan engine oracles, and the k-bounded bitonic kernel merge."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anchors, scan, scoring
from repro.data import synthetic
from repro.kernels import ops
from repro.kernels.score_topk import bitonic_merge_desc
from repro.serve import DenseSession, LexicalSession, Microbatcher, RetrievalService
from repro.serve.microbatch import bucket_size, pad_rows, unpad_results


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------- microbatch


@pytest.mark.parametrize("n,expect", [(1, 8), (7, 8), (8, 8), (9, 16), (65, 128)])
def test_bucket_size(n, expect):
    assert bucket_size(n, min_bucket=8) == expect


@pytest.mark.parametrize("n", [1, 5, 8, 13])
def test_pad_unpad_roundtrip(rng, n):
    q = rng.standard_normal((n, 16)).astype(np.float32)
    padded = pad_rows(q, bucket_size(n), 0.0)
    assert padded.shape[0] == bucket_size(n)
    assert padded.shape[0] % 8 == 0
    np.testing.assert_array_equal(unpad_results(padded, n), q)
    assert (padded[n:] == 0.0).all()


def test_size_trigger_fires_at_max_batch():
    mb = Microbatcher(max_batch=4, max_delay=10.0, pad_value=-1)
    for rid in range(3):
        mb.submit(rid, np.zeros(4, np.int32), now=0.0)
    assert not mb.ready(0.0)  # under size, before deadline
    mb.submit(3, np.zeros(4, np.int32), now=0.0)
    block = mb.pop_block(0.0)
    assert block is not None and block.trigger == "size"
    assert block.rids == (0, 1, 2, 3) and block.n_real == 4
    assert len(mb) == 0


def test_deadline_trigger_fires_on_oldest_request():
    mb = Microbatcher(max_batch=100, max_delay=0.5, min_bucket=8, pad_value=-1)
    mb.submit(0, np.zeros(4, np.int32), now=0.0)
    mb.submit(1, np.zeros(4, np.int32), now=0.3)
    assert mb.pop_block(0.49) is None  # oldest has waited 0.49 < 0.5
    assert mb.next_deadline() == pytest.approx(0.5)
    block = mb.pop_block(0.5)
    assert block is not None and block.trigger == "deadline"
    assert block.n_real == 2 and block.n_padded == 8  # padded to min bucket
    assert (block.queries[2:] == -1).all()


def test_oversize_queue_splits_into_max_batch_blocks():
    mb = Microbatcher(max_batch=4, max_delay=10.0, pad_value=-1)
    for rid in range(10):
        mb.submit(rid, np.zeros(2, np.int32), now=0.0)
    blocks = mb.drain(0.0)
    assert [b.n_real for b in blocks] == [4, 4, 2]
    assert [r for b in blocks for r in b.rids] == list(range(10))


# ------------------------------------------------------------------ dispatch


def _lexical_fixture(n_docs=512, vocab=256, chunk=64, k=10):
    corpus = synthetic.make_corpus(n_docs=n_docs, vocab=vocab, max_len=24, seed=0)
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=vocab, chunk_size=chunk
    )
    session = LexicalSession(
        corpus.tokens, corpus.lengths, "ql_lm", k=k, chunk_size=chunk, stats=stats
    )
    return corpus, stats, session


def test_lexical_dispatch_matches_direct_scan():
    corpus, stats, session = _lexical_fixture()
    queries = synthetic.make_queries(corpus, n_queries=13, seed=3)
    clock = FakeClock()
    service = RetrievalService({"lexical": session}, max_batch=64, max_delay=0.01, clock=clock)
    rids = [service.submit(q, "lexical") for q in queries]
    assert service.poll() == {}  # no trigger yet
    clock.advance(0.02)
    results = service.poll()
    assert sorted(results) == sorted(rids)
    ref = scan.search_local(
        jnp.asarray(queries),
        (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths)),
        scoring.get_scorer("ql_lm"),
        k=session.k, chunk_size=session.chunk_size, stats=stats,
    )
    for row, rid in enumerate(rids):
        np.testing.assert_allclose(results[rid].scores, np.asarray(ref.scores[row]), rtol=1e-6)
        np.testing.assert_array_equal(results[rid].ids, np.asarray(ref.ids[row]))
    rec = service.metrics[-1]
    assert rec.trigger == "deadline" and rec.n_real == 13 and rec.n_padded == 16


@pytest.mark.parametrize("use_kernel", [False, True])
def test_dense_dispatch_matches_host_oracle(rng, use_kernel):
    """Service dense path (incl. Pallas kernel dispatch) == unblocked oracle."""
    vecs = rng.standard_normal((512, 64)).astype(np.float32)
    queries = rng.standard_normal((11, 64)).astype(np.float32)
    session = DenseSession(vecs, "dense_dot", k=9, chunk_size=128, use_kernel=use_kernel)
    service = RetrievalService({"dense": session}, max_batch=11, max_delay=10.0)
    rids = [service.submit(q, "dense") for q in queries]
    results = service.poll()  # size trigger: 11 == max_batch
    assert sorted(results) == sorted(rids)
    ref = scan.search_dense_host(jnp.asarray(queries), jnp.asarray(vecs), k=9)
    for row, rid in enumerate(rids):
        np.testing.assert_allclose(results[rid].scores, np.asarray(ref.scores[row]), rtol=1e-5)
        np.testing.assert_array_equal(results[rid].ids, np.asarray(ref.ids[row]))


def test_every_query_answered_exactly_once_across_waves(rng):
    vecs = rng.standard_normal((256, 32)).astype(np.float32)
    session = DenseSession(vecs, "dense_dot", k=5, chunk_size=64, use_kernel=False)
    clock = FakeClock()
    service = RetrievalService({"dense": session}, max_batch=8, max_delay=0.1, clock=clock)
    answered = {}
    submitted = []
    for wave in range(3):
        for _ in range(11):  # 11 per wave: one size-triggered block + remainder
            submitted.append(service.submit(rng.standard_normal(32).astype(np.float32)))
        answered.update(service.poll())
        clock.advance(0.2)
    answered.update(service.poll())
    answered.update(service.drain())
    assert sorted(answered) == sorted(submitted)
    assert all(len(r.scores) == 5 for r in answered.values())


# -------------------------------------------------------- k-bounded merge


def test_bitonic_merge_desc_matches_numpy(rng):
    for m in (1, 2, 8, 32):
        a_s = -np.sort(-rng.standard_normal((3, m)).astype(np.float32), axis=-1)
        b_s = -np.sort(-rng.standard_normal((3, m)).astype(np.float32), axis=-1)
        a_i = rng.integers(0, 1000, (3, m)).astype(np.int32)
        b_i = rng.integers(1000, 2000, (3, m)).astype(np.int32)
        s, i = bitonic_merge_desc(
            jnp.asarray(a_s), jnp.asarray(a_i), jnp.asarray(b_s), jnp.asarray(b_i)
        )
        cat_s = np.concatenate([a_s, b_s], axis=-1)
        cat_i = np.concatenate([a_i, b_i], axis=-1)
        order = np.argsort(-cat_s, kind="stable")[:, :m]
        np.testing.assert_allclose(
            np.asarray(s), np.take_along_axis(cat_s, order, axis=-1)
        )
        np.testing.assert_array_equal(
            np.asarray(i), np.take_along_axis(cat_i, order, axis=-1)
        )


@pytest.mark.parametrize("k", [5, 16, 100])
def test_kernel_bitonic_merge_matches_host_oracle(rng, k):
    """Acceptance: exact ids on distinct scores, scores within 1e-5."""
    q = jnp.asarray(rng.standard_normal((8, 128)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((1024, 128)), jnp.float32)
    s, i = ops.score_topk(q, d, k=k, block_d=128, merge="bitonic")
    ref = scan.search_dense_host(q, d, k=k)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref.scores), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref.ids))


def test_kernel_bitonic_equals_legacy_concat_merge(rng):
    q = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((512, 64)), jnp.float32)
    s1, i1 = ops.score_topk(q, d, k=12, block_d=64, merge="bitonic")
    s2, i2 = ops.score_topk(q, d, k=12, block_d=64, merge="concat")
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
