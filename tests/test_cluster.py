"""The mesh-sharded map/reduce layer: plans, merges, jobs, serve sessions.

The load-bearing contract under test is **shard-count invariance**: however
the corpus is cut (1/2/4 shards), whichever path folds the shards (host fold
or Pallas kernel), and whichever shards get killed and resumed, the merged
top-k state — ids *and* score bytes — equals the single-host oracle scan,
and the TREC run files written from it are byte-identical.
"""

import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cluster
from repro.core import anchors, scan, scoring, topk
from repro.data import synthetic
from repro.experiments import job as exp_job
from repro.experiments import runner

VOCAB = 2048
N_DOCS = 512
CHUNK = 64
K = 10

SCORERS = lambda: [  # noqa: E731 — tiny grid shared by most tests
    scoring.make_variant("ql_lm"),
    scoring.make_variant("bm25"),
    scoring.make_variant("ql_lm", lam=0.5),
]


@pytest.fixture(scope="module")
def collection():
    corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=32, seed=0)
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
        chunk_size=CHUNK,
    )
    queries = jnp.asarray(synthetic.make_queries(corpus, n_queries=8, seed=1))
    docs = (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths))
    return corpus, stats, queries, docs


@pytest.fixture(scope="module")
def oracle(collection):
    """Single-host whole-corpus scan — the ground truth every plan must hit."""
    _, stats, queries, docs = collection
    return scan.search_local_multi(
        queries, docs, SCORERS(), k=K, chunk_size=CHUNK, stats=stats
    )


def assert_states_identical(got, want, *, err=""):
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids), err_msg=err)
    assert np.asarray(got.scores).tobytes() == np.asarray(want.scores).tobytes(), err


# -- plan layer --------------------------------------------------------------


def test_plan_shards_geometry():
    plan = cluster.plan_shards(N_DOCS, n_shards=4, chunk_size=CHUNK)
    assert plan.n_shards == 4
    assert [s.n_rows for s in plan.shards] == [128] * 4
    assert [s.doc_id_offset for s in plan.shards] == [0, 128, 256, 384]
    # shards tile [0, n_docs) exactly
    assert plan.shards[0].start == 0 and plan.shards[-1].stop == N_DOCS
    for a, b in zip(plan.shards, plan.shards[1:]):
        assert a.stop == b.start
    d = plan.describe()
    assert d["n_shards"] == 4 and d["shards"][1] == [128, 256]


def test_plan_shards_rejects_bad_cuts():
    with pytest.raises(ValueError, match="n_shards"):
        cluster.plan_shards(N_DOCS, n_shards=0, chunk_size=CHUNK)
    with pytest.raises(ValueError, match="equal shards"):
        cluster.plan_shards(N_DOCS, n_shards=3, chunk_size=CHUNK)
    with pytest.raises(ValueError, match="chunk_size"):
        cluster.plan_shards(N_DOCS, n_shards=4, chunk_size=96)


def test_plan_for_mesh_scan_axes(mesh11):
    plan = cluster.plan_for_mesh(mesh11, N_DOCS, chunk_size=CHUNK)
    assert plan.n_shards == 1  # 1x1 mesh: the degenerate single-host cluster
    assert plan.axis_names == ("data", "model")
    assert cluster.mesh_scan_axes(mesh11) == ("data", "model")


def test_plan_for_single_axis_mesh():
    """The degenerate rules_for_mesh fallback maps dp and tp to the same
    axis on a 1-axis mesh; scan_axes must deduplicate or every shard is
    double-counted (and PartitionSpecs get an invalid repeated axis)."""
    from repro.distributed.sharding import AxisRules, rules_for_mesh

    mesh = jax.make_mesh((1,), ("data",))
    assert rules_for_mesh(mesh).scan_axes == ("data",)
    assert cluster.mesh_scan_axes(mesh) == ("data",)
    plan = cluster.plan_for_mesh(mesh, N_DOCS, chunk_size=CHUNK)
    assert plan.n_shards == 1 and plan.axis_names == ("data",)
    # multi-device single-axis rules (can't build the mesh on one device,
    # but the rules algebra is device-independent)
    assert AxisRules(dp=("x",), tp="x").scan_axes == ("x",)


# -- reduce layer ------------------------------------------------------------


def test_merge_lex_is_value_deterministic(oracle):
    """Lexicographic merge ignores shard order/grouping — unlike positional
    ``lax.top_k`` merges, which is why it's the cluster reduce."""
    a = topk.TopKState(scores=oracle.scores[:, :, :K], ids=oracle.ids[:, :, :K])
    empty = topk.init(K, a.scores.shape[:-1])
    ab = topk.merge_lex(a, empty)
    ba = topk.merge_lex(empty, a)
    assert_states_identical(ab, a)
    assert_states_identical(ba, a)


def test_reduce_lex_grouping_invariance(collection, oracle):
    _, stats, queries, docs = collection
    plan = cluster.plan_shards(N_DOCS, n_shards=4, chunk_size=CHUNK)
    states = [
        cluster.map_shard(
            queries, s.take(docs), SCORERS(), k=K, chunk_size=CHUNK, stats=stats,
            doc_id_offset=s.doc_id_offset,
        )
        for s in plan.shards
    ]
    left = cluster.reduce_states(states)
    reverse = cluster.reduce_states(states[::-1])
    paired = topk.merge_lex(
        topk.merge_lex(states[0], states[1]), topk.merge_lex(states[2], states[3])
    )
    for got, label in ((left, "left"), (reverse, "reverse"), (paired, "paired")):
        assert_states_identical(got, oracle, err=label)


def test_reduce_lex_rejects_empty_and_mismatch():
    with pytest.raises(ValueError, match="at least one"):
        topk.reduce_lex([])
    with pytest.raises(ValueError, match="shape mismatch"):
        topk.merge_lex(topk.init(4, (2,)), topk.init(8, (2,)))


# -- map + shard-count invariance -------------------------------------------


@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_shard_count_invariance(collection, oracle, n_shards, use_kernel):
    """1/2/4 shards, host fold and Pallas kernel: bit-identical to the
    single-host oracle (ids and score bytes)."""
    _, stats, queries, docs = collection
    plan = cluster.plan_shards(N_DOCS, n_shards=n_shards, chunk_size=CHUNK)
    state = cluster.scan_shards(
        plan, queries, docs, SCORERS(), k=K, stats=stats, use_kernel=use_kernel
    )
    assert_states_identical(state, oracle, err=f"{n_shards} shards kernel={use_kernel}")


def test_shard_invariance_with_tied_scores(collection):
    """Duplicate docs across a shard boundary force exact score ties; the
    lexicographic tie-break (smaller id) must match the oracle's fold."""
    corpus, stats, queries, _ = collection
    dup = (
        jnp.asarray(np.concatenate([corpus.tokens[:256]] * 2)),
        jnp.asarray(np.concatenate([corpus.lengths[:256]] * 2)),
    )
    want = scan.search_local_multi(
        queries, dup, SCORERS(), k=K, chunk_size=CHUNK, stats=stats
    )
    for n_shards in (2, 4):
        plan = cluster.plan_shards(512, n_shards=n_shards, chunk_size=CHUNK)
        got = cluster.scan_shards(plan, queries, dup, SCORERS(), k=K, stats=stats)
        assert_states_identical(got, want, err=f"{n_shards} shards")
        # the ties are real: every duplicated doc pairs with id+256
        ids = np.asarray(got.ids)
        assert (ids >= 256).any() and (ids < 256).any()


def test_shard_invariance_k_exceeds_shard(collection):
    """k > rows-per-shard: shards emit (-inf, -1) empty slots; the merge must
    rank every real doc above every sentinel and keep sentinel purity."""
    _, stats, queries, docs = collection
    small = jax.tree.map(lambda x: x[:128], docs)
    k = 200  # > 128 total rows, so even the merged state keeps empties
    want = scan.search_local_multi(
        queries, small, SCORERS(), k=k, chunk_size=32, stats=stats
    )
    plan = cluster.plan_shards(128, n_shards=4, chunk_size=32)
    got = cluster.scan_shards(plan, queries, small, SCORERS(), k=k, stats=stats)
    assert_states_identical(got, want)
    mask = np.asarray(topk.valid_mask(got))
    assert (~mask).any(), "expected empty slots with k > corpus"
    assert (np.asarray(got.ids)[~mask] == -1).all()


def test_map_shard_dense_kernel_stacks_grid_axis():
    q = jnp.asarray(synthetic.make_dense_corpus(n_docs=16, dim=32, seed=0))
    d = jnp.asarray(synthetic.make_dense_corpus(n_docs=256, dim=32, seed=1))
    scorer = scoring.get_scorer("dense_dot")
    got = cluster.map_shard(q, d, [scorer], k=K, chunk_size=64, use_kernel=True)
    want = scan.search_local(q, d, scorer, k=K, chunk_size=64, use_kernel=True)
    assert got.ids.shape == (1, 16, K)
    np.testing.assert_array_equal(np.asarray(got.ids)[0], np.asarray(want.ids))


# -- mesh execution (1-device mesh in-process; multi-device in test_system) --


def test_search_mesh_multi_model(collection, oracle, mesh11):
    _, stats, queries, docs = collection
    fn = cluster.search_mesh(
        mesh11, queries, docs, SCORERS(), k=K, chunk_size=CHUNK, stats=stats
    )
    with jax.set_mesh(mesh11):
        state = fn(queries, docs, stats)
    assert_states_identical(state, oracle)


def test_search_sharded_deprecated_alias(mesh11):
    q = jnp.asarray(synthetic.make_dense_corpus(n_docs=16, dim=32, seed=2))
    d = jnp.asarray(synthetic.make_dense_corpus(n_docs=256, dim=32, seed=3))
    with pytest.warns(DeprecationWarning, match="search_mesh"):
        fn = scan.search_sharded(
            mesh11, ("data", "model"), q, d, scoring.get_scorer("dense_dot"),
            k=9, chunk_size=32,
        )
    with jax.set_mesh(mesh11):
        state = fn(q, d, None)
    ref = scan.search_dense_host(q, d, 9)
    assert state.ids.shape == (16, 9)  # alias keeps the squeezed legacy shape
    np.testing.assert_array_equal(np.asarray(state.ids), np.asarray(ref.ids))


# -- sharded jobs: per-shard kill/resume, byte-identical artifacts -----------


def test_sharded_job_kill_resume_run_files_byte_identical(collection, tmp_path):
    """Kill one shard mid-job; resume; merged run files must be byte-identical
    to the uninterrupted single-host job's (the acceptance contract)."""
    _, stats, queries, docs = collection
    scorers = SCORERS()
    kw = dict(k=K, chunk_size=CHUNK, segment_chunks=2, stats=stats)

    single = cluster.run_sharded_scan_job(
        queries, docs, scorers, ckpt_dir=str(tmp_path / "single"), **kw
    )
    assert single.plan.n_shards == 1
    # one-shard layout is the classic flat single-host one
    assert os.path.exists(tmp_path / "single" / "progress.json")

    with pytest.raises(RuntimeError, match="injected failure"):
        cluster.run_sharded_scan_job(
            queries, docs, scorers, n_shards=4, ckpt_dir=str(tmp_path / "sh"),
            fail_at_segment=0, fail_at_shard=2, **kw
        )
    # shards 0 and 1 finished, 2 committed its first segment then died, 3 never ran
    for idx, complete in ((0, True), (1, True)):
        prog = cluster.read_progress(str(tmp_path / "sh" / f"shard_{idx:04d}"))
        assert prog["shards"][str(idx)]["complete"] is complete
    prog2 = cluster.read_progress(str(tmp_path / "sh" / "shard_0002"))
    assert prog2["shards"]["2"]["segments_done"] == 1
    assert cluster.read_progress(str(tmp_path / "sh" / "shard_0003")) is None
    assert cluster.read_cluster_manifest(str(tmp_path / "sh"))["plan"]["n_shards"] == 4

    resumed = cluster.run_sharded_scan_job(
        queries, docs, scorers, n_shards=4, ckpt_dir=str(tmp_path / "sh"), **kw
    )
    by_shard = [r.resumed_from for r in resumed.shard_results]
    assert by_shard[2] == 1 and by_shard[3] == 0  # killed shard resumed mid-way
    assert_states_identical(resumed.state, single.state)

    pa = runner.write_run_files(str(tmp_path / "ra"), scorers, single.state, tag_prefix="t")
    pb = runner.write_run_files(str(tmp_path / "rb"), scorers, resumed.state, tag_prefix="t")
    for name in pa:
        assert open(pa[name], "rb").read() == open(pb[name], "rb").read(), name

    # idempotent re-run: every shard restores, nothing re-folds
    again = cluster.run_sharded_scan_job(
        queries, docs, scorers, n_shards=4, ckpt_dir=str(tmp_path / "sh"), **kw
    )
    assert again.segments_run == 0
    assert_states_identical(again.state, single.state)


def test_sharded_job_rejects_replanned_dir(collection, tmp_path):
    _, stats, queries, docs = collection
    kw = dict(k=K, chunk_size=CHUNK, segment_chunks=2, stats=stats)
    cluster.run_sharded_scan_job(
        queries, docs, SCORERS(), n_shards=4, ckpt_dir=str(tmp_path / "c"), **kw
    )
    with pytest.raises(ValueError, match="different shard plan"):
        cluster.run_sharded_scan_job(
            queries, docs, SCORERS(), n_shards=2, ckpt_dir=str(tmp_path / "c"), **kw
        )
    # resume=False re-plans cleanly
    fresh = cluster.run_sharded_scan_job(
        queries, docs, SCORERS(), n_shards=2, ckpt_dir=str(tmp_path / "c"),
        resume=False, **kw
    )
    assert fresh.plan.n_shards == 2 and fresh.segments_run == fresh.segments_total


def test_sharded_job_kernel_path_kill_resume(collection, tmp_path):
    """Kernel-on sharded job — including a per-shard kill and resume through
    the kernel's init_state merge — == host-fold sharded job, id-exact."""
    _, stats, queries, docs = collection
    kw = dict(k=K, chunk_size=CHUNK, segment_chunks=2, stats=stats, n_shards=2)
    host = cluster.run_sharded_scan_job(queries, docs, SCORERS(), **kw)
    with pytest.raises(RuntimeError, match="injected failure"):
        cluster.run_sharded_scan_job(
            queries, docs, SCORERS(), ckpt_dir=str(tmp_path / "k"),
            use_kernel=True, fail_at_segment=0, fail_at_shard=1, **kw
        )
    kern = cluster.run_sharded_scan_job(
        queries, docs, SCORERS(), ckpt_dir=str(tmp_path / "k"), use_kernel=True, **kw
    )
    # the killed shard resumed from its committed segment, through the
    # kernel branch's init_state fold — not a silent from-scratch re-run
    assert kern.shard_results[1].resumed_from == 1
    assert kern.shard_results[1].segments_run == 1
    np.testing.assert_array_equal(np.asarray(kern.state.ids), np.asarray(host.state.ids))


def test_run_scan_job_is_one_shard_special_case(collection):
    """`experiments.job.run_scan_job` is literally the cluster shard engine."""
    assert exp_job.run_scan_job is cluster.run_scan_job
    _, stats, queries, docs = collection
    kw = dict(k=K, chunk_size=CHUNK, segment_chunks=2, stats=stats)
    a = exp_job.run_scan_job(queries, docs, SCORERS(), **kw)
    b = cluster.run_sharded_scan_job(queries, docs, SCORERS(), n_shards=1, **kw)
    assert_states_identical(b.state, a.state)


# -- serve: shard-resident sessions ------------------------------------------


def test_sharded_session_matches_resident_session(collection, mesh11):
    from repro.serve.session import LexicalSession, ShardedLexicalSession

    corpus, stats, queries, _ = collection
    base = LexicalSession(
        corpus.tokens, corpus.lengths, "ql_lm", k=K, chunk_size=CHUNK, stats=stats
    )
    sharded = ShardedLexicalSession(
        mesh11, corpus.tokens, corpus.lengths, "ql_lm", k=K, chunk_size=CHUNK,
        stats=stats,
    )
    assert sharded.n_docs == base.n_docs
    q = np.asarray(queries)
    a, b = base.search(q), sharded.search(q)
    assert b.ids.shape == (q.shape[0], K)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


def test_sharded_session_serves_through_dispatcher(collection, mesh11):
    from repro.serve import RetrievalService
    from repro.serve.session import ShardedLexicalSession

    corpus, stats, queries, docs = collection
    session = ShardedLexicalSession(
        mesh11, corpus.tokens, corpus.lengths, "bm25", k=K, chunk_size=CHUNK,
        stats=stats,
    )
    svc = RetrievalService({"lexical": session}, max_batch=4, max_delay=0.0)
    q = np.asarray(queries)
    rids = [svc.submit(q[i]) for i in range(4)]
    results = svc.poll() or svc.drain()
    want = scan.search_local_multi(
        queries, docs, [scoring.get_scorer("bm25")], k=K, chunk_size=CHUNK,
        stats=stats,
    )
    for row, rid in enumerate(rids):
        np.testing.assert_array_equal(results[rid].ids, np.asarray(want.ids)[0, row])


def test_sharded_session_validates(collection, mesh11):
    from repro.serve.session import ShardedLexicalSession

    corpus, _, _, _ = collection
    with pytest.raises(ValueError, match="not lexical"):
        ShardedLexicalSession(
            mesh11, corpus.tokens, corpus.lengths, "dense_dot", k=K, chunk_size=CHUNK,
            vocab=VOCAB,
        )
    with pytest.raises(ValueError, match="need stats or vocab"):
        ShardedLexicalSession(
            mesh11, corpus.tokens, corpus.lengths, "ql_lm", k=K, chunk_size=CHUNK
        )


# -- experiment lifecycle at shard counts ------------------------------------


def test_experiment_sharded_run_files_byte_identical(tmp_path):
    import dataclasses

    from repro.experiments import grid as exp_grid

    spec = dataclasses.replace(
        exp_grid.get_experiment("smoke"), segment_chunks=1, n_queries=8
    )
    coll = runner.prepare_collection(spec)
    r1 = runner.run_experiment(spec, out_dir=str(tmp_path / "s1"), collection=coll)
    r4 = runner.run_experiment(
        dataclasses.replace(spec, n_shards=4),
        out_dir=str(tmp_path / "s4"), collection=coll,
    )
    assert r4["job"]["n_shards"] == 4
    assert len(r4["job"]["shards"]) == 4
    for name in r1["runs"]:
        assert (
            open(r1["runs"][name], "rb").read() == open(r4["runs"][name], "rb").read()
        ), name
    assert r1["metrics"] == r4["metrics"]
