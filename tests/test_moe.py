"""MoE layer correctness: with ample capacity, the shard_map MoE equals the
explicit per-token top-k expert mixture."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.sharding import rules_for_mesh
from repro.models import moe


def oracle_moe(x, router_w, w_gate, w_up, w_down, top_k):
    """Direct dense evaluation: every token through its top-k experts."""
    probs = jax.nn.softmax((x.astype(jnp.float32) @ router_w.astype(jnp.float32)), -1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / w.sum(-1, keepdims=True)
    # all experts on all tokens, then select
    g = jnp.einsum("td,edf->tef", x, w_gate)
    u = jnp.einsum("td,edf->tef", x, w_up)
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, w_down)  # [T,E,D]
    sel = jnp.take_along_axis(y_all, ids[:, :, None], axis=1)  # [T,k,D]
    return jnp.einsum("tk,tkd->td", w, sel)


@pytest.mark.parametrize("mode", ["train", "seq", "replicated"])
def test_moe_matches_oracle(mesh11, mode):
    rules = rules_for_mesh(mesh11)
    t, d, f, e, k = 32, 16, 24, 4, 2
    key = jax.random.key(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (2, t // 2, d), jnp.float32) * 0.5
    router_w = jax.random.normal(ks[1], (d, e), jnp.float32)
    w_gate = jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.2
    w_up = jax.random.normal(ks[3], (e, d, f), jnp.float32) * 0.2
    w_down = jax.random.normal(ks[4], (e, f, d), jnp.float32) * 0.2
    layer = moe.make_moe_layer(
        mesh11, rules.dp, rules.tp,
        n_experts=e, top_k=k, capacity_factor=4.0,  # ample: no drops
        tokens_per_shard=t, mode=mode,
    )
    with jax.set_mesh(mesh11):
        y, aux = layer(x, router_w, w_gate, w_up, w_down)
    ref = oracle_moe(x.reshape(t, d), router_w, w_gate, w_up, w_down, k).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_monotone(mesh11):
    """Shrinking capacity only removes contributions (never corrupts)."""
    rules = rules_for_mesh(mesh11)
    t, d, f, e, k = 64, 8, 12, 4, 2
    key = jax.random.key(1)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (1, t, d), jnp.float32)
    ws = [
        jax.random.normal(ks[1], (d, e), jnp.float32),
        jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.2,
        jax.random.normal(ks[3], (e, d, f), jnp.float32) * 0.2,
        jax.random.normal(ks[4], (e, f, d), jnp.float32) * 0.2,
    ]
    outs = {}
    with jax.set_mesh(mesh11):
        for cf in (0.25, 4.0):
            layer = moe.make_moe_layer(
                mesh11, rules.dp, rules.tp, n_experts=e, top_k=k,
                capacity_factor=cf, tokens_per_shard=t, mode="train",
            )
            outs[cf], _ = layer(x, *ws)
    # low capacity zeroes some tokens' expert contributions
    dropped = np.mean(
        np.any(np.asarray(outs[0.25]) != np.asarray(outs[4.0]), axis=-1)
    )
    assert dropped > 0.1


def test_moe_gradients_flow(mesh11):
    rules = rules_for_mesh(mesh11)
    t, d, f, e, k = 16, 8, 12, 4, 2
    keys = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(keys[0], (1, t, d), jnp.float32)
    ws = {
        "r": jax.random.normal(keys[1], (d, e), jnp.float32),
        "g": jax.random.normal(keys[2], (e, d, f), jnp.float32) * 0.2,
        "u": jax.random.normal(keys[3], (e, d, f), jnp.float32) * 0.2,
        "d": jax.random.normal(keys[4], (e, f, d), jnp.float32) * 0.2,
    }
    layer = moe.make_moe_layer(
        mesh11, rules.dp, rules.tp, n_experts=e, top_k=k,
        capacity_factor=2.0, tokens_per_shard=t, mode="seq",
    )

    def loss(ws):
        y, aux = layer(x, ws["r"], ws["g"], ws["u"], ws["d"])
        return jnp.sum(jnp.square(y)) + 0.01 * aux

    with jax.set_mesh(mesh11):
        grads = jax.grad(loss)(ws)
    for name, g in grads.items():
        assert bool(jnp.all(jnp.isfinite(g))), name
        assert float(jnp.sum(jnp.abs(g))) > 0, name
