"""Sequential scan == inverted index (the system-level correctness oracle:
identical scoring math on both paths, per DESIGN §4)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anchors, invindex, scan, scoring, topk
from repro.data import synthetic

VOCAB = 500


@pytest.fixture(scope="module")
def corpus():
    return synthetic.make_corpus(n_docs=256, vocab=VOCAB, max_len=24, seed=3)


@pytest.fixture(scope="module")
def stats_and_index(corpus):
    idx = invindex.build_index(corpus.tokens, corpus.lengths, vocab=VOCAB)
    return invindex.stats_from_index(idx), idx


def test_stats_job_matches_index(corpus, stats_and_index):
    istats, _ = stats_and_index
    jstats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB, chunk_size=64
    )
    np.testing.assert_array_equal(np.asarray(jstats.cf), istats.cf)
    np.testing.assert_array_equal(np.asarray(jstats.df), istats.df)
    assert int(jstats.total_terms) == int(istats.total_terms)


@pytest.mark.parametrize("scorer_name", ["ql_lm", "bm25"])
def test_scan_equals_index(corpus, stats_and_index, scorer_name):
    istats, idx = stats_and_index
    queries = synthetic.make_queries(corpus, n_queries=12, seed=4)
    scorer = scoring.get_scorer(scorer_name)
    state = scan.search_local(
        jnp.asarray(queries), (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths)),
        scorer, k=8, chunk_size=64, stats=istats,
    )
    ref_s, ref_i = invindex.search(idx, queries, istats, k=8, scorer=scorer_name)
    np.testing.assert_allclose(np.asarray(state.scores), ref_s, rtol=3e-5, atol=3e-5)
    # ids may permute under float ties; demand high agreement
    agree = np.mean([len(set(a) & set(b)) / 8 for a, b in zip(np.asarray(state.ids), ref_i)])
    assert agree > 0.9


def test_padded_docs_never_surface(corpus, stats_and_index):
    istats, _ = stats_and_index
    queries = synthetic.make_queries(corpus, n_queries=4, seed=5)
    toks = jnp.concatenate(
        [jnp.asarray(corpus.tokens), jnp.full((64, corpus.tokens.shape[1]), -1, jnp.int32)]
    )
    lens = jnp.concatenate([jnp.asarray(corpus.lengths), jnp.zeros(64, jnp.int32)])
    state = scan.search_local(
        jnp.asarray(queries), (toks, lens), scoring.get_scorer("ql_lm"),
        k=16, chunk_size=64, stats=istats,
    )
    assert int(jnp.max(state.ids)) < 256  # no pad id in the top-k


def test_dense_scan_matches_oracle():
    q = jnp.asarray(np.random.default_rng(0).standard_normal((8, 32)), jnp.float32)
    d = jnp.asarray(np.random.default_rng(1).standard_normal((128, 32)), jnp.float32)
    state = scan.search_local(q, d, scoring.get_scorer("dense_dot"), k=7, chunk_size=32)
    ref = scan.search_dense_host(q, d, 7)
    np.testing.assert_allclose(np.asarray(state.scores), np.asarray(ref.scores), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(state.ids), np.asarray(ref.ids))


def test_anchor_extraction_groups_by_dst():
    dst, toks = synthetic.make_links(n_docs=32, n_links=100, vocab=VOCAB, seed=6)
    out, lens = anchors.extract_anchors(
        jnp.asarray(dst), jnp.asarray(toks), n_docs=32, max_anchor_len=48
    )
    out = np.asarray(out)
    # every non-pad token in row d must come from an anchor pointing at d
    for d in range(32):
        got = out[d][out[d] >= 0]
        pool = toks[dst == d]
        pool = pool[pool >= 0]
        assert set(got.tolist()) <= set(pool.tolist())
    assert int(lens.sum()) > 0
