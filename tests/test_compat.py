"""The pinned-JAX shim layer every new mesh/sharding API use routes through.

`repro.compat` backfills the current-JAX spellings (``jax.set_mesh``,
``jax.lax.axis_size``, differentiable ``optimization_barrier``) on the
container's pinned release; the cluster layer (`repro.cluster`) and the
sharded serve sessions call only the shimmed spellings, so these tests are
what "the pinned JAX keeps passing" means operationally.
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat


def test_set_mesh_is_context_manager(mesh11):
    """Whatever fallback resolved, ``with jax.set_mesh(mesh)`` must work —
    the spelling every call site (cluster, tests, examples) uses."""
    with jax.set_mesh(mesh11):
        x = jnp.ones((4,))
    np.testing.assert_array_equal(np.asarray(x), 1.0)
    # compat.set_mesh is the same entry point (importing repro.compat
    # installed it as jax.set_mesh when the pinned JAX lacks it)
    with compat.set_mesh(mesh11):
        pass


def test_axis_size_inside_shard_map(mesh11):
    """``compat.axis_size`` must return a *concrete* int under tracing (the
    cluster layer uses it in Python control flow to flatten shard indices)."""
    sizes = {}

    def body(x):
        sizes["data"] = compat.axis_size("data")
        sizes["model"] = compat.axis_size("model")
        assert isinstance(sizes["data"], (int, np.integer)) or sizes["data"].shape == ()
        idx = jax.lax.axis_index("data") * compat.axis_size("model") + jax.lax.axis_index("model")
        return x + idx

    fn = shard_map(body, mesh=mesh11, in_specs=P(), out_specs=P(), check_rep=False)
    out = fn(jnp.zeros((2,)))
    assert int(sizes["data"]) == 1 and int(sizes["model"]) == 1
    np.testing.assert_array_equal(np.asarray(out), 0.0)  # shard 0 of a 1x1 mesh


def test_axis_size_matches_mesh_shape():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def body(x):
        return x * compat.axis_size("data")

    fn = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
    np.testing.assert_array_equal(np.asarray(fn(jnp.full((2,), 3.0))), 3.0)


def test_optimization_barrier_differentiable():
    """The shimmed barrier must be identity-valued with identity JVP."""
    y, t = jax.jvp(compat.optimization_barrier, (2.0,), (5.0,))
    assert float(y) == 2.0 and float(t) == 5.0
    g = jax.grad(lambda x: compat.optimization_barrier(x * x))(3.0)
    assert float(g) == 6.0
