"""Observability layer: tracer/metrics semantics, exporters, and the two
contracts the subsystem lives or dies by —

* **near-zero cost off, correct under concurrency on**: the disabled
  tracer returns a shared no-op span (no clock read, no allocation);
  enabled instruments never lose cross-thread updates and spans record
  even when their body raises (the timeline survives a mid-segment crash);
* **tracing observes, never decides**: a traced experiment produces
  byte-identical run files to an untraced one, faults and all.

Plus the deprecation-alias contract (satellite): ``fail_at_segment``
warnings must point at the *caller's* line at every entry point —
``run_scan_job``, ``run_sharded_scan_job``, and ``run_experiment``.
"""

import json
import threading
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro import cluster, obs
from repro.cluster.faults import FaultSchedule, FaultSpec, WorkerCrash
from repro.core import anchors
from repro.data import synthetic
from repro.experiments import grid as exp_grid
from repro.experiments import runner
from repro.obs import export
from repro.obs.metrics import Histogram, Metrics
from repro.obs.trace import NULL_SPAN, Tracer
from repro.serve import LexicalSession, RetrievalService

VOCAB = 1024
N_DOCS = 256
CHUNK = 32
K = 8
N_SHARDS = 2


# -- tracer semantics ---------------------------------------------------------


class StepClock:
    """Deterministic tracer clock: each read advances by ``dt``."""

    def __init__(self, dt=1.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


def test_disabled_tracer_is_a_shared_noop():
    tr = Tracer(enabled=False)
    assert tr.span("x") is NULL_SPAN
    with tr.span("x", "cat", a=1) as sp:
        sp.set(b=2)
    tr.instant("mark")
    tr.record("win", 0.0, 1.0)
    assert len(tr) == 0


def test_spans_record_name_cat_attrs_thread_and_duration():
    tr = Tracer(clock=StepClock())
    with tr.span("outer", "job", shard=3) as sp:
        sp.set(outcome="ok")
        with tr.span("inner", "job"):
            pass
    ev = tr.events()
    assert [e.name for e in ev] == ["inner", "outer"]  # LIFO close order
    outer = ev[1]
    assert outer.cat == "job" and outer.ph == "X"
    assert outer.attrs == {"shard": 3, "outcome": "ok"}
    assert outer.tid == threading.get_ident()
    # inner's [ts, ts+dur] window nests inside outer's (time containment)
    inner = ev[0]
    assert outer.ts < inner.ts and inner.ts + inner.dur < outer.ts + outer.dur


def test_span_records_on_error_and_reraises():
    """A fold that dies mid-span still leaves its span in the timeline,
    tagged with the exception type, and enclosing spans keep correct
    extents — the crash-forensics contract."""
    tr = Tracer(clock=StepClock())
    with pytest.raises(WorkerCrash, match="boom"):
        with tr.span("shard.run", "job", shard=0):
            with tr.span("segment.fold", "job", segment=1):
                raise WorkerCrash("boom")
    fold, shard = tr.events()
    assert fold.name == "segment.fold"
    assert fold.attrs["error"] == "WorkerCrash"
    assert shard.name == "shard.run"
    assert shard.attrs["error"] == "WorkerCrash"
    assert shard.ts < fold.ts and fold.ts + fold.dur < shard.ts + shard.dur


def test_buffer_bound_drops_oldest():
    tr = Tracer(max_events=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert [e.name for e in tr.events()] == ["e6", "e7", "e8", "e9"]


def test_instants_and_filtered_readout():
    tr = Tracer()
    tr.instant("fault.crash", "fault", shard=1)
    with tr.span("segment.fold", "job"):
        pass
    assert [e.name for e in tr.instants()] == ["fault.crash"]
    assert [e.name for e in tr.spans(cat="job")] == ["segment.fold"]
    assert tr.spans(name="nope") == []


def test_record_explicit_window():
    tr = Tracer()
    tr.record("serve.request", 10.0, 10.5, "serve", rid=7)
    (e,) = tr.events()
    assert (e.ts, e.dur) == (10.0, 0.5) and e.attrs == {"rid": 7}


def test_session_installs_and_restores():
    base_tr, base_met = obs.tracer(), obs.metrics()
    with obs.session() as (tr, met):
        assert obs.tracer() is tr and obs.metrics() is met
        assert tr.enabled
    assert obs.tracer() is base_tr and obs.metrics() is base_met


# -- metrics ------------------------------------------------------------------


def test_counter_exact_under_concurrent_increments():
    met = Metrics()
    c = met.counter("hits")

    def hammer():
        for _ in range(10_000):
            c.inc()

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 80_000


def test_histogram_concurrent_observations_all_land():
    h = Histogram("lat")

    def hammer(v):
        for _ in range(5_000):
            h.observe(v)

    threads = [threading.Thread(target=hammer, args=(0.001 * (i + 1),)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.count == 20_000


def test_histogram_quantiles_interpolate_and_clamp():
    h = Histogram("h", bounds=(1.0, 2.0, 4.0, 8.0))
    for v in (1.5, 1.5, 1.5, 7.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["min"] == 1.5 and s["max"] == 7.0
    assert 1.0 <= s["p50"] <= 2.0
    assert s["p99"] <= 7.0  # clamped to the observed max, not the bucket edge
    single = Histogram("one")
    single.observe(0.123)
    assert single.quantile(0.5) == pytest.approx(0.123)


def test_gauge_tracks_last_and_max():
    g = Metrics().gauge("depth")
    for v in (1, 5, 2):
        g.set(v)
    assert g.value == 2 and g.max == 5


def test_registry_get_or_create_and_kind_conflict():
    met = Metrics()
    assert met.counter("a") is met.counter("a")
    with pytest.raises(TypeError, match="Counter"):
        met.gauge("a")
    met.counter("b").inc(3)
    met.histogram("c").observe(0.5)
    s = met.summary()
    assert s["counters"] == {"a": 0, "b": 3}
    assert s["histograms"]["c"]["count"] == 1


# -- exporters ----------------------------------------------------------------


def _sample_tracer():
    tr = Tracer(clock=StepClock(0.5))
    with tr.span("segment.fold", "job", shard=0, segment=0):
        pass
    tr.instant("sched.retry", "sched", shard=1)
    return tr


def test_chrome_trace_structure(tmp_path):
    tr = _sample_tracer()
    met = Metrics()
    met.counter("n").inc()
    path = export.write_chrome_trace(str(tmp_path / "t.json"), tr, metrics=met)
    doc = json.load(open(path))
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    instants = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert spans[0]["name"] == "segment.fold" and spans[0]["dur"] > 0
    assert min(e["ts"] for e in spans + instants) == 0.0  # rebased to t=0
    assert instants[0]["s"] == "t"
    assert metas and metas[0]["name"] == "thread_name"
    assert doc["otherData"]["metrics"]["counters"] == {"n": 1}


def test_jsonl_roundtrip(tmp_path):
    tr = _sample_tracer()
    path = export.write_jsonl(str(tmp_path / "t.jsonl"), tr)
    lines = [json.loads(l) for l in open(path)]
    assert len(lines) == len(tr)
    assert lines[0]["name"] == "segment.fold"
    assert lines[0]["ts"] == tr.events()[0].ts  # raw clock preserved
    assert lines[1]["attrs"] == {"shard": 1}


def test_summary_tree_groups_by_shard():
    txt = export.summary_tree(_sample_tracer())
    assert "shard 0" in txt and "segment.fold" in txt
    assert "sched.retry×1" in txt
    rollup = export.phase_rollup(_sample_tracer())
    assert rollup["shard 0"]["segment.fold"]["count"] == 1


# -- instrumented layers ------------------------------------------------------


@pytest.fixture(scope="module")
def collection():
    corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=24, seed=5)
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
        chunk_size=CHUNK,
    )
    queries = jnp.asarray(synthetic.make_queries(corpus, n_queries=4, seed=6))
    docs = (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths))
    return stats, queries, docs


def _scorers():
    return [__import__("repro.core.scoring", fromlist=["x"]).get_scorer("bm25")]


def _run_job(collection, tmp_path, **kw):
    stats, queries, docs = collection
    return cluster.run_sharded_scan_job(
        queries, docs, _scorers(), k=K, chunk_size=CHUNK, segment_chunks=2,
        n_shards=N_SHARDS, stats=stats, ckpt_dir=str(tmp_path / "ckpt"), **kw,
    )


def test_sharded_job_emits_spans_per_shard(collection, tmp_path):
    with obs.session() as (tr, met):
        _run_job(collection, tmp_path)
    for shard in range(N_SHARDS):
        folds = [s for s in tr.spans("segment.fold") if s.attrs["shard"] == shard]
        assert len(folds) == 2  # 2 segments per shard
        assert [s.attrs["segment"] for s in folds] == [0, 1]
        assert any(s.attrs["shard"] == shard for s in tr.spans("shard.run"))
        assert any(
            s.attrs["shard"] == shard for s in tr.spans("segment.commit_submit")
        )
        assert any(
            s.attrs["shard"] == shard for s in tr.spans("segment.prefetch_wait")
        )
    attempts = tr.spans("shard.attempt")
    assert {s.attrs["outcome"] for s in attempts} == {"ok"}
    # checkpoint commits happen on the writer thread, visible as its spans
    assert all(s.tname == "ckpt-writer" for s in tr.spans("ckpt.save"))
    assert met.histogram("job.segment_fold_s").count == 2 * N_SHARDS
    assert met.summary()["histograms"]["ckpt.save_s"]["count"] >= 2 * N_SHARDS


def test_crashed_fold_attempt_leaves_error_span_and_fault_marker(
    collection, tmp_path
):
    faults = FaultSchedule(
        [FaultSpec(kind="crash", shard=1, segment=1, phase="pre_commit")]
    )
    with obs.session() as (tr, _):
        _run_job(collection, tmp_path, faults=faults, max_retries=1)
    failed = [s for s in tr.spans("shard.attempt") if s.attrs["outcome"] == "failed"]
    assert len(failed) == 1 and failed[0].attrs["shard"] == 1
    # the doomed attempt's shard.run span carries the crash type
    died = [s for s in tr.spans("shard.run") if "error" in s.attrs]
    assert len(died) == 1 and died[0].attrs["error"] == "WorkerCrash"
    (crash,) = tr.instants("fault.crash")
    assert crash.attrs["shard"] == 1 and crash.attrs["segment"] == 1
    (retry,) = tr.instants("sched.retry")
    assert retry.attrs["shard"] == 1 and retry.attrs["error"] == "WorkerCrash"


def test_scheduler_stats_consistent_under_concurrent_chaos(collection, tmp_path):
    """SchedulerStats counters are mutated from every worker thread; the
    final numbers must reconcile exactly with the injected schedule and
    the trace's own event log."""
    n_shards = 8
    stats, queries, docs = collection
    faults = FaultSchedule(
        [
            FaultSpec(kind="crash", shard=s, segment=0, phase="post_commit")
            for s in range(0, n_shards, 2)
        ]
    )
    with obs.session() as (tr, _):
        job = cluster.run_sharded_scan_job(
            queries, docs, _scorers(), k=K, chunk_size=CHUNK, segment_chunks=1,
            n_shards=n_shards, stats=stats, ckpt_dir=str(tmp_path / "c8"),
            faults=faults, max_retries=1, max_workers=4,
        )
    s = job.scheduler
    assert s.retries == n_shards // 2 == len(tr.instants("sched.retry"))
    assert sum(s.attempts) == n_shards + s.retries + s.speculative_launched
    by_outcome = {}
    for sp in tr.spans("shard.attempt"):
        by_outcome[sp.attrs["outcome"]] = by_outcome.get(sp.attrs["outcome"], 0) + 1
    assert by_outcome.get("failed", 0) == s.retries
    assert by_outcome.get("ok", 0) == n_shards
    assert len(tr.instants("sched.steal")) == s.steals


# -- byte identity ------------------------------------------------------------


def test_traced_run_files_byte_identical_to_untraced(tmp_path):
    spec = exp_grid.ExperimentSpec(
        name="obs-id", grids=(exp_grid.GridSpec("bm25"),),
        n_docs=N_DOCS, n_queries=4, vocab=VOCAB, max_doc_len=24,
        k=K, chunk_size=CHUNK, segment_chunks=2, n_shards=N_SHARDS,
    )
    coll = runner.prepare_collection(spec, seed=3)
    faults = lambda: FaultSchedule(  # noqa: E731 — fresh per run
        [FaultSpec(kind="crash", shard=0, segment=0, phase="post_commit")]
    )
    plain = runner.run_experiment(
        spec, out_dir=str(tmp_path / "plain"), seed=3, collection=coll,
        faults=faults(), max_retries=1,
    )
    trace_path = tmp_path / "obs" / "trace.json"
    traced = runner.run_experiment(
        spec, out_dir=str(tmp_path / "traced"), seed=3, collection=coll,
        faults=faults(), max_retries=1, trace_out=str(trace_path),
    )
    # tracing observed a faulted, retried run...
    ob = traced["job"]["obs"]
    assert ob["n_events"] > 0 and plain["job"]["obs"] is None
    doc = json.load(open(trace_path))
    folds = [e for e in doc["traceEvents"] if e["name"] == "segment.fold"]
    assert {e["args"]["shard"] for e in folds} == set(range(N_SHARDS))
    assert ob["metrics"]["histograms"]["job.segment_fold_s"]["count"] >= 4
    assert "shard 0" in ob["phases"]
    assert trace_path.with_suffix(".jsonl").exists()
    # ...and never perturbed the artifacts
    runs = sorted((tmp_path / "plain" / "runs").iterdir())
    assert runs
    for p in runs:
        q = tmp_path / "traced" / "runs" / p.name
        assert p.read_bytes() == q.read_bytes()
    # the lifecycle restored the ambient (disabled) instruments
    assert not obs.tracer().enabled


# -- serve histograms ---------------------------------------------------------


def test_serve_dispatch_populates_histograms_and_request_spans():
    corpus = synthetic.make_corpus(n_docs=128, vocab=256, max_len=24, seed=0)
    session = LexicalSession(
        corpus.tokens, corpus.lengths, "ql_lm", k=5, chunk_size=64, vocab=256
    )
    clock_t = [0.0]

    def clock():
        clock_t[0] += 0.001
        return clock_t[0]

    registry = Metrics()
    service = RetrievalService(
        {"lexical": session}, max_batch=4, max_delay=0.5, clock=clock,
        registry=registry,
    )
    with obs.session() as (tr, _):
        queries = synthetic.make_queries(corpus, n_queries=10, seed=1)
        rids = [service.submit(q, "lexical") for q in queries]
        results = service.poll()
        results.update(service.drain())
    assert sorted(results) == sorted(rids)
    s = registry.summary()
    assert s["counters"]["serve.requests"] == 10
    assert s["counters"]["serve.batches"] == 3  # 4 + 4 + flush(2)
    bs = s["histograms"]["serve.batch_size"]
    assert bs["count"] == 3 and bs["max"] == 4.0 and bs["min"] == 2.0
    for name in ("serve.queue_wait_s", "serve.latency_s"):
        h = s["histograms"][name]
        assert h["count"] == 3
        assert 0 < h["p50"] <= h["p95"] <= h["p99"] <= h["max"]
    # one enqueue→reply span per request, plus one dispatch span per block
    reqs = tr.spans("serve.request")
    assert sorted(e.attrs["rid"] for e in reqs) == sorted(rids)
    assert all(e.dur > 0 for e in reqs)
    dispatches = tr.spans("serve.dispatch")
    assert [d.attrs["n_real"] for d in dispatches] == [4, 4, 2]
    assert {d.attrs["trigger"] for d in dispatches} == {"size", "flush"}


# -- deprecation alias origin (satellite) -------------------------------------


def _one_shard_kwargs(collection):
    stats, queries, docs = collection
    return dict(
        queries=queries, docs=docs, scorers=_scorers(), k=K, chunk_size=CHUNK,
        segment_chunks=2, stats=stats,
    )


def test_legacy_warning_points_at_caller_run_scan_job(collection):
    kw = _one_shard_kwargs(collection)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        with pytest.raises(RuntimeError, match="injected failure"):
            cluster.run_scan_job(
                kw["queries"], kw["docs"], kw["scorers"], k=K, chunk_size=CHUNK,
                segment_chunks=2, stats=kw["stats"], fail_at_segment=0,
            )
    (w,) = [w for w in caught if w.category is DeprecationWarning]
    assert w.filename == __file__  # stacklevel=2: the caller's line, not job.py


def test_legacy_warning_points_at_caller_run_sharded(collection):
    kw = _one_shard_kwargs(collection)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        with pytest.raises(RuntimeError, match="injected failure"):
            cluster.run_sharded_scan_job(
                kw["queries"], kw["docs"], kw["scorers"], k=K, chunk_size=CHUNK,
                segment_chunks=2, stats=kw["stats"], n_shards=2,
                fail_at_segment=0, fail_at_shard=1,
            )
    (w,) = [w for w in caught if w.category is DeprecationWarning]
    assert w.filename == __file__


def test_legacy_warning_points_at_caller_run_experiment(tmp_path):
    """run_experiment converts the legacy kwargs itself instead of
    forwarding them, so the warning is attributed to the experiment's
    caller rather than to runner.py's internal job call."""
    spec = exp_grid.ExperimentSpec(
        name="obs-dep", grids=(exp_grid.GridSpec("bm25"),),
        n_docs=N_DOCS, n_queries=4, vocab=VOCAB, max_doc_len=24,
        k=K, chunk_size=CHUNK, segment_chunks=2, n_shards=2,
    )
    coll = runner.prepare_collection(spec, seed=3)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        report = runner.run_experiment(
            spec, out_dir=str(tmp_path / "dep"), seed=3, collection=coll,
            fail_at_segment=0, fail_at_shard=0, max_retries=1,
        )
    deps = [w for w in caught if w.category is DeprecationWarning]
    assert deps and all(w.filename == __file__ for w in deps)
    # the alias reached the job as a real FaultSpec: it fired and was retried
    assert [f["kind"] for f in report["job"]["faults_fired"]] == ["crash"]
    assert report["job"]["scheduler"]["retries"] == 1


# -- windowed (recent-decay) histograms --------------------------------------


class ManualClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def test_windowed_histogram_forgets_old_samples():
    clock = ManualClock()
    h = Histogram("h", bounds=(1.0, 10.0), window_s=1.0, n_windows=4, clock=clock)
    h.observe(100.0)  # lands in the current sub-window
    assert h.count == 1 and h.quantile(0.99) == 100.0
    clock.t = 0.9  # still inside the ring
    h.observe(0.5)
    assert h.count == 2
    clock.t = 1.3  # first sub-window (0.0-0.25) rotated out -> 100.0 gone
    assert h.count == 1
    assert h.quantile(0.99) == pytest.approx(0.5)
    clock.t = 5.0  # a gap longer than the whole window clears everything
    assert h.count == 0
    assert h.summary()["window_s"] == 1.0


def test_windowed_histogram_rotation_edges():
    clock = ManualClock()
    h = Histogram("h", bounds=(1.0,), window_s=1.0, n_windows=4, clock=clock)
    # one sample per sub-window boundary; each rotation drops exactly one
    for i in range(4):
        clock.t = i * 0.25
        h.observe(float(i))
    assert h.count == 4
    clock.t = 1.0  # rotates out the [0, 0.25) sub-window only
    assert h.count == 3
    clock.t = 1.25
    assert h.count == 2
    # min/max/quantiles come from the merged live sub-windows
    assert h.summary()["max"] == 3.0 and h.summary()["min"] == 2.0


def test_windowed_histogram_tolerates_clock_rewind():
    """Arrival stamping in the open-loop load generator rewinds the service
    clock; a rewound read must not rotate (or crash) — it observes into the
    current sub-window."""
    clock = ManualClock(5.0)
    h = Histogram("h", bounds=(1.0,), window_s=2.0, n_windows=4, clock=clock)
    h.observe(1.0)
    clock.t = 3.0  # rewind
    h.observe(2.0)
    assert h.count == 2
    clock.t = 5.4  # forward again, still same sub-window (0.5s each)
    assert h.count == 2


def test_cumulative_histogram_unchanged_by_default():
    h = Histogram("h", bounds=(1.0, 2.0))
    for v in (0.5, 1.5, 3.0):
        h.observe(v)
    assert h.count == 3 and h.summary().get("window_s") is None


def test_registry_creates_windowed_histogram_once():
    clock = ManualClock()
    m = Metrics()
    h1 = m.histogram("serve.recent", window_s=1.0, n_windows=2, clock=clock)
    h2 = m.histogram("serve.recent")  # get: kwargs only apply at creation
    assert h1 is h2 and h1.window_s == 1.0
    h1.observe(1.0)
    clock.t = 3.0
    assert h2.count == 0  # decayed through the shared instance
