import jax
import numpy as np
import pytest

import repro.compat  # noqa: F401  — installs jax.set_mesh fallback on older JAX

# NOTE: no XLA_FLAGS here on purpose — tests must see the real single CPU
# device (the 512-device override belongs to launch/dryrun.py only).


@pytest.fixture(scope="session")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
