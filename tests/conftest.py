import jax
import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — tests must see the real single CPU
# device (the 512-device override belongs to launch/dryrun.py only).


@pytest.fixture(scope="session")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
