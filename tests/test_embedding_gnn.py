"""EmbeddingBag + graph-partitioning properties."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.data import synthetic
from repro.data.graph_prep import bucket_edges
from repro.data.sampler import build_csr, sample_batch
from repro.models import gnn
from repro.models.embedding import embedding_bag, embedding_bag_ragged, field_embed
from repro.configs import reduced_config


@settings(deadline=None, max_examples=25)
@given(st.integers(2, 6), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_padded_bag_equals_ragged_bag(n_bags, max_len, seed):
    r = np.random.default_rng(seed)
    v, d = 20, 5
    table = jnp.asarray(r.standard_normal((v, d)), jnp.float32)
    lens = r.integers(1, max_len + 1, n_bags)
    ids_pad = np.full((n_bags, max_len), -1, np.int32)
    vals, segs = [], []
    for i, l in enumerate(lens):
        ids = r.integers(0, v, l)
        ids_pad[i, :l] = ids
        vals.extend(ids.tolist())
        segs.extend([i] * l)
    for mode in ("sum", "mean", "max"):
        a = embedding_bag(table, jnp.asarray(ids_pad), mode=mode)
        b = embedding_bag_ragged(
            table, jnp.asarray(vals, jnp.int32), jnp.asarray(segs, jnp.int32),
            n_bags, mode=mode,
        )
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_field_embed_indexing():
    r = np.random.default_rng(1)
    tables = jnp.asarray(r.standard_normal((3, 10, 4)), jnp.float32)
    ids = jnp.asarray(r.integers(0, 10, (5, 3)), jnp.int32)
    out = field_embed(tables, ids)
    for b in range(5):
        for f in range(3):
            np.testing.assert_array_equal(
                np.asarray(out[b, f]), np.asarray(tables[f, ids[b, f]])
            )


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_bucket_edges_preserves_all_edges(log_shards, seed):
    r = np.random.default_rng(seed)
    n_shards = 2**log_shards
    n_nodes = 8 * n_shards
    e = int(r.integers(5, 100))
    src = r.integers(0, n_nodes, e).astype(np.int32)
    dst = r.integers(0, n_nodes, e).astype(np.int32)
    bs, bd, bucket = bucket_edges(src, dst, n_nodes=n_nodes, n_shards=n_shards)
    n_loc = n_nodes // n_shards
    real = bd < n_nodes
    # every original edge appears exactly once
    got = sorted(zip(bs[real].tolist(), bd[real].tolist()))
    want = sorted(zip(src.tolist(), dst.tolist()))
    assert got == want
    # placement: edge in slab s  ⇒  dst in shard s's node range
    slab = np.arange(len(bd)) // bucket
    assert np.all((bd[real] // n_loc) == slab[real])


def test_bucketed_layer_equals_unsharded_forward():
    """1-shard bucketed path == the plain full-graph forward."""
    cfg = reduced_config("pna")
    g = synthetic.make_graph(n_nodes=48, n_edges=200, d_feat=9, seed=3)
    params = gnn.init_params(cfg, 9, jax.random.key(0))
    ref = gnn.forward_full_graph(
        params, jnp.asarray(g["x"]), jnp.asarray(g["src"]), jnp.asarray(g["dst"]), cfg
    )
    # bucket for 1 shard (pad with ghosts) and run the bucketed layer path
    bs, bd, _ = bucket_edges(g["src"], g["dst"], n_nodes=48, n_shards=1, bucket_size=256)
    h = jax.nn.relu(jnp.asarray(g["x"]) @ params["w_in"] + params["b_in"])
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda p, i=i: p[i], params["layers"])
        h = gnn.pna_layer_bucketed(h, jnp.asarray(bs), jnp.asarray(bd), lp, cfg, 48, 0)
    out = h @ params["w_out"] + params["b_out"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


def test_neighbor_sampler_shapes_and_validity():
    g = synthetic.make_graph(n_nodes=200, n_edges=1000, d_feat=7, seed=4)
    csr = build_csr(g["src"], g["dst"], g["x"], g["y"])
    batch = sample_batch(csr, batch_nodes=16, fanout=(5, 3), seed=0, step=2)
    assert batch["seed_x"].shape == (16, 7)
    assert batch["hop1_x"].shape == (16, 5, 7)
    assert batch["hop2_x"].shape == (16, 5, 3, 7)
    # determinism keyed by (seed, step)
    again = sample_batch(csr, batch_nodes=16, fanout=(5, 3), seed=0, step=2)
    np.testing.assert_array_equal(batch["seed_x"], again["seed_x"])
    other = sample_batch(csr, batch_nodes=16, fanout=(5, 3), seed=0, step=3)
    assert not np.array_equal(batch["seed_x"], other["seed_x"])
