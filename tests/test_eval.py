"""repro.eval: metrics against hand-computed values, TREC I/O, significance."""

import numpy as np
import pytest

from repro.eval import metrics, significance, trec

# Hand-worked example, 2 queries × 8 docs, run depth 4.
#   q0 ranking [0, 1, 2, 3]; relevant docs {0, 2, 5} (grades 3, 1, 2)
#   q1 ranking [3, 4, 5, 0]; relevant docs {4}    (grade 1)
RUN = np.array([[0, 1, 2, 3], [3, 4, 5, 0]])
QRELS = np.zeros((2, 8), np.int8)
QRELS[0, 0], QRELS[0, 2], QRELS[0, 5] = 3, 1, 2
QRELS[1, 4] = 1
BINARY = (QRELS > 0).astype(np.int8)


def test_precision_at_k_hand_computed():
    # q0: top-2 = [rel, not] -> 1/2; q1: top-2 = [not, rel] -> 1/2
    np.testing.assert_allclose(metrics.precision_at_k(RUN, QRELS, 2), [0.5, 0.5])
    # q0: [rel, not, rel, not] -> 2/4; q1: [not, rel, not, not] -> 1/4
    np.testing.assert_allclose(metrics.precision_at_k(RUN, QRELS, 4), [0.5, 0.25])


def test_recall_at_k_hand_computed():
    # q0 has 3 relevant, 2 retrieved in top-4; q1 has 1, retrieved
    np.testing.assert_allclose(metrics.recall_at_k(RUN, QRELS, 4), [2 / 3, 1.0])


def test_average_precision_hand_computed():
    # q0: hits at ranks 1, 3 -> (1/1 + 2/3) / 3 relevant = 5/9
    # q1: hit at rank 2 -> (1/2) / 1 = 1/2
    np.testing.assert_allclose(
        metrics.average_precision(RUN, QRELS), [5 / 9, 1 / 2]
    )


def test_reciprocal_rank_hand_computed():
    np.testing.assert_allclose(metrics.reciprocal_rank(RUN, QRELS), [1.0, 0.5])


def test_ndcg_hand_computed():
    # q0 gains at ranks 1..4: 2^3-1, 0, 2^1-1, 0 -> DCG = 7/log2(2) + 1/log2(4)
    # ideal grades [3, 2, 1] -> IDCG = 7/log2(2) + 3/log2(3) + 1/log2(4)
    dcg0 = 7.0 + 1.0 / 2.0
    idcg0 = 7.0 + 3.0 / np.log2(3.0) + 1.0 / 2.0
    # q1: gain 1 at rank 2 -> DCG = 1/log2(3); ideal -> 1/log2(2)
    dcg1 = 1.0 / np.log2(3.0)
    np.testing.assert_allclose(
        metrics.ndcg_at_k(RUN, QRELS, 4), [dcg0 / idcg0, dcg1], rtol=1e-12
    )


def test_ndcg_run_shallower_than_k():
    # depth-3 run, k=5: missing ranks contribute no gain, ideal still uses k
    run = np.array([[0, 1, 2], [3, 4, 5]])
    got = metrics.ndcg_at_k(run, QRELS, 5)
    full = metrics.ndcg_at_k(RUN, QRELS, 4)
    assert got.shape == (2,)
    assert 0.0 < got[0] <= full[0]  # q0 loses nothing (its 4th rank had no gain)


def test_perfect_ranking_is_one():
    run = np.array([[0, 5, 2, 1]])  # q0's docs in descending-grade order
    assert metrics.ndcg_at_k(run, QRELS[:1], 4)[0] == pytest.approx(1.0)
    run_bin = np.array([[0, 2, 5, 7]])
    assert metrics.average_precision(run_bin, BINARY[:1])[0] == pytest.approx(1.0)


def test_empty_slots_and_unjudged_queries():
    run = np.array([[0, -1, -1, -1], [-1, -1, -1, -1]])
    p = metrics.precision_at_k(run, QRELS, 4)
    np.testing.assert_allclose(p, [0.25, 0.0])  # -1 slots never count as hits
    no_rel = np.zeros((2, 8), np.int8)
    assert metrics.average_precision(RUN, no_rel).tolist() == [0.0, 0.0]
    assert metrics.reciprocal_rank(RUN, no_rel).tolist() == [0.0, 0.0]
    assert metrics.ndcg_at_k(RUN, no_rel, 4).tolist() == [0.0, 0.0]


def test_evaluate_run_aggregates():
    rep = metrics.evaluate_run(RUN, QRELS, ks=(2, 4))
    assert rep["aggregate"]["map"] == pytest.approx((5 / 9 + 1 / 2) / 2)
    assert rep["aggregate"]["mrr"] == pytest.approx(0.75)
    assert rep["aggregate"]["p@2"] == pytest.approx(0.5)
    assert set(rep["per_query"]) == {
        "ap", "rr", "p@2", "recall@2", "ndcg@2", "p@4", "recall@4", "ndcg@4",
    }
    with pytest.raises(ValueError, match="exceeds run depth"):
        metrics.evaluate_run(RUN, QRELS, ks=(5,))


def test_trec_run_roundtrip(tmp_path):
    scores = np.array([[4.0, 3.5, 2.25, -1.125], [9.0, 8.5, 0.1, -3.75]])
    path = str(tmp_path / "a.run")
    trec.write_run(path, RUN, scores, run_tag="test/a")
    ids, sc, tag = trec.read_run(path)
    np.testing.assert_array_equal(ids, RUN)
    np.testing.assert_array_equal(sc, scores)
    assert tag == "test/a"


def test_trec_run_valid_mask_roundtrip(tmp_path):
    scores = np.array([[4.0, 3.5, 2.0, 1.0], [9.0, 8.5, 7.0, 6.0]])
    valid = np.array([[True, True, False, False], [True, True, True, True]])
    path = str(tmp_path / "b.run")
    trec.write_run(path, RUN, scores, run_tag="t", valid=valid)
    ids, sc, _ = trec.read_run(path)
    assert ids[0].tolist() == [0, 1, -1, -1]  # masked slots -> empty sentinels
    assert sc[0][2] == -np.inf
    np.testing.assert_array_equal(ids[1], RUN[1])


def test_trec_write_deterministic(tmp_path):
    scores = np.array([[1 / 3, 0.1, 0.07, 1e-17], [2.0, 1.0, 0.5, 0.25]])
    a, b = str(tmp_path / "a.run"), str(tmp_path / "b.run")
    trec.write_run(a, RUN, scores, run_tag="t")
    trec.write_run(b, RUN, scores.copy(), run_tag="t")
    assert open(a, "rb").read() == open(b, "rb").read()


def test_qrels_roundtrip(tmp_path):
    path = str(tmp_path / "qrels.txt")
    trec.write_qrels(path, QRELS)
    back = trec.read_qrels(path, n_queries=2, n_docs=8)
    np.testing.assert_array_equal(back, QRELS)


def test_significance_identical_runs():
    a = np.array([0.2, 0.4, 0.6, 0.8])
    res = significance.paired_randomization_test(a, a.copy(), n_permutations=500)
    assert res.diff == 0.0
    assert res.p_value == pytest.approx(1.0)


def test_significance_detects_dominant_system():
    rng = np.random.default_rng(0)
    b = rng.uniform(0.2, 0.4, size=50)
    a = b + 0.2  # uniformly better
    res = significance.paired_randomization_test(a, b, n_permutations=2000, seed=1)
    assert res.diff == pytest.approx(0.2)
    assert res.p_value < 0.01
    # symmetric: swapping systems flips the sign, not the p-value
    rev = significance.paired_randomization_test(b, a, n_permutations=2000, seed=1)
    assert rev.diff == pytest.approx(-0.2)
    assert rev.p_value == res.p_value


def test_significance_validates_input():
    with pytest.raises(ValueError):
        significance.paired_randomization_test(np.zeros(3), np.zeros(4))
    with pytest.raises(ValueError):
        significance.paired_randomization_test(np.zeros(0), np.zeros(0))
