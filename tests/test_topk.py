"""Property tests for the mergeable top-k combiner (paper's core invariant:
any chunking/ordering of the scan merges to the same top-k)."""

import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import topk


def oracle(scores: np.ndarray, ids: np.ndarray, k: int):
    order = np.argsort(-scores, kind="stable")[:k]
    out_s = np.full(k, -np.inf)
    out_i = np.full(k, -1, np.int64)
    out_s[: len(order)] = scores[order]
    out_i[: len(order)] = ids[order]
    return out_s, out_i


@settings(deadline=None, max_examples=40)
@given(
    st.integers(1, 8),  # k
    st.lists(st.floats(-100, 100, allow_nan=False, width=32), min_size=1, max_size=60),
    st.integers(1, 5),  # number of chunks
)
def test_chunked_update_matches_oracle(k, scores, n_chunks):
    scores = np.asarray(scores, np.float32)
    scores = np.unique(scores)  # distinct values: id ordering is determined
    np.random.shuffle(scores)
    ids = np.arange(len(scores))
    state = topk.init(k, ())
    for chunk in np.array_split(np.arange(len(scores)), n_chunks):
        if len(chunk) == 0:
            continue
        state = topk.update(state, jnp.asarray(scores[chunk]), jnp.asarray(ids[chunk]))
    ref_s, ref_i = oracle(scores, ids, k)
    np.testing.assert_allclose(np.asarray(state.scores), ref_s)
    np.testing.assert_array_equal(np.asarray(state.ids), ref_i)


@settings(deadline=None, max_examples=30)
@given(st.integers(1, 6), st.integers(0, 2**31 - 1))
def test_merge_associative_commutative(k, seed):
    r = np.random.default_rng(seed)
    def mk():
        n = int(r.integers(1, 12))
        s = r.standard_normal(n).astype(np.float32) * 10
        i = r.integers(0, 1000, n)
        st_ = topk.init(k, ())
        return topk.update(st_, jnp.asarray(s), jnp.asarray(i))
    a, b, c = mk(), mk(), mk()
    ab_c = topk.merge(topk.merge(a, b), c)
    a_bc = topk.merge(a, topk.merge(b, c))
    np.testing.assert_allclose(np.asarray(ab_c.scores), np.asarray(a_bc.scores))
    ba = topk.merge(b, a)
    ab = topk.merge(a, b)
    np.testing.assert_allclose(np.asarray(ab.scores), np.asarray(ba.scores))


def test_batched_state_and_dense():
    s = jnp.asarray(np.random.default_rng(1).standard_normal((4, 50)), jnp.float32)
    state = topk.topk_dense(s, 5)
    assert state.scores.shape == (4, 5)
    # folding strictly-worse candidates leaves the state unchanged
    st2 = topk.update(state, s - 100.0, jnp.broadcast_to(jnp.arange(50, 100), s.shape))
    np.testing.assert_allclose(np.asarray(st2.scores), np.asarray(state.scores))
    np.testing.assert_array_equal(np.asarray(st2.ids), np.asarray(state.ids))
