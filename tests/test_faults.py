"""Chaos suite: the reliability layer under deterministic fault injection.

The core assertion, everywhere: whatever schedule of crashes, writer
errors, stragglers, speculative duplicates, and dead workers is injected,
the sharded job completes (or fails with the *original* error once retries
are exhausted) and its merged state — and every TREC run file written from
it — is byte-identical to the fault-free single-host oracle. Scheduling
history must be invisible in the artifacts.
"""

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro import cluster
from repro import obs
from repro.cluster.faults import (
    FaultSchedule,
    FaultSpec,
    InjectedWriterError,
    WorkerCrash,
    parse_fault,
)
from repro.core import anchors, scoring
from repro.data import synthetic
from repro.experiments import runner

VOCAB = 1024
N_DOCS = 256
CHUNK = 32
K = 8
N_SHARDS = 4
SEGMENTS_PER_SHARD = 2  # 64 rows/shard / (CHUNK * segment_chunks=1)


@pytest.fixture(autouse=True)
def tracing_on():
    """Every chaos test runs with the observability layer recording: the
    byte-identity contract must hold with tracing ON (tracing observes,
    never decides — a trace-dependent branch would show up here first)."""
    with obs.session():
        yield


@pytest.fixture(scope="module")
def collection():
    corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=24, seed=11)
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
        chunk_size=CHUNK,
    )
    queries = jnp.asarray(synthetic.make_queries(corpus, n_queries=4, seed=12))
    docs = (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths))
    return stats, queries, docs


@pytest.fixture(scope="module")
def oracle(collection):
    """The fault-free single-host reference every chaos run must match."""
    stats, queries, docs = collection
    return cluster.run_sharded_scan_job(
        queries, docs, _scorers(), k=K, chunk_size=CHUNK, segment_chunks=1,
        n_shards=1, stats=stats, pipelined=False,
    )


def _scorers():
    return [scoring.make_variant("ql_lm"), scoring.make_variant("bm25")]


def _run(collection, *, faults=None, ckpt_dir=None, **kw):
    stats, queries, docs = collection
    args = dict(
        k=K, chunk_size=CHUNK, segment_chunks=1, n_shards=N_SHARDS,
        stats=stats, ckpt_dir=ckpt_dir, faults=faults, pipelined=True,
        max_workers=4,
    )
    args.update(kw)
    return cluster.run_sharded_scan_job(queries, docs, _scorers(), **args)


def assert_matches_oracle(got, oracle, *, err=""):
    np.testing.assert_array_equal(
        np.asarray(got.state.ids), np.asarray(oracle.state.ids), err_msg=err
    )
    assert (
        np.asarray(got.state.scores).tobytes()
        == np.asarray(oracle.state.scores).tobytes()
    ), err


# -- seeded chaos schedules ---------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_seeded_chaos_byte_identical_to_oracle(collection, oracle, tmp_path, seed):
    """Crash pre-/post-commit × straggler × writer-error, derived from one
    seed, against the full reliability stack (retries + stealing +
    speculation): run files stay byte-identical to the fault-free oracle."""
    schedule = FaultSchedule.random(
        seed, n_shards=N_SHARDS, n_segments=SEGMENTS_PER_SHARD
    )
    job = _run(
        collection, faults=schedule, ckpt_dir=str(tmp_path / "ckpt"),
        max_retries=3, speculative=True,
    )
    assert_matches_oracle(job, oracle, err=f"seed {seed}")
    # every seeded schedule contains at least one crash, and every fired
    # crash/writer-error kills an attempt that another attempt — a backoff
    # retry or an already-in-flight speculative rival — must cover
    hard = [f for f in schedule.fired if f["kind"] in ("crash", "writer_error")]
    assert hard, schedule.describe()
    assert job.scheduler.retries + job.scheduler.speculative_launched >= 1
    assert sum(job.scheduler.attempts) >= N_SHARDS + 1
    # the run-file layer sees none of it
    pa = runner.write_run_files(
        str(tmp_path / "ra"), _scorers(), oracle.state, tag_prefix="t"
    )
    pb = runner.write_run_files(
        str(tmp_path / "rb"), _scorers(), job.state, tag_prefix="t"
    )
    for name in pa:
        assert open(pa[name], "rb").read() == open(pb[name], "rb").read(), name


def test_chaos_survives_without_checkpoints(collection, oracle):
    """No ckpt_dir: retries re-fold the whole shard instead of resuming —
    slower, same bytes."""
    schedule = FaultSchedule.random(
        1, n_shards=N_SHARDS, n_segments=SEGMENTS_PER_SHARD
    )
    job = _run(collection, faults=schedule, max_retries=3, speculative=True)
    assert_matches_oracle(job, oracle)


# -- retry semantics ----------------------------------------------------------


def test_pre_commit_crash_retries_from_last_checkpoint(collection, oracle, tmp_path):
    """A pre-commit crash loses the in-flight segment; the retry resumes
    from the last committed one and re-folds only the tail."""
    schedule = FaultSchedule(
        [FaultSpec(kind="crash", shard=1, segment=1, phase="pre_commit")]
    )
    job = _run(
        collection, faults=schedule, ckpt_dir=str(tmp_path / "c"), max_retries=1
    )
    assert_matches_oracle(job, oracle)
    assert schedule.count_fired("crash") == 1
    assert job.scheduler.retries == 1
    assert job.scheduler.attempts[1] == 2
    # the retry resumed at segment 1 (segment 0's commit survived the crash)
    assert job.shard_results[1].resumed_from == 1
    assert job.shard_results[1].segments_run == 1


def test_permanent_failure_surfaces_original_error(collection, tmp_path):
    """A shard that fails on every attempt exhausts max_retries and the job
    raises that shard's original WorkerCrash — not a scheduler wrapper.

    The permanent fault must be *pre*-commit: a post-commit crash at a
    committed segment can never be permanent, because every retry resumes
    past it (which is the whole point of checkpoint-unit re-execution)."""
    schedule = FaultSchedule(
        [FaultSpec(kind="crash", shard=2, segment=1, phase="pre_commit",
                   attempts="all")]
    )
    with pytest.raises(WorkerCrash, match="injected failure before segment 1"):
        _run(
            collection, faults=schedule, ckpt_dir=str(tmp_path / "p"),
            max_retries=2,
        )
    assert schedule.count_fired("crash") == 3  # 1 first try + 2 retries
    # segment 0's commit is still durable: clear the fault and the job
    # completes by resuming shard 2 from its checkpoint
    job = _run(collection, ckpt_dir=str(tmp_path / "p"))
    assert job.shard_results[2].resumed_from == 1


def test_lowest_failed_shard_error_wins(collection, tmp_path):
    """Two permanently-failing shards: the raised error is deterministically
    the lowest-indexed shard's, whatever order the failures land in."""
    schedule = FaultSchedule(
        [
            FaultSpec(kind="crash", shard=3, segment=0, attempts="all"),
            FaultSpec(kind="crash", shard=1, segment=1, attempts="all",
                      phase="pre_commit"),
        ]
    )
    with pytest.raises(WorkerCrash, match="before segment 1"):
        _run(
            collection, faults=schedule, ckpt_dir=str(tmp_path / "p"),
            max_retries=0,
        )


# -- writer errors ------------------------------------------------------------


def test_writer_error_poisons_then_retry_reopens_dir(collection, oracle, tmp_path):
    """An injected checkpoint-writer error leaves a poisoned dir (stale
    ``.tmp-`` and no committed step); the retry re-opens that same dir,
    overwrites the stale tmp, and commits cleanly."""
    schedule = FaultSchedule(
        [FaultSpec(kind="writer_error", shard=0, segment=1)]
    )
    job = _run(
        collection, faults=schedule, ckpt_dir=str(tmp_path / "w"), max_retries=1
    )
    assert_matches_oracle(job, oracle)
    assert schedule.count_fired("writer_error") == 1
    assert job.scheduler.retries == 1
    sdir = str(tmp_path / "w" / "shard_0000")
    assert ckpt.all_steps(sdir) == [1, 2]
    # the retry's commit of the same step replaced the poisoned tmp dir
    assert not [d for d in os.listdir(sdir) if d.startswith(".tmp-")]
    prog = cluster.read_progress(sdir)
    assert prog["shards"]["0"]["complete"]


def test_writer_error_without_retries_fails_job(collection, tmp_path):
    schedule = FaultSchedule(
        [FaultSpec(kind="writer_error", shard=0, segment=0)]
    )
    with pytest.raises(InjectedWriterError, match="injected checkpoint-writer"):
        _run(collection, faults=schedule, ckpt_dir=str(tmp_path / "w"))


# -- stragglers + speculation -------------------------------------------------


def test_straggler_triggers_speculation(collection, oracle, tmp_path):
    """One slow shard, idle peers: when the queue drains the scheduler
    launches a speculative clone from the straggler's last checkpoint;
    whichever attempt commits first wins, bytes unchanged."""
    schedule = FaultSchedule(
        [
            # only attempt 0 is slow: the clone runs at full speed, so the
            # race is real but the artifacts must not care who wins
            FaultSpec(kind="straggler", shard=3, delay_s=0.4, attempts=(0,)),
        ]
    )
    job = _run(
        collection, faults=schedule, ckpt_dir=str(tmp_path / "s"),
        speculative=True,
    )
    assert_matches_oracle(job, oracle)
    assert schedule.count_fired("straggler") >= 1
    assert job.scheduler.speculative_launched >= 1


def test_speculative_win_promotes_clone_checkpoints(collection, oracle, tmp_path):
    """When the clone wins, its checkpoint dir is promoted over the
    primary's: the on-disk lineage is the winner's, no .spec dir remains."""
    schedule = FaultSchedule(
        [FaultSpec(kind="straggler", shard=2, delay_s=0.6, attempts=(0,))]
    )
    job = _run(
        collection, faults=schedule, ckpt_dir=str(tmp_path / "s"),
        speculative=True,
    )
    assert_matches_oracle(job, oracle)
    root = str(tmp_path / "s")
    assert not [d for d in os.listdir(root) if d.endswith(".spec")]
    prog = cluster.read_progress(os.path.join(root, "shard_0002"))
    assert prog["shards"]["2"]["complete"]


# -- dead workers + work stealing ---------------------------------------------


def test_dead_worker_job_completes_via_stealing(collection, oracle, tmp_path):
    """One permanently-dead worker: its queued shards drain through the
    survivors and the job still completes, byte-identical."""
    schedule = FaultSchedule([FaultSpec(kind="dead_worker", worker=0)])
    job = _run(
        collection, faults=schedule, ckpt_dir=str(tmp_path / "d")
    )
    assert_matches_oracle(job, oracle)
    assert job.scheduler.dead_workers == (0,)
    assert job.scheduler.steals >= 1
    assert all(a == 1 for a in job.scheduler.attempts)


def test_all_workers_dead_is_an_error(collection):
    schedule = FaultSchedule(
        [FaultSpec(kind="dead_worker", worker=w) for w in range(4)]
    )
    with pytest.raises(RuntimeError, match="unscanned shards"):
        _run(collection, faults=schedule)


# -- legacy aliases -----------------------------------------------------------


def test_legacy_kwargs_fire_once_on_one_shard(collection, tmp_path):
    """The deprecated kwargs now mean exactly one transient post-commit
    crash: ``fail_at_segment`` fires on ``==`` (not ``>=``), only on
    ``fail_at_shard``, and only on attempt 0 — so the same invocation,
    re-run over the same dir, resumes *past* the crash point and completes
    instead of dying again at the next segment."""
    stats, queries, docs = collection
    kw = dict(
        k=K, chunk_size=CHUNK, segment_chunks=1, n_shards=N_SHARDS,
        stats=stats, ckpt_dir=str(tmp_path / "l"),
    )
    with pytest.warns(DeprecationWarning):
        with pytest.raises(RuntimeError, match="injected failure after segment 0"):
            cluster.run_sharded_scan_job(
                queries, docs, _scorers(), fail_at_segment=0, fail_at_shard=2,
                **kw,
            )
    # resumed run keeps the same legacy kwargs: under the old >= plumbing it
    # would crash again at segment 1; under == it runs to completion
    with pytest.warns(DeprecationWarning):
        job = cluster.run_sharded_scan_job(
            queries, docs, _scorers(), fail_at_segment=0, fail_at_shard=2, **kw
        )
    assert job.shard_results[2].resumed_from == 1
    # only shard 2 ever crashed: every other shard completed on the first try
    for i, r in enumerate(job.shard_results):
        if i != 2:
            assert r.resumed_from in (0, SEGMENTS_PER_SHARD)


def test_legacy_kwarg_conflicts_with_faults(collection):
    stats, queries, docs = collection
    with pytest.raises(ValueError, match="deprecated fail_at_segment"):
        cluster.run_scan_job(
            queries, docs, _scorers(), k=K, chunk_size=CHUNK, segment_chunks=1,
            stats=stats, fail_at_segment=0, faults=FaultSchedule(),
        )


# -- spec parsing -------------------------------------------------------------


def test_parse_fault_round_trips():
    spec = parse_fault("crash:shard=1,segment=0,phase=pre_commit")
    assert spec == FaultSpec(
        kind="crash", shard=1, segment=0, phase="pre_commit"
    )
    assert parse_fault("straggler:shard=2,delay=0.05").delay_s == 0.05
    assert parse_fault("crash:shard=0,segment=1,attempts=all").attempts is None
    assert parse_fault("crash:shard=0,segment=1,attempts=0|2").attempts == (0, 2)
    assert parse_fault("dead_worker:worker=3,after_shards=1").after_shards == 1


@pytest.mark.parametrize(
    "bad",
    [
        "explode:shard=1",
        "crash:shard=1",  # crash needs a segment
        "writer_error:shard=0",  # so does writer_error
        "dead_worker:after_shards=1",  # dead_worker needs a worker
        "crash:shard=1,segment=0,wat=1",
        "straggler:delay",
    ],
)
def test_parse_fault_rejects(bad):
    with pytest.raises(ValueError):
        parse_fault(bad)


# -- the whole stack on virtual devices ---------------------------------------

_CHAOS_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro import cluster
from repro.cluster.faults import FaultSchedule
from repro.core import anchors, scoring
from repro.data import synthetic

corpus = synthetic.make_corpus(n_docs=256, vocab=1024, max_len=24, seed=11)
docs = (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths))
stats = anchors.collection_stats(*docs, vocab=1024, chunk_size=32)
queries = jnp.asarray(synthetic.make_queries(corpus, n_queries=4, seed=12))
scorers = [scoring.make_variant("ql_lm"), scoring.make_variant("bm25")]
kw = dict(k=8, chunk_size=32, segment_chunks=1, stats=stats)

oracle = cluster.run_sharded_scan_job(
    queries, docs, scorers, n_shards=1, pipelined=False, **kw
)
results = {}
for seed in (0, 1, 2):
    schedule = FaultSchedule.random(seed, n_shards=4, n_segments=2)
    job = cluster.run_sharded_scan_job(
        queries, docs, scorers, n_shards=4, devices=jax.devices(),
        max_retries=3, speculative=True, faults=schedule, **kw
    )
    results[f"seed{seed}"] = bool(
        (np.asarray(job.state.ids) == np.asarray(oracle.state.ids)).all()
        and np.asarray(job.state.scores).tobytes()
        == np.asarray(oracle.state.scores).tobytes()
    )
print(json.dumps(results))
"""


@pytest.mark.slow
def test_chaos_on_four_virtual_devices_subprocess():
    """Seeded chaos across 4 placeholder devices (own process so this test
    session keeps its single real device): one scheduler worker per device,
    faults and speculation landing on genuinely different devices."""
    proc = subprocess.run(
        [sys.executable, "-c", _CHAOS_MULTIDEV_SCRIPT],
        capture_output=True, text=True, timeout=900,
        # full env inherited: a stripped env stalls JAX for minutes at
        # interpreter shutdown on this platform
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert all(out.values()), out
