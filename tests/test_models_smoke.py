"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (the full
configs are exercised by the dry-run only)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced_config
from repro.configs.base import GNNConfig, RecsysConfig, TransformerConfig
from repro.data import synthetic
from repro.distributed.sharding import rules_for_mesh
from repro.models import gnn, recsys, transformer as tfm

LM_ARCHS = [a for a in ASSIGNED_ARCHS if isinstance(get_config(a), TransformerConfig)]
REC_ARCHS = [a for a in ASSIGNED_ARCHS if isinstance(get_config(a), RecsysConfig)]

B, S = 2, 32


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_step(arch, mesh11):
    cfg = reduced_config(arch)
    rules = rules_for_mesh(mesh11)
    params = tfm.init_params(cfg, jax.random.key(0))
    ctx = tfm.make_context(cfg, mesh11, rules, tokens_per_shard=B * S)
    batch = synthetic.make_lm_batch(batch=B, seq_len=S, vocab=cfg.vocab, seed=1)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    with jax.set_mesh(mesh11):
        loss_fn = tfm.make_loss_fn(ctx, chunk=16)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    assert jnp.isfinite(loss), arch
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_serve_and_prefill(arch, mesh11):
    cfg = reduced_config(arch)
    rules = rules_for_mesh(mesh11)
    params = tfm.init_params(cfg, jax.random.key(0))
    with jax.set_mesh(mesh11):
        ctx = tfm.make_context(cfg, mesh11, rules, tokens_per_shard=B, moe_mode="train")
        serve = tfm.make_serve_step(ctx, batch=B)
        cache = tfm.init_cache(cfg, B, 64)
        logits, cache2 = serve(params, cache, jnp.ones((B,), jnp.int32), jnp.asarray(3))
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), arch
        assert cache2["k"].shape == cache["k"].shape
        ctx_p = tfm.make_context(cfg, mesh11, rules, tokens_per_shard=B * S, moe_mode="seq")
        prefill = tfm.make_prefill_step(ctx_p)
        lg, cc = prefill(params, jnp.ones((B, S), jnp.int32))
        assert lg.shape == (B, cfg.vocab) and bool(jnp.all(jnp.isfinite(lg)))
        assert cc["k"].shape == (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.hd)


def test_serve_decode_matches_dense_attention(mesh11):
    """serve_step's split-merge attention == plain full-cache attention."""
    from repro.models.attention import attend_cache

    cfg = reduced_config("h2o-danube-1.8b")
    rules = rules_for_mesh(mesh11)
    params = tfm.init_params(cfg, jax.random.key(2))
    with jax.set_mesh(mesh11):
        ctx = tfm.make_context(cfg, mesh11, rules, tokens_per_shard=1)
        serve = tfm.make_serve_step(ctx, batch=2)
        cache = jax.tree.map(
            lambda s: jax.random.normal(jax.random.key(3), s.shape, s.dtype) * 0.1,
            tfm.cache_shapes(cfg, 2, 16),
        )
        t = jnp.asarray(7)
        logits, _ = serve(params, cache, jnp.ones((2,), jnp.int32), t)
        assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("shape_kind", ["full", "sampled", "batched"])
def test_pna_smoke(shape_kind, rng):
    cfg = reduced_config("pna")
    d_feat = 12
    params = gnn.init_params(cfg, d_feat, jax.random.key(0))
    if shape_kind == "full":
        g = synthetic.make_graph(n_nodes=64, n_edges=256, d_feat=d_feat, seed=1)
        logits = gnn.forward_full_graph(
            params, jnp.asarray(g["x"]), jnp.asarray(g["src"]), jnp.asarray(g["dst"]), cfg
        )
        assert logits.shape == (64, cfg.n_classes)
    elif shape_kind == "sampled":
        logits = gnn.forward_sampled(
            params,
            jnp.asarray(rng.standard_normal((8, d_feat)), jnp.float32),
            jnp.asarray(rng.standard_normal((8, 5, d_feat)), jnp.float32),
            jnp.asarray(rng.standard_normal((8, 5, 3, d_feat)), jnp.float32),
            cfg,
        )
        assert logits.shape == (8, cfg.n_classes)
    else:
        logits = gnn.forward_batched_graphs(
            params,
            jnp.asarray(rng.standard_normal((4, 10, d_feat)), jnp.float32),
            jnp.zeros((4, 20), jnp.int32),
            jnp.ones((4, 20), jnp.int32),
            cfg,
        )
        assert logits.shape == (4, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_pna_train_step(rng):
    cfg = reduced_config("pna")
    g = synthetic.make_graph(n_nodes=64, n_edges=256, d_feat=12, seed=2)
    params = gnn.init_params(cfg, 12, jax.random.key(1))

    def loss_fn(p):
        logits = gnn.forward_full_graph(
            p, jnp.asarray(g["x"]), jnp.asarray(g["src"]), jnp.asarray(g["dst"]), cfg
        )
        return gnn.xent_loss(logits, jnp.asarray(g["y"]) % cfg.n_classes)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", REC_ARCHS)
def test_recsys_train_step(arch):
    cfg = reduced_config(arch)
    params = recsys.init_params(cfg, jax.random.key(0))
    if cfg.variant in ("fm", "dcn-v2"):
        batch = synthetic.make_recsys_batch(
            batch=16, n_dense=cfg.n_dense, n_sparse=cfg.n_sparse,
            vocab_per_field=cfg.vocab_per_field, seed=1,
        )
    else:
        batch = synthetic.make_item_sequences(
            batch=16, seq_len=max(cfg.seq_len, 12), n_items=cfg.n_items, seed=1
        )
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    loss, grads = jax.value_and_grad(lambda p: recsys.train_logits(p, batch, cfg))(params)
    assert jnp.isfinite(loss), arch
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(grads)), arch


@pytest.mark.parametrize("arch", REC_ARCHS)
def test_recsys_retrieval_scoring(arch):
    """retrieval_cand scoring path (the MIREX scan integration)."""
    cfg = reduced_config(arch)
    params = recsys.init_params(cfg, jax.random.key(0))
    cand = jnp.arange(32, dtype=jnp.int32)
    if cfg.variant == "dcn-v2":
        user = {
            "dense": jnp.ones((1, cfg.n_dense), jnp.float32),
            "sparse_ids": jnp.ones((1, cfg.n_sparse), jnp.int32),
        }
        scores = recsys.score_block_dcn(params, user, cand, cfg)
    elif cfg.variant == "fm":
        user = {"sparse_ids": jnp.ones((1, cfg.n_sparse), jnp.int32)}
        qv = recsys.user_query_vector(params, user, cfg)
        scores = recsys.score_block_dot(qv, params["tables"][-1][cand])
    elif cfg.variant == "mind":
        caps = recsys.mind_interests(params, jnp.ones((1, 12), jnp.int32), cfg)
        scores = recsys.score_block_multi_interest(caps, params["items"][cand])
    else:
        h = recsys.sasrec_forward(params, jnp.ones((1, 12), jnp.int32), cfg)[:, -1]
        scores = recsys.score_block_dot(h, params["items"][cand])
    assert scores.shape == (1, 32)
    assert bool(jnp.all(jnp.isfinite(scores)))
