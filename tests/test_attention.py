"""Chunked attention vs full reference; traced window toggling; pipeline
fold properties; optimizer behaviour."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import pipeline
from repro.kernels import ref as kref
from repro.models.attention import chunked_attention
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.optim import compress


@pytest.mark.parametrize("window,cap", [(None, None), (16, None), (16, 50.0)])
@pytest.mark.parametrize("q_block", [16, 64])
def test_chunked_attention_matches_reference(rng, window, cap, q_block):
    b, s, h, kv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    k_exp = jnp.repeat(k, h // kv, axis=2)
    v_exp = jnp.repeat(v, h // kv, axis=2)
    out = chunked_attention(
        q, k_exp, v_exp, q_block=q_block, causal=True, window=window, cap=cap
    )
    want = kref.flash_attention_ref(q, k, v, causal=True, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-4, atol=3e-5)


def test_traced_window_active_toggles(rng):
    """window_active as a traced bool: True == windowed, False == full."""
    b, s, h, hd = 1, 32, 2, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    f = jax.jit(
        lambda active: chunked_attention(
            q, k, v, q_block=8, window=4, window_active=active
        )
    )
    on = f(jnp.asarray(True))
    off = f(jnp.asarray(False))
    with_window = chunked_attention(q, k, v, q_block=8, window=4, window_active=None)
    without = chunked_attention(q, k, v, q_block=8, window=None)
    np.testing.assert_allclose(np.asarray(on), np.asarray(with_window), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(off), np.asarray(without), rtol=1e-6)
    assert not np.allclose(np.asarray(on), np.asarray(off))


@settings(deadline=None, max_examples=25)
@given(st.integers(1, 5), st.integers(0, 2**31 - 1))
def test_fold_chunks_equals_direct_sum(n_chunk_pow, seed):
    r = np.random.default_rng(seed)
    chunk = 2**n_chunk_pow
    n = chunk * int(r.integers(1, 6))
    xs = jnp.asarray(r.standard_normal((n, 3)), jnp.float32)
    out = pipeline.fold_chunks(
        xs, chunk, lambda s, c, i: s + c.sum(0), jnp.zeros((3,), jnp.float32)
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(xs.sum(0)), rtol=1e-4, atol=1e-4)


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=0.05, weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_error_feedback_residual_identity(rng):
    """EF invariant: transmitted + residual == accumulated gradient."""
    g = jnp.asarray(rng.standard_normal(64), jnp.float32)
    acc = g  # first step: residual 0
    vals, idx = compress._topk_compress_leaf(acc, 0.1)
    sparse = compress._topk_decompress_leaf(vals, idx, acc.shape)
    residual = acc - sparse
    np.testing.assert_allclose(np.asarray(sparse + residual), np.asarray(acc), rtol=1e-6)
    assert int((np.asarray(sparse) != 0).sum()) <= max(1, int(64 * 0.1))
    # top-k by magnitude: the transmitted part carries the largest coordinates
    kept = np.abs(np.asarray(sparse))[np.asarray(sparse) != 0].min()
    dropped = np.abs(np.asarray(residual)).max()
    assert kept >= dropped - 1e-6
