"""The autotuning subsystem: config semantics, the winner cache, the AMBS
search loop, and — load-bearing above all — the **byte-identity contract**:

    tuning changes speed, never bytes.

A sharded scan job run under *any* legal TuningConfig must produce a merged
top-k state (ids and score bytes) identical to the default-config oracle;
the experiment runner must write byte-identical run files under an explicit
tuning, a cache-hit tuning, and no tuning at all. Deterministic variants
pin the corners in tier-1; hypothesis drives randomized configs through the
same job when installed (skipped, not failed, otherwise — tests/_hyp.py).
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import cluster, tune
from repro.core import anchors, scoring
from repro.data import synthetic
from repro.experiments import grid as exp_grid
from repro.experiments import runner
from repro.tune import DEFAULT, Knob, KnobSpace, TuneCache, TuningConfig
from repro.tune import config as tune_config

from _hyp import HAVE_HYPOTHESIS, given, settings, st

VOCAB = 512
N_DOCS = 256
CHUNK = 64
K = 5
N_SHARDS = 2
SEGMENT_CHUNKS = 1  # 64-row segments: 2 per shard, so prefetch has work

SCORERS = lambda: [  # noqa: E731
    scoring.make_variant("ql_lm"),
    scoring.make_variant("bm25"),
]


@pytest.fixture(scope="module")
def collection():
    corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=32, seed=3)
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
        chunk_size=CHUNK,
    )
    queries = jnp.asarray(synthetic.make_queries(corpus, n_queries=8, seed=4))
    docs = (np.asarray(corpus.tokens), np.asarray(corpus.lengths))
    return stats, queries, docs


def run_job(collection, cfg=None, *, use_kernel=False, ckpt_dir=None, **kw):
    stats, queries, docs = collection
    return cluster.run_sharded_scan_job(
        queries, docs, SCORERS(),
        k=K, chunk_size=CHUNK, segment_chunks=SEGMENT_CHUNKS,
        n_shards=N_SHARDS, stats=stats, ckpt_dir=ckpt_dir,
        use_kernel=use_kernel, tuning=cfg, **kw,
    )


def state_bytes(state) -> bytes:
    return np.asarray(state.scores).tobytes() + np.asarray(state.ids).tobytes()


@pytest.fixture(scope="module")
def oracle(collection):
    """The default-config job — what every tuned run must byte-match."""
    return state_bytes(run_job(collection).state)


# -- TuningConfig semantics ---------------------------------------------------


def test_default_config_is_identity():
    assert TuningConfig() == DEFAULT
    assert DEFAULT.overrides() == {}
    assert DEFAULT.resolve_chunk_size(128) == 128
    assert DEFAULT.lex_block(128) == 128  # None follows the chunk
    assert DEFAULT.dense_block(256) == 256
    assert DEFAULT.fold_key(False) == ()  # host folds: chunk already keys
    assert len(DEFAULT.fold_key(True)) == 3  # kernel folds: block geometry


def test_block_fallback_when_not_dividing():
    cfg = TuningConfig(lex_block_d=48)
    assert cfg.lex_block(64, 48) == 48  # divides: knob applies
    assert cfg.lex_block(64, 100) == 64  # doesn't: fall back to the chunk
    assert TuningConfig(dense_block_d=96).dense_block(32, 100) == 32


def test_config_validation():
    with pytest.raises(ValueError):
        TuningConfig(chunk_size=0)
    with pytest.raises(ValueError):
        TuningConfig(lex_tile_d=-1)
    with pytest.raises(ValueError):
        TuningConfig(backoff_base=-0.5)
    with pytest.raises(ValueError):
        TuningConfig.from_dict({"bogus_knob": 1})
    # non-strict drops unknowns (forward-compat read of a newer file)
    assert TuningConfig.from_dict({"bogus_knob": 1}, strict=False) == DEFAULT


def test_describe_from_dict_roundtrip_and_hash():
    cfg = TuningConfig(chunk_size=32, lex_tile_d=8, serve_max_batch=128)
    assert TuningConfig.from_dict(cfg.describe()) == cfg
    assert cfg.overrides() == {
        "chunk_size": 32, "lex_tile_d": 8, "serve_max_batch": 128,
    }
    assert cfg.config_hash() != DEFAULT.config_hash()
    assert cfg.config_hash() == cfg.replace().config_hash()  # content hash


def test_save_load_roundtrip(tmp_path):
    cfg = TuningConfig(prefetch_depth=3, writer_reuse=True)
    path = tune.save(cfg, str(tmp_path / "cfg.json"))
    assert tune.load(path) == cfg


def test_use_scoping_and_resolve():
    assert tune.active().config == DEFAULT
    cfg = TuningConfig(chunk_size=32)
    with tune.use(cfg, source="cache", cache_hit=True) as rec:
        assert tune.active().config == cfg
        assert rec.provenance() == {
            "config_hash": cfg.config_hash(), "source": "cache", "cache_hit": True,
        }
        # explicit argument beats the installed config
        explicit = TuningConfig(chunk_size=16)
        assert tune_config.resolve(explicit) == explicit
        assert tune_config.resolve(None) == cfg
    assert tune.active().config == DEFAULT  # nothing leaked


# -- winner cache -------------------------------------------------------------


def _put_one(tmp_path, **kw):
    cache = TuneCache(str(tmp_path / "cache.json"))
    args = dict(
        kind="scan_job", shape="scan:test", backend="cpu",
        config=TuningConfig(chunk_size=32), score=123.0,
    )
    args.update(kw)
    key = cache.put(**args)
    return cache, key, args


def test_cache_roundtrip(tmp_path):
    cache, key, args = _put_one(tmp_path, meta={"target": "t"})
    got, hit = cache.get(kind="scan_job", shape="scan:test", backend="cpu")
    assert hit and got == args["config"]
    entry = cache.entry(kind="scan_job", shape="scan:test", backend="cpu")
    assert entry["score"] == 123.0 and entry["meta"] == {"target": "t"}
    assert entry["config_hash"] == args["config"].config_hash()
    # one-call form, same answer
    got2, hit2 = tune.best_config(
        "scan_job", shape="scan:test", backend="cpu", path=cache.path
    )
    assert hit2 and got2 == got


def test_cache_miss_and_backend_isolation(tmp_path):
    cache, _, _ = _put_one(tmp_path)
    assert cache.get(kind="scan_job", shape="scan:other", backend="cpu") == (
        DEFAULT, False,
    )
    assert cache.get(kind="scan_job", shape="scan:test", backend="tpu") == (
        DEFAULT, False,
    )


def _corrupt(cache, mutate):
    data = json.load(open(cache.path))
    (entry,) = data["entries"].values()
    mutate(entry)
    with open(cache.path, "w") as f:
        json.dump(data, f)


def test_cache_stale_space_version_falls_back(tmp_path):
    cache, _, _ = _put_one(tmp_path)
    _corrupt(cache, lambda e: e.update(space_version=tune.SPACE_VERSION - 1))
    assert cache.get(kind="scan_job", shape="scan:test", backend="cpu") == (
        DEFAULT, False,
    )


def test_cache_kind_mismatch_falls_back(tmp_path):
    cache, _, _ = _put_one(tmp_path)
    _corrupt(cache, lambda e: e.update(kind="serve"))
    assert cache.get(kind="scan_job", shape="scan:test", backend="cpu") == (
        DEFAULT, False,
    )


def test_cache_unknown_knob_falls_back(tmp_path):
    cache, _, _ = _put_one(tmp_path)
    _corrupt(cache, lambda e: e.update(config={"block_z": 7}))
    assert cache.get(kind="scan_job", shape="scan:test", backend="cpu") == (
        DEFAULT, False,
    )


def test_cache_unreadable_file_falls_back(tmp_path):
    path = tmp_path / "garbage.json"
    path.write_text("not json {")
    assert TuneCache(str(path)).get(
        kind="scan_job", shape="s", backend="cpu"
    ) == (DEFAULT, False)


def test_shape_sig_agreement():
    """The runner's --tune lookup and the autotune recorder must compute the
    same signature from the same spec — the round trip is structural."""
    spec = exp_grid.get_experiment("smoke")
    assert tune.scan_shape_sig_for(spec) == tune.scan_shape_sig(
        n_docs=spec.n_docs, n_queries=spec.n_queries, k=spec.k,
        n_shards=spec.n_shards, n_models=len(spec.scorers()),
        max_doc_len=spec.max_doc_len,
    )
    # chunk_size is a knob, not a shape: deliberately absent
    assert "c" + str(spec.chunk_size) not in tune.scan_shape_sig_for(spec)


# -- search loop --------------------------------------------------------------


def _toy_space():
    return KnobSpace(
        kind="scan_job",
        knobs=(
            Knob("chunk_size", (32, 64, 128)),
            Knob("prefetch_depth", (1, 2)),
        ),
        base=DEFAULT.replace(chunk_size=32, prefetch_depth=1),
    )


def test_search_finds_planted_optimum():
    space = _toy_space()

    def measure(cfg):
        return 100.0 - abs(cfg.chunk_size - 64) - abs(cfg.prefetch_depth - 2)

    result = tune.run_search(space, measure, budget=6, seed=0)
    assert result.best.config.chunk_size == 64
    assert result.best.config.prefetch_depth == 2
    assert result.default.config == space.base  # the default was measured
    assert result.speedup_x >= 1.0


def test_search_deterministic_and_default_in_tournament():
    space = _toy_space()
    measure = lambda cfg: float(cfg.chunk_size)  # noqa: E731
    r1 = tune.run_search(space, measure, budget=4, seed=7)
    r2 = tune.run_search(space, measure, budget=4, seed=7)
    assert r1.best.config == r2.best.config
    assert {t.config.config_hash() for t in r1.trials} == {
        t.config.config_hash() for t in r2.trials
    }
    # best can never be worse than the default: it is in the tournament
    assert r1.best.score >= r1.default.score


def test_search_failed_trials_rank_last_and_all_fail_raises():
    space = _toy_space()

    def flaky(cfg):
        if cfg.chunk_size == 128:
            raise RuntimeError("boom")
        return float(cfg.chunk_size)

    result = tune.run_search(space, flaky, budget=6, seed=0)
    errs = [t for t in result.trials if t.error]
    assert errs and all(t.score == float("-inf") for t in errs)
    assert result.best.config.chunk_size == 64  # best OK trial wins

    with pytest.raises(RuntimeError, match="every scan_job trial failed"):
        tune.run_search(
            space, lambda cfg: 1 / 0, budget=3, seed=0
        )


def test_candidates_respect_constraint_and_lead_with_base():
    space = KnobSpace(
        kind="scan_job",
        knobs=(Knob("chunk_size", (32, 48, 64)),),
        constraint=lambda cfg: cfg.chunk_size is None or 64 % cfg.chunk_size == 0,
    )
    cands = space.candidates()
    assert cands[0] == space.base  # the default-config oracle leads the pool
    assert all(c.chunk_size in (None, 32, 64) for c in cands)  # 48 rejected


# -- the byte-identity contract ----------------------------------------------

# execution-geometry corners: every one must byte-match the default oracle
VARIANTS = (
    TuningConfig(chunk_size=32),  # finer fold chunks (2x the merges)
    TuningConfig(prefetch_depth=1, cross_shard_prefetch=False),  # no overlap
    TuningConfig(prefetch_depth=4, max_workers=1),  # deep prefetch, serial
    TuningConfig(lex_block_d=32, lex_tile_d=8, dense_block_d=32),  # kernel geo
)


@pytest.mark.parametrize("cfg", VARIANTS, ids=lambda c: str(c.overrides()))
def test_scan_bytes_invariant_to_tuning(collection, oracle, cfg):
    assert state_bytes(run_job(collection, cfg).state) == oracle


def test_scan_bytes_invariant_under_active_config(collection, oracle):
    """No explicit tuning= argument: the installed active config applies and
    still never changes bytes."""
    with tune.use(TuningConfig(chunk_size=32, prefetch_depth=1)):
        assert state_bytes(run_job(collection).state) == oracle


def test_kernel_scan_bytes_invariant_to_tuning(collection, oracle):
    base = state_bytes(run_job(collection, use_kernel=True).state)
    assert base == oracle  # kernel fold matches the host oracle to the bit
    tuned = TuningConfig(lex_block_d=32, lex_tile_d=8)
    assert state_bytes(run_job(collection, tuned, use_kernel=True).state) == base


def test_writer_reuse_checkpointed_job_bytes_and_resume(collection, oracle, tmp_path):
    cfg = TuningConfig(writer_reuse=True, prefetch_depth=1)
    ckpt = str(tmp_path / "ckpt")
    first = run_job(collection, cfg, ckpt_dir=ckpt)
    assert state_bytes(first.state) == oracle
    assert first.segments_run > 0
    # resume from the committed segments: nothing re-runs, same bytes
    again = run_job(collection, cfg, ckpt_dir=ckpt)
    assert again.segments_run == 0
    assert state_bytes(again.state) == oracle


if HAVE_HYPOTHESIS:
    legal_configs = st.builds(
        TuningConfig,
        chunk_size=st.sampled_from([None, 32, 64, 128]),
        prefetch_depth=st.integers(1, 3),
        max_workers=st.sampled_from([None, 1, 2]),
        cross_shard_prefetch=st.booleans(),
        writer_reuse=st.booleans(),
        lex_block_d=st.sampled_from([None, 32, 64]),
        lex_tile_d=st.sampled_from([8, 16, 32]),
        dense_block_d=st.sampled_from([None, 32, 64]),
    )
else:
    legal_configs = None


@settings(max_examples=12, deadline=None)
@given(cfg=legal_configs)
def test_scan_bytes_invariant_to_random_tuning(collection, oracle, cfg):
    """The property itself: ANY legal config — including chunk sizes that
    regroup the whole fold — produces the oracle's exact bytes."""
    assert state_bytes(run_job(collection, cfg).state) == oracle


# -- runner integration -------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_spec():
    return exp_grid.ExperimentSpec(
        name="tunetest",
        grids=(exp_grid.GridSpec("ql_lm"), exp_grid.GridSpec("bm25")),
        n_docs=N_DOCS, n_queries=8, vocab=VOCAB, max_doc_len=32,
        k=K, chunk_size=CHUNK, segment_chunks=2,
        eval_ks=(5,), baseline="ql_lm",
    )


def _run_files(out_dir):
    runs = os.path.join(out_dir, "runs")
    return {
        name: open(os.path.join(runs, name), "rb").read()
        for name in sorted(os.listdir(runs))
    }


def test_runner_tuning_provenance_and_run_file_bytes(tiny_spec, tmp_path):
    coll = runner.prepare_collection(tiny_spec, seed=0)
    default = runner.run_experiment(
        tiny_spec, out_dir=str(tmp_path / "default"), collection=coll
    )
    assert default["job"]["tuning"]["source"] == "default"
    assert default["job"]["tuning"]["overrides"] == {}

    cfg = TuningConfig(chunk_size=32, prefetch_depth=1, lex_tile_d=8)
    tuned = runner.run_experiment(
        tiny_spec, out_dir=str(tmp_path / "tuned"), collection=coll, tuning=cfg
    )
    t = tuned["job"]["tuning"]
    assert t["source"] == "explicit" and t["config_hash"] == cfg.config_hash()
    assert t["chunk_size"] == 32  # divides the shard: the knob applied
    assert t["overrides"]["chunk_size"] == 32

    assert _run_files(tmp_path / "default") == _run_files(tmp_path / "tuned")

    with pytest.raises(ValueError, match="not both"):
        runner.run_experiment(
            tiny_spec, out_dir=str(tmp_path / "x"), collection=coll,
            tuning=cfg, tune_lookup=True,
        )


def test_runner_cache_lookup_hit_and_miss(tiny_spec, tmp_path):
    coll = runner.prepare_collection(tiny_spec, seed=0)
    cache_path = str(tmp_path / "cache.json")

    # cold cache: --tune degrades to the defaults, cache_hit False
    miss = runner.run_experiment(
        tiny_spec, out_dir=str(tmp_path / "miss"), collection=coll,
        tune_lookup=True, tune_cache=cache_path,
    )
    assert miss["job"]["tuning"] == {
        **miss["job"]["tuning"],
        "source": "cache", "cache_hit": False, "overrides": {},
    }

    # record a winner under the runner's own signature, then look it up
    cfg = TuningConfig(chunk_size=32)
    TuneCache(cache_path).put(
        kind="scan_job", shape=tune.scan_shape_sig_for(tiny_spec),
        config=cfg, score=1.0,
        backend=tune.backend_sig(use_kernel=tiny_spec.use_kernel),
    )
    hit = runner.run_experiment(
        tiny_spec, out_dir=str(tmp_path / "hit"), collection=coll,
        tune_lookup=True, tune_cache=cache_path,
    )
    t = hit["job"]["tuning"]
    assert t["cache_hit"] is True and t["source"] == "cache"
    assert t["config_hash"] == cfg.config_hash()
    assert _run_files(tmp_path / "miss") == _run_files(tmp_path / "hit")
