"""Batch experiment engine: multi-scorer parity, resumable jobs, lifecycle."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anchors, scan, scoring, topk
from repro.data import synthetic
from repro.experiments import bench as exp_bench
from repro.experiments import grid as exp_grid
from repro.experiments import job as exp_job
from repro.experiments import runner

VOCAB = 2048
N_DOCS = 512
CHUNK = 128
K = 10


@pytest.fixture(scope="module")
def collection():
    corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=32, seed=0)
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
        chunk_size=CHUNK,
    )
    queries = jnp.asarray(synthetic.make_queries(corpus, n_queries=8, seed=1))
    docs = (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths))
    return corpus, stats, queries, docs


GRID_5 = (
    ("ql_lm", {}),
    ("ql_lm", {"lam": 0.5}),
    ("ql_lm", {"length_prior": False}),
    ("bm25", {}),
    ("bm25", {"k1": 0.9, "b": 0.4}),
)


def test_multi_scorer_parity_vs_independent_passes(collection):
    """One pass over a 5-variant grid == 5 independent single-scorer scans."""
    _, stats, queries, docs = collection
    scorers = [scoring.make_variant(b, **p) for b, p in GRID_5]
    multi = scan.search_local_multi(
        queries, docs, scorers, k=K, chunk_size=CHUNK, stats=stats
    )
    assert multi.scores.shape == (len(scorers), queries.shape[0], K)
    for m, s in enumerate(scorers):
        single = scan.search_local(
            queries, docs, s, k=K, chunk_size=CHUNK, stats=stats
        )
        np.testing.assert_array_equal(
            np.asarray(multi.ids)[m], np.asarray(single.ids), err_msg=s.name
        )
        np.testing.assert_array_equal(
            np.asarray(multi.scores)[m], np.asarray(single.scores), err_msg=s.name
        )


def test_multi_scorer_parity_dense():
    q = jnp.asarray(synthetic.make_dense_corpus(n_docs=16, dim=32, seed=0))
    d = jnp.asarray(synthetic.make_dense_corpus(n_docs=256, dim=32, seed=1))
    scorers = [scoring.get_scorer("dense_dot"), scoring.get_scorer("dense_cosine")]
    multi = scan.search_local_multi(q, d, scorers, k=K, chunk_size=64)
    for m, s in enumerate(scorers):
        single = scan.search_local(q, d, s, k=K, chunk_size=64)
        np.testing.assert_array_equal(np.asarray(multi.ids)[m], np.asarray(single.ids))


def test_multi_scorer_rejects_mixed_kinds(collection):
    _, stats, queries, docs = collection
    with pytest.raises(ValueError, match="single kind"):
        scan.search_local_multi(
            queries, docs,
            [scoring.get_scorer("ql_lm"), scoring.get_scorer("dense_dot")],
            k=K, chunk_size=CHUNK, stats=stats,
        )
    with pytest.raises(ValueError, match="at least one"):
        scan.search_local_multi(queries, docs, [], k=K, chunk_size=CHUNK)
    with pytest.raises(ValueError, match="init_state has k"):
        scan.search_local_multi(
            queries, docs, [scoring.get_scorer("ql_lm")], k=K, chunk_size=CHUNK,
            stats=stats, init_state=topk.init(K + 1, (1, queries.shape[0])),
        )


def test_scan_job_kill_resume_bit_identical(collection, tmp_path):
    """A job killed at a chunk/segment boundary resumes to bit-identical
    state and a byte-identical TREC run file."""
    _, stats, queries, docs = collection
    scorers = [scoring.make_variant(b, **p) for b, p in GRID_5[:4]]
    kw = dict(k=K, chunk_size=CHUNK, segment_chunks=1, stats=stats)

    clean = exp_job.run_scan_job(
        queries, docs, scorers, ckpt_dir=str(tmp_path / "a"), **kw
    )
    assert clean.segments_total == N_DOCS // CHUNK
    assert clean.segments_run == clean.segments_total

    with pytest.raises(RuntimeError, match="injected failure"):
        exp_job.run_scan_job(
            queries, docs, scorers, ckpt_dir=str(tmp_path / "b"),
            fail_at_segment=1, **kw
        )
    prog = exp_job.read_progress(str(tmp_path / "b"))
    assert prog["shards"]["0"]["segments_done"] == 2  # committed before the kill
    assert not prog["shards"]["0"]["complete"]

    resumed = exp_job.run_scan_job(
        queries, docs, scorers, ckpt_dir=str(tmp_path / "b"), **kw
    )
    assert resumed.resumed_from == 2
    assert resumed.segments_run == clean.segments_total - 2
    np.testing.assert_array_equal(
        np.asarray(clean.state.scores), np.asarray(resumed.state.scores)
    )
    np.testing.assert_array_equal(
        np.asarray(clean.state.ids), np.asarray(resumed.state.ids)
    )

    # artifact-level: byte-identical run files
    pa = runner.write_run_files(str(tmp_path / "runs_a"), scorers, clean.state, tag_prefix="t")
    pb = runner.write_run_files(str(tmp_path / "runs_b"), scorers, resumed.state, tag_prefix="t")
    for name in pa:
        assert open(pa[name], "rb").read() == open(pb[name], "rb").read()

    # a re-run of a complete job is a no-op (idempotent)
    again = exp_job.run_scan_job(
        queries, docs, scorers, ckpt_dir=str(tmp_path / "b"), **kw
    )
    assert again.segments_run == 0
    np.testing.assert_array_equal(np.asarray(again.state.ids), np.asarray(clean.state.ids))


def test_scan_job_rejects_foreign_checkpoint(collection, tmp_path):
    """Resume must not silently adopt a checkpoint from a different job,
    even when the combiner state shapes match exactly."""
    _, stats, queries, docs = collection
    scorers = [scoring.get_scorer("ql_lm"), scoring.get_scorer("bm25")]
    kw = dict(k=K, chunk_size=CHUNK, segment_chunks=2, stats=stats)
    exp_job.run_scan_job(queries, docs, scorers, ckpt_dir=str(tmp_path / "c"), **kw)

    other_corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=32, seed=9)
    other_docs = (jnp.asarray(other_corpus.tokens), jnp.asarray(other_corpus.lengths))
    with pytest.raises(ValueError, match="different job"):
        exp_job.run_scan_job(
            queries, other_docs, scorers, ckpt_dir=str(tmp_path / "c"), **kw
        )
    with pytest.raises(ValueError, match="different job"):
        exp_job.run_scan_job(
            queries, docs, scorers[:1], ckpt_dir=str(tmp_path / "c"),
            k=K, chunk_size=CHUNK, segment_chunks=2, stats=stats,
        )
    # a different segmentation geometry must also be rejected: the checkpoint
    # step counts *segments*, so reinterpreting it would skip/double-fold rows
    with pytest.raises(ValueError, match="different job"):
        exp_job.run_scan_job(
            queries, docs, scorers, ckpt_dir=str(tmp_path / "c"),
            k=K, chunk_size=CHUNK, segment_chunks=1, stats=stats,
        )
    # resume=False starts clean instead
    fresh = exp_job.run_scan_job(
        queries, other_docs, scorers, ckpt_dir=str(tmp_path / "c"),
        resume=False, **kw
    )
    assert fresh.resumed_from == 0
    assert fresh.segments_run == fresh.segments_total


def test_scan_job_matches_unsegmented_scan(collection):
    _, stats, queries, docs = collection
    scorers = [scoring.get_scorer("ql_lm"), scoring.get_scorer("bm25")]
    res = exp_job.run_scan_job(
        queries, docs, scorers, k=K, chunk_size=CHUNK, segment_chunks=2,
        stats=stats, ckpt_dir=None,
    )
    direct = scan.search_local_multi(
        queries, docs, scorers, k=K, chunk_size=CHUNK, stats=stats
    )
    np.testing.assert_array_equal(np.asarray(res.state.ids), np.asarray(direct.ids))
    # jitted segment folds vs the eager whole-corpus fold fuse differently on
    # XLA:CPU — rankings are exact, scores agree to float tolerance
    np.testing.assert_allclose(
        np.asarray(res.state.scores), np.asarray(direct.scores), rtol=1e-5, atol=1e-6
    )


def test_grid_expansion_and_parsing():
    spec = exp_grid.parse_grid("bm25:k1=0.9|1.2,b=0.4|0.75")
    variants = spec.expand()
    assert len(variants) == 4
    assert sorted(v.name for v in variants) == [
        "bm25(b=0.4,k1=0.9)", "bm25(b=0.4,k1=1.2)",
        "bm25(b=0.75,k1=0.9)", "bm25(b=0.75,k1=1.2)",
    ]
    assert all(v.kind == "lexical" for v in variants)

    with pytest.raises(KeyError, match="unknown scorer"):
        exp_grid.parse_grid("nope:k=1")
    with pytest.raises(ValueError, match="malformed"):
        exp_grid.parse_grid("bm25:k1")
    with pytest.raises(ValueError, match="duplicate"):
        exp_grid.expand_grids((exp_grid.GridSpec("bm25"), exp_grid.GridSpec("bm25")))
    with pytest.raises(ValueError, match="one corpus representation"):
        exp_grid.expand_grids((exp_grid.GridSpec("bm25"), exp_grid.GridSpec("dense_dot")))
    # bools survive parsing
    spec = exp_grid.parse_grid("ql_lm:length_prior=true|false")
    assert spec.params == (("length_prior", (True, False)),)


def test_registry():
    assert "smoke" in exp_grid.EXPERIMENTS
    spec = exp_grid.get_experiment("smoke")
    assert len(spec.scorers()) == 2
    assert len(exp_grid.get_experiment("bm25-grid").scorers()) == 5
    with pytest.raises(KeyError, match="unknown experiment"):
        exp_grid.get_experiment("nope")
    with pytest.raises(ValueError, match="already registered"):
        exp_grid.register_experiment(spec)


def test_run_experiment_lifecycle(tmp_path):
    spec = exp_grid.get_experiment("smoke")
    report = runner.run_experiment(spec, out_dir=str(tmp_path / "exp"))
    assert report["models"] == ["ql_lm", "bm25"]
    for model in report["models"]:
        assert os.path.exists(report["runs"][model])
        agg = report["metrics"][model]
        assert set(agg) >= {"map", "mrr", "p@5", "ndcg@10", "recall@10"}
        assert 0.0 <= agg["map"] <= 1.0
    assert report["baseline"] == "ql_lm"
    assert set(report["significance"]) == {"bm25"}
    assert 0.0 < report["significance"]["bm25"]["p_value"] <= 1.0
    on_disk = json.load(open(tmp_path / "exp" / "report.json"))
    assert on_disk == report
    # rankings retrieve planted relevance far above chance for both models
    qrels = runner.prepare_collection(spec).qrels
    chance = float((qrels > 0).mean())
    for model in report["models"]:
        assert report["metrics"][model]["p@5"] > 5 * chance


def test_amortization_curve_smoke(collection):
    _, stats, queries, docs = collection
    scorers = [scoring.make_variant(b, **p) for b, p in GRID_5[:4]]
    payload = exp_bench.amortization_curve(
        queries, docs, scorers, k=K, chunk_size=CHUNK, stats=stats,
        sizes=(1, 2, 4), repeats=1, warmup=1,
    )
    assert [pt["models"] for pt in payload["curve"]] == [1, 2, 4]
    assert all(pt["wall_s"] > 0 for pt in payload["curve"])
    assert all("speedup_vs_independent" in pt for pt in payload["curve"])
    assert "amortization_x" in payload
    # unsorted sizes are normalized so t(1) is measured before any speedup
    shuffled = exp_bench.amortization_curve(
        queries, docs, scorers, k=K, chunk_size=CHUNK, stats=stats,
        sizes=(4, 1, 2), repeats=1, warmup=0,
    )
    assert [pt["models"] for pt in shuffled["curve"]] == [1, 2, 4]
    assert all("speedup_vs_independent" in pt for pt in shuffled["curve"])
    with pytest.raises(ValueError, match="variants"):
        exp_bench.amortization_curve(
            queries, docs, scorers[:2], k=K, chunk_size=CHUNK, sizes=(1, 4)
        )


def test_graded_qrels_consistent_with_binary():
    corpus = synthetic.make_corpus(n_docs=256, vocab=VOCAB, max_len=32, seed=3)
    queries = synthetic.make_queries(corpus, n_queries=8, seed=4)
    binary = synthetic.make_qrels(corpus, queries, per_query=20, seed=5)
    graded = synthetic.make_graded_qrels(corpus, queries, per_query=20, seed=5)
    np.testing.assert_array_equal(graded > 0, binary)
    assert graded.max() == 3


def test_valid_mask_small_corpus():
    state = topk.init(4, (2,))
    state = topk.update(
        state, jnp.asarray([[1.0, 2.0], [3.0, 4.0]]), jnp.asarray([[0, 1], [2, 3]])
    )
    mask = np.asarray(topk.valid_mask(state))
    assert mask.sum(axis=-1).tolist() == [2, 2]  # only 2 of k=4 slots filled
