"""SLO-driven adaptive serving: bucket-ladder cap, admission control,
closed-loop policy, open-loop load generation — and the contract that none
of it ever changes a completed request's bytes."""

import json
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anchors
from repro.data import synthetic
from repro.obs.metrics import Histogram, Metrics
from repro.serve import (
    AdaptiveBatchPolicy,
    Admitted,
    AdmissionController,
    Blocked,
    LexicalSession,
    MeteredSession,
    Microbatcher,
    RejectedError,
    RetrievalService,
    Shed,
    TokenBucket,
    VirtualClock,
    burst_schedule,
    poisson_schedule,
    run_open_loop,
)
from repro.serve.admission import BATCH, BATCH_YIELD, INTERACTIVE, QUEUE_FULL, RATE_LIMITED
from repro.serve.microbatch import bucket_size


class ManualClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


class StubState:
    def __init__(self, n, k):
        self.scores = np.arange(n * k, dtype=np.float32).reshape(n, k)
        self.ids = np.arange(n * k, dtype=np.int32).reshape(n, k)


class StubSession:
    """Deterministic per-row 'scan': result row j = f(query row j) only."""

    kind = "stub"
    pad_value = 0
    k = 4
    chunk_size = 64
    n_docs = 128
    scorer = type("S", (), {"name": "stub"})()

    def __init__(self):
        self.block_sizes = []

    def search(self, q):
        self.block_sizes.append(q.shape[0])
        n = q.shape[0]
        s = StubState(n, self.k)
        # per-row function of the query so grouping bugs are visible
        s.scores = (q[:, :1].astype(np.float32) + np.arange(self.k, 0, -1, np.float32))
        s.ids = np.broadcast_to(
            q[:, :1].astype(np.int32) * 10 + np.arange(self.k, dtype=np.int32),
            (n, self.k),
        ).copy()
        return s


# ------------------------------------------------------- bucket-ladder cap


def test_bucket_size_caps_at_max_bucket():
    assert bucket_size(65, min_bucket=8, max_bucket=128) == 128
    assert bucket_size(33, min_bucket=8, max_bucket=64) == 64
    assert bucket_size(3, min_bucket=8, max_bucket=64) == 8
    # a block larger than the cap pads to its own pow2 (never truncates)
    assert bucket_size(200, min_bucket=8, max_bucket=128) == 256
    assert bucket_size(65, min_bucket=8, max_bucket=None) == 128


def test_oversize_backlog_splits_into_capped_blocks():
    mb = Microbatcher(max_batch=512, max_delay=0.0, min_bucket=8, max_bucket=128)
    for rid in range(300):
        mb.submit(rid, np.zeros(3, np.int32), now=0.0)
    blocks = []
    while (b := mb.pop_block(0.0)) is not None:
        blocks.append(b)
    assert [b.n_real for b in blocks] == [128, 128, 44]
    assert all(b.n_padded <= 128 for b in blocks)
    assert [r for b in blocks for r in b.rids] == list(range(300))


def test_retune_is_the_only_reconfiguration_surface():
    mb = Microbatcher(max_batch=64, max_delay=0.005, min_bucket=8, max_bucket=128)
    knobs = mb.retune(max_batch=32, max_delay=0.001)
    assert knobs == {
        "serve_max_batch": 32,
        "serve_max_delay_s": 0.001,
        "serve_min_bucket": 8,
        "serve_max_bucket": 128,
    }
    assert mb.max_batch == 32 and mb.max_delay == 0.001
    # None on max_bucket means *uncap*; omitting it keeps the cap
    mb.retune(max_bucket=None)
    assert mb.max_bucket is None
    mb.retune(max_batch=16)
    assert mb.max_bucket is None and mb.max_batch == 16


def test_deadline_trigger_consistent_with_next_deadline():
    """The trigger must fire at exactly the time next_deadline() returns
    (float-rounding mismatches here livelock an event loop)."""
    mb = Microbatcher(max_batch=100, max_delay=0.005, min_bucket=8)
    for arrival in (0.1234567, 17.77777, 1e6 + 0.333):
        mb.submit(0, np.zeros(2, np.int32), now=arrival)
        t = mb.next_deadline()
        assert mb.pop_block(t) is not None
    assert mb.pop_block(1.0) is None  # empty again


# ------------------------------------------------------------- token bucket


def test_token_bucket_refills_at_rate_up_to_burst():
    tb = TokenBucket(rate=10.0, burst=2.0)
    assert tb.take(0.0) and tb.take(0.0)
    assert not tb.take(0.0)  # burst exhausted
    assert tb.peek(0.05) == pytest.approx(0.5)
    assert tb.next_token_at(0.05) == pytest.approx(0.1)
    assert tb.take(0.1)
    assert tb.peek(100.0) == pytest.approx(2.0)  # capped at burst


# ------------------------------------------------------- admission decisions


def test_admission_queue_bound_sheds_or_blocks():
    shed_ctl = AdmissionController(queue_limit=4, on_full="shed")
    assert shed_ctl.admit(tenant="t", lane=INTERACTIVE, now=0.0, queue_depth=3) is None
    out = shed_ctl.admit(tenant="t", lane=INTERACTIVE, now=0.0, queue_depth=4)
    assert isinstance(out, Shed) and out.reason == QUEUE_FULL

    block_ctl = AdmissionController(queue_limit=4, on_full="block")
    out = block_ctl.admit(tenant="t", lane=INTERACTIVE, now=0.0, queue_depth=4)
    assert isinstance(out, Blocked) and out.reason == QUEUE_FULL


def test_admission_per_tenant_token_buckets():
    ctl = AdmissionController(queue_limit=100)
    ctl.set_rate("alice", INTERACTIVE, rate=1.0, burst=1.0)
    assert ctl.admit(tenant="alice", lane=INTERACTIVE, now=0.0, queue_depth=0) is None
    out = ctl.admit(tenant="alice", lane=INTERACTIVE, now=0.0, queue_depth=0)
    assert isinstance(out, Shed) and out.reason == RATE_LIMITED
    # bob has no bucket: uncapped
    for _ in range(5):
        assert ctl.admit(tenant="bob", lane=INTERACTIVE, now=0.0, queue_depth=0) is None
    # refill admits alice again
    assert ctl.admit(tenant="alice", lane=INTERACTIVE, now=1.1, queue_depth=0) is None


def test_admission_default_rate_gives_each_tenant_its_own_bucket():
    ctl = AdmissionController(queue_limit=100, on_full="block")
    ctl.set_rate("*", INTERACTIVE, rate=1.0, burst=1.0)
    assert ctl.admit(tenant="a", lane=INTERACTIVE, now=0.0, queue_depth=0) is None
    # a's budget is spent, but b gets its own default-rate bucket
    assert ctl.admit(tenant="b", lane=INTERACTIVE, now=0.0, queue_depth=0) is None
    out = ctl.admit(tenant="a", lane=INTERACTIVE, now=0.0, queue_depth=0)
    assert isinstance(out, Blocked) and out.reason == RATE_LIMITED
    assert out.retry_at == pytest.approx(1.0)


def test_batch_lane_yields_above_watermark_and_under_pressure():
    ctl = AdmissionController(queue_limit=10, batch_watermark=0.5)
    # below watermark: both lanes admitted
    assert ctl.admit(tenant="t", lane=BATCH, now=0.0, queue_depth=4) is None
    # above watermark: batch yields, interactive keeps the queue
    out = ctl.admit(tenant="t", lane=BATCH, now=0.0, queue_depth=5)
    assert isinstance(out, Shed) and out.reason == BATCH_YIELD
    assert ctl.admit(tenant="t", lane=INTERACTIVE, now=0.0, queue_depth=5) is None
    # pressure (the policy's SLO-at-risk signal): batch yields at any depth
    ctl.set_pressure(True)
    out = ctl.admit(tenant="t", lane=BATCH, now=0.0, queue_depth=0)
    assert isinstance(out, Shed) and out.reason == BATCH_YIELD
    ctl.set_pressure(False)
    assert ctl.admit(tenant="t", lane=BATCH, now=0.0, queue_depth=0) is None


# ------------------------------------------------------- the closed loop


def _bound_policy(clock, **kw):
    kw.setdefault("slo_p99_s", 0.1)
    kw.setdefault("interval_s", 1.0)
    kw.setdefault("min_samples", 1)
    policy = AdaptiveBatchPolicy(**kw)
    batcher = Microbatcher(max_batch=64, max_delay=0.005, min_bucket=8, max_bucket=128)
    hist = Histogram(
        "serve.recent.request_s", window_s=policy.window_s, n_windows=4, clock=clock
    )
    metrics = Metrics()
    admission = AdmissionController(queue_limit=16)
    policy.bind(
        batchers=[batcher], request_hist=hist, metrics=lambda: metrics,
        admission=admission,
    )
    return policy, batcher, hist, metrics, admission


def test_policy_tightens_above_band_and_sets_pressure():
    clock = ManualClock()
    policy, batcher, hist, metrics, admission = _bound_policy(clock)
    for _ in range(8):
        hist.observe(0.5)  # p99 far above slo * (1 + band)
    assert policy.tick(0.0) == "tighten"
    assert batcher.max_batch == 32 and batcher.max_delay == pytest.approx(0.0025)
    assert admission.pressure
    assert policy.adjustments == 1
    assert metrics.counter("serve.policy.adjustments").value == 1
    assert metrics.gauge("serve.policy.max_batch").value == 32


def test_policy_relaxes_below_band_and_holds_inside():
    clock = ManualClock()
    policy, batcher, hist, metrics, admission = _bound_policy(clock)
    for _ in range(8):
        hist.observe(0.01)
    assert policy.tick(0.0) == "relax"
    assert batcher.max_batch == 128 and not admission.pressure
    # inside the hysteresis band: hold (0.1 slo, band 0.2 -> [0.08, 0.12])
    clock.t = 20.0  # window rotates the old samples out
    for _ in range(8):
        hist.observe(0.1)
    assert policy.tick(20.0) == "hold"
    assert batcher.max_batch == 128


def test_policy_interval_and_min_samples_gate():
    clock = ManualClock()
    policy, batcher, hist, _, _ = _bound_policy(clock, min_samples=4)
    hist.observe(0.5)
    assert policy.tick(0.0) is None  # 1 sample < min_samples
    for _ in range(8):
        hist.observe(0.5)
    assert policy.tick(0.5) is None  # inside interval_s of the last tick
    assert policy.tick(1.0) == "tighten"


def test_policy_damps_reversals_inside_cooldown():
    clock = ManualClock()
    policy, batcher, hist, metrics, _ = _bound_policy(clock, cooldown_intervals=2)
    for _ in range(8):
        hist.observe(0.5)
    assert policy.tick(0.0) == "tighten"  # direction -1, no flip yet
    clock.t = 20.0  # decay the window, then drive p99 low
    for _ in range(8):
        hist.observe(0.01)
    assert policy.tick(20.0) == "relax"  # first flip, applied
    assert policy.flips == 1
    batch_after_flip = batcher.max_batch
    clock.t = 21.0  # back above the band within the cooldown (2 intervals)
    for _ in range(64):
        hist.observe(0.5)
    assert policy.tick(21.0) == "damped"
    assert policy.damped == 1
    assert batcher.max_batch == batch_after_flip  # knobs held
    assert metrics.counter("serve.policy.damped").value == 1
    # after the cooldown the reversal applies
    assert policy.tick(23.0) == "tighten"
    assert policy.flips == 2
    assert policy.oscillation_violations == 0
    assert metrics.counter("serve.policy.oscillation_violations").value == 0


def test_policy_pins_at_bounds():
    clock = ManualClock()
    policy, batcher, hist, _, _ = _bound_policy(clock)
    for _ in range(8):
        hist.observe(0.01)
    assert policy.tick(0.0) == "relax"  # 64 -> 128 (the bucket cap)
    label = "relax"
    while label == "relax":  # delay may still be stepping toward its bound
        clock.t += 1.0
        hist.observe(0.01)  # keep the window populated as time advances
        label = policy.tick(clock.t)
    assert label == "at_bound"
    assert batcher.max_batch == 128  # never grows past the ladder cap


# ------------------------------------------------ service + typed admission


def _stub_service(**kw):
    clock = kw.pop("clock", ManualClock())
    session = StubSession()
    registry = Metrics()
    service = RetrievalService(
        {"stub": session}, max_batch=8, max_delay=0.01, min_bucket=8,
        clock=clock, registry=registry, **kw,
    )
    return service, session, registry, clock


def test_try_submit_without_admission_always_admits():
    service, _, registry, _ = _stub_service()
    out = service.try_submit(np.ones(3, np.int32))
    assert isinstance(out, Admitted) and out.rid == 0
    assert registry.counter("serve.admitted").value == 1


def test_try_submit_sheds_when_queue_full_and_submit_raises():
    service, _, registry, _ = _stub_service(
        admission=AdmissionController(queue_limit=2, on_full="shed")
    )
    assert service.try_submit(np.ones(3, np.int32)).admitted
    assert service.try_submit(np.ones(3, np.int32)).admitted
    out = service.try_submit(np.ones(3, np.int32))
    assert isinstance(out, Shed) and out.reason == QUEUE_FULL
    assert registry.counter("serve.shed").value == 1
    assert registry.counter(f"serve.shed.{QUEUE_FULL}").value == 1
    with pytest.raises(RejectedError) as ei:
        service.submit(np.ones(3, np.int32))
    assert isinstance(ei.value.outcome, Shed)
    assert registry.counter("serve.shed").value == 2


def test_qos_lanes_counted_separately():
    service, _, registry, _ = _stub_service(
        admission=AdmissionController(queue_limit=8, batch_watermark=0.25)
    )
    assert service.try_submit(np.ones(3, np.int32), lane="batch").admitted
    assert service.try_submit(np.ones(3, np.int32), lane="interactive").admitted
    out = service.try_submit(np.ones(3, np.int32), lane="batch")  # depth 2 >= 0.25*8
    assert isinstance(out, Shed) and out.reason == BATCH_YIELD
    assert registry.counter("serve.lane.batch.admitted").value == 1
    assert registry.counter("serve.lane.batch.shed").value == 1
    assert registry.counter("serve.lane.interactive.admitted").value == 1


def test_poll_limit_dispatches_one_block():
    service, session, _, clock = _stub_service()
    for i in range(20):  # 2 full blocks + remainder
        service.submit(np.full(3, i, np.int32))
    out = service.poll(limit=1)
    assert len(out) == 8 and session.block_sizes == [8]
    out = service.poll()
    assert len(out) == 8
    clock.t = 1.0
    assert len(service.poll()) == 4


def test_ready_at_reports_fired_and_future_triggers():
    service, _, _, clock = _stub_service()
    assert service.ready_at(0.0) is None
    service.submit(np.ones(3, np.int32))
    assert service.ready_at(0.0) == pytest.approx(0.01)  # future deadline
    for _ in range(7):
        service.submit(np.ones(3, np.int32))
    assert service.ready_at(0.0) == 0.0  # size trigger already fired


def test_service_with_policy_creates_windowed_histogram_and_ticks():
    clock = ManualClock()
    policy = AdaptiveBatchPolicy(slo_p99_s=0.05, interval_s=0.5, min_samples=4)
    service, session, registry, _ = _stub_service(
        clock=clock, policy=policy,
        admission=AdmissionController(queue_limit=64),
    )
    hist = registry.histogram("serve.recent.request_s")
    assert hist.window_s == policy.window_s
    # requests whose latency blows the SLO (deadline-dispatched long after
    # arrival on the manual clock) must drive a tighten within a few polls
    for step in range(6):
        clock.t = step * 1.0
        for i in range(4):
            service.submit(np.full(3, i, np.int32))
        clock.t = step * 1.0 + 0.9  # waited 0.9s >> slo 50ms
        service.poll()
    assert policy.adjustments >= 1
    # batch is already pinned at min_bucket, so tighten moves the deadline
    assert policy.effective["serve_max_batch"] == 8
    assert policy.effective["serve_max_delay_s"] < 0.01
    assert policy.oscillation_violations == 0
    assert registry.counter("serve.requests").value == 24


# ---------------------------------------------------------------- loadgen


def test_schedules_are_seeded_and_sorted():
    a = poisson_schedule(100.0, 50, seed=7)
    b = poisson_schedule(100.0, 50, seed=7)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) >= 0).all()
    c = burst_schedule(100.0, 50, seed=7, burst_factor=4.0, duty=0.25)
    np.testing.assert_array_equal(c, burst_schedule(100.0, 50, seed=7))
    assert (np.diff(c) >= 0).all()
    assert not np.array_equal(a, c)


def test_metered_session_advances_clock_and_delegates():
    clock = VirtualClock()
    metered = MeteredSession(StubSession(), clock)
    assert metered.kind == "stub" and metered.k == 4
    metered.search(np.zeros((4, 3), np.int32))
    assert clock.t > 0.0


def test_open_loop_accounts_for_every_offered_request():
    clock = VirtualClock()
    session = StubSession()
    registry = Metrics()
    service = RetrievalService(
        {"stub": session}, max_batch=8, max_delay=0.002, min_bucket=8,
        clock=clock, registry=registry,
        admission=AdmissionController(queue_limit=4, on_full="shed"),
    )
    queries = np.arange(60, dtype=np.int32).reshape(60, 1) * np.ones((1, 3), np.int32)
    schedule = poisson_schedule(5000.0, 60, seed=3)
    result = run_open_loop(service, clock, schedule, queries, kind="stub")
    assert result.n_completed + len(result.shed) == 60
    assert result.n_completed == len(result.rid_of)
    assert registry.counter("serve.admitted").value == result.n_completed
    assert registry.counter("serve.shed").value == len(result.shed)
    # exact latencies: every completion is at/after its arrival
    assert (result.latencies() >= 0).all()
    # per-row identity: completed results are a pure function of the query
    for i, rid in result.rid_of.items():
        want = session.search(queries[i : i + 1])
        np.testing.assert_array_equal(result.results[rid].scores, want.scores[0])
        np.testing.assert_array_equal(result.results[rid].ids, want.ids[0])


def test_open_loop_same_seed_same_virtual_arrivals():
    def offered(seed):
        clock = VirtualClock()
        service = RetrievalService(
            {"stub": StubSession()}, max_batch=8, max_delay=0.002, min_bucket=8,
            clock=clock, registry=Metrics(),
            admission=AdmissionController(queue_limit=4),
        )
        q = np.ones((30, 3), np.int32)
        res = run_open_loop(
            service, clock, poisson_schedule(3000.0, 30, seed=seed), q, kind="stub"
        )
        return res.arrivals, sorted(res.rid_of)
    a1, adm1 = offered(5)
    a2, adm2 = offered(5)
    np.testing.assert_array_equal(a1, a2)
    # admission decisions depend only on the schedule and the (real) scan
    # times; the schedule is identical — arrival stamps must be too
    a3, _ = offered(6)
    assert not np.array_equal(a1, a3)


# ------------------------------- byte identity under shed/QoS (real session)


def _small_lexical():
    corpus = synthetic.make_corpus(n_docs=256, vocab=512, max_len=24, seed=0)
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=512,
        chunk_size=64,
    )
    session = LexicalSession(
        corpus.tokens, corpus.lengths, "ql_lm", k=8, chunk_size=64, stats=stats
    )
    return corpus, session


def test_adaptive_service_byte_identical_to_static_oracle_under_load():
    """The acceptance contract: policy + admission + QoS shedding change
    which requests complete and when — never the bytes of any that do."""
    corpus, session = _small_lexical()
    queries = synthetic.make_queries(corpus, n_queries=48, seed=9)

    # oracle: unthrottled static service, one query per wave boundary-free
    oracle_service = RetrievalService(
        {"lexical": session}, max_batch=64, max_delay=60.0
    )
    for q in queries:
        oracle_service.submit(q, "lexical")
    oracle = oracle_service.drain()
    oracle_rows = {
        i: (oracle[i].scores.tobytes(), oracle[i].ids.tobytes())
        for i in range(len(queries))
    }

    clock = ManualClock()
    policy = AdaptiveBatchPolicy(slo_p99_s=0.01, interval_s=0.01, min_samples=2)
    admission = AdmissionController(queue_limit=6, batch_watermark=0.5, on_full="shed")
    service = RetrievalService(
        {"lexical": session}, max_batch=8, max_delay=0.005, min_bucket=8,
        clock=clock, registry=Metrics(), admission=admission, policy=policy,
    )
    completed = {}
    rid_to_qidx = {}
    n_shed = 0
    for i, q in enumerate(queries):
        # batch-lane arrivals land when the queue is deepest (3 admitted
        # since the last poll >= watermark 0.5 * limit 6) -> they yield
        lane = "batch" if i % 4 == 3 else "interactive"
        out = service.try_submit(q, "lexical", lane=lane, tenant=f"t{i % 2}")
        if out.admitted:
            rid_to_qidx[out.rid] = i
        else:
            n_shed += 1
        clock.t += 0.002
        if i % 4 == 3:  # poll sparsely so the queue actually builds depth
            completed.update(service.poll())
    clock.t += 1.0
    completed.update(service.poll())
    completed.update(service.drain())
    assert n_shed > 0  # the tiny queue + batch yield really did shed
    assert len(completed) == len(rid_to_qidx)
    for rid, res in completed.items():
        assert (res.scores.tobytes(), res.ids.tobytes()) == oracle_rows[rid_to_qidx[rid]]
    assert policy.oscillation_violations == 0


# --------------------------- sharded session behind admission (subprocess)

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.core import anchors
from repro.data import synthetic
from repro.obs.metrics import Metrics
from repro.serve import (
    AdmissionController, AdaptiveBatchPolicy, LexicalSession, RetrievalService,
    ShardedLexicalSession,
)

class ManualClock:
    def __init__(self): self.t = 0.0
    def __call__(self): return self.t

mesh = jax.make_mesh((4,), ("data",))
corpus = synthetic.make_corpus(n_docs=512, vocab=512, max_len=24, seed=0)
stats = anchors.collection_stats(
    jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=512, chunk_size=64
)
sharded = ShardedLexicalSession(
    mesh, corpus.tokens, corpus.lengths, "ql_lm", k=8, chunk_size=64, stats=stats
)
single = LexicalSession(
    corpus.tokens, corpus.lengths, "ql_lm", k=8, chunk_size=64, stats=stats
)
queries = synthetic.make_queries(corpus, n_queries=40, seed=4)

# unthrottled single-host oracle
oracle_service = RetrievalService({"lexical": single}, max_batch=64, max_delay=60.0)
for q in queries:
    oracle_service.submit(q, "lexical")
oracle = oracle_service.drain()

# sharded session behind admission + policy, QoS lanes, forced shedding
clock = ManualClock()
policy = AdaptiveBatchPolicy(slo_p99_s=0.01, interval_s=0.01, min_samples=2)
admission = AdmissionController(queue_limit=5, batch_watermark=0.4, on_full="shed")
service = RetrievalService(
    {"lexical": sharded}, max_batch=8, max_delay=0.005, min_bucket=8,
    clock=clock, registry=Metrics(), admission=admission, policy=policy,
)
completed, rid_to_qidx, n_shed = {}, {}, 0
for i, q in enumerate(queries):
    out = service.try_submit(
        q, "lexical", lane="batch" if i % 4 == 0 else "interactive"
    )
    if out.admitted:
        rid_to_qidx[out.rid] = i
    else:
        n_shed += 1
    clock.t += 0.002
    completed.update(service.poll())
clock.t += 1.0
completed.update(service.poll())
completed.update(service.drain())

identical = all(
    completed[rid].scores.tobytes() == oracle[rid_to_qidx[rid]].scores.tobytes()
    and completed[rid].ids.tobytes() == oracle[rid_to_qidx[rid]].ids.tobytes()
    for rid in completed
)
print(json.dumps({
    "n_shed": n_shed,
    "n_completed": len(completed),
    "identical": identical,
    "oscillation_violations": policy.oscillation_violations,
}))
"""


@pytest.mark.slow
def test_sharded_session_behind_admission_byte_identical(tmp_path):
    """Satellite: ShardedLexicalSession under admission control (QoS lanes,
    shedding, 4 mesh shards) returns byte-identical results to the
    unthrottled single-host oracle for every admitted request."""
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["identical"]
    assert out["n_shed"] > 0
    assert out["n_completed"] > 0
    assert out["oscillation_violations"] == 0
