"""Property-based tests for the lexicographic merge contract.

`topk.merge_lex`/`topk.reduce_lex` carry the whole byte-identity story:
whatever shard grouping, merge order, or fault-driven re-execution produced
the per-shard states, the reduced top-k must equal the single-host oracle's
— identical ids AND identical score *bytes*. The hand-picked cases in
`tests/test_cluster.py` pin a few corners; here hypothesis drives random
tied-score corpora through random shard partitions and random merge
parenthesizations. Ties are the hard part: scores are drawn from a small
palette of exactly-representable floats so every draw is full of them, and
the id tie-break is what keeps the result well-defined.

Runs under the `tests/_hyp.py` shim: skipped (not failed) when hypothesis
is not installed; CI installs requirements-dev.txt and runs the full suite.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topk

from _hyp import HAVE_HYPOTHESIS, given, settings, st

# a palette of exactly-representable float32s: every corpus drawn from it
# is riddled with score ties, forcing the id tie-break to do the ranking
SCORES = (-2.0, -0.5, 0.0, 0.25, 0.5, 1.0, 1.5, 2.0)


def lex_topk_oracle(pairs, k):
    """Global (score desc, id asc) top-k as plain python — the oracle."""
    ranked = sorted(pairs, key=lambda p: (-p[0], p[1]))[:k]
    scores = np.full(k, -np.inf, np.float32)
    ids = np.full(k, -1, np.int32)
    for i, (s, d) in enumerate(ranked):
        scores[i] = s
        ids[i] = d
    return topk.TopKState(scores=jnp.asarray(scores), ids=jnp.asarray(ids))


def shard_state(pairs, k):
    """One shard's fold result: its own lex-sorted top-k (possibly empty)."""
    return lex_topk_oracle(pairs, k)


def assert_bit_identical(got, want):
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    assert (
        np.asarray(got.scores).tobytes() == np.asarray(want.scores).tobytes()
    )


if HAVE_HYPOTHESIS:
    corpus_strategy = st.lists(
        st.sampled_from(SCORES), min_size=1, max_size=48
    )
else:  # placeholder: @given skips these tests before the body runs
    corpus_strategy = None


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_reduce_lex_invariant_to_sharding_and_merge_order(data):
    """Random tied-score corpus, random shard partition, random merge
    parenthesization: reduced ids and score bytes equal the global oracle."""
    scores = data.draw(corpus_strategy, label="scores")
    k = data.draw(st.integers(1, 8), label="k")
    n_shards = data.draw(st.integers(1, 6), label="n_shards")
    pairs = list(zip(scores, range(len(scores))))  # unique ids, many ties

    owner = data.draw(
        st.lists(
            st.integers(0, n_shards - 1),
            min_size=len(pairs),
            max_size=len(pairs),
        ),
        label="owner",
    )
    shards = [[p for p, o in zip(pairs, owner) if o == s] for s in range(n_shards)]
    states = [shard_state(sp, k) for sp in shards]  # empty shards stay in

    order = data.draw(st.permutations(range(n_shards)), label="order")
    states = [states[i] for i in order]
    # random parenthesization: repeatedly merge a random adjacent pair —
    # with the shuffle above this walks arbitrary merge trees
    while len(states) > 1:
        i = data.draw(st.integers(0, len(states) - 2), label="merge_at")
        merged = topk.merge_lex(states[i], states[i + 1])
        states = states[:i] + [merged] + states[i + 2 :]

    assert_bit_identical(states[0], lex_topk_oracle(pairs, k))


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_merge_lex_is_commutative(data):
    """merge(a, b) == merge(b, a) bit for bit, even through heavy ties.

    Note merge_lex is a *multiset* merge — it is deliberately not
    idempotent (merging a state with itself duplicates entries). The
    reliability layer keeps duplicate shard contributions out of the
    reduce via first-committed-wins, not via the merge.
    """
    scores = data.draw(corpus_strategy, label="scores")
    k = data.draw(st.integers(1, 8), label="k")
    pairs = list(zip(scores, range(len(scores))))
    cut = data.draw(st.integers(0, len(pairs)), label="cut")
    a = shard_state(pairs[:cut], k)
    b = shard_state(pairs[cut:], k)

    ab = topk.merge_lex(a, b)
    ba = topk.merge_lex(b, a)
    assert_bit_identical(ab, ba)
    assert_bit_identical(ab, lex_topk_oracle(pairs, k))


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_reduce_lex_matches_batched_oracle(data):
    """Batched states ([n_q, k]): every query row reduces independently to
    its own oracle — the shape `cluster.reduce_states` actually merges."""
    n_q = data.draw(st.integers(1, 4), label="n_q")
    k = data.draw(st.integers(1, 6), label="k")
    n_shards = data.draw(st.integers(1, 4), label="n_shards")
    per_query_pairs = []
    shard_states = []
    for s in range(n_shards):
        n = data.draw(st.integers(0, 16), label=f"shard{s}_n")
        rows_s, rows_i = [], []
        for q in range(n_q):
            if len(per_query_pairs) <= q:
                per_query_pairs.append([])
            # ids globally unique per query row via a shard-offset base
            pairs = [
                (data.draw(st.sampled_from(SCORES)), s * 1000 + j)
                for j in range(n)
            ]
            per_query_pairs[q].extend(pairs)
            row = lex_topk_oracle(pairs, k)
            rows_s.append(row.scores)
            rows_i.append(row.ids)
        shard_states.append(
            topk.TopKState(scores=jnp.stack(rows_s), ids=jnp.stack(rows_i))
        )
    got = topk.reduce_lex(shard_states)
    for q in range(n_q):
        want = lex_topk_oracle(per_query_pairs[q], k)
        row = topk.TopKState(scores=got.scores[q], ids=got.ids[q])
        assert_bit_identical(row, want)


def test_merge_lex_rejects_shape_mismatch():
    a = topk.init(4, ())
    b = topk.init(5, ())
    with pytest.raises(ValueError, match="merge_lex shape mismatch"):
        topk.merge_lex(a, b)


def test_reduce_lex_requires_at_least_one_state():
    with pytest.raises(ValueError, match="at least one"):
        topk.reduce_lex([])
