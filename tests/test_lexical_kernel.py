"""Fused lexical-scan kernel: interpret-mode parity vs the host scorers.

The contract under test (ISSUE 3 acceptance): for every lexical scorer ×
parameter variant, with PAD_TOKEN-padded queries/docs and zero-length corpus
rows, the kernel's rankings match the pure-JAX chunked fold **id-exactly**
under the shared tie-break (score desc, then smaller doc id — what
``lax.top_k``'s positional stability means on a scan whose candidate ids
increase monotonically) and score-wise to fp32 tolerance. Plus: a whole
model grid scanned in one kernel pass equals `scan.search_local_multi`.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anchors, scan, scoring, topk
from repro.data import synthetic

VOCAB = 300
CHUNK = 64
N_PAD_ROWS = 64  # zero-length corpus rows appended to the synthetic corpus
N_REAL = 256

VARIANTS = [
    scoring.get_scorer("ql_lm"),
    scoring.make_variant("ql_lm", lam=0.5, length_prior=False),
    scoring.get_scorer("bm25"),
    scoring.make_variant("bm25", k1=0.9, b=0.4),
    scoring.get_scorer("tfidf"),
]


@pytest.fixture(scope="module")
def collection():
    corpus = synthetic.make_corpus(n_docs=N_REAL, vocab=VOCAB, max_len=24, seed=3)
    toks = np.concatenate(
        [corpus.tokens, np.full((N_PAD_ROWS, 24), scoring.PAD_TOKEN, np.int32)]
    )
    lens = np.concatenate([corpus.lengths, np.zeros(N_PAD_ROWS, np.int32)])
    stats = anchors.collection_stats(
        jnp.asarray(toks), jnp.asarray(lens), vocab=VOCAB, chunk_size=CHUNK
    )
    queries = synthetic.make_queries(corpus, n_queries=12, seed=4)
    assert (queries == scoring.PAD_TOKEN).any()  # padded query rows in play
    return (jnp.asarray(toks), jnp.asarray(lens)), stats, jnp.asarray(queries)


def _assert_state_parity(host: topk.TopKState, kern: topk.TopKState):
    np.testing.assert_array_equal(np.asarray(kern.ids), np.asarray(host.ids))
    np.testing.assert_allclose(
        np.asarray(kern.scores), np.asarray(host.scores), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("scorer", VARIANTS, ids=lambda s: s.name)
def test_kernel_matches_host_fold(collection, scorer):
    docs, stats, queries = collection
    host = scan.search_local(queries, docs, scorer, k=16, chunk_size=CHUNK, stats=stats)
    kern = scan.search_local(
        queries, docs, scorer, k=16, chunk_size=CHUNK, stats=stats, use_kernel=True
    )
    _assert_state_parity(host, kern)


def test_kernel_padded_rows_never_surface(collection):
    docs, stats, queries = collection
    kern = scan.search_local(
        queries, docs, scoring.get_scorer("ql_lm"), k=16, chunk_size=CHUNK,
        stats=stats, use_kernel=True,
    )
    assert int(jnp.max(kern.ids)) < N_REAL  # no zero-length row in the top-k


def test_grid_in_one_kernel_pass_matches_multi(collection):
    """[n_models, n_q, k] grid state from one kernel pass == host multi-scan."""
    docs, stats, queries = collection
    host = scan.search_local_multi(
        queries, docs, VARIANTS, k=16, chunk_size=CHUNK, stats=stats
    )
    kern = scan.search_local_multi(
        queries, docs, VARIANTS, k=16, chunk_size=CHUNK, stats=stats, use_kernel=True
    )
    assert kern.scores.shape == (len(VARIANTS), queries.shape[0], 16)
    _assert_state_parity(host, kern)


def test_kernel_k_exceeds_corpus(collection):
    """k > n_docs: empty slots carry the host's (-inf, -1) sentinels."""
    docs, stats, queries = collection
    tiny = (docs[0][:CHUNK], docs[1][:CHUNK])
    host = scan.search_local(
        queries, tiny, scoring.get_scorer("bm25"), k=100, chunk_size=CHUNK, stats=stats
    )
    kern = scan.search_local(
        queries, tiny, scoring.get_scorer("bm25"), k=100, chunk_size=CHUNK,
        stats=stats, use_kernel=True,
    )
    _assert_state_parity(host, kern)
    assert not bool(topk.valid_mask(kern)[:, CHUNK:].any())


def test_kernel_resume_from_init_state(collection):
    """Segmented kernel passes (the scan-job path) == one unsegmented scan."""
    docs, stats, queries = collection
    grid = VARIANTS[:3]
    full = scan.search_local_multi(
        queries, docs, grid, k=16, chunk_size=CHUNK, stats=stats, use_kernel=True
    )
    half = 3 * CHUNK  # chunk-aligned segment boundary
    seg_a = scan.search_local_multi(
        queries, (docs[0][:half], docs[1][:half]), grid,
        k=16, chunk_size=CHUNK, stats=stats, use_kernel=True,
    )
    seg_b = scan.search_local_multi(
        queries, (docs[0][half:], docs[1][half:]), grid,
        k=16, chunk_size=CHUNK, stats=stats,
        doc_id_offset=half, init_state=seg_a, use_kernel=True,
    )
    _assert_state_parity(full, seg_b)


def test_kernel_respects_doc_id_offset(collection):
    docs, stats, queries = collection
    off = scan.search_local(
        queries, docs, scoring.get_scorer("ql_lm"), k=8, chunk_size=CHUNK,
        stats=stats, doc_id_offset=1000, use_kernel=True,
    )
    base = scan.search_local(
        queries, docs, scoring.get_scorer("ql_lm"), k=8, chunk_size=CHUNK,
        stats=stats, use_kernel=True,
    )
    valid = np.asarray(topk.valid_mask(base))
    np.testing.assert_array_equal(
        np.asarray(off.ids)[valid], np.asarray(base.ids)[valid] + 1000
    )
    assert (np.asarray(off.ids)[~valid] == -1).all()  # sentinels never shifted


def test_tiled_tf_matches_dense_reference(collection):
    """The memory-bounded fallback is bit-equal to the seed rank-4 reduction."""
    docs, _, queries = collection
    tiled = scoring.term_frequencies(queries, docs[0])
    dense = scoring.term_frequencies_dense(queries, docs[0])
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(dense))
    # odd tile width exercises the L_d padding path
    tiled7 = scoring.term_frequencies(queries, docs[0], tile_d=7)
    np.testing.assert_array_equal(np.asarray(tiled7), np.asarray(dense))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_non_multiple_chunk_raises(collection, use_kernel):
    docs, stats, queries = collection
    with pytest.raises(ValueError, match="not a multiple of chunk_size"):
        scan.search_local(
            queries, docs, scoring.get_scorer("ql_lm"), k=8, chunk_size=50,
            stats=stats, use_kernel=use_kernel,
        )
