"""End-to-end behaviour tests for the paper's system.

Covers the whole MIREX loop: corpus prep jobs -> scan search -> combiner
merge -> quality vs the indexed baseline; plus a short real training run
(loss decreases) and the multi-device distributed equivalences (subprocess
with 8 placeholder devices — the test process itself stays at 1 device).
"""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anchors, invindex, scan, scoring
from repro.data import synthetic
from repro.launch.train import train


def test_mirex_end_to_end_quality():
    """Full pipeline on a synthetic collection: P@5 of the scan equals the
    indexed baseline's (same model — the infrastructure claim, C4-style)."""
    corpus = synthetic.make_corpus(n_docs=400, vocab=800, max_len=32, seed=10)
    queries = synthetic.make_queries(corpus, n_queries=10, seed=11)
    qrels = synthetic.make_qrels(corpus, queries, per_query=15, seed=12)
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=800, chunk_size=100
    )
    state = scan.search_local(
        jnp.asarray(queries), (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths)),
        scoring.get_scorer("ql_lm"), k=10, chunk_size=100, stats=stats,
    )
    idx = invindex.build_index(corpus.tokens, corpus.lengths, vocab=800)
    _, ref_ids = invindex.search(idx, queries, invindex.stats_from_index(idx), k=10)

    def p_at_5(ids):
        return np.mean([qrels[qi, ids[qi, :5]].mean() for qi in range(len(queries))])

    p_scan, p_idx = p_at_5(np.asarray(state.ids)), p_at_5(ref_ids)
    assert p_scan == pytest.approx(p_idx, abs=0.05)
    assert p_scan >= 0.25  # retrieves the planted relevant docs


def test_lm_training_loss_decreases(tmp_path):
    out = train("gemma2-2b", steps=25, batch=2, seq=16, ckpt_dir=None, lr=1e-2)
    losses = [h["loss"] for h in out["history"]]
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] - 0.05


def test_recsys_training_loss_decreases():
    out = train("dcn-v2", steps=15, batch=32, lr=3e-3)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import scan, scoring, topk
from repro.data import synthetic
from repro.data.graph_prep import bucket_edges
from repro.distributed.sharding import rules_for_mesh
from repro.models import gnn, transformer as tfm
from repro.configs import reduced_config

mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = rules_for_mesh(mesh)
results = {}

# 1) mesh-sharded MIREX scan (repro.cluster, 8 real shards) == unsharded oracle
from repro import cluster
corpus = synthetic.make_dense_corpus(n_docs=512, dim=32, seed=1)
queries = synthetic.make_dense_corpus(n_docs=16, dim=32, seed=2)
fn = cluster.search_mesh(
    mesh, jnp.asarray(queries), jnp.asarray(corpus),
    scoring.get_scorer("dense_dot"), k=9, chunk_size=32,
)
with jax.set_mesh(mesh):
    state = fn(jnp.asarray(queries), jnp.asarray(corpus), None)
ref = scan.search_dense_host(jnp.asarray(queries), jnp.asarray(corpus), 9)
np.testing.assert_allclose(np.asarray(state.scores[0]), np.asarray(ref.scores), rtol=1e-5)
results["scan_ids_equal"] = bool((np.asarray(state.ids[0]) == np.asarray(ref.ids)).all())

# 1b) multi-model lexical grid on the mesh == single-host multi-scan, id-exact
from repro.core import anchors
lex = synthetic.make_corpus(n_docs=512, vocab=1024, max_len=32, seed=5)
lex_docs = (jnp.asarray(lex.tokens), jnp.asarray(lex.lengths))
lex_stats = anchors.collection_stats(*lex_docs, vocab=1024, chunk_size=64)
lex_q = jnp.asarray(synthetic.make_queries(lex, n_queries=8, seed=6))
grid = [scoring.make_variant("ql_lm"), scoring.make_variant("bm25")]
gfn = cluster.search_mesh(mesh, lex_q, lex_docs, grid, k=10, chunk_size=64, stats=lex_stats)
with jax.set_mesh(mesh):
    gstate = gfn(lex_q, lex_docs, lex_stats)
want = scan.search_local_multi(lex_q, lex_docs, grid, k=10, chunk_size=64, stats=lex_stats)
results["mesh_grid_ids_equal"] = bool((np.asarray(gstate.ids) == np.asarray(want.ids)).all())

# 2) LM train loss: 8-way sharded == single-device
batch = synthetic.make_lm_batch(batch=8, seq_len=16, vocab=512, seed=3)
batch = {k: jnp.asarray(v) for k, v in batch.items()}
cfg = reduced_config("qwen3-moe-30b-a3b")
params = tfm.init_params(cfg, jax.random.key(0))
losses = {}
for m in (mesh, jax.make_mesh((1, 1), ("data", "model"))):
    r = rules_for_mesh(m)
    ctx = tfm.make_context(cfg, m, r, tokens_per_shard=(8 // m.shape["data"]) * 16,
                           moe_mode="seq")
    with jax.set_mesh(m):
        loss_fn = tfm.make_loss_fn(ctx, chunk=16)
        loss, _ = loss_fn(params, batch)
    losses[str(m.shape)] = float(loss)
vals = list(losses.values())
results["lm_loss_shard_vs_single_delta"] = abs(vals[0] - vals[1])
assert abs(vals[0] - vals[1]) < 2e-3, losses

# 3) bucketed sharded GNN == local forward
g = synthetic.make_graph(n_nodes=64, n_edges=256, d_feat=9, seed=4)
gcfg = reduced_config("pna")
gp = gnn.init_params(gcfg, 9, jax.random.key(1))
bs, bd, bucket = bucket_edges(g["src"], g["dst"], n_nodes=64, n_shards=8, bucket_size=64)
fwd = gnn.make_sharded_full_graph(mesh, rules, gcfg)
with jax.set_mesh(mesh):
    logits = fwd(gp, jnp.asarray(g["x"]), jnp.asarray(bs), jnp.asarray(bd))
want = gnn.forward_full_graph(gp, jnp.asarray(g["x"]), jnp.asarray(g["src"]), jnp.asarray(g["dst"]), gcfg)
np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=3e-4, atol=3e-4)
results["gnn_sharded_ok"] = True
print(json.dumps(results))
"""


@pytest.mark.slow
def test_multidevice_equivalences_subprocess():
    """Distribution correctness on 8 placeholder devices (own process so
    this test session keeps its single real device)."""
    proc = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["scan_ids_equal"]
    assert out["mesh_grid_ids_equal"]
    assert out["gnn_sharded_ok"]
