"""The pipelined scan executor: shared compiled fold, segment prefetch,
async checkpoint commits, concurrent shards.

The contract under test is that every overlap the executor introduces is
*invisible in the artifacts*: pipelined jobs — including killed-and-resumed
ones, and concurrent-shard ones — produce states, checkpoints, progress
manifests, and TREC run files byte-identical to the synchronous sequential
executor's, while compiling the segment fold exactly once per
configuration.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro import cluster
from repro.core import anchors, pipeline, scoring, topk
from repro.data import synthetic
from repro.experiments import runner

VOCAB = 2048
N_DOCS = 512
CHUNK = 64
K = 10


@pytest.fixture(scope="module")
def collection():
    corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=32, seed=7)
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
        chunk_size=CHUNK,
    )
    queries = jnp.asarray(synthetic.make_queries(corpus, n_queries=8, seed=8))
    docs = (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths))
    return stats, queries, docs


def assert_states_identical(got, want, *, err=""):
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids), err_msg=err)
    assert np.asarray(got.scores).tobytes() == np.asarray(want.scores).tobytes(), err


# -- shared fold cache --------------------------------------------------------


def test_four_shard_job_compiles_fold_exactly_once(collection):
    """The per-shard retrace fix: equal-shaped shards (the plan invariant)
    plus the config-keyed fold cache mean a 4-shard job — 8 segment folds —
    traces the fold one single time."""
    stats, queries, docs = collection
    scorers = [scoring.make_variant("ql_lm", lam=0.777)]  # key unique to this test
    fold = cluster.segment_fold(scorers, k=K, chunk_size=CHUNK, use_kernel=False)
    assert cluster.FOLD_TRACE_COUNTS[fold.key] == 0
    job = cluster.run_sharded_scan_job(
        queries, docs, scorers, k=K, chunk_size=CHUNK, segment_chunks=1,
        n_shards=4, stats=stats,
    )
    assert job.segments_run == 8  # 4 shards x 2 segments each actually folded
    assert cluster.FOLD_TRACE_COUNTS[fold.key] == 1
    # segments are chunk-aligned, so a 2-shard job folds the *same* segment
    # shape — zero new traces for a different shard count
    cluster.run_sharded_scan_job(
        queries, docs, scorers, k=K, chunk_size=CHUNK, segment_chunks=1,
        n_shards=2, stats=stats,
    )
    assert cluster.FOLD_TRACE_COUNTS[fold.key] == 1
    # a different segmentation is a different segment shape: exactly one more
    cluster.run_sharded_scan_job(
        queries, docs, scorers, k=K, chunk_size=CHUNK, segment_chunks=2,
        n_shards=4, stats=stats,
    )
    assert cluster.FOLD_TRACE_COUNTS[fold.key] == 2


def test_fold_cache_keys_on_configuration(collection):
    a = cluster.segment_fold(
        [scoring.make_variant("bm25")], k=K, chunk_size=CHUNK
    )
    b = cluster.segment_fold(
        [scoring.make_variant("bm25")], k=K, chunk_size=CHUNK
    )
    assert a is b  # equal config -> the same shared program
    c = cluster.segment_fold(
        [scoring.make_variant("bm25", k1=0.9)], k=K, chunk_size=CHUNK
    )
    assert c is not a  # a different grid point is a different program


# -- segment prefetch ---------------------------------------------------------


def test_prefetch_segments_yields_exact_slices(collection):
    _, _, docs = collection
    segs = pipeline.segments(N_DOCS, CHUNK, 2)
    got = list(pipeline.prefetch_segments(docs, segs, device=jax.devices()[0]))
    assert len(got) == len(segs)
    for (a, b), seg in zip(segs, got):
        for leaf, want in zip(jax.tree.leaves(seg), jax.tree.leaves(docs)):
            np.testing.assert_array_equal(np.asarray(leaf), np.asarray(want[a:b]))


def test_prefetch_segments_early_close_stops_worker(collection):
    _, _, docs = collection
    segs = pipeline.segments(N_DOCS, CHUNK, 1)  # 8 segments, depth 2
    stream = pipeline.prefetch_segments(docs, segs, depth=2)
    first = next(stream)
    assert jax.tree.leaves(first)[0].shape[0] == CHUNK
    stream.close()  # must not hang on the staged-but-unconsumed segments


def test_prefetch_segments_rejects_bad_depth(collection):
    _, _, docs = collection
    with pytest.raises(ValueError, match="depth"):
        next(pipeline.prefetch_segments(docs, [(0, CHUNK)], depth=0))


# -- pipelined == sequential, byte for byte -----------------------------------


@pytest.mark.parametrize("n_shards", [1, 4])
def test_pipelined_matches_sequential_executor(collection, tmp_path, n_shards):
    stats, queries, docs = collection
    scorers = [scoring.make_variant("ql_lm"), scoring.make_variant("bm25")]
    kw = dict(k=K, chunk_size=CHUNK, segment_chunks=2, stats=stats,
              n_shards=n_shards)
    seq = cluster.run_sharded_scan_job(
        queries, docs, scorers, ckpt_dir=str(tmp_path / "seq"),
        pipelined=False, **kw
    )
    pipe = cluster.run_sharded_scan_job(
        queries, docs, scorers, ckpt_dir=str(tmp_path / "pipe"),
        pipelined=True, **kw
    )
    assert_states_identical(pipe.state, seq.state, err=f"{n_shards} shards")
    pa = runner.write_run_files(str(tmp_path / "ra"), scorers, seq.state, tag_prefix="t")
    pb = runner.write_run_files(str(tmp_path / "rb"), scorers, pipe.state, tag_prefix="t")
    for name in pa:
        assert open(pa[name], "rb").read() == open(pb[name], "rb").read(), name
    # the async writer left the same checkpoint layout the sync path leaves
    sub = "" if n_shards == 1 else "shard_0000"
    assert (
        ckpt.all_steps(str(tmp_path / "pipe" / sub))
        == ckpt.all_steps(str(tmp_path / "seq" / sub))
    )


def test_pipelined_kill_resume_byte_identical(collection, tmp_path):
    """Injected lost-ack kill on the pipelined path: the async writer's
    drain-before-kill makes the commit visible, and the resumed pipelined
    job matches the uninterrupted sequential executor byte for byte."""
    stats, queries, docs = collection
    scorers = [scoring.make_variant("ql_lm"), scoring.make_variant("bm25")]
    kw = dict(k=K, chunk_size=CHUNK, segment_chunks=2, stats=stats, n_shards=4)
    seq = cluster.run_sharded_scan_job(
        queries, docs, scorers, pipelined=False, **kw
    )
    with pytest.raises(RuntimeError, match="injected failure"):
        cluster.run_sharded_scan_job(
            queries, docs, scorers, ckpt_dir=str(tmp_path / "p"),
            fail_at_segment=0, fail_at_shard=2, pipelined=True, **kw
        )
    # the kill struck *after* the async commit drained: segment 1 is durable
    prog = cluster.read_progress(str(tmp_path / "p" / "shard_0002"))
    assert prog["shards"]["2"]["segments_done"] == 1
    resumed = cluster.run_sharded_scan_job(
        queries, docs, scorers, ckpt_dir=str(tmp_path / "p"), pipelined=True, **kw
    )
    assert resumed.shard_results[2].resumed_from == 1
    assert_states_identical(resumed.state, seq.state)


def test_concurrent_shard_executor_matches_sequential(collection, tmp_path):
    """max_workers > 1 forces the thread-pool path even on one device; the
    plan-ordered reduce keeps the merged bytes identical however shards
    interleave, and a shard failure propagates deterministically."""
    stats, queries, docs = collection
    scorers = [scoring.make_variant("ql_lm"), scoring.make_variant("bm25")]
    kw = dict(k=K, chunk_size=CHUNK, segment_chunks=2, stats=stats, n_shards=4)
    seq = cluster.run_sharded_scan_job(queries, docs, scorers, pipelined=False, **kw)
    conc = cluster.run_sharded_scan_job(
        queries, docs, scorers, pipelined=True, max_workers=4, **kw
    )
    assert_states_identical(conc.state, seq.state)

    with pytest.raises(RuntimeError, match="injected failure"):
        cluster.run_sharded_scan_job(
            queries, docs, scorers, ckpt_dir=str(tmp_path / "c"),
            fail_at_segment=0, fail_at_shard=1, pipelined=True, max_workers=4, **kw
        )
    # concurrent peers were already in flight and ran to completion; the
    # resumed job restores them as no-ops and re-runs only the killed shard
    resumed = cluster.run_sharded_scan_job(
        queries, docs, scorers, ckpt_dir=str(tmp_path / "c"),
        pipelined=True, max_workers=4, **kw
    )
    assert resumed.shard_results[1].resumed_from == 1
    assert_states_identical(resumed.state, seq.state)


def test_pipelined_kernel_path_matches_host(collection):
    stats, queries, docs = collection
    scorers = [scoring.make_variant("ql_lm"), scoring.make_variant("bm25")]
    kw = dict(k=K, chunk_size=CHUNK, segment_chunks=2, stats=stats, n_shards=2)
    host = cluster.run_sharded_scan_job(queries, docs, scorers, pipelined=False, **kw)
    kern = cluster.run_sharded_scan_job(
        queries, docs, scorers, pipelined=True, max_workers=2, use_kernel=True, **kw
    )
    np.testing.assert_array_equal(np.asarray(kern.state.ids), np.asarray(host.state.ids))


# -- async checkpointing under the job ---------------------------------------


def test_async_writer_error_fails_the_job(collection, tmp_path, monkeypatch):
    """A checkpoint that cannot commit must fail the job at the next drain
    barrier — never report a scan complete whose progress is not durable."""
    stats, queries, docs = collection
    scorers = [scoring.make_variant("ql_lm")]
    real_save = ckpt.save

    def failing_save(ckpt_dir, step, tree):
        if step == 2:
            raise OSError("disk full (injected)")
        return real_save(ckpt_dir, step, tree)

    monkeypatch.setattr(ckpt, "save", failing_save)
    with pytest.raises(OSError, match="disk full"):
        cluster.run_scan_job(
            queries, docs, scorers, k=K, chunk_size=CHUNK, segment_chunks=2,
            stats=stats, ckpt_dir=str(tmp_path / "w"), pipelined=True,
        )
    # fail-stop: nothing after the failed step 2 was committed, and step 1
    # is intact — the job resumes from there
    assert ckpt.all_steps(str(tmp_path / "w")) == [1]
    prog = cluster.read_progress(str(tmp_path / "w"))
    assert prog["shards"]["0"]["segments_done"] == 1


# -- serve: shared mesh-program cache ----------------------------------------


def test_sharded_sessions_share_mesh_program(collection, mesh11):
    from repro.serve.session import ShardedLexicalSession

    stats, queries, docs = collection
    tokens, lengths = np.asarray(docs[0]), np.asarray(docs[1])
    a = ShardedLexicalSession(
        mesh11, tokens, lengths, "ql_lm", k=K, chunk_size=CHUNK, stats=stats
    )
    b = ShardedLexicalSession(
        mesh11, tokens, lengths, "ql_lm", k=K, chunk_size=CHUNK, stats=stats
    )
    assert a._fn is b._fn  # second session reuses the cached mesh program
    q = np.asarray(queries)
    assert_states_identical(b.search(q), a.search(q))


# -- experiment lifecycle flag ------------------------------------------------


def test_experiment_pipelined_flag_round_trips(tmp_path):
    import dataclasses

    from repro.experiments import grid as exp_grid

    spec = dataclasses.replace(
        exp_grid.get_experiment("smoke"), segment_chunks=1, n_queries=8
    )
    coll = runner.prepare_collection(spec)
    r_seq = runner.run_experiment(
        spec, out_dir=str(tmp_path / "seq"), collection=coll, pipelined=False
    )
    r_pipe = runner.run_experiment(
        spec, out_dir=str(tmp_path / "pipe"), collection=coll, pipelined=True
    )
    assert r_seq["job"]["pipelined"] is False
    assert r_pipe["job"]["pipelined"] is True
    for name in r_seq["runs"]:
        assert (
            open(r_seq["runs"][name], "rb").read()
            == open(r_pipe["runs"][name], "rb").read()
        ), name
    assert r_seq["metrics"] == r_pipe["metrics"]
