"""Packed corpus segments: exact round-trip + byte-identity to the unpacked
oracle across shards × kernel × kill/resume (the pack contract: packing
changes bytes moved, never bytes written)."""

from __future__ import annotations

import filecmp
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import tune
from repro.core import anchors, packing, scan, scoring
from repro.core.scoring import PAD_TOKEN
from repro.experiments import grid as exp_grid
from repro.experiments import runner

from tests._hyp import given, settings, st


def _corpus(rng, n, l, vocab, *, pad_heavy=False):
    """PAD-padded token matrix + lengths, with optional PAD-heavy rows."""
    toks = rng.integers(0, vocab, size=(n, l)).astype(np.int32)
    hi = max(1, l // 4) if pad_heavy else l + 1
    lens = rng.integers(0, hi, size=(n,)).astype(np.int32)
    for i in range(n):
        toks[i, lens[i]:] = PAD_TOKEN
    return toks, lens


# ---------------------------------------------------------------- round-trip


@pytest.mark.parametrize("vocab", [1, 2, 255, 256, 4096, 65535, 65536, 2**20])
@pytest.mark.parametrize("mode", ["auto", "8", "16", "bitpack"])
def test_roundtrip_exact(vocab, mode):
    rng = np.random.default_rng(vocab)
    toks, lens = _corpus(rng, 16, 13, vocab, pad_heavy=True)
    toks[0, :] = PAD_TOKEN  # zero-length doc
    spec = packing.make_spec(vocab, 13, mode)
    if spec is None:
        pytest.skip(f"vocab {vocab} resolves to none under {mode}")
    packed = packing.pack_tokens(toks, spec)
    out = np.asarray(packing.unpack_tokens(packed, spec))
    np.testing.assert_array_equal(out, toks)


def test_roundtrip_pad_to_appends_pad_tokens():
    spec = packing.make_spec(300, 10, "bitpack")
    toks = np.arange(20, dtype=np.int32).reshape(2, 10) % 300
    out = np.asarray(packing.unpack_tokens(packing.pack_tokens(toks, spec), spec, pad_to=16))
    np.testing.assert_array_equal(out[:, :10], toks)
    assert (out[:, 10:] == PAD_TOKEN).all()


def test_width_selection():
    # the ARCHITECTURE.md width table, as code
    assert packing.resolve_mode(255, "auto") == "u8"
    assert packing.resolve_mode(256, "auto") == "u16"
    assert packing.resolve_mode(65535, "auto") == "u16"
    assert packing.resolve_mode(65536, "auto") == "bitpack"
    assert packing.resolve_mode(2**31 - 1, "auto") == "bitpack"
    assert packing.resolve_mode(2**31, "auto") == "none"
    # forced widths degrade (never fail) when the sentinel doesn't fit
    assert packing.resolve_mode(4096, "8") == "u16"
    assert packing.resolve_mode(2**20, "16") == "bitpack"
    assert packing.resolve_mode(1, "bitpack") == "bitpack"
    assert packing.resolve_mode(7, "none") == "none"
    with pytest.raises(ValueError):
        packing.resolve_mode(100, "u32")


def test_pack_rejects_out_of_range_tokens():
    spec = packing.make_spec(100, 4, "auto")
    bad = np.array([[0, 1, 100, 2]], np.int32)  # 100 == sentinel value
    with pytest.raises(ValueError):
        packing.pack_tokens(bad, spec)
    worse = np.array([[0, -5, 1, 2]], np.int32)
    with pytest.raises(ValueError):
        packing.pack_tokens(worse, spec)


def test_packed_corpus_is_a_pytree():
    rng = np.random.default_rng(0)
    toks, lens = _corpus(rng, 8, 6, 300)
    pc = packing.pack_corpus(toks, lens, vocab=300, mode="auto")
    assert isinstance(pc, packing.PackedCorpus)
    leaves, treedef = jax.tree_util.tree_flatten(pc)
    assert len(leaves) == 2  # tokens, lengths — spec rides in the treedef
    pc2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert pc2.spec == pc.spec
    # leading-dim slicing through tree.map (the shard/segment plumbing)
    half = jax.tree.map(lambda x: x[:4], pc)
    assert half.n_docs == 4
    out, out_lens = half.unpack()
    np.testing.assert_array_equal(np.asarray(out), toks[:4])
    # pack_corpus returns the plain tuple when the mode resolves to none
    plain = packing.pack_corpus(toks, lens, vocab=300, mode="none")
    assert isinstance(plain, tuple)


@given(
    vocab=st.integers(min_value=1, max_value=2**21),
    n=st.integers(min_value=1, max_value=12),
    l=st.integers(min_value=1, max_value=40),
    mode=st.sampled_from(["auto", "8", "16", "bitpack"]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=80, deadline=None)
def test_roundtrip_property(vocab, n, l, mode, seed):
    rng = np.random.default_rng(seed)
    toks, lens = _corpus(rng, n, l, vocab, pad_heavy=bool(seed % 2))
    spec = packing.make_spec(vocab, l, mode)
    if spec is None:
        return
    out = np.asarray(packing.unpack_tokens(packing.pack_tokens(toks, spec), spec))
    np.testing.assert_array_equal(out, toks)


# ------------------------------------------------------------- scan parity


@pytest.fixture(scope="module")
def small_collection():
    rng = np.random.default_rng(7)
    vocab, n, l = 8192, 256, 24
    toks, lens = _corpus(rng, n, l, vocab, pad_heavy=True)
    q = rng.integers(0, vocab, size=(4, 6)).astype(np.int32)
    stats = anchors.collection_stats(jnp.asarray(toks), jnp.asarray(lens), vocab)
    return vocab, toks, lens, q, stats


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("mode", ["auto", "16", "bitpack"])
def test_scan_parity_packed_vs_unpacked(small_collection, mode, use_kernel):
    vocab, toks, lens, q, stats = small_collection
    scorers = (scoring.get_scorer("bm25"), scoring.get_scorer("tfidf"))
    ref = scan.search_local_multi(
        jnp.asarray(q), (jnp.asarray(toks), jnp.asarray(lens)), scorers,
        k=10, chunk_size=64, stats=stats, use_kernel=use_kernel,
    )
    pc = jax.tree.map(jnp.asarray, packing.pack_corpus(toks, lens, vocab=vocab, mode=mode))
    got = scan.search_local_multi(
        jnp.asarray(q), pc, scorers,
        k=10, chunk_size=64, stats=stats, use_kernel=use_kernel,
    )
    assert np.asarray(got.scores).tobytes() == np.asarray(ref.scores).tobytes()
    assert np.asarray(got.ids).tobytes() == np.asarray(ref.ids).tobytes()


# ------------------------------------------- job-level byte-identity matrix


def _run(spec, out, coll, tmp_path, **kw):
    return runner.run_experiment(
        spec, out_dir=str(tmp_path / out), collection=coll, trace_out=None, **kw
    )


def _assert_runs_identical(tmp_path, a, b):
    runs = os.listdir(tmp_path / a / "runs")
    assert runs
    for f in runs:
        assert filecmp.cmp(
            str(tmp_path / a / "runs" / f), str(tmp_path / b / "runs" / f),
            shallow=False,
        ), f"{f} differs between {a} and {b}"


@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_job_byte_identity_across_shards(tmp_path, n_shards, use_kernel):
    spec = exp_grid.ExperimentSpec(
        name="pk", grids=(exp_grid.parse_grid("bm25:k1=0.9|1.2"),),
        n_docs=256, n_queries=8, chunk_size=32, segment_chunks=2,
        n_shards=n_shards, use_kernel=use_kernel,
    )
    coll = runner.prepare_collection(spec, seed=0)
    _run(spec, "oracle", coll, tmp_path)
    _run(spec, "packed", coll, tmp_path, tuning=tune.TuningConfig(token_pack="auto"))
    _assert_runs_identical(tmp_path, "oracle", "packed")


def test_job_byte_identity_kill_resume(tmp_path):
    from repro.cluster import build_schedule

    spec = exp_grid.ExperimentSpec(
        name="pkr", grids=(exp_grid.parse_grid("bm25:k1=0.9|1.2"),),
        n_docs=256, n_queries=8, chunk_size=32, segment_chunks=1, n_shards=2,
    )
    coll = runner.prepare_collection(spec, seed=0)
    _run(spec, "oracle", coll, tmp_path)
    # packed run with an injected mid-job crash, resumed from checkpoints
    faults = build_schedule(["crash:shard=1,segment=0,phase=pre_commit"])
    rep = _run(
        spec, "packed", coll, tmp_path,
        tuning=tune.TuningConfig(token_pack="bitpack"),
        faults=faults, max_retries=3,
    )
    assert rep["job"]["faults_fired"]
    assert rep["job"]["tuning"]["pack_resolved"] == "bitpack"
    _assert_runs_identical(tmp_path, "oracle", "packed")


# --------------------------------------------------------------- tune knob


def test_token_pack_knob_validation():
    assert tune.TuningConfig().token_pack == "none"
    assert tune.TuningConfig(token_pack="bitpack").token_pack == "bitpack"
    with pytest.raises(ValueError):
        tune.TuningConfig(token_pack="u64")
    # knob space version bumped for the new knob (stale-cache guard)
    from repro.tune.config import SPACE_VERSION

    assert SPACE_VERSION >= 3
