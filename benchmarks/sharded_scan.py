"""Cluster layer: shard-scaling curve (shards x models-per-pass).

The paper's scaling claim is that shard-parallel sequential scans + a
k-bounded merge run large experiments with little machinery. This benchmark
records the `repro.cluster` shard-scaling surface — 1 -> 4 shards spread
over 4 virtual devices, crossed with models-per-pass — and validates the
claim that matters at any scale: the merged top-k is **bit-identical at
every shard count** (ids and score bytes), so sharding is pure execution
geometry. Runs in a subprocess because the 4-virtual-device XLA flag must be
set before JAX initializes (the benchmark harness process keeps its single
real device, same discipline as tests/test_system.py). Writes
``BENCH_sharded.json``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.serve.bench import write_bench_json

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, time
import jax, jax.numpy as jnp
import numpy as np
from repro import cluster
from repro.core import anchors, scoring
from repro.data import synthetic

N_DOCS, VOCAB, CHUNK, K, N_Q = 4096, 4096, 256, 20, 32
SHARDS = (1, 2, 4)
MODELS = (1, 4)

corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=64, seed=21)
stats = anchors.collection_stats(
    jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
    chunk_size=CHUNK,
)
queries = jnp.asarray(synthetic.make_queries(corpus, n_queries=N_Q, seed=22))
docs = (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths))
grid = [
    scoring.make_variant("ql_lm", lam=lam) for lam in (0.05, 0.15, 0.3, 0.5)
]

devices = jax.devices()
curve, baselines = [], {}
for n_models in MODELS:
    scorers = grid[:n_models]
    for n_shards in SHARDS:
        plan = cluster.plan_shards(N_DOCS, n_shards=n_shards, chunk_size=CHUNK)
        devs = devices[:n_shards] if n_shards > 1 else None

        def run():
            return jax.block_until_ready(
                cluster.scan_shards(
                    plan, queries, docs, scorers, k=K, stats=stats, devices=devs
                )
            )

        state = run()  # warmup + correctness sample
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            run()
            times.append(time.perf_counter() - t0)
        wall = float(np.median(times))
        key = n_models
        if n_shards == 1:
            baselines[key] = (np.asarray(state.ids), np.asarray(state.scores))
        else:
            ids1, sc1 = baselines[key]
            assert (np.asarray(state.ids) == ids1).all(), (n_shards, n_models)
            assert np.asarray(state.scores).tobytes() == sc1.tobytes(), (n_shards, n_models)
        curve.append({
            "shards": n_shards,
            "models": n_models,
            "wall_s": wall,
            "s_per_model": wall / n_models,
            "docs_per_s": N_DOCS / wall,
        })
print(json.dumps({
    "n_docs": N_DOCS, "n_queries": N_Q, "k": K, "chunk_size": CHUNK,
    "n_devices": len(devices), "curve": curve, "bit_identical_across_shards": True,
}))
"""


def run(csv_rows: list):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.pop("XLA_FLAGS", None)  # the worker pins its own device count
    proc = subprocess.run(
        [sys.executable, "-c", _WORKER],
        capture_output=True, text=True, timeout=1800, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    payload = json.loads(proc.stdout.strip().splitlines()[-1])
    # the scaling claim this repo actually promises: sharding never changes
    # a bit of the merged ranking (speed is hardware's business; virtual CPU
    # devices share one backend so wall-clock parallelism is not asserted)
    assert payload["bit_identical_across_shards"]
    assert payload["n_devices"] == 4, payload["n_devices"]

    write_bench_json(payload, "BENCH_sharded.json")
    for pt in payload["curve"]:
        csv_rows.append(
            (
                f"sharded_scan/shards{pt['shards']}_models{pt['models']}",
                pt["wall_s"] * 1e6,
                f"docs_per_s={pt['docs_per_s']:.0f}",
            )
        )
    return True
