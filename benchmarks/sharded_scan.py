"""Cluster layer: shard-scaling curve + shards x models-per-pass surface.

The paper's scaling claim is that shard-parallel sequential scans + a
k-bounded merge run large experiments with little machinery. This benchmark
records the `repro.cluster` shard-scaling curve — 1 -> 4 shards spread over
4 virtual devices through the **pipelined executor** (shared compiled fold,
double-buffered segment prefetch, concurrent shards) — and validates the
claim that matters at any scale: the merged top-k is **bit-identical at
every shard count** (ids and score bytes), so sharding is pure execution
geometry. Each curve point carries ``scaling_x`` = docs_per_s[n] /
docs_per_s[1 shard], so an anti-scaling regression (the pre-pipeline
executor re-traced the fold per shard and ran shards serially, *losing* 4x
at 4 shards) is visible at a glance. The shards × models-per-pass cross
rides along as ``grid_curve`` (the model-axis amortization itself is
`benchmarks/experiments_amortization`'s claim); on a host whose virtual
devices share few physical cores its wall-clock is advisory — bit-identity
is still asserted at every point. Grid points are re-timed under the same
equal-treatment protocol as the primary curve (a prior recording's
shards=4 dip was an artifact of timing them asymmetrically; see the
worker's comment).

A previous recording showed *anti*-scaling at 4 shards × 1 model (254k
docs/s vs 397k at 2 shards) on a 2-core host: the executor staged segments
onto all 4 shard home devices while only 2 workers drove them, so half the
host→device transfers were paid for shards that then re-sliced on a
different device anyway. `run_sharded_scan_job` now trims its device
round-robin to the worker pool (and the cross-shard stager follows), so a
thin host stages only what it can drive; this bench needs no workaround —
it passes the per-point device list and lets the job trim.

Runs in a subprocess because the 4-virtual-device XLA flag must be set
before JAX initializes (the benchmark harness process keeps its single real
device, same discipline as tests/test_system.py). Writes
``BENCH_sharded.json``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.serve.bench import write_bench_json

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, time
import jax, jax.numpy as jnp
import numpy as np
from repro import cluster
from repro.core import anchors, scoring
from repro.data import synthetic

N_DOCS, VOCAB, CHUNK, K, N_Q = 49152, 4096, 128, 20, 32
SEGMENT_CHUNKS = 32  # 4096-row segments: same segment shape at every shard count
SHARDS = (1, 2, 4)
MODELS = (1, 4)
REPS = 10

corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=64, seed=21)
stats = anchors.collection_stats(
    jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
    chunk_size=CHUNK,
)
queries = jnp.asarray(synthetic.make_queries(corpus, n_queries=N_Q, seed=22))
# the corpus streams from *host* memory, as in the paper's cluster: shard
# slices are numpy views (free) and each segment pays one host->device
# transfer, which the pipelined executor hides under the previous segment's
# fold — keeping the corpus device-resident is the serve layer's job
docs = (
    np.asarray(corpus.tokens, dtype=np.int32),
    np.asarray(corpus.lengths, dtype=np.int32),
)
grid = [
    scoring.make_variant("ql_lm", lam=lam) for lam in (0.05, 0.15, 0.3, 0.5)
]

devices = jax.devices()
# virtual CPU devices share the host's cores: oversubscribing the pool past
# the physical cores adds contention, not parallelism, so the bench caps
# workers there (a real 4-chip host keeps the one-worker-per-device default)
workers_cap = os.cpu_count() or 1


def time_point(scorers, n_shards, reps=REPS):
    devs = devices[:n_shards]

    def run():
        job = cluster.run_sharded_scan_job(
            queries, docs, scorers,
            k=K, chunk_size=CHUNK, segment_chunks=SEGMENT_CHUNKS,
            n_shards=n_shards, stats=stats, ckpt_dir=None,
            devices=devs, pipelined=True,
            max_workers=min(n_shards, workers_cap),
        )
        return jax.block_until_ready(job.state)

    state = run()  # warmup (the fold compiles once, shared by every point)
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        run()
        walls.append(time.perf_counter() - t0)
    return state, min(walls)


def check_identical(state, baseline, label):
    ids1, sc1 = baseline
    assert (np.asarray(state.ids) == ids1).all(), label
    assert np.asarray(state.scores).tobytes() == sc1.tobytes(), label


# -- primary curve: single-model shard scaling (the paper's docs/s claim) ----
curve = []
for n_shards in SHARDS:
    state, wall = time_point(grid[:1], n_shards)
    if n_shards == 1:
        baseline = (np.asarray(state.ids), np.asarray(state.scores))
    else:
        check_identical(state, baseline, f"curve shards={n_shards}")
    curve.append({"shards": n_shards, "wall_s": wall, "docs_per_s": N_DOCS / wall})

# tighten noisy rounds: while the curve is non-monotonic (a loaded host's
# noise, not a property of the executor), re-time EVERY curve point with
# the same rep count and keep each point's min over all observations — the
# equal-treatment peak-throughput estimator (no point gets more samples
# than any other, so the recorded ordering is not an artifact of selective
# re-measurement)
for _ in range(6):
    walls = [p["wall_s"] for p in curve]
    if all(b <= a for a, b in zip(walls, walls[1:])):
        break
    for p in curve:
        _, wall = time_point(grid[:1], p["shards"])
        if wall < p["wall_s"]:
            p["wall_s"] = wall
            p["docs_per_s"] = N_DOCS / wall
for p in curve:
    p["scaling_x"] = curve[0]["wall_s"] / p["wall_s"]

# -- grid cross: shards x models-per-pass (bit-identity everywhere) ----------
grid_curve, grid_baselines = [], {}
for n_models in MODELS:
    scorers = grid[:n_models]
    for n_shards in SHARDS:
        state, wall = time_point(scorers, n_shards, reps=4)
        if n_shards == 1:
            grid_baselines[n_models] = (np.asarray(state.ids), np.asarray(state.scores))
        else:
            check_identical(
                state, grid_baselines[n_models], f"grid m={n_models} sh={n_shards}"
            )
        grid_curve.append({
            "shards": n_shards,
            "models": n_models,
            "wall_s": wall,
            "s_per_model": wall / n_models,
            "docs_per_s": N_DOCS / wall,
        })

# grid points get the same equal-treatment re-timing as the primary curve,
# per model count. A previous recording showed an anti-scaling dip at
# shards=4 x models=1 (675k docs/s vs 765k at 2 shards) that the primary
# curve contradicted in the same process (768k at the identical config):
# the dip was sampling noise from the asymmetric protocol — grid points got
# reps=4 with no re-timing rounds while curve points were re-timed until
# monotone. With the protocol equalized, a dip that survives in the
# recording indicts the executor, not the sampler.
for n_models in MODELS:
    pts = [p for p in grid_curve if p["models"] == n_models]
    for _ in range(6):
        walls = [p["wall_s"] for p in pts]
        if all(b <= a for a, b in zip(walls, walls[1:])):
            break
        for p in pts:
            _, wall = time_point(grid[:n_models], p["shards"], reps=4)
            if wall < p["wall_s"]:
                p["wall_s"] = wall
                p["s_per_model"] = wall / n_models
                p["docs_per_s"] = N_DOCS / wall

print(json.dumps({
    "n_docs": N_DOCS, "n_queries": N_Q, "k": K, "chunk_size": CHUNK,
    "segment_chunks": SEGMENT_CHUNKS, "n_devices": len(devices),
    "executor": "pipelined", "max_workers": workers_cap,
    "curve": curve, "scaling_x": curve[-1]["scaling_x"],
    "grid_curve": grid_curve,
    "bit_identical_across_shards": True,
}))
"""


def run(csv_rows: list):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.pop("XLA_FLAGS", None)  # the worker pins its own device count
    proc = subprocess.run(
        [sys.executable, "-c", _WORKER],
        capture_output=True, text=True, timeout=1800, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    payload = json.loads(proc.stdout.strip().splitlines()[-1])
    # the scaling claim this repo actually promises: sharding never changes
    # a bit of the merged ranking, and the pipelined executor stops paying
    # the old per-shard retrace tax (wall-clock beyond that is the
    # hardware's business; on a thin shared host the curve is advisory)
    assert payload["bit_identical_across_shards"]
    assert payload["n_devices"] == 4, payload["n_devices"]

    write_bench_json(payload, "BENCH_sharded.json")
    for pt in payload["curve"]:
        csv_rows.append(
            (
                f"sharded_scan/shards{pt['shards']}",
                pt["wall_s"] * 1e6,
                f"docs_per_s={pt['docs_per_s']:.0f};scaling_x={pt['scaling_x']:.2f}",
            )
        )
    for pt in payload["grid_curve"]:
        csv_rows.append(
            (
                f"sharded_scan/grid_shards{pt['shards']}_models{pt['models']}",
                pt["wall_s"] * 1e6,
                f"docs_per_s={pt['docs_per_s']:.0f}",
            )
        )
    return True
