"""Packed-corpus scan benchmark: docs/s and bytes-moved, packed vs unpacked.

The pack contract in numbers: the same 8k-doc lexical scan is run with the
corpus stored unpacked (int32), ``u16`` (auto width for the 8192-token
vocab) and ``bitpack`` (14 bit-planes), on both the host fold and the
interpret-mode Pallas kernel. Byte-identity of every packed result against
the unpacked oracle is asserted before any number is recorded — a fast
wrong scan is worthless. ``bytes_moved`` is what the corpus stream actually
weighs (token matrix + lengths): the quantity every transfer hop — staging
``device_put``s, HBM→VMEM tiles — pays per pass, and the knob this
benchmark exists to measure (on this CPU host the decode *costs* compute,
so docs/s is reported honestly and the win is the 2x+ byte reduction; on a
bandwidth-bound accelerator the byte ratio is the speedup ceiling).

Writes ``BENCH_packed.json``; registered in ``benchmarks/run.py``.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import anchors, packing, scan, scoring

K = 20
CHUNK = 512
N_QUERIES = 32
MODES = ("none", "auto", "bitpack")


def _build(n_docs: int, seed: int = 0):
    from repro.data import synthetic

    corpus = synthetic.make_corpus(
        n_docs=n_docs, vocab=common.VOCAB, max_len=common.MAX_LEN, seed=seed
    )
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths),
        vocab=common.VOCAB, chunk_size=CHUNK,
    )
    queries = synthetic.make_queries(corpus, n_queries=N_QUERIES, seed=seed + 1)
    scorers = (scoring.get_scorer("bm25"), scoring.get_scorer("tfidf"))
    return corpus, stats, jnp.asarray(queries), scorers


def _docs_for(corpus, mode: str):
    if mode == "none":
        return (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths))
    packed = packing.pack_corpus(
        np.asarray(corpus.tokens), np.asarray(corpus.lengths),
        vocab=common.VOCAB, mode=mode,
    )
    return jax.tree.map(jnp.asarray, packed)


def measure(n_docs: int, *, reps: int = 3) -> dict:
    corpus, stats, queries, scorers = _build(n_docs)
    points = []
    oracle: dict[str, bytes] = {}
    for use_kernel in (False, True):
        path = "kernel" if use_kernel else "host"

        def run_scan(q, d, _uk=use_kernel):
            return scan.search_local_multi(
                q, d, scorers, k=K, chunk_size=CHUNK, stats=stats, use_kernel=_uk
            )

        jitted = jax.jit(run_scan)
        base_docs_per_s = None
        for mode in MODES:
            docs = _docs_for(corpus, mode)
            resolved = docs.spec.mode if isinstance(docs, packing.PackedCorpus) else "none"
            state = jax.block_until_ready(jitted(queries, docs))
            blob = np.asarray(state.scores).tobytes() + np.asarray(state.ids).tobytes()
            if mode == "none":
                oracle[path] = blob
            else:
                # identity first: a packed scan that changed one byte would
                # make every number below meaningless
                assert blob == oracle[path], f"{path}/{mode} diverged from oracle"
            wall = common.timeit(
                lambda: jax.block_until_ready(jitted(queries, docs)),
                repeats=reps, warmup=0,  # first call above already compiled
            )
            token_bytes = jax.tree.leaves(docs)[0].nbytes
            total_bytes = packing.tree_nbytes(docs)
            docs_per_s = n_docs / wall
            if mode == "none":
                base_docs_per_s = docs_per_s
                base_token_bytes = token_bytes
                base_total_bytes = total_bytes
            points.append({
                "path": path,
                "mode": mode,
                "resolved": resolved,
                "wall_s": wall,
                "docs_per_s": docs_per_s,
                "token_bytes": token_bytes,
                "total_bytes": total_bytes,
                "speedup_vs_unpacked": docs_per_s / base_docs_per_s,
                "bytes_ratio_tokens": base_token_bytes / token_bytes,
                "bytes_ratio_total": base_total_bytes / total_bytes,
            })
    best_bytes = max(p["bytes_ratio_tokens"] for p in points)
    best_speed = max(
        p["speedup_vs_unpacked"] for p in points if p["mode"] != "none"
    )
    return {
        "n_docs": n_docs,
        "vocab": common.VOCAB,
        "max_len": common.MAX_LEN,
        "n_queries": N_QUERIES,
        "k": K,
        "chunk_size": CHUNK,
        "byte_identity": True,  # asserted above for every packed point
        "points": points,
        "best_bytes_ratio": best_bytes,
        "best_speedup": best_speed,
    }


def check(payload: dict) -> None:
    """Regression guard: packing must earn its keep — either the scan gets
    >=1.3x faster or the corpus stream shrinks >=2x (it is the latter on
    this CPU host: u16 halves token bytes, bitpack cuts them 2.29x)."""
    assert payload["byte_identity"]
    assert (
        payload["best_speedup"] >= 1.3 or payload["best_bytes_ratio"] >= 2.0
    ), (
        f"packing regressed: best speedup {payload['best_speedup']:.2f}x, "
        f"best bytes ratio {payload['best_bytes_ratio']:.2f}x"
    )


def run(rows: list, *, n_docs: int | None = None, reps: int = 3,
        json_path: str = "BENCH_packed.json") -> dict:
    payload = measure(n_docs or common.N_DOCS, reps=reps)
    common.write_bench_json(payload, json_path)
    for p in payload["points"]:
        rows.append((
            f"packed_scan/{p['path']}/{p['mode']}",
            p["wall_s"] * 1e6,
            f"{p['docs_per_s']:.0f}docs/s;{p['bytes_ratio_tokens']:.2f}x_bytes",
        ))
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized corpus (1024 docs, 1 rep)")
    ap.add_argument("--json", default="BENCH_packed.json")
    args = ap.parse_args()
    rows: list = []
    payload = run(
        rows,
        n_docs=1024 if args.smoke else None,
        reps=1 if args.smoke else 3,
        json_path=args.json,
    )
    check(payload)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(json.dumps(
        {k: payload[k] for k in ("best_speedup", "best_bytes_ratio")}, indent=2
    ))


if __name__ == "__main__":
    main()
