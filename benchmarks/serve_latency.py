"""Serve-mode benchmark: batch-size vs latency/throughput -> BENCH_serve.json.

The service-layer view of claim C1: the microbatcher's block size is the
amortization lever, so the curve of per-query latency against batch size is
the serving-relevant restatement of paper Figure 2. Runs both session
kinds — the lexical raw-token scan and the dense Pallas-kernel path — and
writes the lexical curve (the paper's setting) to ``BENCH_serve.json``.

On this CPU host the scan has no shared I/O fixed cost, so the absolute
curve is reported, not asserted (same caveat as fig2_scaling); the asserts
here check service invariants: every submitted query is answered exactly
once and padding never leaks into results. One *shape* property is
guarded, though (:func:`check`, called by the harness): amortization must
stay monotone through the largest batch point. The bucket-ladder cap
(``serve_max_bucket``) exists precisely to keep the big-batch tail from
falling off the per-query sweet spot — a reappearing cliff at the largest
point means the cap regressed.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import make_collection
from repro.data import synthetic
from repro.serve import DenseSession, LexicalSession
from repro.serve.bench import sweep_batch_sizes, write_bench_json

BATCH_SIZES = (16, 64, 256)
K = 32
CHUNK = 512
DENSE_DIM = 128
DENSE_DOCS = 16_384


def run(csv_rows: list):
    # --- lexical serve curve (the paper's setting) ------------------------
    corpus, stats, _ = make_collection()
    session = LexicalSession(
        corpus.tokens, corpus.lengths, "ql_lm", k=K, chunk_size=CHUNK, stats=stats
    )
    payload = sweep_batch_sizes(
        session,
        lambda n, seed: synthetic.make_queries(corpus, n_queries=n, seed=200 + seed),
        BATCH_SIZES,
        repeats=3,
    )
    for pt in payload["curve"]:
        csv_rows.append(
            (f"serve_lexical_b{pt['batch']}", pt["us_per_query"], f"qps={pt['qps']:.1f}")
        )
    csv_rows.append(
        ("serve_lexical_amortization_x", payload.get("amortization_x", 1.0),
         "C1 serve-mode (report; CPU host has no shared I/O cost)")
    )

    # --- dense serve curve (Pallas kernel dispatch) -----------------------
    vecs = synthetic.make_dense_corpus(n_docs=DENSE_DOCS, dim=DENSE_DIM, seed=7)
    dsession = DenseSession(vecs, "dense_dot", k=K, chunk_size=2048, use_kernel=True)
    rng = np.random.default_rng(11)
    dense_payload = sweep_batch_sizes(
        dsession,
        lambda n, seed: rng.standard_normal((n, DENSE_DIM)).astype(np.float32),
        BATCH_SIZES,
        repeats=2,
    )
    for pt in dense_payload["curve"]:
        csv_rows.append(
            (f"serve_dense_b{pt['batch']}", pt["us_per_query"], f"qps={pt['qps']:.1f}")
        )

    payload["dense"] = dense_payload
    path = write_bench_json(payload)
    csv_rows.append(("serve_bench_json", float(len(payload["curve"])), path))
    return payload


def check(payload: dict) -> None:
    """Regression guard (harness hook): the amortization curve must stay
    monotone through the largest batch point — ``amortization_x`` at the
    biggest batch may not fall below the mid-curve peak (small tolerance
    for run-to-run noise). An uncapped bucket ladder fails this on this
    host: the @256 point pads past the per-query sweet spot and its
    amortization drops ~10% below the @64 peak."""
    curve = payload["curve"]
    if len(curve) < 3:
        return
    peak = max(pt["amortization_x"] for pt in curve[1:-1])
    tail = curve[-1]["amortization_x"]
    assert tail >= peak * 0.95, (
        f"serve amortization cliff at batch {curve[-1]['batch']}: "
        f"{tail:.3f}x < 0.95 * mid-curve peak {peak:.3f}x "
        "(bucket-ladder cap regressed?)"
    )
