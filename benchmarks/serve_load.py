"""Open-loop sustained-load serve benchmark -> BENCH_serve_load.json.

The C1 sweep (serve_latency) is closed-loop: it waits for each wave, so it
can never see the serving *knee*. This benchmark offers load the server
did not agree to — seeded Poisson (or burst) arrivals replayed through the
discrete-event generator in `repro.serve.loadgen`, with the virtual clock
advanced by the real, metered scan time of every dispatched block — and
sweeps offered QPS across the knee (factors of the calibrated capacity).

At every point it runs the service twice over the *same* schedule and
query set:

* **static** — the default trigger knobs, no admission, no policy: the
  pre-PR serving configuration, where an overloaded queue grows without
  bound and tail latency follows it;
* **adaptive** — the SLO closed loop (`AdaptiveBatchPolicy`) plus
  admission control (bounded queue, shed): latency is held near the SLO
  by bounding the backlog and re-picking the triggers online.

Asserted invariants (per run): every completed request's scores AND ids
are byte-identical to a single-scan oracle of the whole query set (the
policy/admission change speed and admission, never bytes); shed accounting
is exact (completed + shed == offered, and matches the obs counters); the
policy's oscillation guard reports zero violations. The full run
additionally asserts the headline: at some offered QPS the static config
violates the p99 SLO while adaptive meets it with occupancy no worse.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import make_collection, write_bench_json
from repro.data import synthetic
from repro.obs.metrics import Metrics
from repro.serve import (
    AdaptiveBatchPolicy,
    AdmissionController,
    LexicalSession,
    MeteredSession,
    RetrievalService,
    VirtualClock,
    burst_schedule,
    poisson_schedule,
    run_open_loop,
)
from repro.serve.microbatch import bucket_size, pad_rows
from repro.tune import config as tune_config

K = 32
CHUNK = 512
N_REQUESTS = 2000
QPS_FACTORS = (0.25, 0.75, 1.5)  # below / near / past the capacity knee
SEED = 0


def _warm_ladder(session, queries: np.ndarray, min_bucket: int, cap: int) -> None:
    """Compile every bucket shape the batcher can produce before anything
    is timed: the load runs meter *real* scan seconds into the virtual
    clock, and a first-dispatch jit trace would otherwise appear as a
    massive in-band stall (and the adaptive run would hit fresh shapes
    mid-flight whenever the policy re-picks the block size)."""
    size = min_bucket
    while size <= cap:
        block = pad_rows(queries[: min(size, len(queries))], size, session.pad_value)
        np.asarray(session.search(block).scores)
        size *= 2


def _oracle_rows(session, queries: np.ndarray) -> list[tuple[bytes, bytes]]:
    """Per-query (scores, ids) bytes from ONE scan of the whole set in a
    single padded block — the grouping-free oracle. Per-row independence
    of the scan makes this the reference for *any* microbatch grouping."""
    n = len(queries)
    padded = pad_rows(
        queries, bucket_size(n, min_bucket=1, max_bucket=None), session.pad_value
    )
    state = session.search(padded)
    scores = np.asarray(state.scores)[:n]
    ids = np.asarray(state.ids)[:n]
    return [(scores[i].tobytes(), ids[i].tobytes()) for i in range(n)]


def _calibrate(session, queries: np.ndarray, cap: int) -> float:
    """Median wall seconds of one full cap-sized block scan (the unit the
    capacity estimate and the SLO are derived from)."""
    block = queries[:cap]
    times = []
    for _ in range(1 + 3):  # 1 warmup
        t0 = time.perf_counter()
        np.asarray(session.search(block).scores)
        times.append(time.perf_counter() - t0)
    return float(np.median(times[1:]))


def _run_point(
    session,
    queries: np.ndarray,
    schedule: np.ndarray,
    *,
    adaptive: bool,
    slo_s: float,
    queue_limit: int,
    interval_s: float,
):
    clock = VirtualClock()
    metered = MeteredSession(session, clock)
    registry = Metrics()
    policy = admission = None
    if adaptive:
        policy = AdaptiveBatchPolicy(
            slo_p99_s=slo_s, interval_s=interval_s, window_s=8 * interval_s
        )
        admission = AdmissionController(queue_limit=queue_limit, on_full="shed")
    service = RetrievalService(
        {session.kind: metered},
        clock=clock,
        registry=registry,
        admission=admission,
        policy=policy,
    )
    result = run_open_loop(service, clock, schedule, queries, kind=session.kind)
    return result, service, registry, policy


def _summarize(result, service, registry, policy) -> dict:
    blocks = service.metrics
    n_padded = sum(r.n_padded for r in blocks)
    summary = {
        "n_offered": result.n_offered,
        "n_completed": result.n_completed,
        "n_shed": len(result.shed),
        "shed_rate": result.shed_rate,
        "n_blocks": len(blocks),
        "occupancy": (sum(r.n_real for r in blocks) / n_padded) if n_padded else 0.0,
        "duration_s": result.duration_s,
        **result.latency_quantiles(),
    }
    if policy is not None:
        summary["policy"] = {
            k: policy.describe()[k]
            for k in ("adjustments", "flips", "damped", "oscillation_violations")
        }
        summary["effective"] = policy.effective
    return summary


def _check_run(result, registry, oracle, policy=None) -> None:
    """The per-run invariants: byte identity, exact shed accounting against
    the obs counters, and a quiet oscillation guard."""
    for i, rid in result.rid_of.items():
        res = result.results[rid]
        assert (res.scores.tobytes(), res.ids.tobytes()) == oracle[i], (
            f"request {i} (rid {rid}) differs from the single-scan oracle"
        )
    assert result.n_completed + len(result.shed) == result.n_offered
    assert registry.counter("serve.admitted").value == result.n_completed
    assert registry.counter("serve.shed").value == len(result.shed)
    assert registry.counter("serve.requests").value == result.n_completed
    shed_by_reason = {}
    for _, outcome in result.shed:
        shed_by_reason[outcome.reason] = shed_by_reason.get(outcome.reason, 0) + 1
    for reason, count in shed_by_reason.items():
        assert registry.counter(f"serve.shed.{reason}").value == count, reason
    if policy is not None:
        assert policy.oscillation_violations == 0, "oscillation guard broke"


def sweep(
    *,
    n_requests: int = N_REQUESTS,
    qps_factors=QPS_FACTORS,
    qps_list=None,
    slo_p99_ms: float | None = None,
    seed: int = SEED,
    schedule_kind: str = "poisson",
) -> dict:
    corpus, stats, _ = make_collection()
    session = LexicalSession(
        corpus.tokens, corpus.lengths, "ql_lm", k=K, chunk_size=CHUNK, stats=stats
    )
    cfg = tune_config.resolve(None)
    cap = cfg.serve_max_bucket or cfg.serve_max_batch
    queries = synthetic.make_queries(corpus, n_queries=n_requests, seed=300 + seed)
    oracle = _oracle_rows(session, queries)
    _warm_ladder(session, queries, cfg.serve_min_bucket, cap)

    t_cap = _calibrate(session, queries, cap)
    capacity_qps = cap / t_cap
    slo_s = (slo_p99_ms / 1e3) if slo_p99_ms is not None else 3.0 * t_cap
    # bound the admitted backlog to one cap-block's worth of work: worst
    # queue wait ~= t_cap (SLO/3), leaving the rest of the SLO for the
    # request's own block and scheduling jitter
    queue_limit = cap
    # the policy reacts on the dispatch timescale of this host
    interval_s = max(t_cap / 2.0, 1e-3)

    if qps_list:
        points_qps = [(q, q / capacity_qps) for q in qps_list]
    else:
        points_qps = [(f * capacity_qps, f) for f in qps_factors]

    make_schedule = poisson_schedule if schedule_kind == "poisson" else burst_schedule
    points = []
    for qps, factor in points_qps:
        schedule = make_schedule(qps, n_requests, seed=seed)
        point = {"offered_qps": qps, "capacity_factor": factor}
        for mode in ("static", "adaptive"):
            result, service, registry, policy = _run_point(
                session,
                queries,
                schedule,
                adaptive=(mode == "adaptive"),
                slo_s=slo_s,
                queue_limit=queue_limit,
                interval_s=interval_s,
            )
            _check_run(result, registry, oracle, policy)
            point[mode] = _summarize(result, service, registry, policy)
        point["static_meets_slo"] = point["static"]["p99_ms"] <= slo_s * 1e3
        point["adaptive_meets_slo"] = point["adaptive"]["p99_ms"] <= slo_s * 1e3
        points.append(point)

    return {
        "benchmark": "serve_load",
        "kind": session.kind,
        "n_docs": session.n_docs,
        "k": K,
        "chunk_size": CHUNK,
        "schedule": schedule_kind,
        "seed": seed,
        "n_requests": n_requests,
        "calibration": {
            "cap_block": cap,
            "t_cap_block_ms": t_cap * 1e3,
            "capacity_qps": capacity_qps,
        },
        "slo_p99_ms": slo_s * 1e3,
        "queue_limit": queue_limit,
        "policy_interval_ms": interval_s * 1e3,
        "points": points,
    }


def _slo_win(payload: dict) -> dict | None:
    """The headline point: static violates the p99 SLO, adaptive meets it,
    occupancy no worse (small tolerance)."""
    for point in payload["points"]:
        if (
            not point["static_meets_slo"]
            and point["adaptive_meets_slo"]
            and point["adaptive"]["occupancy"] >= point["static"]["occupancy"] - 0.05
        ):
            return point
    return None


def run(csv_rows: list):
    payload = sweep()
    for point in payload["points"]:
        f = point["capacity_factor"]
        for mode in ("static", "adaptive"):
            s = point[mode]
            csv_rows.append(
                (
                    f"serve_load_{f:.2f}x_{mode}_p99_us",
                    s["p99_ms"] * 1e3,  # CSV column is us_per_call
                    f"qps={point['offered_qps']:.0f} shed={s['shed_rate']:.2f} "
                    f"occ={s['occupancy']:.2f}",
                )
            )
    win = _slo_win(payload)
    assert win is not None, (
        "no offered-QPS point where adaptive meets the p99 SLO, static "
        f"violates it, and occupancy is no worse: {json.dumps(payload['points'])}"
    )
    payload["slo_win"] = {
        "capacity_factor": win["capacity_factor"],
        "offered_qps": win["offered_qps"],
        "static_p99_ms": win["static"]["p99_ms"],
        "adaptive_p99_ms": win["adaptive"]["p99_ms"],
        "slo_p99_ms": payload["slo_p99_ms"],
    }
    csv_rows.append(
        (
            "serve_load_slo_win_factor",
            win["capacity_factor"],
            f"static_p99={win['static']['p99_ms']:.1f}ms "
            f"adaptive_p99={win['adaptive']['p99_ms']:.1f}ms "
            f"slo={payload['slo_p99_ms']:.1f}ms",
        )
    )
    path = write_bench_json(payload, "BENCH_serve_load.json")
    csv_rows.append(("serve_load_bench_json", float(len(payload["points"])), path))
    return payload


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-requests", type=int, default=N_REQUESTS)
    ap.add_argument(
        "--qps-list", type=float, nargs="*", default=None,
        help="absolute offered QPS points (default: factors of calibrated capacity)",
    )
    ap.add_argument("--slo-p99-ms", type=float, default=None)
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--schedule", choices=("poisson", "burst"), default="poisson")
    ap.add_argument("--json", default="BENCH_serve_load.json")
    ap.add_argument(
        "--smoke", action="store_true",
        help="short CI run: invariants only (byte identity, shed accounting, "
        "zero oscillation violations), no SLO-win assertion",
    )
    args = ap.parse_args(argv)
    if args.smoke:
        payload = sweep(
            n_requests=min(args.n_requests, 400),
            qps_factors=(0.5, 1.5),
            slo_p99_ms=args.slo_p99_ms,
            seed=args.seed,
            schedule_kind=args.schedule,
        )
    else:
        payload = sweep(
            n_requests=args.n_requests,
            qps_list=args.qps_list,
            slo_p99_ms=args.slo_p99_ms,
            seed=args.seed,
            schedule_kind=args.schedule,
        )
        win = _slo_win(payload)
        if win is not None:
            payload["slo_win"] = {
                "capacity_factor": win["capacity_factor"],
                "offered_qps": win["offered_qps"],
                "static_p99_ms": win["static"]["p99_ms"],
                "adaptive_p99_ms": win["adaptive"]["p99_ms"],
                "slo_p99_ms": payload["slo_p99_ms"],
            }
    path = write_bench_json(payload, args.json)
    for point in payload["points"]:
        print(
            f"{point['capacity_factor']:.2f}x capacity "
            f"({point['offered_qps']:.0f} qps): "
            f"static p99 {point['static']['p99_ms']:.1f}ms "
            f"(shed {point['static']['shed_rate']:.0%}) | "
            f"adaptive p99 {point['adaptive']['p99_ms']:.1f}ms "
            f"(shed {point['adaptive']['shed_rate']:.0%}, "
            f"occ {point['adaptive']['occupancy']:.2f})"
        )
    print(f"slo {payload['slo_p99_ms']:.1f}ms -> {path}")


if __name__ == "__main__":
    main()
