"""Lexical-scan hot path: tiled/fused tf vs the seed rank-4 path -> BENCH_lexical.json.

The paper's setting is the raw-token scan, and its whole argument is that
the scan is bandwidth-bound on the document stream. The seed
``term_frequencies`` materialized the ``[n_q, L_q, n_d, L_d]`` equality
cross-product per chunk, so HBM traffic scaled with query length × doc
length; this benchmark records the fix:

* ``seed``   — rank-4 `scoring.term_frequencies_dense` fold (the baseline);
* ``tiled``  — `scan.search_local`'s default path, tf tiled over ``L_d``;
* ``kernel`` — the fused Pallas lexical kernel (`kernels/lexical_scan.py`),
  timed under the active backend (interpret=Python on this CPU host, so its
  wall-clock is reported but only asserted on a compiled backend);
* models-per-pass — the multi-model grid *inside one kernel pass*: the tf
  reduction is shared in VMEM, so per-model cost falls with grid size
  (claim C1 on the model axis, PR 2's amortization moved into the kernel).

Asserts: the tiled path is >= 2x the seed path at n_docs=8192, n_q=64
(acceptance criterion; ~10x measured on this host), and kernel rankings are
id-identical to the host fold.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_collection, timeit, write_bench_json
from repro.core import pipeline, scan, scoring, topk
from repro.data import synthetic
from repro.kernels import ops

N_Q = 64
L_Q = 8
K = 32
CHUNK = 512
GRID_SIZES = (1, 2, 4, 8)
KERNEL_DOCS = 2048  # interpret mode pays Python per grid step; keep it honest


def _seed_scan_fn(queries, docs, scorer, stats, *, k, chunk_size):
    """The pre-tentpole hot path: rank-4 tf materialized per chunk."""

    @jax.jit
    def run(q):
        def fold(state, chunk, start):
            d_tok, d_len = chunk
            tf = scoring.term_frequencies_dense(q, d_tok)
            s = scorer.fn(q, d_tok, d_len, stats, tf=tf)
            ids = start + jnp.arange(s.shape[-1], dtype=jnp.int32)
            return topk.update(state, s, jnp.broadcast_to(ids, s.shape))

        return pipeline.fold_chunks(docs, chunk_size, fold, topk.init(k, (q.shape[0],)))

    return lambda: jax.block_until_ready(run(queries))


def run(csv_rows: list):
    corpus, stats, _ = make_collection()
    stats = jax.tree.map(jnp.asarray, stats)
    docs = (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths))
    queries = jnp.asarray(
        synthetic.make_queries(corpus, n_queries=N_Q, max_q_len=L_Q, seed=11)
    )
    scorer = scoring.get_scorer("ql_lm")
    n_docs = docs[0].shape[0]

    seed_s = timeit(
        _seed_scan_fn(queries, docs, scorer, stats, k=K, chunk_size=CHUNK), repeats=3
    )
    tiled = jax.jit(
        lambda q: scan.search_local(q, docs, scorer, k=K, chunk_size=CHUNK, stats=stats)
    )
    tiled_s = timeit(lambda: jax.block_until_ready(tiled(queries)), repeats=3)
    speedup = seed_s / tiled_s

    # kernel path: ranking parity vs the host fold, then wall-clock under the
    # active backend (interpret on CPU — honest but not a hardware number)
    kdocs = jax.tree.map(lambda x: x[:KERNEL_DOCS], docs)
    kern = jax.jit(
        lambda q: scan.search_local(
            q, kdocs, scorer, k=K, chunk_size=CHUNK, stats=stats, use_kernel=True
        )
    )
    host_ref = jax.block_until_ready(
        scan.search_local(queries, kdocs, scorer, k=K, chunk_size=CHUNK, stats=stats)
    )
    kern_state = jax.block_until_ready(kern(queries))
    assert np.array_equal(np.asarray(kern_state.ids), np.asarray(host_ref.ids)), (
        "fused lexical kernel diverged from the host fold"
    )
    kernel_s = timeit(lambda: jax.block_until_ready(kern(queries)), repeats=1)

    # models-per-pass: one kernel pass scans the whole grid, tf shared on-chip
    grid_curve = []
    for m in GRID_SIZES:
        scorers = [
            scoring.make_variant("ql_lm", lam=round(0.1 + 0.1 * i, 2)) for i in range(m)
        ]
        multi = jax.jit(
            lambda q, sc=tuple(scorers): scan.search_local_multi(
                q, kdocs, sc, k=K, chunk_size=CHUNK, stats=stats, use_kernel=True
            )
        )
        total_s = timeit(lambda: jax.block_until_ready(multi(queries)), repeats=1)
        grid_curve.append(
            {
                "n_models": m,
                "total_ms": total_s * 1e3,
                "ms_per_model": total_s / m * 1e3,
                "amortization_x": grid_curve[0]["total_ms"] / 1e3 * m / total_s
                if grid_curve
                else 1.0,
            }
        )

    payload = {
        "benchmark": "lexical_scan",
        "scorer": scorer.name,
        "n_docs": n_docs,
        "n_q": N_Q,
        "max_q_len": L_Q,
        "k": K,
        "chunk_size": CHUNK,
        "kernel_backend": ops.kernel_backend(),
        "kernel_n_docs": KERNEL_DOCS,
        "seed_ms": seed_s * 1e3,
        "tiled_ms": tiled_s * 1e3,
        "kernel_ms": kernel_s * 1e3,
        "speedup_tiled_vs_seed": speedup,
        "models_per_pass": grid_curve,
    }
    write_bench_json(payload, "BENCH_lexical.json")

    csv_rows.append(("lexical_seed_tf_scan", seed_s * 1e6, f"n_docs={n_docs}"))
    csv_rows.append(
        ("lexical_tiled_tf_scan", tiled_s * 1e6, f"speedup={speedup:.2f}x vs seed")
    )
    csv_rows.append(
        (
            "lexical_kernel_scan",
            kernel_s * 1e6,
            f"backend={payload['kernel_backend']} n_docs={KERNEL_DOCS}",
        )
    )
    csv_rows.append(
        (
            "lexical_grid_in_kernel_x",
            grid_curve[-1]["amortization_x"],
            f"{GRID_SIZES[-1]} models/pass",
        )
    )
    # acceptance: the memory-bounded tf path must beat the seed by >= 2x
    assert speedup >= 2.0, f"tiled tf path only {speedup:.2f}x over seed"
    return payload
