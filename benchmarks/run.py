# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: fig2 scaling (C1/C2), table1 LOC (C3), P@k quality
(C4), corpus-prep throughput, dense-scan throughput, serve-mode latency,
experiment-engine models-per-pass amortization.
Each module validates its paper claim with asserts and contributes CSV
rows. Modules are imported and run independently: a failure (including an
import error) in one benchmark is reported and the rest still run."""

from __future__ import annotations

import importlib
import sys
import traceback

MODULES = (
    "table1_loc",
    "quality_pk",
    "anchors_throughput",
    "retrieval_scan",
    "fig2_scaling",
    "lexical_scan",
    "serve_latency",
    "experiments_amortization",
    "sharded_scan",
    "packed_scan",
    "pipeline_scan",
    "autotune",
    "serve_load",
)


def main() -> None:
    rows: list[tuple] = []
    failures = []
    for name in MODULES:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            result = mod.run(rows)
            # optional per-module regression guard over the payload it
            # just measured (curve-shape asserts live with the benchmark)
            if hasattr(mod, "check"):
                mod.check(result)
            print(f"# [ok] {name}", file=sys.stderr)
        except Exception:  # noqa: BLE001 — isolate per-benchmark failures
            failures.append(name)
            traceback.print_exc()
    print("name,us_per_call,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.3f},{derived}")
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
