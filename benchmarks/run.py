# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: fig2 scaling (C1/C2), table1 LOC (C3), P@k quality
(C4), corpus-prep throughput, dense-scan throughput. Each module validates
its paper claim with asserts and contributes CSV rows."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import anchors_throughput, fig2_scaling, quality_pk, retrieval_scan, table1_loc

    rows: list[tuple] = []
    failures = []
    for name, mod in (
        ("table1_loc", table1_loc),
        ("quality_pk", quality_pk),
        ("anchors_throughput", anchors_throughput),
        ("retrieval_scan", retrieval_scan),
        ("fig2_scaling", fig2_scaling),
    ):
        try:
            mod.run(rows)
            print(f"# [ok] {name}", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    print("name,us_per_call,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.3f},{derived}")
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
