"""Paper §3.2 quality sanity (C4): P@5/10/20 of the scan run.

The paper reports P@5/10/20 = .42/.39/.35 for its simple LM w/ length prior on
ClueWeb09 anchor text. On our synthetic collection the absolute values are
not comparable; the validated claims are (a) the scan's P@k equals the
indexed baseline's P@k (same model ⇒ same ranking), and (b) both retrieve
the planted relevance far above chance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import VOCAB, make_collection
from repro.core import invindex, scan, scoring
from repro.data import synthetic
from repro.eval import precision_at_k


def run(csv_rows: list):
    corpus, stats, index = make_collection(seed=7)
    queries = synthetic.make_queries(corpus, n_queries=64, seed=8)
    qrels = synthetic.make_qrels(corpus, queries, per_query=25, seed=9)
    jstats = jax.tree.map(jnp.asarray, stats)
    state = scan.search_local(
        jnp.asarray(queries), (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths)),
        scoring.get_scorer("ql_lm"), k=20, chunk_size=512, stats=jstats,
    )
    _, idx_ids = invindex.search(index, queries, stats, k=20)

    chance = qrels.mean()
    for k in (5, 10, 20):
        ps = float(precision_at_k(np.asarray(state.ids), qrels, k).mean())
        pi = float(precision_at_k(np.asarray(idx_ids), qrels, k).mean())
        csv_rows.append((f"quality_scan_p@{k}", ps, f"index={pi:.3f} chance={chance:.4f}"))
        assert abs(ps - pi) < 0.06, (k, ps, pi)
        assert ps > 10 * chance, (k, ps, chance)
    return True
