"""Pipelined vs sequential scan executor on the checkpointed cluster job.

Times the same 4-shard, multi-model, segment-checkpointed scan job
(`cluster.run_sharded_scan_job`) through both executors on 4 virtual
devices:

* **sequential** (``pipelined=False``) — shards run one after another,
  each shard's doc slice is staged on its device up front, and every
  segment's ``save → progress → prune`` commit blocks the fold;
* **pipelined** (``pipelined=True``) — shards run concurrently on the
  device-aware worker pool, segments double-buffer host→device under the
  previous segment's fold, and commits run on the async writer thread
  behind a drain barrier.

Both executors share one compiled fold (`cluster.segment_fold`), and the
benchmark asserts their merged states — and the checkpoint step layouts
they leave behind — are byte-identical, which is the whole executor
contract: overlap is invisible in the artifacts. Runs in a subprocess (the
virtual-device flag must precede JAX init). Writes ``BENCH_pipeline.json``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.serve.bench import write_bench_json

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, shutil, tempfile, time
import jax, jax.numpy as jnp
import numpy as np
from repro import checkpoint as ckpt
from repro import cluster
from repro.core import anchors, scoring
from repro.data import synthetic

N_DOCS, VOCAB, CHUNK, K, N_Q = 16384, 4096, 128, 20, 32
SEGMENT_CHUNKS = 16  # 2048-row segments -> 2 checkpoint commits per shard
N_SHARDS = 4
REPS = 5

corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=64, seed=31)
stats = anchors.collection_stats(
    jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
    chunk_size=CHUNK,
)
queries = jnp.asarray(synthetic.make_queries(corpus, n_queries=N_Q, seed=32))
docs = (
    np.asarray(corpus.tokens, dtype=np.int32),
    np.asarray(corpus.lengths, dtype=np.int32),
)
scorers = [scoring.make_variant("ql_lm"), scoring.make_variant("bm25")]
devices = jax.devices()
workers = min(N_SHARDS, os.cpu_count() or 1)

root = tempfile.mkdtemp(prefix="bench-pipeline-")


def run_job(pipelined, ckpt_dir):
    job = cluster.run_sharded_scan_job(
        queries, docs, scorers,
        k=K, chunk_size=CHUNK, segment_chunks=SEGMENT_CHUNKS,
        n_shards=N_SHARDS, stats=stats, ckpt_dir=ckpt_dir,
        devices=devices[:N_SHARDS], pipelined=pipelined,
        max_workers=workers if pipelined else None,
    )
    return jax.block_until_ready(job.state)


def time_executor(pipelined, tag):
    state = run_job(pipelined, os.path.join(root, f"warm-{tag}"))  # warmup+compile
    walls = []
    for r in range(REPS):
        d = os.path.join(root, f"{tag}-{r}")  # fresh dir: no resume shortcuts
        t0 = time.perf_counter()
        run_job(pipelined, d)
        walls.append(time.perf_counter() - t0)
    return state, min(walls)


seq_state, seq_wall = time_executor(False, "seq")
pipe_state, pipe_wall = time_executor(True, "pipe")

# the executor contract: overlap changes nothing observable
assert (np.asarray(pipe_state.ids) == np.asarray(seq_state.ids)).all()
assert (
    np.asarray(pipe_state.scores).tobytes() == np.asarray(seq_state.scores).tobytes()
)
for shard in range(N_SHARDS):
    sub = f"shard_{shard:04d}"
    assert (
        ckpt.all_steps(os.path.join(root, "seq-0", sub))
        == ckpt.all_steps(os.path.join(root, "pipe-0", sub))
    ), sub
    pseq = cluster.read_progress(os.path.join(root, "seq-0", sub))
    ppipe = cluster.read_progress(os.path.join(root, "pipe-0", sub))
    assert pseq == ppipe, sub

shutil.rmtree(root, ignore_errors=True)
print(json.dumps({
    "n_docs": N_DOCS, "n_queries": N_Q, "k": K, "chunk_size": CHUNK,
    "segment_chunks": SEGMENT_CHUNKS, "n_shards": N_SHARDS,
    "n_models": len(scorers), "n_devices": len(devices),
    "max_workers": workers,
    "sequential_wall_s": seq_wall,
    "pipelined_wall_s": pipe_wall,
    "speedup_x": seq_wall / pipe_wall,
    "docs_per_s_sequential": N_DOCS / seq_wall,
    "docs_per_s_pipelined": N_DOCS / pipe_wall,
    "bit_identical": True,
}))
"""


def run(csv_rows: list):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.pop("XLA_FLAGS", None)  # the worker pins its own device count
    proc = subprocess.run(
        [sys.executable, "-c", _WORKER],
        capture_output=True, text=True, timeout=1800, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    payload = json.loads(proc.stdout.strip().splitlines()[-1])
    # the hard claim: the pipelined executor's artifacts are byte-identical
    # to the sequential reference. Speed is asserted only where the
    # executor can actually overlap (multiple workers): on a 1-core host
    # the two executors differ by noise plus thread overhead, and failing
    # the bench there would punish the hardware, not the code
    assert payload["bit_identical"]
    if payload["max_workers"] > 1:
        assert payload["speedup_x"] > 1.0, payload["speedup_x"]

    write_bench_json(payload, "BENCH_pipeline.json")
    csv_rows.append(
        (
            "pipeline_scan/sequential",
            payload["sequential_wall_s"] * 1e6,
            f"docs_per_s={payload['docs_per_s_sequential']:.0f}",
        )
    )
    csv_rows.append(
        (
            "pipeline_scan/pipelined",
            payload["pipelined_wall_s"] * 1e6,
            f"docs_per_s={payload['docs_per_s_pipelined']:.0f};"
            f"speedup_x={payload['speedup_x']:.2f}",
        )
    )
    return True
