"""Experiment engine: models-per-pass amortization (claim C1, model axis).

The paper proves one corpus pass amortizes over a *query* batch; the batch
experiment engine applies the same economics to a *model grid*: one pass
folds every scorer variant, sharing the corpus stream and (for lexical
grids) the per-chunk term-frequency reduction. Validated claims: (a) a
4-model pass beats 4 independent passes on wall-clock, and (b) the grid's
per-model rankings match independent single-scorer scans exactly (parity —
the amortization is free). Writes the curve to ``BENCH_experiments.json``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import anchors, scan, scoring
from repro.data import synthetic
from repro.experiments.bench import amortization_curve, write_bench_json

N_DOCS = 2048
VOCAB = 4096
CHUNK = 256
K = 20
SIZES = (1, 2, 4, 8)


def run(csv_rows: list):
    corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=64, seed=11)
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
        chunk_size=CHUNK,
    )
    queries = jnp.asarray(synthetic.make_queries(corpus, n_queries=32, seed=12))
    docs = (jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths))
    # a realistic mixed grid: QL-LM smoothing sweep + BM25 parameter points
    scorers = [
        scoring.make_variant("ql_lm", lam=lam) for lam in (0.05, 0.15, 0.3, 0.5)
    ] + [
        scoring.make_variant("bm25"),
        scoring.make_variant("bm25", k1=0.9, b=0.4),
        scoring.make_variant("tfidf"),
        scoring.make_variant("ql_lm", length_prior=False),
    ]

    payload = amortization_curve(
        queries, docs, scorers, k=K, chunk_size=CHUNK, stats=stats, sizes=SIZES
    )
    write_bench_json(payload, "BENCH_experiments.json")
    for pt in payload["curve"]:
        csv_rows.append(
            (
                f"experiments_pass_{pt['models']}_models",
                pt["s_per_model"] * 1e6,
                f"speedup_vs_independent={pt['speedup_vs_independent']:.2f}x",
            )
        )

    # (a) amortization is real: 4 models in one pass beat 4 independent passes
    by_m = {pt["models"]: pt for pt in payload["curve"]}
    assert by_m[4]["speedup_vs_independent"] > 1.2, payload["curve"]

    # (b) and it is free: grid rankings == independent single-scorer rankings
    # (eager on both sides: jit-vs-eager fusion shifts scores ~1e-6, and a
    # tie at the k boundary could then flip an id — parity is exact like-for-like)
    multi = scan.search_local_multi(
        queries, docs, tuple(scorers[:4]), k=K, chunk_size=CHUNK, stats=stats
    )
    for m, s in enumerate(scorers[:4]):
        single = scan.search_local(queries, docs, s, k=K, chunk_size=CHUNK, stats=stats)
        assert np.array_equal(np.asarray(multi.ids)[m], np.asarray(single.ids)), s.name
    return True
