"""Paper Figure 2: processing time vs query-set size — scan vs inverted index.

Claims (DESIGN C1/C2): C1 — scan per-query cost amortizes with query-set
size (paper: 35 s/q @50q → 1.6 s/q @5000q); C2 — the indexed baseline's
advantage shrinks as the query set grows (~10× → 3.6×).

The paper's amortization comes from a **fixed cost shared by all queries**:
one streaming pass over the corpus (disk + Hadoop job setup in 2010). The
TPU-native analog of that fixed cost is the corpus's one HBM→VMEM pass in
the fused scan kernel; scoring FLOPs grow with |Q| while the stream is paid
once. We therefore validate the claims on the **roofline model of the
dense_scan cell** (same hardware constants as EXPERIMENTS §Roofline), where
the mechanism is explicit:

    t(|Q|) = max(corpus_bytes/chip / HBM_bw,  2·|D|·dim·|Q| / (chips·peak))

and *report* the measured CPU curve alongside (an in-memory jnp scan has no
shared fixed cost, so the CPU curve is flat per query — noted, not asserted;
the 2010 effect is about I/O amortization, not arithmetic).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_collection, timeit
from repro.core import invindex, scan, scoring
from repro.data import synthetic

QUERY_SET_SIZES = (64, 512, 1024, 2560, 5120)
K = 100

# v5e single-pod constants (as in §Roofline)
CHIPS = 256
PEAK = 197e12
HBM_BW = 819e9

# dense_scan cell dims (configs/shapes.py)
N_DOCS_TPU = 16_777_216
DIM = 256


def model_time(n_q: int) -> float:
    corpus_bytes_per_chip = N_DOCS_TPU * DIM * 2 / CHIPS  # bf16, one pass
    mem = corpus_bytes_per_chip / HBM_BW
    comp = 2.0 * N_DOCS_TPU * DIM * n_q / (CHIPS * PEAK)
    return max(mem, comp)


def run(csv_rows: list):
    # --- roofline-model curve (the TPU-native Figure 2) -------------------
    per_q = {}
    for n_q in (50, *QUERY_SET_SIZES, 5000):
        t = model_time(n_q)
        per_q[n_q] = t / n_q
        csv_rows.append((f"fig2_tpu_model_q{n_q}", t / n_q * 1e6, f"total_s={t:.6f}"))
    amortization = per_q[50] / per_q[5000]
    csv_rows.append(("fig2_tpu_amortization_x", amortization, "C1 (paper ~22x incl. setup)"))
    # index baseline model: per-query cost ~constant -> gap = scan/index falls
    gap_small = per_q[50]
    gap_large = per_q[5000]
    csv_rows.append(("fig2_tpu_gap_shrink_x", gap_small / gap_large, "C2: >1 means gap shrinks"))
    assert amortization > 3.0, f"C1 violated in the model: {amortization:.2f}x"
    assert gap_small > gap_large, "C2 violated in the model"

    # --- measured CPU curve (reported; no shared fixed cost on this host) --
    corpus, stats, index = make_collection()
    all_queries = synthetic.make_queries(corpus, n_queries=max(QUERY_SET_SIZES), seed=1)
    scorer = scoring.get_scorer("ql_lm")
    d_tokens = jnp.asarray(corpus.tokens)
    d_len = jnp.asarray(corpus.lengths)
    jstats = jax.tree.map(jnp.asarray, stats)

    @jax.jit
    def scan_job(q):
        return scan.search_local(
            q, (d_tokens, d_len), scorer, k=K, chunk_size=512, stats=jstats
        )

    for n_q in QUERY_SET_SIZES:
        q = jnp.asarray(all_queries[:n_q])
        t_scan = timeit(lambda: jax.block_until_ready(scan_job(q)), repeats=2)
        t_idx = timeit(lambda: invindex.search(index, all_queries[:n_q], stats, k=K), repeats=1)
        csv_rows.append((f"fig2_cpu_scan_q{n_q}", t_scan / n_q * 1e6, f"total_s={t_scan:.3f}"))
        csv_rows.append((f"fig2_cpu_index_q{n_q}", t_idx / n_q * 1e6, f"total_s={t_idx:.3f}"))
    return amortization
