"""Dense-scan throughput: the MIREX engine on learned representations
(retrieval_cand's hot path) — jnp scan engine vs the unblocked oracle, plus
the Pallas kernel in interpret mode for correctness-parity (its wall time on
CPU is meaningless; the TPU roofline for this cell lives in EXPERIMENTS
§Roofline).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import scan, scoring
from repro.data import synthetic

N_DOCS = 262_144
DIM = 256
N_Q = 64
K = 100


def run(csv_rows: list):
    d = jnp.asarray(synthetic.make_dense_corpus(n_docs=N_DOCS, dim=DIM, seed=4))
    q = jnp.asarray(synthetic.make_dense_corpus(n_docs=N_Q, dim=DIM, seed=5))
    scorer = scoring.get_scorer("dense_dot")

    blocked = jax.jit(
        lambda q, d: scan.search_local(q, d, scorer, k=K, chunk_size=4096)
    )
    t_blocked = timeit(lambda: jax.block_until_ready(blocked(q, d)))
    oracle = jax.jit(lambda q, d: scan.search_dense_host(q, d, K))
    t_oracle = timeit(lambda: jax.block_until_ready(oracle(q, d)))

    state_b = blocked(q, d)
    state_o = oracle(q, d)
    np.testing.assert_allclose(
        np.asarray(state_b.scores), np.asarray(state_o.scores), rtol=1e-5
    )
    docs_per_s = N_DOCS * N_Q / t_blocked
    csv_rows.append(("dense_scan_blocked_qdocs_per_s", docs_per_s, f"total_s={t_blocked:.3f}"))
    csv_rows.append(("dense_scan_oracle_qdocs_per_s", N_DOCS * N_Q / t_oracle, f"total_s={t_oracle:.3f}"))
    return t_blocked, t_oracle
