"""Paper §3.2 corpus-prep jobs: anchor-text extraction + collection stats.

The paper's anchor job took 11 h for 0.5 B pages on 15 machines (~3.4 k
pages/s/machine); our analog measures the same jobs' throughput on this host
— the deliverable is that both jobs exist, scale by sharding (they ride the
same map+psum dataflow as the scan), and their cost is amortized once per
collection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import VOCAB, timeit
from repro.core import anchors
from repro.data import synthetic

N_DOCS = 16_384
N_LINKS = 65_536


def run(csv_rows: list):
    corpus = synthetic.make_corpus(n_docs=N_DOCS, vocab=VOCAB, max_len=48, seed=2)
    dst, toks = synthetic.make_links(
        n_docs=N_DOCS, n_links=N_LINKS, vocab=VOCAB, seed=3
    )
    d_tokens, d_len = jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths)
    link_dst, link_toks = jnp.asarray(dst), jnp.asarray(toks)

    stats_job = jax.jit(
        lambda t, l: anchors.collection_stats(t, l, vocab=VOCAB, chunk_size=1024)
    )
    t_stats = timeit(lambda: jax.block_until_ready(stats_job(d_tokens, d_len)))
    csv_rows.append(("anchors_stats_docs_per_s", N_DOCS / t_stats, f"total_s={t_stats:.3f}"))

    anchor_job = jax.jit(
        lambda d, t: anchors.extract_anchors(d, t, n_docs=N_DOCS, max_anchor_len=64)
    )
    t_anchor = timeit(lambda: jax.block_until_ready(anchor_job(link_dst, link_toks)))
    csv_rows.append(("anchors_links_per_s", N_LINKS / t_anchor, f"total_s={t_anchor:.3f}"))
    return t_stats, t_anchor
