"""Autotune harness: search TuningConfig knob spaces against real jobs.

This is the *recorder* half of the autotuning subsystem (`repro.tune` is
the library): each target binds a KnobSpace to an existing measurement
path — the sharded scan job for the scan knobs, the retrieval service for
the microbatch triggers — runs the async model-based search, and records
the winner in the persistent cache under the same shape signature the
experiment runner's ``--tune`` lookup computes (`tune.scan_shape_sig_for`
on the same spec object — the round-trip is structural, not string luck).

Two contracts are enforced on every single trial, not just the winner:

* **byte identity** — the trial's merged top-k state (scan) or per-request
  results (serve) must be byte-identical to a default-config oracle run
  once up front. Tuning changes speed, never bytes; a config that changes
  bytes fails its trial AND fails the whole benchmark.
* **default in the tournament** — the space's base config is candidate #0
  (see `KnobSpace.candidates`), so the recorded winner is ≥ the default
  within the measurement session by construction.

    PYTHONPATH=src python -m benchmarks.autotune --budget 8 \
        --cache results/tune_cache.json --json BENCH_autotune.json

Targets: ``scan_smoke`` (CI-sized scan job, seconds), ``serve``
(microbatch triggers over a resident lexical session), ``scan_bench``
(the 8k-doc benchmark collection; minutes on CPU — opt in via
``--targets``). The flash-attention block knobs (``flash_block_q/k``,
``decode_block_s``) live in the knob space but have no target here: on a
CPU host the kernels run in interpret mode, where block-size timings say
nothing about a compiled backend (see `tune.backend_sig`).
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Callable

import numpy as np

from benchmarks import common
from repro import tune
from repro.cluster.job import run_sharded_scan_job
from repro.core import packing
from repro.experiments import grid as exp_grid
from repro.experiments import runner
from repro.serve.service import RetrievalService
from repro.serve.session import LexicalSession
from repro.tune import Knob, KnobSpace, TuningConfig

SERVE_N_QUERIES = 256


@dataclasses.dataclass(frozen=True)
class Target:
    """One tunable workload: a knob space bound to a measurement closure."""

    name: str
    space: KnobSpace
    shape: str
    backend: str
    measure: Callable[[TuningConfig], float]  # figure of merit, higher=better
    meta: dict


def _effective_chunk(cfg: TuningConfig, *, n_docs: int, n_shards: int, declared: int) -> int:
    """The runner's tuned-chunk rule: a chunk knob applies only when it
    divides the per-shard rows — a knob may be ignored, never fail a job."""
    if cfg.chunk_size is None:
        return declared
    shards = max(1, n_shards)
    per_shard = n_docs // shards
    if n_docs % shards == 0 and per_shard % cfg.chunk_size == 0:
        return cfg.chunk_size
    return declared


def _state_bytes(state) -> bytes:
    return np.asarray(state.scores).tobytes() + np.asarray(state.ids).tobytes()


def _scan_target(
    name: str,
    spec: exp_grid.ExperimentSpec,
    *,
    chunk_values: tuple,
    prefetch_values: tuple,
    repeats: int,
    seed: int = 0,
) -> Target:
    """Bind a scan-knob space to `run_sharded_scan_job` on ``spec``'s
    geometry (no checkpoint dir: this measures the steady scan, not I/O)."""
    coll = runner.prepare_collection(spec, seed=seed)
    queries = np.asarray(coll.queries)
    docs = (np.asarray(coll.corpus.tokens), np.asarray(coll.corpus.lengths))
    scorers = spec.scorers()
    shards = max(1, spec.n_shards)
    per_shard = spec.n_docs // shards
    lexical = all(getattr(s, "kind", None) == "lexical" for s in scorers)

    # packed corpus representations, built once per resolved width — the
    # knob changes which representation the trial streams, not the corpus
    _packed_cache: dict = {"none": docs}

    def docs_for(cfg: TuningConfig):
        mode = cfg.token_pack if lexical else "none"
        if mode not in _packed_cache:
            _packed_cache[mode] = packing.pack_corpus(
                docs[0], docs[1], vocab=spec.vocab, mode=mode
            )
        return _packed_cache[mode]

    def legal(cfg: TuningConfig) -> bool:
        # only chunks that actually apply: a knob the job would ignore is a
        # wasted trial re-measuring the declared chunk
        return cfg.chunk_size is None or (
            spec.n_docs % shards == 0 and per_shard % cfg.chunk_size == 0
        )

    space = KnobSpace(
        kind="scan_job",
        knobs=(
            Knob("chunk_size", chunk_values),
            Knob("prefetch_depth", prefetch_values),
            Knob("token_pack", ("none", "auto", "bitpack") if lexical else ("none",)),
        ),
        constraint=legal,
    )

    def run_job(cfg: TuningConfig):
        return run_sharded_scan_job(
            queries,
            docs_for(cfg),
            scorers,
            k=spec.k,
            chunk_size=_effective_chunk(
                cfg, n_docs=spec.n_docs, n_shards=shards, declared=spec.chunk_size
            ),
            segment_chunks=spec.segment_chunks,
            n_shards=shards,
            stats=coll.stats,
            ckpt_dir=None,
            use_kernel=spec.use_kernel,
            tuning=cfg,
        ).state

    oracle = _state_bytes(run_job(space.base))

    def measure(cfg: TuningConfig) -> float:
        got = _state_bytes(run_job(cfg))  # doubles as the jit warmup
        if got != oracle:
            raise AssertionError(
                f"byte-identity violated: {cfg.overrides()} changed the "
                "merged top-k state vs the default-config oracle"
            )
        wall = common.timeit(lambda: run_job(cfg), repeats=repeats, warmup=0)
        return spec.n_docs * len(scorers) / wall  # scored docs/s

    return Target(
        name=name,
        space=space,
        shape=tune.scan_shape_sig_for(spec),
        backend=tune.backend_sig(use_kernel=spec.use_kernel),
        measure=measure,
        meta={
            "spec": spec.name,
            "n_docs": spec.n_docs,
            "n_queries": spec.n_queries,
            "n_models": len(scorers),
            "n_shards": shards,
            "declared_chunk": spec.chunk_size,
            "score_unit": "docs*models/s",
        },
    )


def _serve_target(*, repeats: int, seed: int = 0) -> Target:
    """Bind the microbatch-trigger knobs to a full submit/poll/drain stream
    over a resident LexicalSession (the C1 serving path)."""
    spec = exp_grid.get_experiment("smoke")
    coll = runner.prepare_collection(spec, seed=seed)
    scorer = spec.scorers()[0]
    session = LexicalSession(
        np.asarray(coll.corpus.tokens),
        np.asarray(coll.corpus.lengths),
        scorer,
        k=spec.k,
        chunk_size=spec.chunk_size,
        stats=coll.stats,
        vocab=spec.vocab,
    )
    from repro.data import synthetic

    stream = np.asarray(
        synthetic.make_queries(coll.corpus, n_queries=SERVE_N_QUERIES, max_q_len=4, seed=7)
    )

    # deadline pinned far out: the sweep measures the *size* trigger (and
    # the drain tail), not the wall clock of the submit loop
    base = TuningConfig().replace(serve_max_delay_s=60.0)
    space = KnobSpace(
        kind="serve",
        knobs=(
            Knob("serve_max_batch", (16, 32, 64, 128)),
            Knob("serve_min_bucket", (8, 16)),
            Knob("serve_max_bucket", (64, 128, 256)),
        ),
        base=base,
    )

    def run_stream(cfg: TuningConfig):
        service = RetrievalService({session.kind: session}, tuning=cfg)
        results = {}
        t0 = time.perf_counter()
        for row in stream:
            service.submit(row, session.kind)
            results.update(service.poll())
        results.update(service.drain())
        wall = time.perf_counter() - t0
        assert len(results) == len(stream), (len(results), len(stream))
        return results, wall

    def result_bytes(results) -> bytes:
        # rids are assigned in submit order, so rid order == stream order
        out = []
        for rid in sorted(results):
            out.append(results[rid].scores.tobytes())
            out.append(results[rid].ids.tobytes())
        return b"".join(out)

    oracle = result_bytes(run_stream(base)[0])

    def measure(cfg: TuningConfig) -> float:
        results, _ = run_stream(cfg)  # warmup + byte check
        got = result_bytes(results)
        if got != oracle:
            raise AssertionError(
                f"byte-identity violated: {cfg.overrides()} changed "
                "per-request results vs the default-config oracle"
            )
        walls = [run_stream(cfg)[1] for _ in range(repeats)]
        return len(stream) / float(np.median(walls))  # qps

    return Target(
        name="serve",
        space=space,
        shape=tune.serve_shape_sig(
            n_docs=spec.n_docs, k=spec.k, chunk_size=spec.chunk_size, kind=session.kind
        ),
        backend=tune.backend_sig(use_kernel=False),
        measure=measure,
        meta={
            "n_docs": spec.n_docs,
            "n_stream": len(stream),
            "scorer": scorer.name,
            "score_unit": "queries/s",
        },
    )


def build_target(name: str, *, seed: int = 0) -> Target:
    if name == "scan_smoke":
        return _scan_target(
            name,
            exp_grid.get_experiment("smoke"),
            chunk_values=(64, 128, 256),
            prefetch_values=(1, 2, 4),
            repeats=3,
            seed=seed,
        )
    if name == "scan_bench":
        spec = dataclasses.replace(
            exp_grid.get_experiment("smoke"),
            name="bench",
            n_docs=common.N_DOCS,
            n_queries=32,
            vocab=common.VOCAB,
            chunk_size=512,
            segment_chunks=4,
        )
        return _scan_target(
            name,
            spec,
            chunk_values=(256, 512, 1024, 2048),
            prefetch_values=(1, 2),
            repeats=2,
            seed=seed,
        )
    if name == "serve":
        return _serve_target(repeats=3, seed=seed)
    raise KeyError(f"unknown autotune target {name!r}; have {sorted(TARGETS)}")


TARGETS = ("scan_smoke", "serve", "scan_bench")
DEFAULT_TARGETS = ("scan_smoke", "serve")  # scan_bench is minutes on CPU


def tune_target(
    target: Target,
    *,
    budget: int,
    seed: int = 0,
    cache_path: str | None = None,
    log: Callable[[str], None] | None = None,
) -> dict:
    """Search one target, enforce the contracts, record the winner, and
    verify the write→reload→hit round trip. Returns the report block."""
    result = tune.run_search(
        target.space, target.measure, budget=budget, seed=seed, log=log
    )
    bad = [t for t in result.trials if t.error]
    if bad:
        raise RuntimeError(
            f"{target.name}: {len(bad)} trial(s) failed "
            f"(first: {bad[0].config.overrides()} -> {bad[0].error})"
        )
    assert result.best.score >= result.default.score, (
        result.best.score,
        result.default.score,
    )

    cache = tune.TuneCache(cache_path)
    key = cache.put(
        kind=target.space.kind,
        shape=target.shape,
        config=result.best.config,
        score=result.best.score,
        backend=target.backend,
        meta={"target": target.name, "speedup_x": result.speedup_x, **target.meta},
    )
    # the round trip the runner's --tune depends on: written -> found -> same
    reloaded, hit = cache.get(
        kind=target.space.kind, shape=target.shape, backend=target.backend
    )
    assert hit, f"{target.name}: winner not found under its own key {key}"
    assert reloaded.config_hash() == result.best.config.config_hash(), key

    block = result.describe()
    block.update(
        shape=target.shape,
        backend=target.backend,
        cache_key=key,
        cache_hit_roundtrip=True,
        byte_identity=True,  # enforced per trial; any violation raised above
        meta=target.meta,
    )
    return block


def autotune(
    *,
    budget: int = 8,
    targets=DEFAULT_TARGETS,
    cache_path: str | None = None,
    seed: int = 0,
    log: Callable[[str], None] | None = None,
) -> dict:
    report = {}
    for name in targets:
        target = build_target(name, seed=seed)
        report[name] = tune_target(
            target, budget=budget, seed=seed, cache_path=cache_path, log=log
        )
    return {
        "benchmark": "autotune",
        "budget": budget,
        "cache": tune.cache.cache_path(cache_path),
        "targets": report,
    }


def run(rows: list) -> None:
    """benchmarks.run entry point: tiny-budget pass over the fast targets."""
    payload = autotune(budget=6, targets=DEFAULT_TARGETS)
    common.write_bench_json(payload, "BENCH_autotune.json")
    for name, block in payload["targets"].items():
        best = block["best"]["score"]
        rows.append(
            (
                f"autotune_{name}",
                1e6 / best if best > 0 else float("inf"),
                f"speedup={block['speedup_x']:.2f}x "
                f"best={block['best']['overrides'] or 'default'}",
            )
        )
        assert block["speedup_x"] >= 1.0, (name, block["speedup_x"])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--budget", type=int, default=8, help="trials per target")
    ap.add_argument("--targets", nargs="+", default=list(DEFAULT_TARGETS),
                    choices=list(TARGETS))
    ap.add_argument("--cache", default=None,
                    help="winner-cache path (default: $REPRO_TUNE_CACHE or "
                         f"{tune.cache.DEFAULT_PATH})")
    ap.add_argument("--json", default="BENCH_autotune.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    payload = autotune(
        budget=args.budget,
        targets=tuple(args.targets),
        cache_path=args.cache,
        seed=args.seed,
        log=lambda m: print(m, file=sys.stderr),
    )
    path = common.write_bench_json(payload, args.json)
    for name, block in payload["targets"].items():
        print(
            f"{name}: default {block['default']['score']:.1f} -> "
            f"best {block['best']['score']:.1f} "
            f"({block['speedup_x']:.2f}x) "
            f"overrides={block['best']['overrides'] or '{}'} "
            f"[{block['cache_key']}]"
        )
    print(f"wrote {path}; winners cached in {payload['cache']}")


if __name__ == "__main__":
    main()
