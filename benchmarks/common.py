"""Shared benchmark fixtures: a CPU-sized synthetic ClueWeb stand-in."""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import anchors, invindex, scoring
from repro.data import synthetic
from repro.tune import config as tune_config

VOCAB = 8192
N_DOCS = 8192
MAX_LEN = 64


def make_collection(seed: int = 0):
    corpus = synthetic.make_corpus(
        n_docs=N_DOCS, vocab=VOCAB, max_len=MAX_LEN, seed=seed
    )
    stats = anchors.collection_stats(
        jnp.asarray(corpus.tokens), jnp.asarray(corpus.lengths), vocab=VOCAB,
        chunk_size=512,
    )
    stats = jax.tree.map(lambda x: jax.device_get(x), stats)
    index = invindex.build_index(corpus.tokens, corpus.lengths, vocab=VOCAB)
    return corpus, stats, index


def timeit(fn, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds on the monotonic performance clock."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def write_bench_json(payload: dict, path: str) -> str:
    """Persist a BENCH_*.json with the measurement-provenance block stamped
    (host, backend, jax version, device count) — numbers from different
    machines/backends must be distinguishable in the perf trajectory. The
    active TuningConfig's hash/source rides along for the same reason: a
    number is only comparable to another measured under the same knobs."""
    payload = dict(payload)
    payload.setdefault("provenance", obs.provenance())
    payload.setdefault("tuning", tune_config.provenance())
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path
