"""Paper Table 1: size of the code a researcher must touch per experiment.

MIREX's C3: the experiment surface is ~350 lines vs 59k–1.4M for the general
engines. Our analog: a *new retrieval approach* in this framework is a new
``score_block`` in ``core/scoring.py`` (+ optionally a kernel); the scan,
combiner, sharding, and launchers are untouched. We count:

  * experiment surface (what you read+edit to try a new approach),
  * the paper-system core (scan/topk/scoring/pipeline),
  * the whole framework,
and report the paper's numbers for the 2010 systems alongside.
"""

from __future__ import annotations

import os

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")

EXPERIMENT_SURFACE = ["core/scoring.py"]
PAPER_CORE = ["core/scan.py", "core/topk.py", "core/pipeline.py", "core/scoring.py",
              "core/anchors.py"]

PAPER_TABLE = {  # from MIREX Table 1
    "mapreduce_anchors_search_2010": (2, 350),
    "terrier_2.2.1": (300, 59_000),
    "monetdb_pf_tijah_0.32.2": (920, 1_393_000),
    "lemur_indri_4.11": (1210, 540_000),
}


def _loc(paths) -> tuple[int, int]:
    files = lines = 0
    for p in paths:
        full = os.path.join(SRC, p)
        with open(full) as f:
            lines += sum(1 for ln in f if ln.strip() and not ln.strip().startswith("#"))
        files += 1
    return files, lines


def _loc_tree(root) -> tuple[int, int]:
    files = lines = 0
    for dirpath, _, names in os.walk(root):
        for n in names:
            if n.endswith(".py"):
                with open(os.path.join(dirpath, n)) as f:
                    lines += sum(1 for ln in f if ln.strip() and not ln.strip().startswith("#"))
                files += 1
    return files, lines


def run(csv_rows: list):
    surf = _loc(EXPERIMENT_SURFACE)
    core = _loc(PAPER_CORE)
    whole = _loc_tree(SRC)
    csv_rows.append(("table1_experiment_surface_loc", surf[1], f"files={surf[0]}"))
    csv_rows.append(("table1_paper_core_loc", core[1], f"files={core[0]}"))
    csv_rows.append(("table1_framework_loc", whole[1], f"files={whole[0]}"))
    for name, (nf, nl) in PAPER_TABLE.items():
        csv_rows.append((f"table1_{name}_loc", nl, f"files={nf} (paper-reported)"))
    # C3: the experiment surface stays two orders below the general engines
    assert core[1] < 1500, core
    return surf, core, whole
